#!/usr/bin/env bash
# Host-tuned launcher for the benchmark/measurement entry points.
#
#   ./run.sh                            # python -m benchmarks.run
#   ./run.sh --quick                    # what CI records
#   ./run.sh -m repro.launch.dryrun ... # any other module, verbatim
#
# The environment below is the measurement configuration the committed
# BENCH_*.json records assume:
#
#   * tcmalloc, preloaded when present: glibc malloc's arena locking shows
#     up in the multi-client round loop; the huge report threshold keeps
#     tcmalloc's large-alloc warnings out of the timing stream;
#   * JAX_ENABLE_X64: FedNL state is f64 — the bit-parity gates are pinned
#     against f64 trajectories;
#   * one host device: the single-process benchmarks must not be skewed by
#     XLA carving the host into virtual devices.  (--xla_step_marker_location
#     would mark round boundaries in profiles but is TPU-only: CPU XLA
#     rejects the whole flag string, so it must not be set here.)
set -euo pipefail
cd "$(dirname "$0")"

TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -e "$TCMALLOC" ]]; then
    export LD_PRELOAD="$TCMALLOC${LD_PRELOAD:+:$LD_PRELOAD}"
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
fi

export TF_CPP_MIN_LOG_LEVEL=4
export JAX_ENABLE_X64=1
export XLA_FLAGS="--xla_force_host_platform_device_count=1${XLA_FLAGS:+ $XLA_FLAGS}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == -m ]]; then
    exec python "$@"
fi
exec python -m benchmarks.run "$@"
