"""Load test for the gateway: remote tenants, fair share, warm latency.

Starts a real :class:`~repro.gateway.GatewayServer` on localhost, submits a
fleet of backlogged tenants across the three default priority classes over
TCP, and measures what the §14 contract promises:

* **fair share**: with every class backlogged and the resident set spilling
  each tick (``max_resident == admit_per_tick`` and 3x oversubscription),
  admissions — and therefore rounds — are distributed by deficit
  round-robin, so the measured per-class round rates must match the
  configured 4/2/1 weights within 10%;
* **warm latency**: engine tick p50/p99 measured only after the compile
  counter stops moving (cold-start ticks are jit compiles, reported
  separately — same methodology as benchmarks/serve_load.py);
* **bit parity across the wire**: one reference tenant's RESULT is compared
  record-for-record (hex floats) against a solo session.

``python -m benchmarks.run --quick`` records the result to
``BENCH_gateway.json``.
"""

from __future__ import annotations

import threading
import time

import numpy as np

SHAPE = (12, 4, 20)
CLASSES = ("high", "normal", "low")


def _spec_of(seed: int, rounds: int):
    from repro.api import CompressorSpec, DataSpec, ExperimentSpec

    return ExperimentSpec(
        data=DataSpec(shape=SHAPE, seed=1),
        compressor=CompressorSpec("topk", 8.0),
        rounds=rounds,
        seed=seed,
    )


def gateway_load_benchmark(
    per_class: int = 6,
    fleet_rounds: int = 400,
    measure_ticks: int = 48,
    warmup_timeout_s: float = 120.0,
) -> dict:
    """Run the load test; returns the BENCH_gateway.json payload."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.api import open_session
    from repro.gateway import GatewayClient, GatewayConfig, GatewayServer
    from repro.serve_fednl import DEFAULT_PRIORITIES, ServeConfig

    n_tenants = per_class * len(CLASSES)
    server = GatewayServer(
        GatewayConfig(
            port=0,
            serve=ServeConfig(
                max_resident=4,
                admit_per_tick=4,
                priorities=dict(DEFAULT_PRIORITIES),
            ),
        )
    )
    ready = threading.Event()
    addr: dict = {}

    def announce(host, port):
        addr["host"], addr["port"] = host, port
        ready.set()

    thread = threading.Thread(
        target=server.run, kwargs={"ready": announce}, daemon=True
    )
    thread.start()
    assert ready.wait(60), "gateway did not bind"

    out: dict = {
        "n_tenants": n_tenants,
        "per_class": per_class,
        "weights": dict(DEFAULT_PRIORITIES),
    }
    with GatewayClient(addr["host"], addr["port"]) as gwc:
        # --- bit parity across the wire (one short reference tenant) ------
        ref_spec = _spec_of(seed=999, rounds=6)
        t0 = time.perf_counter()
        ref = gwc.submit(ref_spec, priority="high")
        out["submit_rtt_ms"] = round((time.perf_counter() - t0) * 1e3, 2)
        got = gwc.result(ref.id)
        with open_session(ref_spec) as s:
            want = s.run()
        out["bit_parity"] = bool(
            [float(r.grad_norm).hex() for r in got.records]
            == [float(r.grad_norm).hex() for r in want.records]
            and np.array_equal(got.x, want.x)
        )

        # --- the backlogged fleet ------------------------------------------
        handles = []
        for i in range(n_tenants):
            prio = CLASSES[i % len(CLASSES)]
            handles.append(
                gwc.submit(_spec_of(seed=i, rounds=fleet_rounds),
                           priority=prio)
            )

        # warm up until the compile counter stops moving (two quiet polls)
        deadline = time.monotonic() + warmup_timeout_s
        prev = -1
        quiet = 0
        while quiet < 2:
            time.sleep(0.5)
            stats = gwc.status()
            if stats["compiles"] == prev:
                quiet += 1
            else:
                quiet = 0
                prev = stats["compiles"]
            if time.monotonic() > deadline:
                break
        warm_start_tick = stats["ticks"]
        warm_start_idx = len(server.tick_latencies())
        base_rounds = dict(stats["rounds_by_class"])
        base_adm = dict(stats["admissions_by_class"])

        # --- measurement window (all classes stay backlogged) --------------
        while True:
            time.sleep(0.25)
            stats = gwc.status()
            if stats["ticks"] - warm_start_tick >= measure_ticks:
                break
        measure_end_idx = len(server.tick_latencies())
        d_rounds = {
            c: stats["rounds_by_class"][c] - base_rounds[c] for c in CLASSES
        }
        d_adm = {
            c: stats["admissions_by_class"][c] - base_adm[c] for c in CLASSES
        }
        ticks_measured = stats["ticks"] - warm_start_tick

        # every tenant must still be mid-flight (otherwise a drained class
        # skews the share measurement)
        still_queued = sum(stats["backlog"].values())

        for h in handles:
            gwc.cancel(h.id)
        final_stats = gwc.status()

    server.request_stop()
    thread.join(30)
    lat = (
        np.asarray(server.tick_latencies()[warm_start_idx:measure_end_idx])
        * 1e3
    )
    cold = np.asarray(server.tick_latencies()[:warm_start_idx]) * 1e3

    # fair-share ratio: per-class round rate normalized by weight should be
    # flat; report the worst relative deviation from the weight-implied share
    w = {c: DEFAULT_PRIORITIES[c] for c in CLASSES}
    total_r = sum(d_rounds.values())
    total_w = sum(w.values())
    share_err = {
        c: abs(d_rounds[c] / max(total_r, 1) - w[c] / total_w)
        / (w[c] / total_w)
        for c in CLASSES
    }
    out.update(
        {
            "concurrent_remote_tenants": n_tenants,
            "ticks_measured": int(ticks_measured),
            "rounds_by_class": d_rounds,
            "admissions_by_class": d_adm,
            "fair_share_max_rel_err": round(max(share_err.values()), 4),
            "fair_share_rel_err": {
                c: round(e, 4) for c, e in share_err.items()
            },
            "fair_share_within_10pct": bool(
                max(share_err.values()) <= 0.10
            ),
            "all_classes_backlogged": bool(still_queued > 0),
            # warm tick latency (compile ticks excluded by construction:
            # the window opens after the compile counter goes quiet)
            "p50_tick_ms": round(float(np.percentile(lat, 50)), 3)
            if lat.size
            else None,
            "p99_tick_ms": round(float(np.percentile(lat, 99)), 3)
            if lat.size
            else None,
            "cold_start_ticks": int(cold.size),
            "cold_start_total_ms": round(float(cold.sum()), 1),
            "spills": final_stats["spills"],
            "resumes": final_stats["resumes"],
            "cancelled": final_stats["cancelled"],
        }
    )
    return out


def main() -> int:
    bench = {"schema": 1, **gateway_load_benchmark()}
    for k, v in bench.items():
        print(f"{k}: {v}")
    ok = (
        bench["bit_parity"]
        and bench["fair_share_within_10pct"]
        and bench["concurrent_remote_tenants"] >= 16
    )
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
