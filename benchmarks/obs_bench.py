"""Observability overhead benchmark — the BENCH_obs.json record.

Measures the two CI-gated contracts of ``repro.obs`` (ISSUE §15):

* **bit parity**: an engine-served fleet run WITH the recorder enabled is
  bit-identical, record for record and on the final iterate, to solo
  ``open_session(spec).run()`` references taken with the recorder off —
  observability never touches numerics.
* **overhead ≤3%**: enabled-vs-disabled round throughput through one
  long-lived engine.  Methodology: one ``FedNLServer`` serves a warm-up
  fleet first (jit compiles land there, once per branch table / slot
  bucket — a fresh engine per mode would re-trace and the comparison
  would measure compile jitter, not the recorder), then the same spec
  fleet repeatedly with alternating recorder on/off; each mode's
  throughput is the best of ``repeats`` runs (min wall), which is the
  standard way to strip scheduler noise from a short benchmark.

Also records the disabled-path cost (ns per instrumented call against the
NullRecorder) — the "disabled cost is one attribute lookup" claim, in
numbers.

``python -m benchmarks.run --quick --json-obs BENCH_obs.json`` records it;
``scripts/smoke_obs.py`` gates parity + a loose overhead sanity bound in
tier-1 CI (the 3% bar is asserted here, where repeats make it stable).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.serve_load import SHAPE, _build_specs, _hex_traj

OVERHEAD_BAR_PCT = 3.0


def _serve_fleet(srv, specs) -> float:
    """Serve one fleet to completion; returns wall seconds (reports are
    checked by the caller via the returned handles)."""
    t0 = time.perf_counter()
    handles = [srv.submit(spec) for spec in specs]
    srv.serve_until_idle()
    wall = time.perf_counter() - t0
    for h in handles:
        h.result()  # raise on any failure
    return wall, handles


def _disabled_call_ns(n: int = 200_000) -> float:
    """ns per (guarded) instrumented call against the disabled recorder."""
    from repro.obs import core as obs

    rec = obs.CURRENT
    assert not rec.enabled
    t0 = time.perf_counter()
    for _ in range(n):
        if rec.enabled:  # pragma: no cover - disabled path
            rec.add("x")
    return (time.perf_counter() - t0) / n * 1e9


def obs_overhead_benchmark(
    n_tenants: int = 8,
    rounds: int = 16,
    repeats: int = 3,
    max_resident: int = 8,
) -> dict:
    """Run the parity + overhead measurement; returns the BENCH_obs.json
    payload."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro import obs
    from repro.api import open_session
    from repro.serve_fednl import FedNLServer, ServeConfig

    specs = _build_specs(n_tenants, rounds)
    z = specs[0].data.build()
    total_rounds = sum(s.rounds for s in specs)

    # solo references, recorder off (the parity bar's right-hand side)
    obs.disable()
    solo_reports = []
    for spec in specs:
        with open_session(spec, z=z) as s:
            solo_reports.append(s.run())

    walls: dict[str, list[float]] = {"off": [], "on": []}
    bit_parity = True
    prev = obs.core.CURRENT
    try:
        with FedNLServer(
            ServeConfig(
                max_resident=max_resident, admit_per_tick=max_resident
            )
        ) as srv:
            _serve_fleet(srv, specs)  # warm-up: compiles land here
            for _rep in range(repeats):
                for mode in ("off", "on"):
                    if mode == "on":
                        obs.enable(span_capacity=8192)
                    else:
                        obs.disable()
                    wall, handles = _serve_fleet(srv, specs)
                    walls[mode].append(wall)
                    if mode == "on":
                        # every obs-on fleet must match the obs-off solos
                        for h, want in zip(handles, solo_reports):
                            got = h.result()
                            if (
                                _hex_traj(got) != _hex_traj(want)
                                or got.rounds != want.rounds
                                or not np.array_equal(got.x, want.x)
                            ):
                                bit_parity = False
    finally:
        obs.set_current(prev)

    off_s = min(walls["off"])
    on_s = min(walls["on"])
    off_rps = total_rounds / off_s
    on_rps = total_rounds / on_s
    overhead_pct = (off_rps / on_rps - 1.0) * 100.0
    return {
        "shape": list(SHAPE),
        "n_tenants": n_tenants,
        "rounds_per_fleet": total_rounds,
        "repeats": repeats,
        "bit_parity": bool(bit_parity),
        "off_rounds_per_s": round(off_rps, 1),
        "on_rounds_per_s": round(on_rps, 1),
        "off_wall_s": [round(w, 4) for w in walls["off"]],
        "on_wall_s": [round(w, 4) for w in walls["on"]],
        "overhead_pct": round(overhead_pct, 2),
        "overhead_bar_pct": OVERHEAD_BAR_PCT,
        "disabled_call_ns": round(_disabled_call_ns(), 1),
        "verified": bool(bit_parity and overhead_pct <= OVERHEAD_BAR_PCT),
    }


def main() -> int:
    bench = {"schema": 1, **obs_overhead_benchmark()}
    for k, v in bench.items():
        print(f"{k}: {v}")
    return 0 if bench["verified"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
