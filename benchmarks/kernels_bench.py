"""Fused-round kernel benchmark -> BENCH_kernels.json.

Measures the round hot path end to end — hessian="fused" (strip SYRK +
packed-triu emission + threshold/window selection under a per-client
lax.map) vs hessian="jnp" (the single-dot_general parity reference under
vmap) — on the largest-d dataset (w8a, d=301), plus the two micro terms
that compose it.

Every claim in the record is gated:

  * bit parity: the fused round must replay the jnp round bit-for-bit on
    tiny for all six compressors (state, grad_norm, integer bit accounting);
  * HLO flops: XLA's cost_analysis of the fused round program must show
    FEWER flops than the jnp program (the §5.10 half-work trick must be
    visible in the compiled module, not just in wall time);
  * roofline: each program's achieved flop rate must sit under the
    *measured* gemm ceiling of this host (a 'speedup' that implies
    above-roof throughput is a broken benchmark, not a fast kernel).

``verified`` is the AND of the three gates; CI uploads the JSON as an
artifact so regressions show up as a diff.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.roofline import hlo_cost, measure_cpu_machine


def _timed_rounds(round_fn, state, rounds: int) -> tuple[float, object]:
    state, m = round_fn(state)  # compile + warm
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(rounds):
        state, m = round_fn(state)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / rounds, m


def _round_parity_tiny(rounds: int = 3) -> bool:
    """Fused round == jnp round, bitwise, all six compressors (tiny)."""
    import numpy as np

    from repro.core.fednl import FedNLConfig, fednl_init, make_fednl_round
    from repro.data import (
        DATASET_SHAPES,
        add_intercept,
        make_synthetic_logreg,
        partition_clients,
    )

    _, nc, ni = DATASET_SHAPES["tiny"]
    x, y = make_synthetic_logreg("tiny", seed=1)
    z = jnp.asarray(partition_clients(add_intercept(x), y, nc, ni, seed=1))
    for comp in ("topk", "randk", "randseqk", "toplek", "natural", "identity"):
        finals = {}
        for hessian in ("jnp", "fused"):
            cfg = FedNLConfig(compressor=comp, hessian=hessian)
            state = fednl_init(z, cfg, seed=1)
            round_fn = jax.jit(make_fednl_round(z, cfg))
            bits = []
            for _ in range(rounds):
                state, m = round_fn(state)
                bits.append(int(m.sent_bits))
            finals[hessian] = (np.asarray(state.x), np.asarray(state.h_global), bits)
        xj, hj, bj = finals["jnp"]
        xf, hf, bf = finals["fused"]
        if not (np.array_equal(xj, xf) and np.array_equal(hj, hf) and bj == bf):
            return False
    return True


def kernel_round_benchmark(dataset: str = "w8a", rounds: int = 10) -> dict:
    """The BENCH_kernels.json record (see module docstring)."""
    from repro.core.fednl import FedNLConfig, fednl_init, make_fednl_round
    from repro.data import (
        DATASET_SHAPES,
        add_intercept,
        make_synthetic_logreg,
        partition_clients,
    )
    from repro.kernels import ops

    _, nc, ni = DATASET_SHAPES[dataset]
    x, y = make_synthetic_logreg(dataset, seed=1)
    z = jnp.asarray(partition_clients(add_intercept(x), y, nc, ni, seed=1))
    n_clients, n_i, d = z.shape

    out: dict = {
        "schema": 1,
        "dataset": dataset,
        "shape": {"n_clients": n_clients, "n_i": n_i, "d": d},
        "backend": jax.default_backend(),
        "rounds_timed": rounds,
    }

    # --- the end-to-end round: fused vs the pure-jnp parity reference ------
    times: dict[str, float] = {}
    flops: dict[str, float] = {}
    for hessian in ("jnp", "fused"):
        cfg = FedNLConfig(compressor="topk", hessian=hessian)
        state = fednl_init(z, cfg, seed=1)
        # one AOT compile serves both the timing loop and the flop gate
        compiled = jax.jit(make_fednl_round(z, cfg)).lower(state).compile()
        times[hessian], _ = _timed_rounds(compiled, state, rounds)
        costs = compiled.cost_analysis()
        if isinstance(costs, list):
            costs = costs[0]
        flops[hessian] = float(costs.get("flops", 0.0))

    # --- micro terms: per-client Hessian sweep and TopK selection ----------
    h = jax.random.uniform(jax.random.PRNGKey(0), (n_clients, n_i), dtype=z.dtype)
    sweeps = {
        "hessian_vmap_jnp": jax.jit(
            lambda z, h: jax.vmap(lambda zi, hi: zi.T @ (hi[:, None] * zi))(z, h)
        ),
        "hessian_map_strips": jax.jit(
            lambda z, h: jax.lax.map(
                lambda a: ops.hessian_syrk_packed(a[0], a[1]), (z, h)
            )
        ),
    }
    micro = {}
    for name, fn in sweeps.items():
        fn(z, h).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            r = fn(z, h)
        jax.block_until_ready(r)
        micro[name] = (time.perf_counter() - t0) / 3

    from repro.compressors import select as csel
    from repro.linalg import triu_size

    t = triu_size(d)
    k = 8 * d
    u = jax.random.normal(jax.random.PRNGKey(1), (n_clients, t), dtype=z.dtype)
    sel = {
        "select_vmap_sort": jax.jit(
            lambda u: jax.vmap(lambda ui: csel.topk_dense(ui, k))(u)
        ),
        "select_map_mask": jax.jit(
            lambda u: jax.lax.map(lambda ui: csel.topk_dense_masked(ui, k), u)
        ),
    }
    for name, fn in sel.items():
        fn(u).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            r = fn(u)
        jax.block_until_ready(r)
        micro[name] = (time.perf_counter() - t0) / 3
    out["micro_ms"] = {kk: round(v * 1e3, 2) for kk, v in micro.items()}

    # --- gates --------------------------------------------------------------
    # NB: XLA's cost_analysis counts a lax.map loop body ONCE, not x trip
    # count, so the fused round's reported module flops are not comparable
    # to the vmapped jnp round's.  The half-work claim is gated on the
    # per-client SYRK programs (loop-free HLO on both sides); the fused
    # round's true per-round flops are estimated as n_clients x its
    # per-client oracle program.
    z0, h0 = z[0], h[0]
    syrk_flops = {
        "jnp_per_client": hlo_cost(lambda z, h: z.T @ (h[:, None] * z), z0, h0)[
            "flops"
        ],
        "fused_per_client": hlo_cost(
            lambda z, h: ops.hessian_syrk_packed(z, h), z0, h0
        )["flops"],
    }
    flops_est = {
        "jnp": flops["jnp"],  # vmapped: module flops are the round flops
        "fused": n_clients
        * (
            syrk_flops["fused_per_client"]
            + hlo_cost(lambda u: csel.topk_dense_masked(u, k), u[0])["flops"]
        ),
    }

    machine = measure_cpu_machine()
    speedup = times["jnp"] / times["fused"]
    achieved = {kk: flops_est[kk] / times[kk] for kk in times}
    gates = {
        "bit_parity_tiny_all_compressors": _round_parity_tiny(),
        "syrk_halfwork_visible_in_hlo": (
            syrk_flops["fused_per_client"] < syrk_flops["jnp_per_client"]
        ),
        "under_measured_roof": all(
            v <= machine.peak_flops * 1.1 for v in achieved.values()
        ),
        "round_speedup_above_1.05": speedup > 1.05,
    }
    out.update(
        {
            "round_ms": {kk: round(v * 1e3, 1) for kk, v in times.items()},
            "round_speedup": round(speedup, 3),
            "syrk_hlo_flops_per_client": syrk_flops,
            "round_flops_est": flops_est,
            "round_hlo_flops_raw": flops,
            "round_achieved_gflops": {
                kk: round(v / 1e9, 2) for kk, v in achieved.items()
            },
            "machine": {
                "name": machine.name,
                "measured_peak_gflops": round(machine.peak_flops / 1e9, 2),
                "measured_mem_gbps": round(machine.hbm_bw / 1e9, 2),
            },
            "gates": gates,
            "verified": all(gates.values()),
        }
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(kernel_round_benchmark(), indent=2))
