# One function per paper table. Print ``name,us_per_call,derived`` CSV and
# record the machine-readable perf trajectory to BENCH_sweep.json +
# BENCH_session.json + BENCH_serve.json + BENCH_gateway.json + BENCH_obs.json.
#
#   PYTHONPATH=src python -m benchmarks.run [--quick] [--json BENCH_sweep.json]
#       [--json-session BENCH_session.json] [--json-serve BENCH_serve.json]
#       [--json-gateway BENCH_gateway.json] [--json-obs BENCH_obs.json]
#
# --quick runs only the sweep-engine speedup benchmark, the session-mode
# overhead benchmark, and the serving-engine load test (what CI records and
# uploads as artifacts); the full run additionally times every paper table.
# Tables 1-4 mirror the paper's Tables 1-3 + Appendix B progression; the
# roofline rows read the dry-run sweep JSON (produced separately by
# ``python -m repro.launch.dryrun --arch all --shape all --both-meshes
# --json results/dryrun_all.json`` — that entry point needs its own process
# because it forces 512 host devices).
import argparse
import json
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="sweep speedup benchmark only (skip the paper tables)")
    ap.add_argument("--json", default="BENCH_sweep.json", metavar="PATH",
                    help="where to write the machine-readable benchmark record")
    ap.add_argument("--json-session", default="BENCH_session.json",
                    metavar="PATH",
                    help="where to write the session-overhead benchmark record")
    ap.add_argument("--json-serve", default="BENCH_serve.json", metavar="PATH",
                    help="where to write the serving-engine load-test record")
    ap.add_argument("--json-gateway", default="BENCH_gateway.json",
                    metavar="PATH",
                    help="where to write the gateway load-test record")
    ap.add_argument("--json-kernels", default="BENCH_kernels.json",
                    metavar="PATH",
                    help="where to write the fused-round kernel benchmark record")
    ap.add_argument("--json-topology", default="BENCH_topology.json",
                    metavar="PATH",
                    help="where to write the topology-layer benchmark record")
    ap.add_argument("--json-obs", default="BENCH_obs.json", metavar="PATH",
                    help="where to write the observability overhead/parity "
                         "record")
    args = ap.parse_args()

    bench: dict = {"schema": 1, "tables": {}}
    rows = []

    if not args.quick:
        from benchmarks import tables

        for fn in tables.ALL_TABLES:
            t0 = time.perf_counter()
            try:
                table_rows = fn()
                rows.extend(table_rows)
                bench["tables"][fn.__name__] = {
                    "wall_s": round(time.perf_counter() - t0, 3),
                    "rows": len(table_rows),
                }
            except Exception as e:  # noqa: BLE001 — report per-table
                rows.append((f"{fn.__name__}/ERROR", 0.0, f"{type(e).__name__}:{e}"))
                bench["tables"][fn.__name__] = {
                    "wall_s": round(time.perf_counter() - t0, 3),
                    "error": f"{type(e).__name__}:{e}",
                }

        from benchmarks import roofline_report

        rows.extend(roofline_report.roofline_rows())

    # the sweep-engine measurement itself: sequential-vs-batched on one grid
    from benchmarks.tables import session_overhead_benchmark, sweep_speedup_benchmark

    sweep = sweep_speedup_benchmark()
    bench["sweep"] = sweep
    rows.append((
        "sweep/solve_many_batched_speedup",
        sweep["batched_s"] * 1e6 / sweep["n_specs"],
        f"specs={sweep['n_specs']};speedup={sweep['speedup']}x;"
        f"bit_parity={sweep['bit_parity']}",
    ))

    # session-mode cost: per-round step overhead vs monolithic solve
    session = {"schema": 1, **session_overhead_benchmark()}
    for backend, m in session["backends"].items():
        rows.append((
            f"session/step_overhead_{backend}",
            m["step1_us_per_round"],
            f"solve={m['solve_us_per_round']}us/rd;"
            f"run={m['session_run_us_per_round']}us/rd;"
            f"step1_overhead={m['step1_overhead_us_per_round']}us/rd;"
            f"bit_parity={m['bit_parity']}",
        ))

    # fused-round kernel path: hessian="fused" vs the pure-jnp reference,
    # roofline-gated (see benchmarks.kernels_bench)
    from benchmarks.kernels_bench import kernel_round_benchmark

    kernels = kernel_round_benchmark()
    rows.append((
        "kernels/fused_round_speedup",
        kernels["round_ms"]["fused"] * 1e3,
        f"jnp={kernels['round_ms']['jnp']}ms;"
        f"speedup={kernels['round_speedup']}x;"
        f"verified={kernels['verified']}",
    ))

    # topology layer: tree-of-stars hop cost + staleness/accuracy table
    from benchmarks.topology_bench import topology_benchmark

    topo = topology_benchmark()
    rows.append((
        "topology/tree_vs_star_n64",
        topo["sync_tree"]["n64"]["tree_ms_per_round"] * 1e3,
        f"star={topo['sync_tree']['n64']['star_ms_per_round']}ms/rd;"
        f"overhead={topo['sync_tree']['n64']['tree_overhead_x']}x;"
        f"bit_parity={topo['bit_parity']};"
        f"async_s0_bit_equal={topo['async_staleness'][0]['bit_equal_to_sync']}",
    ))

    # serving engine: Poisson arrivals of mixed tenants vs sequential solos
    from benchmarks.serve_load import serve_load_benchmark

    serve = {"schema": 3, **serve_load_benchmark()}
    rows.append((
        "serve/engine_vs_sequential",
        serve["p50_round_latency_ms"] * 1e3,
        f"tenants={serve['n_tenants']};peak={serve['concurrent_peak']};"
        f"ratio={serve['throughput_ratio']}x;"
        f"bit_parity={serve['bit_parity']};"
        f"p99={serve['p99_round_latency_ms']}ms;"
        f"cold_ticks={serve['cold_start_ticks']};"
        f"occupancy={serve['batch_occupancy']};spills={serve['spills']}",
    ))

    # observability: enabled-vs-disabled throughput + bit parity (repro.obs)
    from benchmarks.obs_bench import obs_overhead_benchmark

    obs_bench = {"schema": 1, **obs_overhead_benchmark()}
    rows.append((
        "obs/enabled_overhead",
        obs_bench["overhead_pct"] * 1e3,  # milli-% — keep the CSV numeric
        f"overhead={obs_bench['overhead_pct']}%;"
        f"bar={obs_bench['overhead_bar_pct']}%;"
        f"bit_parity={obs_bench['bit_parity']};"
        f"disabled_call_ns={obs_bench['disabled_call_ns']};"
        f"verified={obs_bench['verified']}",
    ))

    # gateway: remote tenants over TCP, DRR fair share, warm tick latency
    from benchmarks.gateway_load import gateway_load_benchmark

    gateway = {"schema": 1, **gateway_load_benchmark()}
    rows.append((
        "gateway/fair_share_load",
        gateway["p50_tick_ms"] * 1e3,
        f"remote_tenants={gateway['concurrent_remote_tenants']};"
        f"share_err={gateway['fair_share_max_rel_err']};"
        f"within_10pct={gateway['fair_share_within_10pct']};"
        f"bit_parity={gateway['bit_parity']};"
        f"p99={gateway['p99_tick_ms']}ms",
    ))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    with open(args.json, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    with open(args.json_session, "w") as f:
        json.dump(session, f, indent=2)
        f.write("\n")
    with open(args.json_serve, "w") as f:
        json.dump(serve, f, indent=2)
        f.write("\n")
    with open(args.json_gateway, "w") as f:
        json.dump(gateway, f, indent=2)
        f.write("\n")
    with open(args.json_kernels, "w") as f:
        json.dump(kernels, f, indent=2)
        f.write("\n")
    with open(args.json_topology, "w") as f:
        json.dump(topo, f, indent=2)
        f.write("\n")
    with open(args.json_obs, "w") as f:
        json.dump(obs_bench, f, indent=2)
        f.write("\n")
    print(
        f"# wrote {args.json}, {args.json_session}, {args.json_serve}, "
        f"{args.json_gateway}, {args.json_kernels}, {args.json_topology} "
        f"and {args.json_obs}",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
