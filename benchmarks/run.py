# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   PYTHONPATH=src python -m benchmarks.run [--quick]
#
# Tables 1-4 mirror the paper's Tables 1-3 + Appendix B progression; the
# roofline rows read the dry-run sweep JSON (produced separately by
# ``python -m repro.launch.dryrun --arch all --shape all --both-meshes
# --json results/dryrun_all.json`` — that entry point needs its own process
# because it forces 512 host devices).
import sys


def main() -> None:
    rows = []
    from benchmarks import tables

    for fn in tables.ALL_TABLES:
        try:
            rows.extend(fn())
        except Exception as e:  # noqa: BLE001 — report per-table
            rows.append((f"{fn.__name__}/ERROR", 0.0, f"{type(e).__name__}:{e}"))

    from benchmarks import roofline_report

    rows.extend(roofline_report.roofline_rows())

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
