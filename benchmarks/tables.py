"""One benchmark per paper table, driven by declarative sweeps.

Table 1 (single-node vanilla FedNL): per-compressor wall time on the
  W8A-shaped problem vs the reference-style NumPy loop — the x-speedup story.
Table 2 (FedNL-LS vs solvers): init/solve split on W8A/A9A/PHISHING-shaped
  problems vs centralized Newton and GD archetypes (CVXPY unavailable offline).
Table 3 (multi-node): sharded round wall time + uplink bytes, dense_psum vs
  sparse_allgather aggregation.
Table 4 (Appendix B progression): ablation of our optimization steps.
Table 6 (FedNL-PP participation sweep): per-round uplink payload bits and
  wall time of the partial-participation star protocol across
  tau in {0.1n, 0.5n, n}, vs full-participation FedNL over the same wire.

Sweeps are *SweepSpecs* — each table builds its base spec, declares the
varying axis with ``spec.grid(...)``, and runs the whole grid through ONE
``solve_many`` call.  The measurement tables pin ``batch="never"`` so each
spec is timed in isolation (batching would fold per-spec wall time into one
shared program); ``sweep_speedup_benchmark`` below is the batched-vs-
sequential measurement itself and feeds BENCH_sweep.json.

Every table function returns rows: (name, us_per_call, derived).
"""

from __future__ import annotations

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.api import CompressorSpec, DataSpec, ExperimentSpec, solve, solve_many
from repro.api.accounting import sharded_uplink_bits
from repro.baselines import run_fednl_numpy_reference
from repro.core import newton_baseline, gd_baseline

# benchmark-scale problem shapes (full W8A shape is used by examples/e2e;
# benches keep wall time civil on 1 CPU core and report per-round time).
BENCH_SHAPES = {
    "w8a": (301, 24, 348),
    "a9a": (124, 24, 229),
    "phishing": (69, 24, 77),
}
ROUNDS = 25

ALL_COMPRESSORS = ["identity", "topk", "randk", "randseqk", "toplek", "natural"]


def _base_spec(name: str, seed: int = 0, **overrides) -> ExperimentSpec:
    overrides.setdefault("rounds", ROUNDS)
    return ExperimentSpec(
        data=DataSpec(shape=BENCH_SHAPES[name], seed=seed), **overrides
    )


def table1_singlenode():
    """Per-compressor FedNL(B) + the NumPy-reference speedup factor."""
    rows = []
    base = _base_spec("w8a")
    z = base.data.build()
    ref_rounds = 3
    _, ref_t = run_fednl_numpy_reference(np.asarray(z), 1e-3, ref_rounds)
    ref_per_round = ref_t / ref_rounds
    rows.append(("table1/reference_numpy_per_round", ref_per_round * 1e6,
                 f"rounds={ref_rounds}"))
    # batch="never": this table measures per-spec wall time, so every spec
    # must own its program (the batched engine is measured separately by
    # sweep_speedup_benchmark)
    sweep = base.grid(compressor=ALL_COMPRESSORS, batch="never")
    srep = solve_many(sweep)
    for spec, rep in zip(srep.specs, srep.reports):
        per_round = rep.wall_time_s / rep.rounds
        speedup = ref_per_round / per_round
        rows.append((
            f"table1/fednl_{spec.compressor.name}_per_round",
            per_round * 1e6,
            f"gn={rep.grad_norms[-1]:.2e};speedup_vs_ref={speedup:.1f}x",
        ))
    return rows


def table2_ls_vs_solvers():
    rows = []
    base = _base_spec(
        "w8a",
        seed=1,
        algorithm="fednl-ls",
        compressor=CompressorSpec("randseqk"),
        option="A",
        mu=1e-3,
        rounds=60,
        tol=1e-9,
    )
    sweep = base.grid(
        data=[DataSpec(shape=BENCH_SHAPES[n], seed=1) for n in BENCH_SHAPES],
        batch="never",  # per-spec init/solve timing is the measurement
    )
    srep = solve_many(sweep)
    for name, spec, rep in zip(BENCH_SHAPES, srep.specs, srep.reports):
        rows.append((
            f"table2/{name}/fednl_ls_randseqk",
            rep.wall_time_s * 1e6,
            f"init={rep.init_time_s:.2f}s;rounds={rep.rounds};gn={rep.grad_norms[-1]:.1e}",
        ))
        z = spec.data.build()
        nb = newton_baseline(z, 1e-3, tol=1e-9)
        rows.append((
            f"table2/{name}/newton_centralized",
            nb.wall_time_s * 1e6,
            f"init={nb.init_time_s:.2f}s;iters={nb.rounds};gn={nb.grad_norms[-1]:.1e}",
        ))
        gd = gd_baseline(z, 1e-3, iters=3000, tol=1e-9)
        rows.append((
            f"table2/{name}/gd_centralized",
            gd.wall_time_s * 1e6,
            f"iters={gd.rounds};gn={gd.grad_norms[-1]:.1e}",
        ))
    return rows


def table3_multinode():
    """Sharded round (mesh on the single real device; collective semantics are
    identical, wall time measures the sharded program)."""
    rows = []
    base = _base_spec("w8a", seed=2, backend="sharded", devices=1)
    d, n_clients, _ = base.data.dims()
    t = d * (d + 1) // 2
    k = base.fednl_config().k_for(d)
    sweep = base.grid(aggregate=["dense_psum", "sparse_allgather"], batch="never")
    srep = solve_many(sweep)
    for spec, rep in zip(srep.specs, srep.reports):
        per_round = rep.wall_time_s / rep.rounds
        payload = sharded_uplink_bits(spec.aggregate, t, k, n_clients) // 8
        rows.append((
            f"table3/{spec.aggregate}_per_round",
            per_round * 1e6,
            f"gn={rep.grad_norms[-1]:.1e};uplink_bytes={payload}",
        ))
    return rows


def table4_progression():
    """Appendix-B-style ablation of this implementation's optimizations."""
    import time

    import jax.numpy as jnp

    rows = []
    base = _base_spec("w8a", seed=3)
    z = base.data.build()
    n, n_i, d = z.shape

    # v0: reference numpy loop (from table 1, re-measured light)
    _, t_ref = run_fednl_numpy_reference(np.asarray(z), 1e-3, 2)
    rows.append(("table4/v0_numpy_reference", t_ref / 2 * 1e6, "baseline"))

    # v1: jax but python-loop over clients (no vmap), dense hessians
    cfg = base.fednl_config()
    from repro.compressors import get_compressor
    from repro.linalg import pack_triu, triu_size, frob_norm_from_packed
    from repro.objectives.logreg import logreg_oracles
    from repro.core.fednl import fednl_init, master_step

    comp = get_compressor("topk", triu_size(d), cfg.k_for(d))

    def python_loop_round(state_x, h_local, h_global):
        grads, s_list, l_list = [], [], []
        for i in range(n):
            _, g, hess = logreg_oracles(z[i], state_x, 1e-3)
            hp = pack_triu(hess)
            delta = hp - h_local[i]
            s_i, _ = comp.compress(jax.random.PRNGKey(i), delta)
            grads.append(g)
            s_list.append(s_i)
            l_list.append(frob_norm_from_packed(delta, d))
        grad = jnp.mean(jnp.stack(grads), axis=0)
        s = jnp.mean(jnp.stack(s_list), axis=0)
        l = jnp.mean(jnp.stack(l_list))
        x_new = master_step(state_x, h_global, grad, l, cfg)
        return x_new, h_global + s

    state = fednl_init(z, cfg)
    fn = jax.jit(python_loop_round)
    x_cur, hg = state.x, state.h_global
    x_cur, hg = fn(x_cur, state.h_local, hg)  # compile
    jax.block_until_ready(x_cur)
    t0 = time.perf_counter()
    for _ in range(5):
        x_cur, hg = fn(x_cur, state.h_local, hg)
    jax.block_until_ready(x_cur)
    rows.append(("table4/v1_python_client_loop", (time.perf_counter() - t0) / 5 * 1e6,
                 "jit per-client loop"))

    # v2: vmap-fused clients (the shipped path)
    rep = solve(base, z=z)
    rows.append(("table4/v2_vmap_fused", rep.wall_time_s / rep.rounds * 1e6,
                 "vmapped clients + packed triu"))

    # v3: + pallas hessian kernel routing (interpret mode on CPU — measures
    # correctness path; on TPU this is the MXU SYRK)
    rep_k = solve(base.replace(use_kernel=True, rounds=3), z=z)
    rows.append(("table4/v3_pallas_kernel_interpret", rep_k.wall_time_s / rep_k.rounds * 1e6,
                 "hessian_syrk interpret=True (CPU); TPU target path"))
    return rows


def table5_wire_formats():
    """Section-7 wire codecs over the loopback star transport: *measured*
    uplink bytes per round vs the analytic message_bits model, plus the
    bandwidth/latency cost-model round time (repro.comm.cost)."""
    from repro.comm.cost import DEFAULT_COST

    rows = []
    base = _base_spec("phishing", seed=4, backend="star-loopback", rounds=3)
    d, n, _ = base.data.dims()
    bcast_bits = d * 64
    # batch="never": per-spec event-loop timing (pool dispatch would
    # interleave the runs and distort per-round wall time)
    sweep = base.grid(compressor=ALL_COMPRESSORS, batch="never")
    srep = solve_many(sweep)
    for spec, rep in zip(srep.specs, srep.reports):
        per_round = rep.wall_time_s / rep.rounds
        measured = rep.extras["measured_payload_bits"]
        match = bool((measured == rep.sent_bits_payload).all())
        uplink_bits = float(measured[-1])
        wire_s = DEFAULT_COST.round_s(uplink_bits, bcast_bits, n)
        rows.append((
            f"table5/wire_{spec.compressor.name}_per_round",
            per_round * 1e6,
            f"frame_bytes={int(rep.extras['measured_frame_bytes'][-1])};"
            f"payload_bits={int(uplink_bits)};"
            f"measured_eq_analytic={match};"
            f"cost_model_round={wire_s * 1e3:.2f}ms",
        ))
    return rows


def table6_pp_participation():
    """FedNL-PP over the loopback star transport: payload bits and wall time
    scale with tau (only the sampled clients compute or transmit), compared
    against full-participation FedNL on the identical problem/wire."""
    from repro.comm.cost import DEFAULT_COST

    rows = []
    base = _base_spec("phishing", seed=5, backend="star-loopback", rounds=6)
    d, n, _ = base.data.dims()
    bcast_bits = d * 64

    full = solve(base)
    rows.append((
        "table6/fednl_full_per_round",
        full.wall_time_s / full.rounds * 1e6,
        f"uplink_bits={int(full.extras['measured_payload_bits'][-1])};"
        f"cost_model_round="
        f"{DEFAULT_COST.round_s(float(full.extras['measured_payload_bits'][-1]), bcast_bits, n) * 1e3:.2f}ms",
    ))
    sweep = base.replace(algorithm="fednl-pp").grid(
        tau=sorted({max(1, int(frac * n)) for frac in [0.1, 0.5, 1.0]}),
        batch="never",
    )
    srep = solve_many(sweep)
    for spec, rep in zip(srep.specs, srep.reports):
        per_round = rep.wall_time_s / rep.rounds
        measured = rep.extras["measured_payload_bits"]
        uplink_bits = float(measured[-1])
        wire_s = DEFAULT_COST.round_s(uplink_bits, spec.tau * bcast_bits, spec.tau)
        match = bool((measured == rep.sent_bits_payload).all())
        rows.append((
            f"table6/fednl_pp_tau{spec.tau}_per_round",
            per_round * 1e6,
            f"uplink_bits={int(uplink_bits)};"
            f"measured_eq_analytic={match};"
            f"cost_model_round={wire_s * 1e3:.2f}ms",
        ))
    return rows


def sweep_speedup_benchmark(n_seeds: int = 8, rounds: int = 20) -> dict:
    """The headline measurement of the sweep engine: one seeds x compressors
    grid run twice — sequentially (``batch="never"``: one trace/compile and
    one device round-trip per spec, the pre-solve_many world) and batched
    (``batch="auto"``: one compiled program per group) — plus a bit-parity
    check between the two.  Feeds BENCH_sweep.json (benchmarks/run.py).
    """
    base = ExperimentSpec(data=DataSpec(dataset="tiny", seed=1), rounds=rounds)
    axes = dict(seed=list(range(n_seeds)), compressor=["topk", "randseqk"])
    sequential = solve_many(base.grid(batch="never", **axes))
    batched = solve_many(base.grid(batch="auto", **axes))
    parity = all(
        [g.hex() for g in a.grad_norms] == [g.hex() for g in b.grad_norms]
        and bool((a.x == b.x).all())
        and list(a.sent_bits) == list(b.sent_bits)
        for a, b in zip(batched.reports, sequential.reports)
    )
    return {
        "n_specs": len(batched.reports),
        "rounds": rounds,
        "grid": {k: [str(v) for v in vs] for k, vs in axes.items()},
        "sequential_s": round(sequential.wall_time_s, 3),
        "batched_s": round(batched.wall_time_s, 3),
        "speedup": round(sequential.wall_time_s / batched.wall_time_s, 2),
        "specs_per_s_batched": round(
            len(batched.reports) / batched.wall_time_s, 2
        ),
        "bit_parity": parity,
        "batched_groups": batched.extras["n_groups"],
        "log": batched.log,
    }


def _session_overhead_one(backend: str, rounds: int) -> dict:
    """Three executions of one spec, bit-identical trajectories:
      solve      solve(spec) — open -> run -> close, chunked segment
      run        an already-open session's run() (excludes open/compile)
      step1      an already-open session stepped one round at a time — the
                 worst case: every round pays record materialization (host
                 sync) and observer-path bookkeeping

    Not a sweep: the same spec is re-run per execution MODE (check_api_
    migration's sequential-sweep rule watches for loops over specs)."""
    import time

    from repro.api import open_session

    spec = ExperimentSpec(
        data=DataSpec(dataset="tiny", seed=1), backend=backend, rounds=rounds
    )
    z = spec.data.build()

    t0 = time.perf_counter()
    rep = solve(spec, z=z)
    solve_s = time.perf_counter() - t0

    with open_session(spec, z=z) as s:
        t0 = time.perf_counter()
        run_rep = s.run()
        run_s = time.perf_counter() - t0

    with open_session(spec, z=z) as s:
        t0 = time.perf_counter()
        while s.round < rounds:
            s.step(1)
        step_rep = s.report()
        step_s = time.perf_counter() - t0

    parity = [g.hex() for g in rep.grad_norms] == [
        g.hex() for g in run_rep.grad_norms
    ] == [g.hex() for g in step_rep.grad_norms]
    return {
        "solve_us_per_round": round(solve_s * 1e6 / rounds, 1),
        "session_run_us_per_round": round(run_s * 1e6 / rounds, 1),
        "step1_us_per_round": round(step_s * 1e6 / rounds, 1),
        "step1_overhead_us_per_round": round((step_s - run_s) * 1e6 / rounds, 1),
        "bit_parity": parity,
    }


def session_overhead_benchmark(rounds: int = 30) -> dict:
    """Session-mode cost tracking (BENCH_session.json): per-round overhead of
    round-granular stepping vs the monolithic observer-free run, on the
    local simulation and the star-loopback wire backend."""
    return {
        "rounds": rounds,
        "backends": {
            backend: _session_overhead_one(backend, rounds)
            for backend in ["local", "star-loopback"]
        },
    }


ALL_TABLES = [table1_singlenode, table2_ls_vs_solvers, table3_multinode,
              table4_progression, table5_wire_formats, table6_pp_participation]
