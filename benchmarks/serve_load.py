"""Load test for the multi-tenant serving engine (``repro.serve_fednl``).

Drives a Poisson arrival process of mixed-spec tenants into one
``FedNLServer`` and measures what an LLM-style serving benchmark would:
sessions/sec, p50/p99 per-round latency, batch occupancy, spill/resume
counts — plus the two bars the subsystem is accountable for:

Latency methodology: ticks that trigger a jit compile (detected by the
engine's compile counter advancing) are *cold-start* ticks — they cost
hundreds of ms once per (branch table, slot bucket) and then never again.
Folding them into the percentile stream made the reported p99 a compile
benchmark, not a serving one (two compiles out of ~100 ticks landed
exactly at the 99th percentile).  The steady-state p50/p99 therefore
exclude them, and the cold-start ticks are reported separately
(count / each / total) so the one-time cost stays visible instead of
masquerading as tail latency.

* **bit parity**: every served tenant's trajectory equals its solo
  ``open_session(spec).run()`` bit-for-bit (the solo runs double as the
  sequential baseline);
* **throughput**: serving N tenants through the engine beats running them
  back-to-back as solo sessions on round throughput — the win is shared
  compiled tick kernels (a handful of compiles for the whole fleet vs one
  jit per session) exactly as in-flight batching amortizes prefill in an
  LLM engine.

``python -m benchmarks.run --quick`` records the result to
``BENCH_serve.json``.
"""

from __future__ import annotations

import time

import numpy as np

SHAPE = (12, 4, 20)  # d, n_clients, n_i
COMPRESSORS = ["topk", "randk", "randseqk", "identity"]


def _build_specs(n_tenants: int, rounds: int):
    from repro.api import CompressorSpec, DataSpec, ExperimentSpec

    # mixed compressors / k / seeds / round budgets on one shared problem:
    # heterogeneous tenants that are nevertheless co-schedulable (§11)
    return [
        ExperimentSpec(
            data=DataSpec(shape=SHAPE, seed=1),
            compressor=CompressorSpec(
                COMPRESSORS[i % len(COMPRESSORS)],
                8.0 if i % 2 == 0 else 4.0,
            ),
            rounds=rounds + (i % 5),
            seed=i,
        )
        for i in range(n_tenants)
    ]


def _hex_traj(report):
    return (
        [float(r.grad_norm).hex() for r in report.records],
        [r.sent_bits for r in report.records],
    )


def serve_load_benchmark(
    n_tenants: int = 16,
    rounds: int = 24,
    arrival_rate_hz: float = 50.0,
    max_resident: int = 16,
    seed: int = 0,
) -> dict:
    """Run the load test; returns the BENCH_serve.json payload."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.api import open_session
    from repro.serve_fednl import FedNLServer, ServeConfig

    specs = _build_specs(n_tenants, rounds)
    z = specs[0].data.build()

    # --- sequential baseline (and the bit-parity reference) ---------------
    t0 = time.perf_counter()
    solo_reports = []
    for spec in specs:
        with open_session(spec, z=z) as s:
            solo_reports.append(s.run())
    seq_wall = time.perf_counter() - t0
    total_rounds = sum(r.rounds for r in solo_reports)

    # --- engine run under Poisson arrivals --------------------------------
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate_hz, n_tenants))
    latencies_ms: list[float] = []  # warm ticks only (module docstring)
    cold_ms: list[float] = []  # ticks that paid a jit compile
    concurrent_peak = 0
    handles = []
    with FedNLServer(
        ServeConfig(max_resident=max_resident, admit_per_tick=max_resident)
    ) as srv:
        t_start = time.perf_counter()
        next_i = 0
        prev_compiles = 0
        while next_i < n_tenants or srv._has_work():
            now = time.perf_counter() - t_start
            while next_i < n_tenants and arrivals[next_i] <= now:
                handles.append(srv.submit(specs[next_i]))
                next_i += 1
            if srv._has_work():
                t1 = time.perf_counter()
                out = srv.tick()
                tick_ms = (time.perf_counter() - t1) * 1e3
                compiles = sum(g.compiles for g in srv._groups.values())
                if compiles > prev_compiles:
                    prev_compiles = compiles
                    cold_ms.append(tick_ms)
                else:
                    # every session advanced this tick waited the whole tick
                    latencies_ms.extend([tick_ms] * max(out["slots"], 1))
                in_flight = sum(1 for h in handles if not h.done)
                concurrent_peak = max(concurrent_peak, in_flight)
            elif next_i < n_tenants:
                time.sleep(
                    max(0.0, arrivals[next_i] - (time.perf_counter() - t_start))
                )
        serve_wall = time.perf_counter() - t_start
        stats = srv.stats()
        served_reports = [h.result() for h in handles]

    # --- bit parity (all tenants; the bar requires >= 8 concurrent) -------
    bit_parity = all(
        _hex_traj(got) == _hex_traj(want)
        and got.rounds == want.rounds
        and np.array_equal(got.x, want.x)
        for got, want in zip(served_reports, solo_reports)
    )

    lat = np.asarray(latencies_ms) if latencies_ms else np.zeros(1)
    return {
        "n_tenants": n_tenants,
        "concurrent_peak": concurrent_peak,
        "arrival_rate_hz": arrival_rate_hz,
        "max_resident": max_resident,
        "total_rounds": total_rounds,
        "bit_parity": bool(bit_parity),
        "sessions_per_s": round(n_tenants / serve_wall, 3),
        # steady-state percentiles: compile (cold-start) ticks excluded
        "p50_round_latency_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_round_latency_ms": round(float(np.percentile(lat, 99)), 3),
        "cold_start_ticks": len(cold_ms),
        "cold_start_ms": [round(c, 1) for c in cold_ms],
        "cold_start_total_ms": round(float(sum(cold_ms)), 1),
        "batch_occupancy": (
            round(stats["batch_occupancy"], 4)
            if stats["batch_occupancy"] is not None
            else None
        ),
        "spills": stats["spills"],
        "resumes": stats["resumes"],
        "ticks": stats["ticks"],
        "compiles": stats["compiles"],
        "serve_wall_s": round(serve_wall, 3),
        "sequential_wall_s": round(seq_wall, 3),
        "serve_rounds_per_s": round(total_rounds / serve_wall, 1),
        "sequential_rounds_per_s": round(total_rounds / seq_wall, 1),
        "throughput_ratio": round(seq_wall / serve_wall, 2),
    }


def main() -> int:
    bench = {"schema": 2, **serve_load_benchmark()}
    for k, v in bench.items():
        print(f"{k}: {v}")
    ok = bench["bit_parity"] and bench["concurrent_peak"] >= 8
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
