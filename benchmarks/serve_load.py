"""Load test for the multi-tenant serving engine (``repro.serve_fednl``).

Drives a Poisson arrival process of mixed-spec tenants into one
``FedNLServer`` and measures what an LLM-style serving benchmark would:
sessions/sec, p50/p99 per-round latency, batch occupancy, spill/resume
counts — plus the two bars the subsystem is accountable for:

* **bit parity**: every served tenant's trajectory equals its solo
  ``open_session(spec).run()`` bit-for-bit (the solo runs double as the
  sequential baseline — and they run with the recorder OFF while the
  engine runs with it ON, so parity here also exercises the §15
  never-touch-numerics invariant);
* **throughput**: serving N tenants through the engine beats running them
  back-to-back as solo sessions on round throughput — the win is shared
  compiled tick kernels (a handful of compiles for the whole fleet vs one
  jit per session) exactly as in-flight batching amortizes prefill in an
  LLM engine.

Timing methodology (schema 3): all tick and queue timings come from a
private ``repro.obs`` recorder installed around the engine phase — the
``engine.tick`` span ring (duration + slots + the jit-compile delta per
tick) and the ``engine.queue.wait_s`` histogram — not from hand-rolled
``time.perf_counter()`` bookkeeping in this harness.  Ticks whose span
reports a compile delta are *cold-start* ticks: they cost hundreds of ms
once per (branch table, slot bucket) and then never again, so they are
excluded from the steady-state percentiles and reported separately
(count / each / total).  The queue-wait histogram is allocation-free
log2 buckets, so its p50/p99 are bucket upper bounds (factor-2
resolution, keys suffixed ``_le``); its mean/max are exact.

``python -m benchmarks.run --quick`` records the result to
``BENCH_serve.json``.
"""

from __future__ import annotations

import time

import numpy as np

SHAPE = (12, 4, 20)  # d, n_clients, n_i
COMPRESSORS = ["topk", "randk", "randseqk", "identity"]


def _build_specs(n_tenants: int, rounds: int):
    from repro.api import CompressorSpec, DataSpec, ExperimentSpec

    # mixed compressors / k / seeds / round budgets on one shared problem:
    # heterogeneous tenants that are nevertheless co-schedulable (§11)
    return [
        ExperimentSpec(
            data=DataSpec(shape=SHAPE, seed=1),
            compressor=CompressorSpec(
                COMPRESSORS[i % len(COMPRESSORS)],
                8.0 if i % 2 == 0 else 4.0,
            ),
            rounds=rounds + (i % 5),
            seed=i,
        )
        for i in range(n_tenants)
    ]


def _hex_traj(report):
    return (
        [float(r.grad_norm).hex() for r in report.records],
        [r.sent_bits for r in report.records],
    )


def _hist_summary(hists) -> dict:
    """Merge same-name log2 histograms (one per label set) into one
    mean/max-exact, percentile-approximate summary in milliseconds."""
    from repro.obs import HIST_BUCKETS, Histogram

    merged = Histogram("merged", ())
    for h in hists:
        for i in range(HIST_BUCKETS):
            merged.buckets[i] += h.buckets[i]
        merged.count += h.count
        merged.sum += h.sum
        merged.min = min(merged.min, h.min)
        merged.max = max(merged.max, h.max)
    if merged.count == 0:
        return {"count": 0, "mean_ms": 0.0, "max_ms": 0.0,
                "p50_le_ms": 0.0, "p99_le_ms": 0.0}
    p50 = merged.quantile_le(0.5)
    p99 = merged.quantile_le(0.99)
    return {
        "count": merged.count,
        "mean_ms": round(merged.sum / merged.count * 1e3, 3),
        "max_ms": round(merged.max * 1e3, 3),
        # log-bucket upper bounds — factor-2 resolution, hence the _le keys
        "p50_le_ms": round(min(p50, merged.max) * 1e3, 3),
        "p99_le_ms": round(min(p99, merged.max) * 1e3, 3),
    }


def serve_load_benchmark(
    n_tenants: int = 16,
    rounds: int = 24,
    arrival_rate_hz: float = 50.0,
    max_resident: int = 16,
    seed: int = 0,
) -> dict:
    """Run the load test; returns the BENCH_serve.json payload."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro import obs
    from repro.api import open_session
    from repro.serve_fednl import FedNLServer, ServeConfig

    specs = _build_specs(n_tenants, rounds)
    z = specs[0].data.build()

    # --- sequential baseline (and the bit-parity reference), obs OFF ------
    t0 = time.perf_counter()
    solo_reports = []
    for spec in specs:
        with open_session(spec, z=z) as s:
            solo_reports.append(s.run())
    seq_wall = time.perf_counter() - t0
    total_rounds = sum(r.rounds for r in solo_reports)

    # --- engine run under Poisson arrivals, obs ON -------------------------
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate_hz, n_tenants))
    concurrent_peak = 0
    handles = []
    prev = obs.core.CURRENT
    rec = obs.Recorder(span_capacity=16384)
    obs.set_current(rec)
    try:
        with FedNLServer(
            ServeConfig(max_resident=max_resident, admit_per_tick=max_resident)
        ) as srv:
            t_start = time.perf_counter()
            next_i = 0
            while next_i < n_tenants or srv._has_work():
                now = time.perf_counter() - t_start
                while next_i < n_tenants and arrivals[next_i] <= now:
                    handles.append(srv.submit(specs[next_i]))
                    next_i += 1
                if srv._has_work():
                    srv.tick()
                    in_flight = sum(1 for h in handles if not h.done)
                    concurrent_peak = max(concurrent_peak, in_flight)
                elif next_i < n_tenants:
                    time.sleep(
                        max(
                            0.0,
                            arrivals[next_i]
                            - (time.perf_counter() - t_start),
                        )
                    )
            serve_wall = time.perf_counter() - t_start
            stats = srv.stats()
            served_reports = [h.result() for h in handles]
    finally:
        obs.set_current(prev)

    # --- tick/queue timings: read back from the recorder (schema 3) -------
    latencies_ms: list[float] = []  # steady-state, slot-weighted
    cold_ms: list[float] = []  # ticks whose span saw a compile delta
    for span in rec.spans("engine.tick"):
        tick_ms = span.dur_s * 1e3
        if span.labels.get("compiles", 0) > 0:
            cold_ms.append(tick_ms)
        else:
            # every session advanced this tick waited the whole tick
            latencies_ms.extend([tick_ms] * max(span.labels.get("slots", 0), 1))
    queue_wait = _hist_summary(rec.hists("engine.queue.wait_s"))
    service = _hist_summary(rec.hists("engine.batch.launch_s"))

    # --- bit parity (all tenants; the bar requires >= 8 concurrent) -------
    bit_parity = all(
        _hex_traj(got) == _hex_traj(want)
        and got.rounds == want.rounds
        and np.array_equal(got.x, want.x)
        for got, want in zip(served_reports, solo_reports)
    )

    lat = np.asarray(latencies_ms) if latencies_ms else np.zeros(1)
    return {
        "n_tenants": n_tenants,
        "concurrent_peak": concurrent_peak,
        "arrival_rate_hz": arrival_rate_hz,
        "max_resident": max_resident,
        "total_rounds": total_rounds,
        "bit_parity": bool(bit_parity),
        "sessions_per_s": round(n_tenants / serve_wall, 3),
        # steady-state percentiles: compile (cold-start) ticks excluded
        "p50_round_latency_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_round_latency_ms": round(float(np.percentile(lat, 99)), 3),
        "cold_start_ticks": len(cold_ms),
        "cold_start_ms": [round(c, 1) for c in cold_ms],
        "cold_start_total_ms": round(float(sum(cold_ms)), 1),
        # where a round's time goes: admission queue vs batched service
        # (engine.queue.wait_s / engine.batch.launch_s — repro.obs recorder)
        "queue_wait_ms": queue_wait,
        "service_time_ms": service,
        "batch_occupancy": (
            round(stats["batch_occupancy"], 4)
            if stats["batch_occupancy"] is not None
            else None
        ),
        "spills": stats["spills"],
        "resumes": stats["resumes"],
        "ticks": stats["ticks"],
        "compiles": stats["compiles"],
        "serve_wall_s": round(serve_wall, 3),
        "sequential_wall_s": round(seq_wall, 3),
        "serve_rounds_per_s": round(total_rounds / serve_wall, 1),
        "sequential_rounds_per_s": round(total_rounds / seq_wall, 1),
        "throughput_ratio": round(seq_wall / serve_wall, 2),
    }


def main() -> int:
    bench = {"schema": 3, **serve_load_benchmark()}
    for k, v in bench.items():
        print(f"{k}: {v}")
    ok = bench["bit_parity"] and bench["concurrent_peak"] >= 8
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
