"""Topology-layer benchmark: tree-of-stars latency + staleness/accuracy.

    PYTHONPATH=src python -m benchmarks.run --quick --json-topology BENCH_topology.json

Measures, over the in-process loopback wire (socket-free, CI-stable):

  * sync round latency of a depth-2 tree-of-stars vs the flat star at
    n=16 and n=64 clients, with the tree==star bit-parity flag — the tree
    pays one extra aggregation hop per round, and combine="exact" must pay
    it without perturbing a single bit of the trajectory;
  * async round throughput and final accuracy vs the staleness bound
    (staleness in {0, 1, 2, 4} under the same spec'd arrival schedule) —
    the pinned staleness-vs-accuracy table: larger bounds commit rounds
    without waiting for the barrier, trading gradient freshness for
    throughput, and staleness=0 must be the sync run bit for bit.
"""

from __future__ import annotations

import numpy as np

from repro.api import DataSpec, ExperimentSpec, TopologySpec, solve

SYNC_ROUNDS = 6
ASYNC_ROUNDS = 12
STALENESS_GRID = (0, 1, 2, 4)


def _spec(n_clients: int, **overrides) -> ExperimentSpec:
    base = dict(
        data=DataSpec(shape=(16, n_clients, 8), seed=1),
        rounds=SYNC_ROUNDS,
        seed=0,
        backend="star-loopback",
    )
    base.update(overrides)
    return ExperimentSpec(**base)


def topology_benchmark() -> dict:
    out: dict = {"schema": 1, "sync_tree": {}, "async_staleness": []}

    for n in (16, 64):
        spec = _spec(n)
        tree_spec = spec.replace(
            topology=TopologySpec(kind="tree", fanout=4, depth=2)
        )
        # warm the jit caches so the table compares steady-state round cost,
        # not whichever variant happened to pay first-trace compile
        solve(spec.replace(rounds=1))
        solve(tree_spec.replace(rounds=1))
        star = solve(spec)
        tree = solve(tree_spec)
        parity = bool(
            np.array_equal(star.x, tree.x)
            and np.array_equal(
                star.extras["measured_payload_bits"],
                tree.extras["measured_payload_bits"],
            )
        )
        out["sync_tree"][f"n{n}"] = {
            "star_ms_per_round": round(1e3 * star.wall_time_s / star.rounds, 3),
            "tree_ms_per_round": round(1e3 * tree.wall_time_s / tree.rounds, 3),
            "tree_overhead_x": round(
                tree.wall_time_s / max(star.wall_time_s, 1e-9), 3
            ),
            "bit_parity": parity,
        }

    # staleness/accuracy: same problem, same arrival schedule, growing bound
    sync = solve(_spec(16, rounds=ASYNC_ROUNDS))
    for s in STALENESS_GRID:
        topo = TopologySpec(
            mode="async", staleness=s, max_delay=4, schedule_seed=0
        )
        solve(_spec(16, rounds=1, topology=topo))  # warm
        rep = solve(_spec(16, rounds=ASYNC_ROUNDS, topology=topo))
        out["async_staleness"].append(
            {
                "staleness": s,
                "rounds_per_s": round(rep.rounds / max(rep.wall_time_s, 1e-9), 1),
                "final_grad_norm": float(rep.grad_norms[-1]),
                # staleness=0 is the sync barrier bit for bit; larger bounds
                # drift (stale gradients) but must still converge
                "bit_equal_to_sync": bool(np.array_equal(rep.x, sync.x)),
            }
        )

    out["bit_parity"] = bool(
        all(v["bit_parity"] for v in out["sync_tree"].values())
        and out["async_staleness"][0]["bit_equal_to_sync"]
    )
    return out
