"""Roofline table from the dry-run sweep JSON (results/dryrun_all.json).

The dry-run itself must run in its own process (512 fake devices); this
module only reads its JSON output and emits the per-(arch x shape x mesh)
roofline rows for benchmarks/run.py and EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import os

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_all.json")


def load_records(path: str = DEFAULT_PATH):
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        return json.load(fh)


def roofline_rows(path: str = DEFAULT_PATH):
    rows = []
    for rec in load_records(path):
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skip":
            rows.append((name, 0.0, "SKIP:" + rec["reason"].split(";")[0][:80]))
            continue
        if rec["status"] != "ok":
            rows.append((name, 0.0, "FAIL:" + rec.get("error", "?")[:80]))
            continue
        r = rec.get("roofline")
        if not r:
            rows.append((name, 0.0, f"compiled_ok;compile_s={rec['compile_s']}"))
            continue
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        derived = (
            f"dom={r['dominant']};compute={r['compute_s']:.4f};"
            f"mem={r['memory_s']:.4f};coll={r['collective_s']:.4f}"
        )
        uf = r.get("useful_fraction")
        if uf is not None:
            derived += f";useful={uf:.3f}"
        rows.append((name, step_s * 1e6, derived))
    return rows


def markdown_table(path: str = DEFAULT_PATH) -> str:
    """EXPERIMENTS.md-ready table."""
    recs = load_records(path)
    lines = [
        "| arch | shape | mesh | status | compute_s | memory_s | collective_s "
        "| dominant | useful | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec["status"] == "skip":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | SKIP | — | — | — | — | — | — |"
            )
            continue
        if rec["status"] != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | FAIL | — | — | — | — | — | — |"
            )
            continue
        mem = rec.get("memory_analysis", {})
        temp = (mem.get("temp_bytes") or 0) / 1e9
        r = rec.get("roofline")
        if r:
            uf = r.get("useful_fraction")
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok "
                f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} "
                f"| {r['dominant']} | {uf:.3f} | {temp:.2f} |"
                if uf is not None else
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok "
                f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} "
                f"| {r['dominant']} | — | {temp:.2f} |"
            )
        else:
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok (compile proof) "
                f"| — | — | — | — | — | {temp:.2f} |"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
