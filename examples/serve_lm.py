"""Serve a reduced assigned architecture: batched greedy decode with a KV (or
SSM-state) cache — the serve_step the decode dry-run shapes lower.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_lm_params, init_decode_cache
from repro.models.encdec import init_encdec_params, init_encdec_cache
from repro.train import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family == "encdec":
        params = init_encdec_params(jax.random.PRNGKey(0), cfg)
        cache = init_encdec_cache(cfg, args.batch, args.tokens + 8, 16)
    else:
        params = init_lm_params(jax.random.PRNGKey(0), cfg)
        cache = init_decode_cache(cfg, args.batch, args.tokens + 8)
    step = jax.jit(make_serve_step(cfg))

    toks = jnp.zeros((args.batch, 1), dtype=jnp.int32)
    # warm-up compile
    logits, cache = step(params, cache, toks)
    out = [np.asarray(jnp.argmax(logits[:, 0, : cfg.vocab], axis=-1))]

    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        toks = jnp.asarray(out[-1][:, None], dtype=jnp.int32)
        logits, cache = step(params, cache, toks)
        out.append(np.asarray(jnp.argmax(logits[:, 0, : cfg.vocab], axis=-1)))
    dt = time.perf_counter() - t0
    seqs = np.stack(out, axis=1)
    print(f"{cfg.name}: decoded {args.batch} x {args.tokens} tokens "
          f"({args.batch * (args.tokens - 1) / dt:.0f} tok/s on CPU)")
    for b in range(min(2, args.batch)):
        print(f"  seq[{b}]: {seqs[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
