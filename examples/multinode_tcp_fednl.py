"""Real multi-node FedNL: master + client OS processes over TCP localhost.

This is the paper's Section-7 deployment in miniature — every round, each
client process uplinks its compressed Hessian correction through the
Section-7 wire codecs (repro.comm.wire) to the master socket, and the run is
seed-aligned so the resulting iterates are identical to the single-node
simulation (checked at the end).

    PYTHONPATH=src python examples/multinode_tcp_fednl.py
"""

import jax

jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.comm.cost import DEFAULT_COST
from repro.core import FedNLConfig, run_fednl
from repro.launch.multiproc import _build_problem, run_multiproc


def main():
    shape = (24, 8, 40)  # d, n_clients, n_i: 8 client processes
    for comp in ["topk", "randseqk", "natural"]:
        cfg = FedNLConfig(compressor=comp, lam=1e-3)
        res = run_multiproc(cfg, shape=shape, rounds=12, tol=1e-14, seed=0)
        ref = run_fednl(_build_problem("", shape, 0), cfg, rounds=12, tol=1e-14, seed=0)
        r = min(res.rounds, ref.rounds)
        dx = float(np.max(np.abs(res.x - ref.x)))
        comm_ms = DEFAULT_COST.round_s(
            float(res.measured_payload_bits[-1]), shape[0] * 64, shape[1]
        ) * 1e3
        print(f"{comp:9s}: {res.rounds} rounds over TCP, ||grad||={res.grad_norms[-1]:.2e}, "
              f"uplink={res.measured_frame_bytes.sum() / 1e3:.1f} kB framed, "
              f"cost-model {comm_ms:.2f} ms/round, max|x_tcp - x_sim|={dx:.1e}")
        assert dx <= 1e-8, "TCP run must reproduce the simulation trajectory"
        assert (res.measured_payload_bits[:r] == res.sent_bits[:r]).all()


if __name__ == "__main__":
    main()
