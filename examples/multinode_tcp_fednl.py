"""Real multi-node FedNL: master + client OS processes over TCP localhost.

This is the paper's Section-7 deployment in miniature, driven through the
declarative API: one ExperimentSpec per compressor with ``backend="star-tcp"``
(master + one OS process per client, Section-7 wire codecs), and the *same
spec* re-solved with ``backend="local"`` — the only field that changes — to
check the TCP run reproduces the single-node simulation.

    PYTHONPATH=src python examples/multinode_tcp_fednl.py
"""

import jax

jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.api import CompressorSpec, DataSpec, ExperimentSpec, solve
from repro.comm.cost import DEFAULT_COST


def main():
    shape = (24, 8, 40)  # d, n_clients, n_i: 8 client processes
    base = ExperimentSpec(
        data=DataSpec(shape=shape, seed=0),
        backend="star-tcp",
        rounds=12,
        tol=1e-14,
        seed=0,
    )
    for comp in ["topk", "randseqk", "natural"]:
        spec = base.replace(compressor=CompressorSpec(comp))
        rep = solve(spec)
        ref = solve(spec.replace(backend="local"))
        r = min(rep.rounds, ref.rounds)
        dx = float(np.max(np.abs(rep.x - ref.x)))
        comm_ms = DEFAULT_COST.round_s(
            float(rep.extras["measured_payload_bits"][-1]), shape[0] * 64, shape[1]
        ) * 1e3
        print(f"{comp:9s}: {rep.rounds} rounds over TCP, ||grad||={rep.grad_norms[-1]:.2e}, "
              f"uplink={rep.extras['measured_frame_bytes'].sum() / 1e3:.1f} kB framed, "
              f"cost-model {comm_ms:.2f} ms/round, max|x_tcp - x_sim|={dx:.1e}")
        assert dx <= 1e-8, "TCP run must reproduce the simulation trajectory"
        assert (rep.extras["measured_payload_bits"][:r]
                == rep.sent_bits_payload[:r]).all()


if __name__ == "__main__":
    main()
