"""Real multi-node FedNL: master + client OS processes over TCP localhost.

This is the paper's Section-7 deployment in miniature, driven through the
declarative API: one ExperimentSpec per compressor with ``backend="star-tcp"``
(master + one OS process per client, Section-7 wire codecs), and the *same
spec* re-solved with ``backend="local"`` — the only field that changes — to
check the TCP run reproduces the single-node simulation.

The second half drives the same deployment through the Session API
(DESIGN.md §10): step a live multi-node run by hand, checkpoint the master
mid-run, tear the whole process tree down, and resume from the checkpoint —
the fresh client processes rebuild their state from the spec + replayed PRNG
spine (no client state ever touches disk), bit-identical to an
uninterrupted run.

    PYTHONPATH=src python examples/multinode_tcp_fednl.py
"""

import tempfile
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.api import CompressorSpec, DataSpec, ExperimentSpec, open_session, solve
from repro.comm.cost import DEFAULT_COST


def main():
    shape = (24, 8, 40)  # d, n_clients, n_i: 8 client processes
    base = ExperimentSpec(
        data=DataSpec(shape=shape, seed=0),
        backend="star-tcp",
        rounds=12,
        tol=1e-14,
        seed=0,
    )
    for comp in ["topk", "randseqk", "natural"]:
        spec = base.replace(compressor=CompressorSpec(comp))
        rep = solve(spec)
        ref = solve(spec.replace(backend="local"))
        r = min(rep.rounds, ref.rounds)
        dx = float(np.max(np.abs(rep.x - ref.x)))
        comm_ms = DEFAULT_COST.round_s(
            float(rep.extras["measured_payload_bits"][-1]), shape[0] * 64, shape[1]
        ) * 1e3
        print(f"{comp:9s}: {rep.rounds} rounds over TCP, ||grad||={rep.grad_norms[-1]:.2e}, "
              f"uplink={rep.extras['measured_frame_bytes'].sum() / 1e3:.1f} kB framed, "
              f"cost-model {comm_ms:.2f} ms/round, max|x_tcp - x_sim|={dx:.1e}")
        assert dx <= 1e-8, "TCP run must reproduce the simulation trajectory"
        assert (rep.extras["measured_payload_bits"][:r]
                == rep.sent_bits_payload[:r]).all()

    # --- pause and resume the multi-node run -------------------------------
    spec = base.replace(compressor=CompressorSpec("topk"))
    uninterrupted = solve(spec)
    ckpt = Path(tempfile.mkdtemp()) / "tcp_master.fnlsess"
    with open_session(spec) as session:  # spawns the 8 client processes
        session.step(2)
        session.step(3)  # step(2)+step(3): composable round driving
        session.save(ckpt)  # serialize ONLY master-side state
    # the `with` exit stopped the master and tore down every client process
    print(f"checkpointed master at round 5 -> {ckpt.name} "
          f"({ckpt.stat().st_size} bytes), cluster torn down")

    with open_session(spec, restore=ckpt) as session:  # fresh cluster
        resumed = session.run()
    same = [g.hex() for g in resumed.grad_norms] == [
        g.hex() for g in uninterrupted.grad_norms
    ]
    print(f"resumed round 5 -> {resumed.rounds}; clients rebuilt by PRNG-"
          f"spine replay; bit-identical to uninterrupted run: {same}")
    assert same, "kill -> resume must reproduce the uninterrupted trajectory"


if __name__ == "__main__":
    main()
