"""Multi-node FedNL on an 8-device mesh (fake CPU devices in this container;
on a real cluster the same code runs over ICI/DCN).

Demonstrates both aggregation strategies:
  dense_psum        faithful dense collective (paper semantics)
  sparse_allgather  compressed collective (beyond-paper, DESIGN.md §7)

    PYTHONPATH=src python examples/distributed_fednl.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import FedNLConfig
from repro.data import make_synthetic_logreg, add_intercept, partition_clients
from repro.distributed import (
    make_sharded_fednl_round,
    shard_problem,
    sharded_fednl_init,
)
from repro.linalg import triu_size


def main():
    print(f"devices: {jax.device_count()}")
    d, n, n_i = 121, 48, 96  # 48 clients sharded 6-per-device
    x, y = make_synthetic_logreg((d, n, n_i), seed=0)
    z = jnp.asarray(partition_clients(add_intercept(x), y, n, n_i, seed=0))

    mesh = jax.make_mesh((8,), ("data",))
    zs = shard_problem(z, mesh)
    t = triu_size(d)

    for agg in ["dense_psum", "sparse_allgather"]:
        cfg = FedNLConfig(compressor="topk", k_multiplier=8.0, lam=1e-3)
        st = sharded_fednl_init(zs, cfg, mesh)
        rf = jax.jit(make_sharded_fednl_round(zs, cfg, mesh, aggregate=agg))
        for r in range(40):
            st, m = rf(st)
            if float(m["grad_norm"]) < 1e-14:
                break
        k = cfg.k_for(d)
        payload = k * 12 if agg == "sparse_allgather" else t * 8
        print(f"{agg:17s}: {r + 1} rounds, ||grad|| = {float(m['grad_norm']):.2e}, "
              f"collective payload/client/round = {payload / 1e3:.1f} kB "
              f"({'idx+val pairs' if 'sparse' in agg else 'dense packed triu'})")


if __name__ == "__main__":
    main()
