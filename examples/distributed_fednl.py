"""Multi-node FedNL on an 8-device mesh (fake CPU devices in this container;
on a real cluster the same code runs over ICI/DCN).

One ExperimentSpec with ``backend="sharded"``; the sweep varies only the
``aggregate`` field between the two collective strategies:
  dense_psum        faithful dense collective (paper semantics)
  sparse_allgather  compressed collective (beyond-paper, DESIGN.md §7)

    PYTHONPATH=src python examples/distributed_fednl.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

jax.config.update("jax_enable_x64", True)

from repro.api import CompressorSpec, DataSpec, ExperimentSpec, solve
from repro.linalg import triu_size


def main():
    print(f"devices: {jax.device_count()}")
    d, n, n_i = 121, 48, 96  # 48 clients sharded 6-per-device
    t = triu_size(d)
    base = ExperimentSpec(
        data=DataSpec(shape=(d, n, n_i), seed=0),
        compressor=CompressorSpec("topk", k_multiplier=8.0),
        backend="sharded",
        devices=8,
        rounds=40,
        tol=1e-14,
    )
    k = base.fednl_config().k_for(d)

    for agg in ["dense_psum", "sparse_allgather"]:
        rep = solve(base.replace(aggregate=agg))
        payload = k * 12 if agg == "sparse_allgather" else t * 8
        print(f"{agg:17s}: {rep.rounds} rounds, ||grad|| = {rep.grad_norms[-1]:.2e}, "
              f"collective payload/client/round = {payload / 1e3:.1f} kB "
              f"({'idx+val pairs' if 'sparse' in agg else 'dense packed triu'})")


if __name__ == "__main__":
    main()
