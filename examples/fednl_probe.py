"""FedNL-probe: the paper's technique as a first-class feature of the LM
framework — federated Newton training of a logistic-regression head on top of
a frozen assigned-architecture backbone (DESIGN.md §4).

Each client holds private token sequences; the frozen backbone (here the
reduced granite-3-2b for CPU speed) maps them to pooled features, and FedNL
trains the binary classifier head with compressed Hessian communication.

    PYTHONPATH=src python examples/fednl_probe.py [--arch granite-3-2b]
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.api import CompressorSpec, ExperimentSpec, solve
from repro.configs import get_config
from repro.models import init_lm_params
from repro.models.lm import _run_blocks, COMPUTE_DTYPE
from repro.data import partition_clients


def backbone_features(params, cfg, tokens):
    """Frozen-backbone mean-pooled features (B, d_model)."""
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    h = _run_blocks(x, params, cfg, jnp.arange(tokens.shape[1]))
    return jnp.mean(h.astype(jnp.float64), axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    print(f"backbone: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model})")

    # synthetic private data: class decides token distribution
    rng = np.random.default_rng(0)
    n_total = args.clients * args.samples
    labels = np.where(rng.random(n_total) < 0.5, 1.0, -1.0)
    lo, hi = cfg.vocab // 4, 3 * cfg.vocab // 4
    tokens = np.where(
        (labels[:, None] > 0), rng.integers(0, lo, (n_total, 16)),
        rng.integers(hi, cfg.vocab, (n_total, 16)),
    ).astype(np.int32)

    feats = np.asarray(backbone_features(params, cfg, jnp.asarray(tokens)))
    feats = feats / (np.linalg.norm(feats, axis=1, keepdims=True) + 1e-9)

    # federated logistic head on the features (the paper's exact problem
    # class); the backbone features ride into solve() as a pre-built problem
    z = jnp.asarray(partition_clients(feats, labels, args.clients, args.samples,
                                      seed=0, shuffle=False))
    spec = ExperimentSpec(
        compressor=CompressorSpec("toplek", k_multiplier=8.0),
        rounds=100,
        tol=1e-13,
    )
    rep = solve(spec, z=z)
    print(f"FedNL(B)/toplek head: {rep.rounds} rounds, "
          f"||grad|| = {rep.grad_norms[-1]:.2e}")

    # train-set accuracy of the probe
    margin = feats @ rep.x * labels
    acc = float((margin > 0).mean())
    print(f"probe train accuracy: {acc:.3f}")


if __name__ == "__main__":
    main()
