"""Run a whole paper table in one call: ExperimentSpec.grid -> solve_many.

    PYTHONPATH=src python examples/sweep_grid.py

Builds the compressor x seed grid of single-node FedNL runs (the shape of
the paper's Table 1 sweep), executes it through the batched sweep engine —
every shape-compatible spec group becomes ONE compiled program, so the grid
costs a couple of compiles instead of one per spec — and aggregates the
per-round records with the SweepReport helpers.
"""

import numpy as np

from repro.api import DataSpec, ExperimentSpec, solve_many

base = ExperimentSpec(
    data=DataSpec(dataset="tiny", seed=1),
    algorithm="fednl",
    rounds=12,
)
sweep = base.grid(
    compressor=["topk", "randk", "randseqk", "toplek", "natural"],
    seed=[0, 1, 2],
)
print(f"grid: {sweep.n_specs} specs "
      f"({' x '.join(f'{name}[{len(vals)}]' for name, vals in sweep.axes)})")

report = solve_many(sweep)
print(report.summary())
for line in report.log:
    print("  engine:", line)

# per-compressor convergence, averaged over the seed axis
print(f"\n{'compressor':<10s} {'final ||grad||':>16s} {'MB uplinked':>12s}")
for (comp,), runs in report.group_by("compressor.name").items():
    gn = np.mean([r.grad_norms[-1] for r in runs])
    mb = np.mean([np.sum(r.sent_bits) for r in runs]) / 8e6
    print(f"{comp:<10s} {gn:>16.3e} {mb:>12.3f}")

# the full per-round bit/accuracy tables, one row per spec
grad_table = report.round_table("grad_norm")
bits_table = report.round_table("sent_bits")
print(f"\nround tables: grad {grad_table.shape}, bits {bits_table.shape}; "
      f"median round-5 grad norm {np.median(grad_table[:, 5]):.3e}")
