"""End-to-end gateway demo: a fleet of prioritized remote submissions.

Starts a gateway in a subprocess (as a real deployment would run
``scripts/gateway_serve.py``), then from this process: submits experiments
across the three priority classes, watches one of them round-by-round over
a second connection, fetches every result, and verifies one trajectory
bit-for-bit against a local solo run — the DESIGN.md §14 contract.

    PYTHONPATH=src python examples/gateway_client.py
"""

import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def main() -> int:
    from repro.api import CompressorSpec, DataSpec, ExperimentSpec, solve
    from repro.gateway import GatewayClient, GatewayError, stream_records

    proc = subprocess.Popen(
        [sys.executable, "scripts/gateway_serve.py", "--port", "0",
         "--max-resident", "4"],
        stdout=subprocess.PIPE, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    try:
        _, host, port = proc.stdout.readline().split()  # "LISTENING h p"
        print(f"gateway up on {host}:{port}")

        def spec_of(seed, comp, rounds):
            return ExperimentSpec(
                data=DataSpec(shape=(12, 4, 20), seed=1),
                compressor=CompressorSpec(comp, 8.0),
                rounds=rounds, seed=seed,
            )

        with GatewayClient(host, int(port), connect_retry_s=30) as gwc:
            # a bad submission fails HERE, naming the field — not ticks later
            try:
                gwc.submit(spec_of(0, "topk", 4), priority="platinum")
            except GatewayError as e:
                print(f"rejected synchronously ({e.field}): {e}")

            fleet = [
                ("high", spec_of(0, "topk", 12)),
                ("normal", spec_of(1, "randk", 10)),
                ("normal", spec_of(2, "randseqk", 10)),
                ("low", spec_of(3, "identity", 8)),
            ]
            handles = [(gwc.submit(s, priority=p), s) for p, s in fleet]

            # live-stream the low-priority tenant on its own connection
            watch = handles[-1][0]
            for rec in stream_records(host, int(port), watch.id):
                print(f"  [{watch.id} {watch.priority}] round {rec.round} "
                      f"||grad||={rec.grad_norm:.3e}")

            for h, spec in handles:
                report = h.result()
                print(f"{h.id} ({h.priority}): {report.rounds} rounds, "
                      f"final ||grad||={report.final_grad_norm:.3e}")

            # the §14 bar: remote result == local solve, bit for bit
            h0, spec0 = handles[0]
            local = solve(spec0)
            remote = h0.result()
            same = all(
                float(a.grad_norm).hex() == float(b.grad_norm).hex()
                for a, b in zip(remote.records, local.records)
            ) and (remote.x == local.x).all()
            print(f"bit-identical to local solve: {same}")
            stats = gwc.status()
            print(f"engine stats: ticks={stats['ticks']} "
                  f"admissions_by_class={stats['admissions_by_class']}")
            return 0 if same else 1
    finally:
        proc.kill()
        proc.wait(10)


if __name__ == "__main__":
    sys.exit(main())
