"""Real multi-node FedNL-PP: partial participation over TCP localhost.

Algorithm 3 in miniature, driven through the declarative API — the master
samples tau of the 8 client processes each round; only those receive a SELECT
frame and uplink the compressed triple ``encode(S_i) || dl_i || dg_i`` through
the Section-7 wire codecs.  The fault-free tau = n spec is re-solved with
``backend="local"`` (the only field that changes) and checked bit-identical;
a second sweep injects 20% dropout and shows both Algorithm-3 fallback
policies still drive the gradient below 1e-9.

    PYTHONPATH=src python examples/multinode_pp_fednl.py
"""

import jax

jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.api import DataSpec, ExperimentSpec, FaultSpec, solve


def main():
    shape = (24, 8, 40)  # d, n_clients, n_i: 8 client processes
    n = shape[1]
    base = ExperimentSpec(
        algorithm="fednl-pp",
        data=DataSpec(shape=shape, seed=0),
        backend="star-tcp",
        seed=0,
    )

    # --- fault-free: tau = n reproduces the simulation bit-for-bit ---------
    spec = base.replace(tau=n, rounds=10)
    rep = solve(spec)
    ref = solve(spec.replace(backend="local"))
    dx = float(np.max(np.abs(rep.x_hist - ref.x_hist)))
    print(f"tau={n} (full): {rep.rounds} rounds over TCP, "
          f"uplink={rep.extras['measured_frame_bytes'].sum() / 1e3:.1f} kB framed, "
          f"max|x_tcp - x_sim|={dx:.1e}")
    assert dx == 0.0, "fault-free PP run must be bit-identical to the simulation"
    assert (rep.extras["measured_payload_bits"] == rep.sent_bits_payload).all()

    # --- partial participation with injected dropout -----------------------
    fault = FaultSpec(drop_prob=0.2, seed=7)
    for policy in ["partial", "resample"]:
        rep = solve(base.replace(
            tau=3, rounds=60, fault=fault, on_dropout=policy,
        ))
        drops = sum(len(d) for d in rep.dropped)
        parts = sum(len(p) for p in rep.participants)
        print(f"tau=3 drop=20% on_dropout={policy}: contributions={parts} "
              f"drops={drops} ||grad(x_final)||={rep.final_grad_norm:.2e}")
        assert rep.final_grad_norm < 1e-9, "dropout-injected PP run must still converge"


if __name__ == "__main__":
    main()
