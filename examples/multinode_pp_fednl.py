"""Real multi-node FedNL-PP: partial participation over TCP localhost.

Algorithm 3 in miniature — the master samples tau of the 8 client processes
each round; only those receive a SELECT frame and uplink the compressed
triple ``encode(S_i) || dl_i || dg_i`` through the Section-7 wire codecs.
The fault-free tau = n run is checked bit-identical against the single-node
simulation; a second run injects 20% dropout and shows both Algorithm-3
fallback policies still drive the gradient below 1e-9.

    PYTHONPATH=src python examples/multinode_pp_fednl.py
"""

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.comm.transport import FaultSpec
from repro.core import FedNLConfig, eval_full, run_fednl_pp
from repro.launch.multiproc import _build_problem, run_multiproc_pp


def main():
    shape = (24, 8, 40)  # d, n_clients, n_i: 8 client processes
    n = shape[1]
    cfg = FedNLConfig(compressor="topk", lam=1e-3)
    z = _build_problem("", shape, 0)

    # --- fault-free: tau = n reproduces the simulation bit-for-bit ---------
    res = run_multiproc_pp(cfg, tau=n, shape=shape, rounds=10, seed=0)
    ref = run_fednl_pp(z, cfg, tau=n, rounds=10, seed=0)
    dx = float(np.max(np.abs(res.x_hist - ref.x_hist)))
    print(f"tau={n} (full): {res.rounds} rounds over TCP, "
          f"uplink={res.measured_frame_bytes.sum() / 1e3:.1f} kB framed, "
          f"max|x_tcp - x_sim|={dx:.1e}")
    assert dx == 0.0, "fault-free PP run must be bit-identical to the simulation"
    assert (res.measured_payload_bits == res.sent_bits).all()

    # --- partial participation with injected dropout -----------------------
    fault = FaultSpec(drop_prob=0.2, seed=7)
    for policy in ["partial", "resample"]:
        res = run_multiproc_pp(
            cfg, tau=3, shape=shape, rounds=60, seed=0,
            on_dropout=policy, fault=fault,
        )
        _, g = eval_full(z, jnp.asarray(res.x), cfg.lam)
        gn = float(jnp.linalg.norm(g))
        drops = sum(len(d) for d in res.dropped)
        parts = sum(len(p) for p in res.participants)
        print(f"tau=3 drop=20% on_dropout={policy}: contributions={parts} "
              f"drops={drops} ||grad(x_final)||={gn:.2e}")
        assert gn < 1e-9, "dropout-injected PP run must still converge"


if __name__ == "__main__":
    main()
