"""Hierarchical + elastic FedNL: the repro.comm topology layer end to end.

Three runs of the same problem over the loopback wire backend:

  1. a depth-2 tree-of-stars (16 clients behind 4 aggregators) that
     reproduces the flat star bit for bit while the root reads 4 uplinks
     per round instead of 16;
  2. bounded-staleness async aggregation — the barrier replaced by the
     contract "an update computed against x^r lands by commit r+s", with
     the staleness/accuracy trade printed per bound;
  3. an elastic cohort — one client joins mid-run (late INIT at the
     current iterate, its T*64-bit state uplink accounted exactly) and one
     leaves (retired from the Hessian invariant exactly, via the master's
     per-client mirrors).

    PYTHONPATH=src python examples/tree_async_fednl.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.api import (
    DataSpec,
    ExperimentSpec,
    MembershipEvent,
    MembershipSpec,
    TopologySpec,
    solve,
)


def main():
    base = ExperimentSpec(
        data=DataSpec(shape=(16, 16, 12), seed=0),  # d=16, 16 clients
        backend="star-loopback",
        rounds=12,
        seed=0,
    )

    # --- 1. tree-of-stars: 4 aggregators x 4 clients, bit-parity ----------
    star = solve(base)
    tree = solve(
        base.replace(topology=TopologySpec(kind="tree", fanout=4, depth=2))
    )
    print("tree-of-stars (4 aggregators x 4 clients, combine='exact'):")
    print(f"  flat star : ||grad|| = {star.grad_norms[-1]:.2e}")
    print(f"  tree      : ||grad|| = {tree.grad_norms[-1]:.2e}  "
          f"bit-identical to star: {np.array_equal(star.x, tree.x)}")

    # --- 2. async: bounded staleness instead of the barrier ---------------
    print("\nasync aggregation (max_delay=3, spec'd arrival schedule):")
    for s in (0, 1, 3):
        rep = solve(
            base.replace(
                topology=TopologySpec(
                    mode="async", staleness=s, max_delay=3, schedule_seed=7
                )
            )
        )
        note = (
            "== sync barrier bit for bit"
            if np.array_equal(rep.x, star.x)
            else "stale gradients, still converging"
        )
        print(f"  staleness={s}: ||grad|| = {rep.grad_norms[-1]:.2e}  ({note})")

    # --- 3. elastic membership: join + leave as spec'd events -------------
    mem = MembershipSpec(
        events=(
            MembershipEvent(round=3, action="join", client=15),
            MembershipEvent(round=6, action="leave", client=0),
        )
    )
    rep = solve(base.replace(membership=mem))
    sizes = {r.round: len(r.participants) for r in rep.records}
    print("\nelastic membership (client 15 joins @3, client 0 leaves @6):")
    print(f"  cohort sizes: r0={sizes[0]} r3={sizes[3]} r6={sizes[6]}")
    print(f"  ||grad|| = {rep.grad_norms[-1]:.2e} "
          f"(checkpoint/resume replays the same cohort history)")


if __name__ == "__main__":
    main()
