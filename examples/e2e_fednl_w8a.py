"""End-to-end driver at the paper's full single-node scale (Table 1 setup):

  W8A-shaped problem, d = 301 features (300 + intercept), n = 142 clients,
  n_i = 348 samples/client, lambda = 1e-3, FedNL(B), alpha = 1 (scaled
  compressors), r <= 1000 rounds with early stop at ||grad|| < 1e-15.

Pipeline: generate -> write LIBSVM to disk -> mmap-parse -> shuffle/partition
-> train -> report per-compressor wall time and accuracy -> save the model.

    PYTHONPATH=src python examples/e2e_fednl_w8a.py [--rounds 1000] [--fast]
"""

import argparse
import os
import tempfile
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.api import CompressorSpec, DataSpec, ExperimentSpec, solve
from repro.data import (
    make_synthetic_logreg,
    write_libsvm,
    parse_libsvm,
    add_intercept,
    partition_clients,
)
from repro.train.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=1000)
    ap.add_argument("--fast", action="store_true",
                    help="stop at tol instead of running all rounds")
    ap.add_argument("--out", default="results/e2e_fednl_w8a")
    args = ap.parse_args()

    d, n, n_i = 301, 142, 348
    t0 = time.perf_counter()
    x, y = make_synthetic_logreg("w8a", seed=0)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "w8a.libsvm")
        write_libsvm(path, x, y)
        x2, y2 = parse_libsvm(path, n_features=d - 1)
    z = jnp.asarray(partition_clients(add_intercept(x2), y2, n, n_i, seed=0))
    print(f"data pipeline: {time.perf_counter() - t0:.2f}s "
          f"(write+mmap-parse+partition, {z.shape})")

    os.makedirs(args.out, exist_ok=True)
    # one declarative spec; the sweep varies only the compressor field
    # (z from the LIBSVM round-trip above is passed straight to solve)
    base = ExperimentSpec(
        data=DataSpec(dataset="w8a", seed=0),
        rounds=args.rounds,
        tol=1e-15 if args.fast else 0.0,
    )
    summary = []
    for comp in ["randseqk", "topk", "toplek", "randk", "natural", "identity"]:
        rep = solve(base.replace(compressor=CompressorSpec(comp, 8.0)), z=z)
        mb = float(np.sum(rep.sent_bits)) / 8e6
        line = (f"FedNL(B)/{comp:9s} rounds={rep.rounds:4d} "
                f"||grad||={rep.grad_norms[-1]:.2e} "
                f"solve={rep.wall_time_s:8.2f}s init={rep.init_time_s:5.2f}s "
                f"uplink={mb:9.1f} MB")
        print(line)
        summary.append(line)
        save_checkpoint(os.path.join(args.out, f"model_{comp}.npz"),
                        {"x": jnp.asarray(rep.x)})
    with open(os.path.join(args.out, "summary.txt"), "w") as fh:
        fh.write("\n".join(summary) + "\n")
    print(f"saved models + summary to {args.out}/")


if __name__ == "__main__":
    main()
