"""Quickstart: train L2-regularized logistic regression with FedNL in ~seconds.

    PYTHONPATH=src python examples/quickstart.py [--compressor topk]

One declarative ExperimentSpec describes the whole run; solve() executes it.
Change only ``backend=`` ("local" | "sharded" | "star-loopback" | "star-tcp")
to re-run the identical experiment on another execution backend.
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)  # FedNL is an FP64 algorithm
import jax.numpy as jnp

from repro.api import CompressorSpec, DataSpec, ExperimentSpec, solve
from repro.core import newton_baseline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compressor", default="topk",
                    choices=["topk", "randk", "randseqk", "toplek", "natural", "identity"])
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--backend", default="local")
    args = ap.parse_args()

    # a small federated problem: 8 clients x 40 samples, d = 24
    spec = ExperimentSpec(
        data=DataSpec(dataset="tiny", seed=0),
        compressor=CompressorSpec(args.compressor, k_multiplier=8.0),
        backend=args.backend,
        rounds=args.rounds,
        tol=1e-14,
    )
    d, n, n_i = spec.data.dims()
    print(f"problem: {n} clients x {n_i} samples, d={d}")

    # build the problem once, shared with the centralized baseline below
    # (star-tcp workers rebuild their shards from the seed instead)
    z = spec.data.build()
    rep = solve(spec) if args.backend == "star-tcp" else solve(spec, z=z)
    print(f"FedNL(B)/{args.compressor}@{rep.backend}: {rep.rounds} rounds, "
          f"||grad|| = {rep.grad_norms[-1]:.2e}, "
          f"solve {rep.wall_time_s:.2f}s (init {rep.init_time_s:.2f}s)")
    for r in range(0, rep.rounds, max(1, rep.rounds // 10)):
        print(f"  round {r:3d}  ||grad|| = {rep.records[r].grad_norm:.3e}")

    nb = newton_baseline(z, 1e-3)
    err = float(jnp.linalg.norm(jnp.asarray(rep.x) - jnp.asarray(nb.x)))
    print(f"distance to centralized Newton solution: {err:.2e}")


if __name__ == "__main__":
    main()
