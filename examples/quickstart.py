"""Quickstart: train L2-regularized logistic regression with FedNL in ~seconds.

    PYTHONPATH=src python examples/quickstart.py [--compressor topk]
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)  # FedNL is an FP64 algorithm
import jax.numpy as jnp

from repro.core import FedNLConfig, run_fednl, newton_baseline
from repro.data import make_synthetic_logreg, add_intercept, partition_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compressor", default="topk",
                    choices=["topk", "randk", "randseqk", "toplek", "natural", "identity"])
    ap.add_argument("--rounds", type=int, default=60)
    args = ap.parse_args()

    # a small federated problem: 8 clients x 40 samples, d = 24
    x, y = make_synthetic_logreg("tiny", seed=0)
    z = jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=0))
    print(f"problem: {z.shape[0]} clients x {z.shape[1]} samples, d={z.shape[2]}")

    cfg = FedNLConfig(compressor=args.compressor, k_multiplier=8.0, lam=1e-3,
                      option="B")
    res = run_fednl(z, cfg, rounds=args.rounds, tol=1e-14)
    print(f"FedNL(B)/{args.compressor}: {res.rounds} rounds, "
          f"||grad|| = {res.grad_norms[-1]:.2e}, "
          f"solve {res.wall_time_s:.2f}s (init {res.init_time_s:.2f}s)")
    for r in range(0, res.rounds, max(1, res.rounds // 10)):
        print(f"  round {r:3d}  ||grad|| = {res.grad_norms[r]:.3e}")

    nb = newton_baseline(z, 1e-3)
    err = float(jnp.linalg.norm(jnp.asarray(res.x) - jnp.asarray(nb.x)))
    print(f"distance to centralized Newton solution: {err:.2e}")


if __name__ == "__main__":
    main()
