"""Quickstart: train L2-regularized logistic regression with FedNL in ~seconds.

    PYTHONPATH=src python examples/quickstart.py [--compressor topk]

One declarative ExperimentSpec describes the whole run.  The simple path is
still one call — ``solve(spec)`` — and changing only ``backend=`` ("local" |
"sharded" | "star-loopback" | "star-tcp") re-runs the identical experiment on
another execution backend.  The second half shows the incremental Session
form of the same run (DESIGN.md §10): stream rounds through an observer,
stop early on a custom criterion, checkpoint mid-run, resume bit-identically.
"""

import argparse
import tempfile
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)  # FedNL is an FP64 algorithm
import jax.numpy as jnp

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    StopPolicy,
    open_session,
    solve,
)
from repro.core import newton_baseline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compressor", default="topk",
                    choices=["topk", "randk", "randseqk", "toplek", "natural", "identity"])
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--backend", default="local")
    args = ap.parse_args()

    # a small federated problem: 8 clients x 40 samples, d = 24
    spec = ExperimentSpec(
        data=DataSpec(dataset="tiny", seed=0),
        compressor=CompressorSpec(args.compressor, k_multiplier=8.0),
        backend=args.backend,
        rounds=args.rounds,
        tol=1e-14,
    )
    d, n, n_i = spec.data.dims()
    print(f"problem: {n} clients x {n_i} samples, d={d}")

    # build the problem once, shared with the centralized baseline below
    # (star-tcp workers rebuild their shards from the seed instead)
    z = spec.data.build()

    # --- the simple path: one declarative spec, one call -------------------
    rep = solve(spec) if args.backend == "star-tcp" else solve(spec, z=z)
    print(f"FedNL(B)/{args.compressor}@{rep.backend}: {rep.rounds} rounds, "
          f"||grad|| = {rep.grad_norms[-1]:.2e}, "
          f"solve {rep.wall_time_s:.2f}s (init {rep.init_time_s:.2f}s)")
    for r in range(0, rep.rounds, max(1, rep.rounds // 10)):
        print(f"  round {r:3d}  ||grad|| = {rep.records[r].grad_norm:.3e}")

    nb = newton_baseline(z, 1e-3)
    err = float(jnp.linalg.norm(jnp.asarray(rep.x) - jnp.asarray(nb.x)))
    print(f"distance to centralized Newton solution: {err:.2e}")

    # --- the incremental path: the SAME run, round by round ----------------
    # An observer streams records as they are produced; run() accepts a
    # custom early-stop criterion solve() has no field for (here: stop once
    # the round's uplink is cheap AND the gradient dropped 6 orders).
    session = open_session(spec) if args.backend == "star-tcp" else \
        open_session(spec, z=z)
    session.on_round(
        lambda rec: rec.round % 10 == 0
        and print(f"  [observer] round {rec.round:3d}  "
                  f"||grad|| = {rec.grad_norm:.3e}")
    )
    session.step(5)  # drive a few rounds by hand...
    ckpt = Path(tempfile.mkdtemp()) / "quickstart.fnlsess"
    session.save(ckpt)  # ...checkpoint mid-run...
    early = session.run(  # ...then finish under a custom stop criterion
        until=StopPolicy(predicate=lambda rec: rec.grad_norm < 1e-6)
    )
    session.close()
    print(f"session: stopped early at round {early.rounds} "
          f"(||grad|| = {early.grad_norms[-1]:.2e}), checkpoint at round 5")

    # resume the checkpoint under the original budget: bit-identical to the
    # uninterrupted solve() above
    with open_session(spec, restore=ckpt) as resumed:
        rep2 = resumed.run()
    same = [g.hex() for g in rep2.grad_norms] == [g.hex() for g in rep.grad_norms]
    print(f"resumed from round 5 -> {rep2.rounds} rounds; "
          f"bit-identical to solve(): {same}")
    assert same, "save -> resume must reproduce the uninterrupted trajectory"


if __name__ == "__main__":
    main()
