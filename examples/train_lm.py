"""Train a reduced assigned architecture for a few hundred steps on the
synthetic token stream; loss must visibly decrease.  Demonstrates the LM-side
substrate (optimizer, accumulation, checkpointing).

    PYTHONPATH=src python examples/train_lm.py --arch mamba2-2.7b --steps 100
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_lm_params
from repro.models.encdec import init_encdec_params
from repro.train import (
    make_train_step,
    synthetic_token_stream,
    adamw_init,
    save_checkpoint,
)
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    init = init_encdec_params if cfg.family == "encdec" else init_lm_params
    params = init(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name} reduced: {n_params / 1e6:.1f}M params, family={cfg.family}")

    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    stream = synthetic_token_stream(cfg, args.batch, args.seq, seed=0)

    t0 = time.perf_counter()
    first = None
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, m = step(params, opt, batch)
        loss = float(m["loss"])
        if first is None:
            first = loss
        if i % max(1, args.steps // 10) == 0:
            print(f"step {i:4d}  loss {loss:.4f}  gnorm {float(m['grad_norm']):.3f}")
    print(f"loss {first:.4f} -> {loss:.4f} in {args.steps} steps "
          f"({time.perf_counter() - t0:.1f}s)")
    if args.out:
        save_checkpoint(args.out, params)
        print(f"checkpoint saved to {args.out}")


if __name__ == "__main__":
    main()
