"""CI gate for the multi-tenant serving engine (socket-free, < ~2 min).

    PYTHONPATH=src python scripts/smoke_serve.py

Admits four mixed specs to one ``FedNLServer`` — three batch-lane tenants
(different compressors and round budgets, co-batched through one switched
round kernel at differing round indices) plus one solo-lane star-loopback
tenant (full wire protocol over in-process connections) — serves them to
completion under memory pressure (``max_resident=2`` forces spill/resume
churn), and asserts the §11 bar: every served trajectory bit-identical to a
solo ``open_session(spec).run()``.  Exits nonzero on any mismatch.
"""

from __future__ import annotations

import sys

import numpy as np


def main() -> int:
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.api import CompressorSpec, DataSpec, ExperimentSpec, open_session
    from repro.serve_fednl import FedNLServer, ServeConfig

    shape = (12, 4, 20)

    def spec_of(seed, comp, rounds, backend="local", algo="fednl"):
        return ExperimentSpec(
            data=DataSpec(shape=shape, seed=1),
            algorithm=algo,
            compressor=CompressorSpec(comp, 8.0),
            backend=backend,
            rounds=rounds,
            seed=seed,
        )

    specs = [
        spec_of(0, "topk", 6),
        spec_of(1, "randk", 4),
        spec_of(2, "randseqk", 7),
        spec_of(3, "topk", 5, backend="star-loopback"),
    ]
    cfg = ServeConfig(max_resident=2, admit_per_tick=2)
    with FedNLServer(cfg) as server:
        handles = [server.submit(s) for s in specs]
        ticks = server.serve_until_idle(max_ticks=200)
        stats = server.stats()
        reports = [h.result() for h in handles]

    failures = []
    for spec, rep in zip(specs, reports):
        with open_session(spec) as s:
            want = s.run()
        label = (f"{spec.compressor.name}/r{spec.rounds}/{spec.backend}")
        if rep.rounds != want.rounds:
            failures.append(f"{label}: rounds {rep.rounds} != {want.rounds}")
            continue
        served = [float(r.grad_norm).hex() for r in rep.records]
        solo = [float(r.grad_norm).hex() for r in want.records]
        if served != solo:
            failures.append(f"{label}: grad-norm trajectory diverged")
        if [r.sent_bits for r in rep.records] != [
            r.sent_bits for r in want.records
        ]:
            failures.append(f"{label}: bit accounting diverged")
        if not np.array_equal(rep.x, want.x):
            failures.append(f"{label}: final iterate diverged")

    print(
        f"served {len(specs)} tenants in {ticks} ticks: "
        f"{stats['spills']} spills, {stats['resumes']} resumes, "
        f"{stats['batch_launches']} batched launches "
        f"({stats['compiles']} compiles, "
        f"occupancy {stats['batch_occupancy']:.2f})"
    )
    if stats["spills"] == 0:
        failures.append(
            "memory-pressure path not exercised (expected spills under "
            f"max_resident={cfg.max_resident})"
        )
    if failures:
        print("smoke_serve FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("smoke_serve OK: engine-served == solo bit-for-bit "
          "(4 mixed tenants, spill/resume churn included)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
