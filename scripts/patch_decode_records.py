import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Re-measure the three decode records whose sweep pass ran with the (later
reverted-to-conditional) q/k/v-dh constraint, and patch results/dryrun_all.json
so the single-pod roofline table reflects the shipped configuration."""

import json
import sys

from repro.launch.dryrun import run_one

TARGETS = [("mixtral-8x22b", "mixtral-8x22b")]

path = "results/dryrun_all.json"
records = json.load(open(path))
for arch, name in TARGETS:
    rec = run_one(arch, "decode_32k", False, roofline_probes=True)
    for i, old in enumerate(records):
        if old["arch"] == name and old["shape"] == "decode_32k" and old["mesh"] == "16x16":
            records[i] = rec
            print("patched", name)
            break
with open(path, "w") as fh:
    json.dump(records, fh, indent=2, default=float)
print("done")
