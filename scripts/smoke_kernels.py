"""CI gate for the fused-round kernel layer (socket-free, < ~1 min).

    PYTHONPATH=src python scripts/smoke_kernels.py

Pins the three contracts the fused hot path rests on (DESIGN.md §12),
on shapes small enough for tier-1:

  1. selection contract — f32 rank keys, lowest-index tie-break: on a
     vector engineered so distinct f64 values collide in f32, the sorted
     top-k indices, the threshold mask, the Pallas kernel (interpret) and
     an independent numpy lexsort all select the same set;
  2. masked == sorted — ``topk_dense_masked`` / ``randseqk_dense_masked``
     replay the sort+scatter dense forms bit-for-bit (the fused round
     swaps formulations under lax.map; they must be interchangeable);
  3. packed SYRK — ``hessian_syrk_packed`` == ``pack_triu(hessian_fused)``
     bitwise, across the d <= 128 plain-gemm and d > 128 strip regimes;
  4. round parity — the fused round replays the jnp reference round
     bit-for-bit on tiny (state, grad norm, integer bit accounting).

Exits nonzero on any mismatch.
"""

from __future__ import annotations

import sys

import numpy as np


def _check_selection_contract() -> list[str]:
    import jax.numpy as jnp

    from repro.compressors import select as csel
    from repro.kernels.compressor_select import select_topk_pallas

    t, k = 512, 100
    # distinct f64 magnitudes that collide once rounded to f32 rank keys
    base = np.float64(np.float32(np.linspace(0.5, 2.0, t // 4)))
    eps = np.array([0.0, 1e-12, 2.5e-12, -1e-12])
    u = (base[:, None] + eps[None, :]).ravel()
    u *= np.where(np.arange(t) % 3 == 0, -1.0, 1.0)
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.permutation(u))

    keys = np.asarray(csel.rank_keys(u))
    if len(np.unique(keys)) >= t:
        return ["near-tie fixture has no f32 collisions (fixture bug)"]

    want = np.sort(np.lexsort((np.arange(t), -keys))[:k])
    got_sort = np.sort(np.asarray(csel.topk_indices(u, k)))
    got_mask = np.flatnonzero(np.asarray(csel.threshold_keep_mask(keys, k)))
    dense, _sent = select_topk_pallas(u, k, interpret=True)
    got_pallas = np.flatnonzero(np.asarray(dense))

    fails = []
    if not np.array_equal(got_sort, want):
        fails.append("topk_indices disagrees with numpy lexsort contract")
    if not np.array_equal(got_mask, want):
        fails.append("threshold_keep_mask disagrees with sorted top-k")
    if not np.array_equal(got_pallas, want):
        fails.append("pallas select_topk (interpret) disagrees with contract")
    return fails


def _check_masked_formulations() -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.compressors import select as csel

    fails = []
    for t, k, s in [(300, 24, 7), (257, 1, 200)]:
        u = jax.random.normal(jax.random.PRNGKey(t), (t,), dtype=jnp.float64)
        if not np.array_equal(
            np.asarray(jax.jit(csel.topk_dense_masked, static_argnums=1)(u, k)),
            np.asarray(jax.jit(csel.topk_dense, static_argnums=1)(u, k)),
        ):
            fails.append(f"topk masked != sorted (t={t}, k={k})")
        if not np.array_equal(
            np.asarray(csel.randseqk_dense_masked(u, k, jnp.asarray(s))),
            np.asarray(csel.randseqk_dense(u, k, jnp.asarray(s))),
        ):
            fails.append(f"randseqk masked != gathered (t={t}, k={k})")
    return fails


def _check_packed_syrk() -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.linalg import pack_triu

    fails = []
    for n, d in [(40, 24), (60, 150)]:  # plain-gemm and strip regimes
        kz, kh = jax.random.split(jax.random.PRNGKey(d))
        z = jax.random.normal(kz, (n, d), dtype=jnp.float64)
        h = jax.random.uniform(kh, (n,), dtype=jnp.float64)
        got = np.asarray(jax.jit(ops.hessian_syrk_packed)(z, h))
        want = np.asarray(jax.jit(lambda z, h: pack_triu(ops.hessian_fused(z, h)))(z, h))
        if not np.array_equal(got, want):
            fails.append(f"hessian_syrk_packed != pack_triu(hessian_fused) (d={d})")
    return fails


def _check_round_parity() -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core.fednl import FedNLConfig, fednl_init, make_fednl_round
    from repro.data import (
        DATASET_SHAPES,
        add_intercept,
        make_synthetic_logreg,
        partition_clients,
    )

    _, nc, ni = DATASET_SHAPES["tiny"]
    x, y = make_synthetic_logreg("tiny", seed=1)
    z = jnp.asarray(partition_clients(add_intercept(x), y, nc, ni, seed=1))

    fails = []
    for comp in ("topk", "randseqk", "toplek"):
        finals = {}
        for hessian in ("jnp", "fused"):
            cfg = FedNLConfig(compressor=comp, hessian=hessian)
            state = fednl_init(z, cfg, seed=1)
            # the raw round kernel IS the subject here (allowlisted in
            # check_api_migration.py): parity below the facade
            round_fn = jax.jit(make_fednl_round(z, cfg))
            bits = []
            for _ in range(2):
                state, m = round_fn(state)
                bits.append((int(m.sent_elems), int(m.sent_bits)))
            finals[hessian] = (
                np.asarray(state.x),
                np.asarray(state.h_global),
                float(m.grad_norm).hex(),
                bits,
            )
        xj, hj, gj, bj = finals["jnp"]
        xf, hf, gf, bf = finals["fused"]
        if not (
            np.array_equal(xj, xf)
            and np.array_equal(hj, hf)
            and gj == gf
            and bj == bf
        ):
            fails.append(f"fused round != jnp round on tiny ({comp})")
    return fails


def main() -> int:
    import jax

    jax.config.update("jax_enable_x64", True)

    failures = []
    for name, check in [
        ("selection contract (f32 keys, near-tie)", _check_selection_contract),
        ("masked == sorted formulations", _check_masked_formulations),
        ("packed SYRK bit-identity", _check_packed_syrk),
        ("fused round bit parity (tiny)", _check_round_parity),
    ]:
        fails = check()
        if fails:
            failures.extend(fails)
            print(f"FAIL {name}")
        else:
            print(f"PASS {name}")

    if failures:
        print("smoke_kernels FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("smoke_kernels OK: selection contract, masked formulations, "
          "packed SYRK and fused-round parity all bit-exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
