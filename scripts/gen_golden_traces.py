"""Regenerate tests/golden/fednl_traces.json — pinned first-10-round
trajectories for the golden-trace regression tests.

    PYTHONPATH=src python scripts/gen_golden_traces.py

Floats are stored as C99 hex literals (float.hex()): the pins are BIT-exact,
so any refactor of the round body, compressors, or codecs that changes a
single ulp of the trajectory fails tests/test_golden_traces.py immediately.
Only regenerate after deliberately changing numerical behaviour, and say so
in the commit message.
"""

import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.core import FedNLConfig, run_fednl
from repro.data import add_intercept, make_synthetic_logreg, partition_clients

OUT = pathlib.Path(__file__).resolve().parent.parent / "tests" / "golden" / "fednl_traces.json"

ROUNDS = 10
COMPRESSORS = ["topk", "randseqk", "toplek"]


def problem():
    x, y = make_synthetic_logreg("tiny", seed=1)
    return jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=1))


def main():
    z = problem()
    traces = {}
    for comp in COMPRESSORS:
        cfg = FedNLConfig(compressor=comp, lam=1e-3)
        res = run_fednl(z, cfg, rounds=ROUNDS, seed=0)
        traces[comp] = {
            "grad_norms_hex": [float(g).hex() for g in res.grad_norms],
            "sent_bits": [int(b) for b in res.sent_bits],
        }
    payload = {
        "problem": "synthetic tiny seed=1, partition(8, 40) seed=1, "
                   "FedNLConfig(lam=1e-3) seed=0",
        "rounds": ROUNDS,
        "traces": traces,
    }
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
