"""CI gate for repro.obs: parity, disabled default, overhead sanity, export.

    PYTHONPATH=src python scripts/smoke_obs.py [--skip-net]

Asserts the §15 observability contract end to end:

* the process default is the no-op recorder (``obs.CURRENT is obs.NULL``);
* **bit parity, engine path**: with a live recorder installed, an
  engine-served fleet (spill churn included, ``max_resident=2``) is
  bit-identical to solo ``open_session(spec).run()`` references taken
  with the recorder off — and the recorder actually saw the run
  (``engine.tick`` spans, admission counters, queue-wait samples);
* **bit parity, gateway path**: the same bar over localhost TCP through a
  ``GatewayServer`` whose process recorder is enabled, plus the METRICS
  RPC verb returning the live snapshot and the per-verb RPC histograms
  (``--skip-net`` skips this phase for socketless environments);
* **overhead sanity**: obs-on vs obs-off fleet wall time on a warm engine
  stays under a loose 1.5x bound — the real ≤3% bar lives in
  ``benchmarks/obs_bench.py`` / BENCH_obs.json where repeated
  measurement makes it stable, this gate only catches a catastrophic
  regression (an allocation or sync smuggled into the hot path);
* **export sanity**: Prometheus text renders every series, and the span
  ring round-trips through JSONL losslessly.

Exits nonzero on any failure.
"""

from __future__ import annotations

import sys
import tempfile
import threading
import time


def _hex_traj(report):
    return (
        [float(r.grad_norm).hex() for r in report.records],
        [r.sent_bits for r in report.records],
    )


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--skip-net", action="store_true",
                    help="skip the localhost-TCP gateway phase")
    args = ap.parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro import obs
    from repro.api import CompressorSpec, DataSpec, ExperimentSpec, open_session
    from repro.serve_fednl import FedNLServer, ServeConfig

    failures: list[str] = []
    shape = (12, 4, 20)

    def spec_of(seed, comp, rounds):
        return ExperimentSpec(
            data=DataSpec(shape=shape, seed=1),
            compressor=CompressorSpec(comp, 8.0),
            rounds=rounds,
            seed=seed,
        )

    specs = [
        spec_of(0, "topk", 6),
        spec_of(1, "randk", 7),
        spec_of(2, "randseqk", 5),
        spec_of(3, "identity", 6),
    ]

    # --- phase 0: the disabled default -------------------------------------
    if obs.core.CURRENT is not obs.NULL:
        failures.append("process default recorder is not obs.NULL")
    if obs.NULL.enabled:
        failures.append("NullRecorder.enabled must be False")
    if obs.bucket_index(1.0) != 31 or obs.bucket_le(31) != 2.0:
        failures.append("histogram bucket geometry drifted from the §15 pin")

    # --- solo references, recorder off -------------------------------------
    z = specs[0].data.build()
    solos = []
    for spec in specs:
        with open_session(spec, z=z) as s:
            solos.append(s.run())

    # --- phase 1: engine-served parity, recorder ON ------------------------
    rec = obs.enable(span_capacity=4096)
    try:
        with FedNLServer(
            ServeConfig(max_resident=2, admit_per_tick=4)
        ) as srv:
            handles = [srv.submit(spec) for spec in specs]
            srv.serve_until_idle()
            stats = srv.stats()
            for spec, h, want in zip(specs, handles, solos):
                got = h.result()
                label = f"{spec.compressor.name}/r{spec.rounds}"
                if _hex_traj(got) != _hex_traj(want):
                    failures.append(f"{label}: obs-on served trajectory "
                                    "diverged from obs-off solo")
                if not np.array_equal(got.x, want.x):
                    failures.append(f"{label}: final iterate diverged")
        if stats["spills"] == 0:
            failures.append("spill churn not exercised under max_resident=2")
        ticks = rec.spans("engine.tick")
        if not ticks:
            failures.append("no engine.tick spans recorded")
        elif not any(s.labels.get("compiles", 0) > 0 for s in ticks):
            failures.append("no tick span carries a compile delta")
        if not rec.value("engine.admissions", cls="normal"):
            failures.append("engine.admissions{cls=normal} never incremented")
        qw = rec.hists("engine.queue.wait_s")
        if not qw or sum(h.count for h in qw) == 0:
            failures.append("engine.queue.wait_s histogram is empty")

        # --- export sanity on the populated recorder -----------------------
        text = obs.export.prometheus_text(rec)
        if "engine_tick_bucket{" not in text or "_total" not in text:
            failures.append("prometheus export missing expected series")
        with tempfile.NamedTemporaryFile(suffix=".jsonl") as f:
            rec.dump_spans_jsonl(f.name)
            back = obs.load_spans_jsonl(f.name)
        if back != rec.spans():
            failures.append("span JSONL round-trip is lossy")
    finally:
        obs.disable()

    # --- phase 2: overhead sanity on a warm engine -------------------------
    def fleet_wall(srv) -> float:
        t0 = time.perf_counter()
        hs = [srv.submit(spec) for spec in specs]
        srv.serve_until_idle()
        for h in hs:
            h.result()
        return time.perf_counter() - t0

    with FedNLServer(ServeConfig(max_resident=4, admit_per_tick=4)) as srv:
        fleet_wall(srv)  # warm-up: compiles land here
        off = min(fleet_wall(srv) for _ in range(2))
        obs.enable()
        try:
            on = min(fleet_wall(srv) for _ in range(2))
        finally:
            obs.disable()
    if on > off * 1.5:
        failures.append(
            f"obs-on fleet took {on:.3f}s vs {off:.3f}s off — catastrophic "
            "overhead (loose 1.5x sanity bound; the 3% bar is BENCH_obs)"
        )

    # --- phase 3: gateway-served parity + METRICS verb over TCP ------------
    if not args.skip_net:
        from repro.gateway import GatewayClient, GatewayConfig, GatewayServer

        rec = obs.enable(span_capacity=4096)
        try:
            server = GatewayServer(
                GatewayConfig(
                    port=0,
                    serve=ServeConfig(max_resident=2, admit_per_tick=4),
                )
            )
            ready = threading.Event()
            addr = {}

            def announce(host, port):
                addr["host"], addr["port"] = host, port
                ready.set()

            thread = threading.Thread(
                target=server.run, kwargs={"ready": announce}, daemon=True
            )
            thread.start()
            if not ready.wait(60):
                failures.append("gateway did not bind within 60s")
            else:
                with GatewayClient(addr["host"], addr["port"]) as gwc:
                    hs = [gwc.submit(spec) for spec in specs[:2]]
                    reports = [gwc.result(h.id) for h in hs]
                    snap = gwc.metrics()
                    prom = gwc.metrics(format="prometheus")
                for got, want in zip(reports, solos[:2]):
                    if _hex_traj(got) != _hex_traj(want) or not np.array_equal(
                        got.x, want.x
                    ):
                        failures.append(
                            "gateway-served (obs on) diverged from obs-off solo"
                        )
                if not snap.get("enabled"):
                    failures.append("METRICS verb says recorder disabled")
                else:
                    m = snap["metrics"]
                    if not any(
                        k.startswith("gateway.rpc.s") for k in m["histograms"]
                    ):
                        failures.append("no gateway.rpc.s histograms in METRICS")
                    if "gateway.tick.s" not in m["histograms"]:
                        failures.append("no gateway.tick.s histogram in METRICS")
                if "engine_tick" not in prom.get("prometheus", ""):
                    failures.append("prometheus format missing engine_tick")
                server.request_stop()
                thread.join(30)
        finally:
            obs.disable()

    if obs.core.CURRENT is not obs.NULL:
        failures.append("recorder not restored to NULL after the smoke")

    if failures:
        print("smoke_obs FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    net = "skipped" if args.skip_net else "included"
    print(
        "smoke_obs OK: obs-on engine-served == obs-off solo bit-for-bit "
        f"(spill churn included), gateway phase {net}, overhead within the "
        "sanity bound, exports render and round-trip"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
