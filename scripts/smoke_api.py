"""CI gate: every registered algorithm x backend pair solves a 3-round spec.

    PYTHONPATH=src python scripts/smoke_api.py [--skip-tcp]

Walks the repro.api registries (so newly registered algorithms/backends are
covered automatically), runs a 3-round solve() on a small synthetic problem
for every pair the backend supports, and asserts the pair either completes
with a well-formed RunReport or is *declared* unsupported — a pair that is
reachable but crashes fails the gate.  Exits non-zero on any failure.
"""

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    get_algorithm,
    get_backend,
    list_algorithms,
    list_backends,
    solve,
)

SHAPE = (12, 4, 20)  # d, n_clients, n_i — 4 clients keeps TCP spawn cheap


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-tcp", action="store_true",
                    help="skip star-tcp pairs (no-socket environments)")
    args = ap.parse_args()

    failures = 0
    for algo_name in list_algorithms():
        algo = get_algorithm(algo_name)
        for backend_name in list_backends():
            if args.skip_tcp and backend_name == "star-tcp":
                continue
            backend = get_backend(backend_name)
            pair = f"{algo_name:9s} x {backend_name:13s}"
            if not backend.supports(algo):
                print(f"{pair} declared-unsupported (ok)")
                continue
            spec = ExperimentSpec(
                algorithm=algo_name,
                data=DataSpec(shape=SHAPE, seed=1),
                compressor=CompressorSpec("topk"),
                backend=backend_name,
                rounds=3,
                seed=0,
                tau=2 if algo.kind == "pp" else None,
            )
            try:
                rep = solve(spec)
                assert rep.rounds == 3, f"expected 3 rounds, got {rep.rounds}"
                assert len(rep.records) == 3
                assert all(r.sent_bits > 0 for r in rep.records)
                gn = (rep.records[-1].grad_norm if algo.kind == "full"
                      else rep.final_grad_norm)
                print(f"{pair} ok  gn={gn:.2e} "
                      f"bits/round={rep.records[-1].sent_bits}")
            except Exception as e:  # noqa: BLE001 — report per-pair
                failures += 1
                print(f"{pair} FAIL {type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
