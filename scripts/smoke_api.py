"""CI gate: every registered algorithm x backend pair solves a 3-round spec,
a solve_many sweep reproduces sequential solve() bit-for-bit, and the
Session API's step composability holds (step 2 + step 3 == solve 5).

    PYTHONPATH=src python scripts/smoke_api.py [--skip-tcp]

Walks the repro.api registries (so newly registered algorithms/backends are
covered automatically), runs a 3-round solve() on a small synthetic problem
for every pair the backend supports, and asserts the pair either completes
with a well-formed RunReport or is *declared* unsupported — a pair that is
reachable but crashes fails the gate.  Then runs a socket-free 2x2
seed x compressor grid through ``solve_many`` on the local backend and
asserts per-spec bit-parity with sequential ``solve()`` (the sweep engine's
core contract).  Finally steps a 5-round spec as 2 + 3 through
``open_session`` on every session-capable socket-free backend, round-trips a
mid-run checkpoint, and asserts bit parity against ``solve()`` (the
DESIGN.md §10 numerics contract).  Exits non-zero on any failure.

NOTE the per-pair loop and the sweep parity reference below deliberately
call solve() sequentially — each pair must fail in isolation, and the
parity check needs the non-batched trajectories; this file is allowlisted
in scripts/check_api_migration.py's sequential-sweep-loop rule.
"""

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    get_algorithm,
    get_backend,
    list_algorithms,
    list_backends,
    open_session,
    solve,
    solve_many,
)

SHAPE = (12, 4, 20)  # d, n_clients, n_i — 4 clients keeps TCP spawn cheap


def sweep_smoke() -> int:
    """Tier-1 sweep gate: 2x2 grid via solve_many == sequential solve()."""
    base = ExperimentSpec(data=DataSpec(shape=SHAPE, seed=1), rounds=3)
    sweep = base.grid(seed=[0, 1], compressor=["topk", "randseqk"])
    rep = solve_many(sweep)
    failures = 0
    if rep.extras["batched_specs"] != 4:
        failures += 1
        print(f"sweep smoke FAIL: expected 4 batched specs, got "
              f"{rep.extras['batched_specs']} (log: {rep.log})")
    for i, spec in enumerate(sweep.specs()):
        ref = solve(spec)
        got, want = rep.reports[i], ref
        same = (
            [g.hex() for g in got.grad_norms] == [g.hex() for g in want.grad_norms]
            and bool((got.x == want.x).all())
            and list(got.sent_bits) == list(want.sent_bits)
        )
        if not same:
            failures += 1
            print(f"sweep smoke FAIL: spec[{i}] "
                  f"(seed={spec.seed}, comp={spec.compressor.name}) drifted "
                  f"from sequential solve()")
    if not failures:
        print(f"sweep smoke ok: {len(rep.reports)} specs bit-identical to "
              f"sequential solve() ({rep.summary()})")
    return failures


def session_smoke() -> int:
    """Tier-1 session gate: step(2)+step(3) == solve(rounds=5) bit-for-bit,
    and a mid-run save -> restore continues identically, on every
    session-capable socket-free backend x algorithm kind."""
    import tempfile
    from pathlib import Path

    tmp = Path(tempfile.mkdtemp())
    cases = [
        ("fednl", "local"),
        ("fednl-pp", "local"),
        ("fednl", "sharded"),
        ("fednl", "star-loopback"),
        ("fednl-pp", "star-loopback"),
    ]
    failures = 0
    for algo_name, backend_name in cases:
        tag = f"session {algo_name:9s} x {backend_name:13s}"
        spec = ExperimentSpec(
            algorithm=algo_name,
            data=DataSpec(shape=SHAPE, seed=1),
            backend=backend_name,
            rounds=5,
            seed=0,
            tau=2 if get_algorithm(algo_name).kind == "pp" else None,
        )
        try:
            want = solve(spec)
            with open_session(spec) as s:
                s.step(2)
                ck = tmp / f"{algo_name}-{backend_name}.fnlsess"
                s.save(ck)
                s.step(3)
                stepped = s.report()
            with open_session(spec, restore=ck) as s:
                resumed = s.run()
            for got, label in ((stepped, "step(2)+step(3)"),
                               (resumed, "save@2 -> restore -> run")):
                same = (
                    got.rounds == want.rounds
                    and bool((got.x == want.x).all())
                    and list(got.sent_bits) == list(want.sent_bits)
                    and all(
                        (g.grad_norm is None and w.grad_norm is None)
                        or float(g.grad_norm).hex() == float(w.grad_norm).hex()
                        for g, w in zip(got.records, want.records)
                    )
                )
                if not same:
                    failures += 1
                    print(f"{tag} FAIL: {label} drifted from solve(5)")
        except Exception as e:  # noqa: BLE001 — report per-pair
            failures += 1
            print(f"{tag} FAIL {type(e).__name__}: {e}")
            continue
        print(f"{tag} ok  (2+3 == 5; checkpoint round-trip)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-tcp", action="store_true",
                    help="skip star-tcp pairs (no-socket environments)")
    args = ap.parse_args()

    failures = 0
    for algo_name in list_algorithms():
        algo = get_algorithm(algo_name)
        for backend_name in list_backends():
            if args.skip_tcp and backend_name == "star-tcp":
                continue
            backend = get_backend(backend_name)
            pair = f"{algo_name:9s} x {backend_name:13s}"
            if not backend.supports(algo):
                print(f"{pair} declared-unsupported (ok)")
                continue
            spec = ExperimentSpec(
                algorithm=algo_name,
                data=DataSpec(shape=SHAPE, seed=1),
                compressor=CompressorSpec("topk"),
                backend=backend_name,
                rounds=3,
                seed=0,
                tau=2 if algo.kind == "pp" else None,
            )
            try:
                rep = solve(spec)
                assert rep.rounds == 3, f"expected 3 rounds, got {rep.rounds}"
                assert len(rep.records) == 3
                assert all(r.sent_bits > 0 for r in rep.records)
                gn = (rep.records[-1].grad_norm if algo.kind == "full"
                      else rep.final_grad_norm)
                print(f"{pair} ok  gn={gn:.2e} "
                      f"bits/round={rep.records[-1].sent_bits}")
            except Exception as e:  # noqa: BLE001 — report per-pair
                failures += 1
                print(f"{pair} FAIL {type(e).__name__}: {e}")
    try:
        failures += sweep_smoke()
    except Exception as e:  # noqa: BLE001 — the gate must report, not crash
        failures += 1
        print(f"sweep smoke FAIL {type(e).__name__}: {e}")
    try:
        failures += session_smoke()
    except Exception as e:  # noqa: BLE001 — the gate must report, not crash
        failures += 1
        print(f"session smoke FAIL {type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
