"""obs_top — a curses-free live console view of a running gateway.

    PYTHONPATH=src python scripts/gateway_serve.py --port 9970 --obs &
    PYTHONPATH=src python scripts/obs_top.py --port 9970

Polls the gateway's METRICS verb (plus the engine STATUS stats) every
``--interval`` seconds and redraws a compact dashboard with plain ANSI
escapes — no curses, works in any dumb terminal and under ``watch``.
``--once`` prints a single frame and exits (scripting / CI); ``--prom``
dumps the Prometheus text exposition instead of the table.
"""

from __future__ import annotations

import argparse
import sys
import time


def render(status: dict, metrics_reply: dict, width: int = 78) -> str:
    from repro.obs.export import render_snapshot

    lines = []
    lines.append("FedNL gateway — obs_top")
    lines.append("=" * width)
    lines.append(
        "engine: tick {ticks}  tenants {tenants}  finished {finished}  "
        "failed {failed}  queued {queued}  spills {spills}".format(
            ticks=status.get("ticks", 0),
            tenants=status.get("tenants", 0),
            finished=status.get("finished", 0),
            failed=status.get("failed", 0),
            queued=status.get("queued", 0),
            spills=status.get("spills", 0),
        )
    )
    backlog = status.get("backlog", {})
    if backlog:
        lines.append(
            "backlog: "
            + "  ".join(f"{cls}={n}" for cls, n in sorted(backlog.items()))
        )
    occ = status.get("batch_occupancy")
    lines.append(
        f"batch: launches {status.get('batch_launches', 0)}  "
        f"occupancy {occ if occ is not None else '-'}  "
        f"compiles {status.get('compiles', 0)}  "
        f"connections {status.get('connections', 0)}  "
        f"subscriptions {status.get('subscriptions', 0)}"
    )
    lines.append("-" * width)
    if not metrics_reply.get("enabled", False):
        lines.append(
            "recorder disabled — restart the gateway with --obs to see "
            "metrics"
        )
    else:
        lines.append(render_snapshot(metrics_reply["metrics"], width=width))
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh period in seconds")
    ap.add_argument("--once", action="store_true",
                    help="print one frame and exit (no screen clearing)")
    ap.add_argument("--prom", action="store_true",
                    help="dump the Prometheus text exposition instead")
    args = ap.parse_args(argv)

    from repro.gateway import GatewayClient

    with GatewayClient(args.host, args.port) as gwc:
        while True:
            if args.prom:
                reply = gwc.metrics(format="prometheus")
                frame = reply.get(
                    "prometheus", "# recorder disabled (gateway without --obs)\n"
                )
            else:
                frame = render(gwc.status(), gwc.metrics())
            if args.once or args.prom:
                sys.stdout.write(frame)
                return 0
            # ANSI: home + clear-to-end — flicker-free enough without curses
            sys.stdout.write("\x1b[H\x1b[2J" + frame)
            sys.stdout.flush()
            time.sleep(args.interval)


if __name__ == "__main__":
    sys.exit(main())
