"""Launch a FedNL gateway: the serving engine behind a TCP socket.

    PYTHONPATH=src python scripts/gateway_serve.py --port 9970

Prints ``LISTENING <host> <port>`` on stdout once the socket is bound (an
ephemeral ``--port 0`` is how tests and benchmarks discover the port), then
serves until SIGINT/SIGTERM.  ``--spill-dir`` makes checkpoints survive the
process — a killed gateway's tenants resume bit-identically from their
FNLS1 spills (tests/test_gateway.py pins this).
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=9970,
                    help="TCP port (0 = ephemeral, announced on stdout)")
    ap.add_argument("--max-resident", type=int, default=16)
    ap.add_argument("--admit-per-tick", type=int, default=8)
    ap.add_argument("--eviction", default="lru", choices=("lru", "cost"))
    ap.add_argument("--spill-dir", default=None,
                    help="checkpoint dir (default: private tmp, removed at "
                         "shutdown; set one to survive a kill)")
    ap.add_argument("--priorities", default=None,
                    help='JSON class->weight map, e.g. '
                         '\'{"high": 4, "normal": 2, "low": 1}\'')
    ap.add_argument("--quantum", type=float, default=1.0)
    ap.add_argument("--stream-queue", type=int, default=256,
                    help="bounded per-observer record queue (drop-oldest)")
    ap.add_argument("--obs", action="store_true",
                    help="enable the repro.obs recorder (serve live metrics "
                         "over the METRICS verb — watch with "
                         "scripts/obs_top.py)")
    ap.add_argument("--obs-spans", type=int, default=8192,
                    help="span ring-buffer capacity when --obs is set")
    args = ap.parse_args(argv)

    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.gateway import GatewayConfig, GatewayServer
    from repro.serve_fednl import DEFAULT_PRIORITIES, ServeConfig

    if args.obs:
        from repro import obs

        obs.enable(span_capacity=args.obs_spans)

    priorities = (
        {k: float(v) for k, v in json.loads(args.priorities).items()}
        if args.priorities
        else dict(DEFAULT_PRIORITIES)
    )
    cfg = GatewayConfig(
        host=args.host,
        port=args.port,
        stream_queue=args.stream_queue,
        serve=ServeConfig(
            max_resident=args.max_resident,
            admit_per_tick=args.admit_per_tick,
            eviction=args.eviction,
            spill_dir=args.spill_dir,
            priorities=priorities,
            quantum=args.quantum,
        ),
    )

    def announce(host, port):
        print(f"LISTENING {host} {port}", flush=True)

    try:
        GatewayServer(cfg).run(ready=announce)
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
