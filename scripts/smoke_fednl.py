"""Single-node FedNL smoke: every compressor through the one solve() facade.

    PYTHONPATH=src python scripts/smoke_fednl.py
"""

import jax

jax.config.update("jax_enable_x64", True)

from repro.api import CompressorSpec, DataSpec, ExperimentSpec, solve
from repro.core import newton_baseline

spec = ExperimentSpec(
    data=DataSpec(dataset="tiny", seed=1),
    rounds=60,
    tol=1e-14,
    seed=0,
)
z = spec.data.build()
print("z", z.shape, z.dtype)

for comp in ["identity", "topk", "randk", "randseqk", "toplek", "natural"]:
    rep = solve(spec.replace(compressor=CompressorSpec(comp)), z=z)
    print(f"{comp:10s} rounds={rep.rounds:3d} gn={rep.grad_norms[-1]:.3e} "
          f"f={rep.f_vals[-1]:.8f} wall={rep.wall_time_s:.2f}s init={rep.init_time_s:.2f}s")

nb = newton_baseline(z, 1e-3)
print(f"newton     rounds={nb.rounds} gn={nb.grad_norms[-1]:.3e} f={nb.f_vals[-1]:.8f}")
