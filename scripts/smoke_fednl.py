import sys, time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.data import make_synthetic_logreg, add_intercept, partition_clients
from repro.core import FedNLConfig, run_fednl, newton_baseline

x, y = make_synthetic_logreg("tiny", seed=1)
z = jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=1))
print("z", z.shape, z.dtype)

for comp in ["identity", "topk", "randk", "randseqk", "toplek", "natural"]:
    cfg = FedNLConfig(compressor=comp, lam=1e-3, option="B")
    res = run_fednl(z, cfg, rounds=60, tol=1e-14)
    print(f"{comp:10s} rounds={res.rounds:3d} gn={res.grad_norms[-1]:.3e} "
          f"f={res.f_vals[-1]:.8f} wall={res.wall_time_s:.2f}s init={res.init_time_s:.2f}s")

nb = newton_baseline(z, 1e-3)
print(f"newton     rounds={nb.rounds} gn={nb.grad_norms[-1]:.3e} f={nb.f_vals[-1]:.8f}")
