"""Single-node FedNL smoke: every compressor through one solve_many() sweep.

    PYTHONPATH=src python scripts/smoke_fednl.py

(tol-based early stopping needs a per-round host sync, so the engine runs
these specs per spec — the log shows the fallback decisions.)
"""

import jax

jax.config.update("jax_enable_x64", True)

from repro.api import DataSpec, ExperimentSpec, solve_many
from repro.core import newton_baseline

spec = ExperimentSpec(
    data=DataSpec(dataset="tiny", seed=1),
    rounds=60,
    tol=1e-14,
    seed=0,
)
z = spec.data.build()
print("z", z.shape, z.dtype)

sweep = spec.grid(
    compressor=["identity", "topk", "randk", "randseqk", "toplek", "natural"]
)
srep = solve_many(sweep)
for s, rep in zip(srep.specs, srep.reports):
    print(f"{s.compressor.name:10s} rounds={rep.rounds:3d} "
          f"gn={rep.grad_norms[-1]:.3e} f={rep.f_vals[-1]:.8f} "
          f"wall={rep.wall_time_s:.2f}s init={rep.init_time_s:.2f}s")
print(srep.summary())

nb = newton_baseline(z, 1e-3)
print(f"newton     rounds={nb.rounds} gn={nb.grad_norms[-1]:.3e} f={nb.f_vals[-1]:.8f}")
