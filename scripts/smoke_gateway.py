"""CI gate for the gateway: localhost TCP, mixed priorities, bit parity.

    PYTHONPATH=src python scripts/smoke_gateway.py

Starts an in-process :class:`~repro.gateway.GatewayServer` on an ephemeral
localhost port, submits three tenants at three priority classes over real
TCP (one per class, different compressors/budgets), streams one tenant's
records while it runs, fetches all three RunReports, and asserts the §14
bar end to end:

* every gateway-served trajectory (streamed records AND report records)
  is bit-identical to a solo ``open_session(spec).run()``;
* spill churn happened (``max_resident=1`` forces it), proving the bit
  bar holds across checkpoint round-trips observed over the network;
* per-class admission counters are populated for every class.

Exits nonzero on any mismatch.
"""

from __future__ import annotations

import sys
import threading


def main() -> int:
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.api import CompressorSpec, DataSpec, ExperimentSpec, open_session
    from repro.gateway import GatewayClient, GatewayConfig, GatewayServer
    from repro.serve_fednl import ServeConfig

    shape = (12, 4, 20)

    def spec_of(seed, comp, rounds):
        return ExperimentSpec(
            data=DataSpec(shape=shape, seed=1),
            algorithm="fednl",
            compressor=CompressorSpec(comp, 8.0),
            rounds=rounds,
            seed=seed,
        )

    jobs = [  # (priority, spec)
        ("high", spec_of(0, "topk", 6)),
        ("normal", spec_of(1, "randk", 5)),
        ("low", spec_of(2, "randseqk", 7)),
    ]

    server = GatewayServer(
        GatewayConfig(
            port=0,
            serve=ServeConfig(max_resident=1, admit_per_tick=2),
        )
    )
    ready = threading.Event()
    addr = {}

    def announce(host, port):
        addr["host"], addr["port"] = host, port
        ready.set()

    thread = threading.Thread(
        target=server.run, kwargs={"ready": announce}, daemon=True
    )
    thread.start()
    if not ready.wait(60):
        print("smoke_gateway FAILED: gateway did not bind within 60s")
        return 1

    failures = []
    with GatewayClient(addr["host"], addr["port"]) as gwc:
        handles = [
            gwc.submit(spec, priority=prio) for prio, spec in jobs
        ]
        # stream the low-priority tenant on a second connection while the
        # submitting connection collects results
        streamed = {}

        def observe(tid):
            with GatewayClient(addr["host"], addr["port"]) as obs:
                streamed[tid] = list(obs.stream(tid))

        obs_thread = threading.Thread(
            target=observe, args=(handles[2].id,), daemon=True
        )
        obs_thread.start()
        reports = [gwc.result(h.id) for h in handles]
        obs_thread.join(120)
        stats = gwc.status()

    for (prio, spec), h, rep in zip(jobs, handles, reports):
        with open_session(spec) as s:
            want = s.run()
        label = f"{prio}/{spec.compressor.name}/r{spec.rounds}"
        served = [float(r.grad_norm).hex() for r in rep.records]
        solo = [float(r.grad_norm).hex() for r in want.records]
        if served != solo:
            failures.append(f"{label}: report trajectory diverged")
        if [r.sent_bits for r in rep.records] != [
            r.sent_bits for r in want.records
        ]:
            failures.append(f"{label}: bit accounting diverged")
        if not np.array_equal(rep.x, want.x):
            failures.append(f"{label}: final iterate diverged")
        if h.id in streamed:
            got = [float(r.grad_norm).hex() for r in streamed[h.id]]
            if got != solo:
                failures.append(
                    f"{label}: streamed records diverged from solo "
                    f"({len(got)} streamed vs {len(solo)} solo)"
                )

    if handles[2].id not in streamed:
        failures.append("observer thread never finished its stream")
    if stats["spills"] == 0:
        failures.append(
            "memory-pressure path not exercised (expected spills under "
            "max_resident=1)"
        )
    for cls in ("high", "normal", "low"):
        if stats["admissions_by_class"].get(cls, 0) == 0:
            failures.append(f"no admissions recorded for class {cls!r}")

    print(
        f"gateway served {len(jobs)} tenants over TCP: "
        f"{stats['spills']} spills, {stats['resumes']} resumes, "
        f"admissions by class {stats['admissions_by_class']}"
    )
    if failures:
        print("smoke_gateway FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(
        "smoke_gateway OK: gateway-served == solo bit-for-bit "
        "(3 priority classes, spill churn, remote stream included)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
