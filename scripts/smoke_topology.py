"""Fast loopback smoke test of the repro.comm topology layer (CI gate).

    PYTHONPATH=src python scripts/smoke_topology.py

Socket-free: everything runs over in-process loopback transports.  Gates the
two topology-layer contracts cheap enough for tier-1:

  * a depth-2 tree-of-stars (combine="exact") reproduces the flat star
    trajectory AND its measured wire accounting bit for bit;
  * a join+leave membership schedule converges, with the joined client's
    late-INIT uplink (T*64 payload bits) accounted into its round exactly.

Exits non-zero on any mismatch.
"""

import sys

import jax

jax.config.update("jax_enable_x64", True)
import numpy as np

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    MembershipEvent,
    MembershipSpec,
    TopologySpec,
    solve,
)

SHAPE = (12, 4, 20)  # (d, n_clients, n_i)
ROUNDS = 6

failures = 0

# --- depth-2 tree == flat star, bit for bit --------------------------------
for comp in ["topk", "randk", "natural"]:
    spec = ExperimentSpec(
        data=DataSpec(shape=SHAPE, seed=1),
        compressor=CompressorSpec(comp),
        rounds=ROUNDS,
        seed=0,
        backend="star-loopback",
    )
    star = solve(spec)
    tree = solve(spec.replace(topology=TopologySpec(kind="tree", fanout=2, depth=2)))
    x_ok = bool(np.array_equal(star.x, tree.x))
    gn_ok = all(
        float(a.grad_norm).hex() == float(b.grad_norm).hex()
        for a, b in zip(star.records, tree.records)
    )
    bits_ok = bool(
        np.array_equal(
            star.extras["measured_payload_bits"],
            tree.extras["measured_payload_bits"],
        )
        and np.array_equal(
            star.extras["measured_frame_bytes"],
            tree.extras["measured_frame_bytes"],
        )
    )
    ok = x_ok and gn_ok and bits_ok
    print(f"tree  {comp:8s} {'ok' if ok else 'FAIL'}  x_bitwise={x_ok} "
          f"gn_bitwise={gn_ok} measured_bits={bits_ok} "
          f"gn={tree.grad_norms[-1]:.1e}")
    failures += not ok

# --- one join + one leave on the elastic star ------------------------------
d, n, n_i = (10, 8, 16)
mem = MembershipSpec(
    events=(
        MembershipEvent(round=2, action="join", client=7),
        MembershipEvent(round=4, action="leave", client=0),
    )
)
spec = ExperimentSpec(
    data=DataSpec(shape=(d, n, n_i), seed=1),
    rounds=10,
    seed=0,
    backend="star-loopback",
    membership=mem,
)
rep = solve(spec)
t_bits = d * (d + 1) // 2 * 64
join_extra = (
    rep.records[2].sent_bits_payload
    - rep.records[1].sent_bits_payload
    - rep.records[1].sent_bits_payload // 7  # one more regular uplink
)
conv_ok = rep.grad_norms[-1] < 1e-6
cohort_ok = (
    rep.records[1].participants == tuple(range(7))
    and rep.records[2].participants == tuple(range(8))
    and rep.records[4].participants == tuple(range(1, 8))
)
bits_ok = join_extra == t_bits
ok = conv_ok and cohort_ok and bits_ok
print(f"elastic join+leave {'ok' if ok else 'FAIL'}  "
      f"gn={rep.grad_norms[-1]:.1e} cohort={cohort_ok} "
      f"join_ack_bits={join_extra} (=T*64: {bits_ok})")
failures += not ok

sys.exit(1 if failures else 0)
