"""Fast loopback smoke test of the repro.comm star subsystem (CI gate).

    PYTHONPATH=src python scripts/smoke_comm.py

Runs every compressor's full encode -> frame -> decode star round trip over
the in-process loopback transport on the tiny problem, asserting (a) the
trajectory matches the single-node simulation and (b) measured wire bits
equal the analytic message_bits model.  Exits non-zero on any mismatch.
"""

import sys

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.comm.cost import DEFAULT_COST
from repro.comm.star import run_loopback
from repro.core import FedNLConfig, run_fednl
from repro.data import add_intercept, make_synthetic_logreg, partition_clients

ROUNDS = 8

x, y = make_synthetic_logreg("tiny", seed=1)
z = jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=1))
n, _, d = z.shape

failures = 0
for comp in ["identity", "topk", "randk", "randseqk", "toplek", "natural"]:
    cfg = FedNLConfig(compressor=comp, lam=1e-3)
    ref = run_fednl(z, cfg, rounds=ROUNDS, seed=0)
    lb = run_loopback(z, cfg, rounds=ROUNDS, seed=0)
    dx = float(np.max(np.abs(lb.x - ref.x)))
    bits_ok = bool((lb.measured_payload_bits == lb.sent_bits).all())
    traj_ok = dx <= 1e-8
    comm_ms = DEFAULT_COST.round_s(float(lb.measured_payload_bits[-1]), d * 64, n) * 1e3
    status = "ok" if (bits_ok and traj_ok) else "FAIL"
    print(f"{comp:9s} {status}  max|dx|={dx:.1e} gn={lb.grad_norms[-1]:.1e} "
          f"payload_bits/round={int(lb.measured_payload_bits[-1])} "
          f"(=analytic: {bits_ok}) cost_model={comm_ms:.2f}ms/round")
    failures += not (bits_ok and traj_ok)

sys.exit(1 if failures else 0)
