"""CI gate: user-reachable entry points must go through repro.api.solve,
and sweeps must go through repro.api.solve_many.

    python scripts/check_api_migration.py

Rule 1 greps the user-facing layers (examples/, scripts/, benchmarks/, the
launch CLIs) for direct calls to the legacy per-variant drivers.  Those
drivers still exist — the api backends wrap them, repro.core stays the
independent bit-parity reference, and tests may exercise them deliberately —
but an *entry point* hand-building a legacy driver call is a regression to
the pre-facade world (a new scenario would again mean a new driver), so it
fails CI.  Allowlisted call sites are the wrapping layers themselves.

Rule 2 flags sequential sweep loops — a ``solve(`` call inside a ``for``
body in benchmarks/ or scripts/.  Looping solve() pays a fresh trace/compile
and a device round-trip per spec; that is exactly what ``solve_many`` (one
compiled program per batch group) exists to replace, so new sweep loops in
the measurement layers fail CI.

Rule 3 flags direct ``<backend>.run(...)`` / ``<backend>.open(...)`` calls
outside ``repro.api``.  The Backend strategy protocol is the facade's
internal seam: entry points that grab a backend object and drive it by hand
bypass spec validation, capability checks and the Session bookkeeping — use
``solve(spec)`` or ``open_session(spec)`` instead.

Rule 4 flags hand-rolled session polling loops — a ``.step(`` call inside a
``for``/``while`` body in benchmarks/ or scripts/.  Driving many sessions
round-by-round by hand is the serving engine's job: ``repro.serve_fednl``
multiplexes concurrent sessions through shared batched round kernels with
spill/resume under memory pressure, bit-identically.  New polling loops in
the measurement/CI layers fail CI (single-session step-contract checks are
allowlisted with a reason).

Rule 5 flags direct ``hessian_syrk_pallas`` calls or imports outside
``src/repro/kernels/``.  The raw Pallas kernel has sharp edges the
``kernels.ops`` wrappers own: interpret-mode resolution (CPU CI would
crash compiling for a missing TPU), block-size padding, and the packed /
mirrored emission that keeps the fused round bit-identical to the jnp
reference.  Callers everywhere else go through ``ops.hessian_syrk`` /
``ops.hessian_syrk_packed`` / ``ops.hessian_fused`` so those policies
cannot be bypassed.

Rule 6 flags direct ``StarMaster(...)`` / ``AggregatorNode(...)``
construction outside ``src/repro/comm/``.  Which master class a spec needs
(plain / tree / async / elastic) and how aggregator subtrees are wired are
``repro.comm.topology`` policy — ``make_master`` / ``open_loopback_master``
/ ``build_aggregator`` are the sanctioned seams.  A call site hand-building
a master bypasses topology/membership dispatch and the SUBTREE coverage
handshake, so the run silently ignores those spec fields.

Rule 7 flags raw socket / FNL1-frame construction outside ``repro/comm``
and ``repro/gateway`` — ``socket.socket(`` / ``socket.create_connection(``
/ ``asyncio.start_server(`` / ``pack_frame(`` / ``unpack_header(`` /
``HEADER_FMT`` anywhere else.  Those two packages own the wire: framing
invariants (magic, header layout, exact-bit accounting) and connection
lifecycle (retry, NODELAY, shutdown) live behind ``send_frame`` /
``recv_frame`` / ``GatewayClient`` / the transport classes.  A script or
test hand-rolling a socket gets none of that and silently forks the
protocol (tests/test_comm.py is allowlisted: it pins the framing contract
itself).

Rule 8 flags raw ``time.perf_counter()`` / ``time.monotonic()`` calls in
the instrumented hot layers (``src/repro/serve_fednl``,
``src/repro/gateway``, ``src/repro/comm``).  Timing instrumentation there
goes through ``repro.obs`` (``obs.now()`` / ``obs.monotonic()`` plus
recorder counters/histograms/spans — DESIGN.md §15): one clock discipline,
one export surface, and no ad-hoc perf bookkeeping drifting away from what
the METRICS verb reports.  ``time.sleep`` and the obs package itself (which
owns the clock aliases) are out of scope.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# entry-point layers that must speak ExperimentSpec/solve() only
SCANNED = ["examples", "scripts", "benchmarks", "src/repro/launch"]

# legacy per-variant drivers (the api backends are their only sanctioned
# non-test callers; repro/ and tests/ are intentionally not scanned)
LEGACY_CALLS = [
    r"\brun_fednl\s*\(",
    r"\brun_fednl_pp\s*\(",
    r"\brun_loopback\s*\(",
    r"\brun_pp_loopback\s*\(",
    r"\brun_multiproc\s*\(",
    r"\brun_multiproc_pp\s*\(",
    r"\brun_star_master\s*\(",
    r"\bmake_fednl_round\s*\(",
    r"\bmake_fednl_ls_round\s*\(",
    r"\bmake_fednl_pp_round\s*\(",
    r"\bmake_sharded_fednl_round\s*\(",
]

# deliberate exceptions, each with a reason
ALLOWLIST = {
    # generates the reference pins the api parity suite is checked AGAINST —
    # it must keep using the independent legacy driver, not the facade
    "scripts/gen_golden_traces.py",
    # self-check of the comm layer against the independent reference driver
    "scripts/smoke_comm.py",
    # this checker's own pattern table
    "scripts/check_api_migration.py",
    # the TCP driver the star-tcp backend wraps: run_multiproc[_pp] live
    # here, and its master_fn closures call the star loops directly
    "src/repro/launch/multiproc.py",
    # the kernel benchmark/gate measure the raw round kernel itself (fused
    # vs jnp parity + timing below the facade) — the round kernel IS the
    # measurement subject, not an entry point hand-building a driver
    "benchmarks/kernels_bench.py",
    "scripts/smoke_kernels.py",
}

PATTERN = re.compile("|".join(LEGACY_CALLS))

# --- rule 2: sequential sweep loops ----------------------------------------

# layers whose sweeps must be declarative (examples may loop solve() for
# pedagogy; benchmarks and scripts are the measurement/CI surface)
SWEEP_SCANNED = ["benchmarks", "scripts"]

# solve( but not solve_many( and not a method call like facade.solve(
SOLVE_CALL = re.compile(r"(?<![\w.])solve\s*\(")
LOOP_HEADER = re.compile(r"^(\s*)(?:for|while)\b.*:")

SWEEP_ALLOWLIST = {
    # the registry smoke must run each algorithm x backend pair in isolation
    # (one pair failing must not abort the others), and the sweep smoke's
    # parity reference deliberately IS the sequential path
    "scripts/smoke_api.py",
    # star-vs-tree parity pairs on the star-loopback backend: each pair is
    # an A/B comparison of two topologies over full wire protocols — no
    # batch group can ever hold them, so solve_many buys nothing
    "benchmarks/topology_bench.py",
    "scripts/smoke_topology.py",
    # this checker's own pattern table
    "scripts/check_api_migration.py",
}


# --- rule 3: direct backend .run()/.open() calls outside repro.api ----------

# the facade seam: a receiver that *is* a backend — `get_backend(...).run(`,
# `some_backend.run(`, `STAR_TCP_BACKEND.open(` ... — driven by hand.  The
# name heuristic deliberately requires "backend" in the receiver so event-
# loop objects (client.run(), master.run(rounds)) stay out of scope.
BACKEND_DRIVE = re.compile(
    r"(?:\bget_backend\s*\([^)]*\)|\b\w*(?:backend|BACKEND)\w*)\s*\.\s*(?:run|open)\s*\("
)

# rule 3 scans the entry-point layers AND the library itself; only repro.api
# (the facade/session machinery the rule protects) is exempt
BACKEND_SCANNED = ["examples", "scripts", "benchmarks", "src/repro"]

BACKEND_ALLOWLIST = {
    # this checker's own pattern table
    "scripts/check_api_migration.py",
}


# --- rule 4: hand-rolled session polling loops ------------------------------

# a session stepped round-by-round inside a loop body; outside
# repro.serve_fednl that is a hand-rolled serving engine
STEP_CALL = re.compile(r"\.step\s*\(")

# same measurement/CI surface as rule 2
STEP_SCANNED = ["benchmarks", "scripts"]

STEP_ALLOWLIST = {
    # pins the DESIGN.md §10 step-composability contract itself:
    # step(2)+step(3) == run() per algorithm x backend pair
    "scripts/smoke_api.py",
    # measures the per-round session-stepping overhead deliberately — the
    # step loop IS the measurement subject, vs run()'s chunked path
    "benchmarks/tables.py",
    # this checker's own pattern table
    "scripts/check_api_migration.py",
}


# --- rule 5: raw Pallas SYRK kernel used outside the kernels package --------

# a call OR an import: `from repro.kernels.hessian_syrk import ...` smuggles
# the raw kernel past the ops-layer policies just as surely as calling it
KERNEL_RAW = re.compile(r"\bhessian_syrk_pallas\b|\brepro\.kernels\.hessian_syrk\b")

# everything but the kernels package itself (ops.py is the sanctioned wrapper)
KERNEL_SCANNED = ["examples", "scripts", "benchmarks", "src/repro", "tests"]

KERNEL_ALLOWLIST = {
    # this checker's own pattern table
    "scripts/check_api_migration.py",
}


# --- rule 6: masters/aggregators hand-built outside repro.comm --------------

# bare construction (subclass *definitions* like `class TreeMaster(StarMaster)`
# don't match: the class name is immediately followed by `)` there)
MASTER_RAW = re.compile(r"\b(?:StarMaster|AggregatorNode)\s*\(")

# everything but the comm package itself (topology.py owns the factories)
MASTER_SCANNED = ["examples", "scripts", "benchmarks", "src/repro", "tests"]

MASTER_ALLOWLIST = {
    # this checker's own pattern table
    "scripts/check_api_migration.py",
}


# --- rule 7: raw sockets / FNL1 frames outside repro.comm + repro.gateway ---

# hand-rolled wire plumbing: raw socket construction or direct use of the
# frame packing primitives (send_frame/recv_frame/GatewayClient are the
# sanctioned seams and do not match)
WIRE_RAW = re.compile(
    r"\bsocket\s*\.\s*(?:socket|create_connection)\s*\("
    r"|\basyncio\s*\.\s*start_server\s*\("
    r"|\bpack_frame\s*\(|\bunpack_header\s*\(|\bHEADER_FMT\b"
)

# the whole tree: entry points, library, and tests
WIRE_SCANNED = ["examples", "scripts", "benchmarks", "src/repro", "tests"]

WIRE_ALLOWLIST = {
    # pins the framing contract itself (header layout, magic rejection)
    "tests/test_comm.py",
    # this checker's own pattern table
    "scripts/check_api_migration.py",
}


# --- rule 8: raw clocks in the instrumented hot layers ----------------------

# raw perf_counter/monotonic calls; repro.obs owns the clock aliases
TIME_RAW = re.compile(r"\btime\s*\.\s*(?:perf_counter|monotonic)\s*\(")

# the layers whose timing is obs-instrumented (DESIGN.md §15)
TIME_SCANNED = [
    "src/repro/serve_fednl",
    "src/repro/gateway",
    "src/repro/comm",
]

TIME_ALLOWLIST: set[str] = set()


def is_wire_internal(rel: str) -> bool:
    return rel.startswith(("src/repro/comm/", "src/repro/gateway/"))


def is_comm_internal(rel: str) -> bool:
    return rel.startswith("src/repro/comm/")


def is_kernels_internal(rel: str) -> bool:
    return rel.startswith("src/repro/kernels/")


def is_api_internal(rel: str) -> bool:
    return rel.startswith("src/repro/api/")


def find_calls_in_loops(text: str, call: re.Pattern) -> list[tuple[int, str]]:
    """Line numbers of ``call`` matches lexically inside a ``for``/``while``
    body (indentation-scoped, good enough for the flat scripts we scan),
    plus comprehension/generator forms — ``[solve(s) for s in specs]`` is
    the same one-call-per-item loop in its most idiomatic spelling."""
    hits = []
    open_loops: list[int] = []  # indent depths of active loop blocks
    for lineno, line in enumerate(text.splitlines(), 1):
        stripped = line.strip()
        if not stripped or stripped.startswith("#"):
            continue
        indent = len(line) - len(line.lstrip())
        open_loops = [i for i in open_loops if indent > i]
        in_comprehension = (
            call.search(line) and re.search(r"\bfor\b", line)
        )
        if call.search(line) and (open_loops or in_comprehension):
            hits.append((lineno, stripped))
        m = LOOP_HEADER.match(line)
        if m:
            open_loops.append(len(m.group(1)))
    return hits


def main() -> int:
    bad: list[str] = []
    for layer in SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in ALLOWLIST:
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if PATTERN.search(line) and not line.lstrip().startswith("#"):
                    bad.append(f"{rel}:{lineno}: {line.strip()}")
    sweep_bad: list[str] = []
    for layer in SWEEP_SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in SWEEP_ALLOWLIST:
                continue
            for lineno, line in find_calls_in_loops(path.read_text(), SOLVE_CALL):
                sweep_bad.append(f"{rel}:{lineno}: {line}")
    backend_bad: list[str] = []
    for layer in BACKEND_SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in BACKEND_ALLOWLIST or is_api_internal(rel):
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if BACKEND_DRIVE.search(line) and not line.lstrip().startswith("#"):
                    backend_bad.append(f"{rel}:{lineno}: {line.strip()}")
    step_bad: list[str] = []
    for layer in STEP_SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in STEP_ALLOWLIST:
                continue
            for lineno, line in find_calls_in_loops(path.read_text(), STEP_CALL):
                step_bad.append(f"{rel}:{lineno}: {line}")
    kernel_bad: list[str] = []
    for layer in KERNEL_SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in KERNEL_ALLOWLIST or is_kernels_internal(rel):
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if KERNEL_RAW.search(line) and not line.lstrip().startswith("#"):
                    kernel_bad.append(f"{rel}:{lineno}: {line.strip()}")
    master_bad: list[str] = []
    for layer in MASTER_SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in MASTER_ALLOWLIST or is_comm_internal(rel):
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if MASTER_RAW.search(line) and not line.lstrip().startswith("#"):
                    master_bad.append(f"{rel}:{lineno}: {line.strip()}")
    wire_bad: list[str] = []
    for layer in WIRE_SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in WIRE_ALLOWLIST or is_wire_internal(rel):
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if WIRE_RAW.search(line) and not line.lstrip().startswith("#"):
                    wire_bad.append(f"{rel}:{lineno}: {line.strip()}")
    time_bad: list[str] = []
    for layer in TIME_SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in TIME_ALLOWLIST:
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if TIME_RAW.search(line) and not line.lstrip().startswith("#"):
                    time_bad.append(f"{rel}:{lineno}: {line.strip()}")
    if bad:
        print("legacy driver calls reachable outside the facade "
              "(migrate to repro.api.solve or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in bad))
    if sweep_bad:
        print("sequential sweep loops (one trace/compile per spec — migrate "
              "to repro.api.solve_many or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in sweep_bad))
    if backend_bad:
        print("direct backend .run()/.open() calls outside repro.api "
              "(bypasses spec validation/capability checks — use solve() / "
              "open_session(), or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in backend_bad))
    if step_bad:
        print("hand-rolled session polling loops (stepping sessions round-"
              "by-round in a loop — serve concurrent sessions through "
              "repro.serve_fednl.FedNLServer, or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in step_bad))
    if kernel_bad:
        print("raw hessian_syrk_pallas usage outside src/repro/kernels/ "
              "(bypasses interpret resolution, padding and packed emission "
              "— use kernels.ops.hessian_syrk / hessian_syrk_packed / "
              "hessian_fused, or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in kernel_bad))
    if master_bad:
        print("StarMaster/AggregatorNode hand-built outside src/repro/comm/ "
              "(bypasses topology/membership dispatch — use "
              "repro.comm.topology.make_master / open_loopback_master / "
              "build_aggregator, or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in master_bad))
    if wire_bad:
        print("raw socket/frame construction outside repro/comm + "
              "repro/gateway (hand-rolled wire plumbing forks the protocol "
              "— use send_frame/recv_frame over a transport Connection, or "
              "GatewayClient, or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in wire_bad))
    if time_bad:
        print("raw time.perf_counter()/time.monotonic() in the instrumented "
              "hot layers (timing there goes through repro.obs — use "
              "obs.now()/obs.monotonic() and recorder instruments, or "
              "allowlist with a reason):")
        print("\n".join(f"  {b}" for b in time_bad))
    if (bad or sweep_bad or backend_bad or step_bad or kernel_bad
            or master_bad or wire_bad or time_bad):
        return 1
    print(f"api migration clean: {', '.join(SCANNED)} go through solve(); "
          f"{', '.join(SWEEP_SCANNED)} sweep via solve_many(); no direct "
          "backend .run()/.open() outside repro.api; no hand-rolled "
          "session polling loops; raw hessian_syrk_pallas confined to "
          "src/repro/kernels/; masters/aggregators built only via the "
          "repro.comm.topology seams; raw sockets/frames confined to "
          "repro/comm + repro/gateway; raw clocks in the hot layers "
          "confined to repro.obs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
