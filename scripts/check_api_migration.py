"""CI gate: user-reachable entry points must go through repro.api.solve.

    python scripts/check_api_migration.py

Greps the user-facing layers (examples/, scripts/, benchmarks/, the launch
CLIs) for direct calls to the legacy per-variant drivers.  Those drivers
still exist — the api backends wrap them, repro.core stays the independent
bit-parity reference, and tests may exercise them deliberately — but an
*entry point* hand-building a legacy driver call is a regression to the
pre-facade world (a new scenario would again mean a new driver), so it
fails CI.  Allowlisted call sites are the wrapping layers themselves.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# entry-point layers that must speak ExperimentSpec/solve() only
SCANNED = ["examples", "scripts", "benchmarks", "src/repro/launch"]

# legacy per-variant drivers (the api backends are their only sanctioned
# non-test callers; repro/ and tests/ are intentionally not scanned)
LEGACY_CALLS = [
    r"\brun_fednl\s*\(",
    r"\brun_fednl_pp\s*\(",
    r"\brun_loopback\s*\(",
    r"\brun_pp_loopback\s*\(",
    r"\brun_multiproc\s*\(",
    r"\brun_multiproc_pp\s*\(",
    r"\brun_star_master\s*\(",
    r"\bmake_fednl_round\s*\(",
    r"\bmake_fednl_ls_round\s*\(",
    r"\bmake_fednl_pp_round\s*\(",
    r"\bmake_sharded_fednl_round\s*\(",
]

# deliberate exceptions, each with a reason
ALLOWLIST = {
    # generates the reference pins the api parity suite is checked AGAINST —
    # it must keep using the independent legacy driver, not the facade
    "scripts/gen_golden_traces.py",
    # self-check of the comm layer against the independent reference driver
    "scripts/smoke_comm.py",
    # this checker's own pattern table
    "scripts/check_api_migration.py",
    # the TCP driver the star-tcp backend wraps: run_multiproc[_pp] live
    # here, and its master_fn closures call the star loops directly
    "src/repro/launch/multiproc.py",
}

PATTERN = re.compile("|".join(LEGACY_CALLS))


def main() -> int:
    bad: list[str] = []
    for layer in SCANNED:
        for path in sorted((ROOT / layer).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in ALLOWLIST:
                continue
            for lineno, line in enumerate(path.read_text().splitlines(), 1):
                if PATTERN.search(line) and not line.lstrip().startswith("#"):
                    bad.append(f"{rel}:{lineno}: {line.strip()}")
    if bad:
        print("legacy driver calls reachable outside the facade "
              "(migrate to repro.api.solve or allowlist with a reason):")
        print("\n".join(f"  {b}" for b in bad))
        return 1
    print(f"api migration clean: {', '.join(SCANNED)} go through solve()")
    return 0


if __name__ == "__main__":
    sys.exit(main())
