"""Data pipeline: LIBSVM parser/writer roundtrip, partitioning semantics."""

import numpy as np
import pytest

from repro.data import (
    parse_libsvm,
    write_libsvm,
    make_synthetic_logreg,
    add_intercept,
    absorb_labels,
    partition_clients,
)


def test_libsvm_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((20, 7))
    x[rng.random((20, 7)) < 0.5] = 0.0  # sparsity
    y = np.where(rng.random(20) < 0.5, 1.0, -1.0)
    p = tmp_path / "toy.libsvm"
    write_libsvm(p, x, y)
    x2, y2 = parse_libsvm(p, n_features=7)
    np.testing.assert_allclose(x2, x, rtol=1e-15)
    np.testing.assert_allclose(y2, y)


def test_libsvm_parses_handwritten():
    import tempfile, os

    content = "+1 1:0.5 3:2.0\n-1 2:1.5\n0 1:1.0\n"
    with tempfile.NamedTemporaryFile("w", suffix=".libsvm", delete=False) as fh:
        fh.write(content)
        path = fh.name
    try:
        x, y = parse_libsvm(path)
        assert x.shape == (3, 3)
        np.testing.assert_allclose(x[0], [0.5, 0, 2.0])
        np.testing.assert_allclose(x[1], [0, 1.5, 0])
        np.testing.assert_allclose(y, [1, -1, -1])  # 0/1 labels normalized
    finally:
        os.unlink(path)


def test_partition_shapes_match_paper_setup():
    """W8A-shaped: d=301 (300+intercept), n=142, n_i=348."""
    x, y = make_synthetic_logreg("w8a", seed=0)
    z = partition_clients(add_intercept(x), y, 142, 348, seed=0)
    assert z.shape == (142, 348, 301)
    # intercept column absorbed the label: +-1
    assert set(np.unique(z[..., -1])) <= {-1.0, 1.0}


def test_partition_drops_excess_and_shuffles():
    x = np.arange(50, dtype=np.float64).reshape(25, 2)
    y = np.ones(25)
    z = partition_clients(x, y, 3, 8, seed=1)
    assert z.shape == (3, 8, 2)
    z2 = partition_clients(x, y, 3, 8, seed=2)
    assert not np.allclose(z, z2)  # different shuffles


def test_partition_raises_when_insufficient():
    x = np.zeros((10, 2))
    y = np.ones(10)
    with pytest.raises(ValueError):
        partition_clients(x, y, 4, 3)


def test_absorb_labels():
    x = np.asarray([[1.0, 2.0], [3.0, 4.0]])
    y = np.asarray([1.0, -1.0])
    z = absorb_labels(x, y)
    np.testing.assert_allclose(z, [[1, 2], [-3, -4]])
