"""End-to-end system behaviour: the full paper pipeline — generate a dataset,
write/parse LIBSVM from disk, partition to clients, train FedNL to the
target tolerance, validate against the centralized Newton solution, and check
communication accounting.  This is the paper's `bin_fednl_local` experience."""

import jax.numpy as jnp
import numpy as np

from repro.core import FedNLConfig, run_fednl, newton_baseline, gd_baseline, eval_full
from repro.data import (
    make_synthetic_logreg,
    write_libsvm,
    parse_libsvm,
    add_intercept,
    partition_clients,
)
from repro.linalg import triu_size


def test_end_to_end_pipeline(tmp_path):
    n_clients, n_i, d = 8, 40, 24
    # 1) generate + round-trip through the LIBSVM disk format (paper §5.2)
    x, y = make_synthetic_logreg((d, n_clients, n_i), seed=3)
    path = tmp_path / "train.libsvm"
    write_libsvm(path, x, y)
    x2, y2 = parse_libsvm(path, n_features=d - 1)
    np.testing.assert_allclose(x2, x, rtol=1e-12)

    # 2) paper preprocessing: intercept, shuffle, split
    z = jnp.asarray(partition_clients(add_intercept(x2), y2, n_clients, n_i, seed=3))
    assert z.shape == (n_clients, n_i, d)

    # 3) FedNL(B)/TopK[k=8d] to the paper's accuracy regime
    cfg = FedNLConfig(compressor="topk", k_multiplier=8.0, lam=1e-3, option="B")
    res = run_fednl(z, cfg, rounds=100, tol=1e-14)
    assert res.grad_norms[-1] < 1e-13

    # 4) agrees with centralized Newton
    nb = newton_baseline(z, 1e-3, tol=1e-14)
    np.testing.assert_allclose(res.x, nb.x, atol=1e-9)

    # 5) f at the solution is a true global value
    f, g = eval_full(z, jnp.asarray(res.x), 1e-3)
    assert float(jnp.linalg.norm(g)) < 1e-12

    # 6) communication accounting: TopK sends exactly k entries/client/round
    k = cfg.k_for(d)
    bits_per_round = res.sent_bits[0]
    assert bits_per_round == n_clients * k * (64 + 32)


def test_fednl_beats_gd_in_rounds():
    """Second-order vs first-order archetype: FedNL needs orders of magnitude
    fewer rounds than GD at equal tolerance (the paper's Table 2 story)."""
    x, y = make_synthetic_logreg("tiny", seed=5)
    z = jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=5))
    cfg = FedNLConfig(compressor="randseqk", lam=1e-3)
    fednl = run_fednl(z, cfg, rounds=100, tol=1e-9)
    gd = gd_baseline(z, 1e-3, iters=20000, tol=1e-9)
    assert fednl.rounds * 20 < gd.rounds


def test_compressed_rounds_send_less_than_ident():
    x, y = make_synthetic_logreg("tiny", seed=6)
    z = jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=6))
    d = z.shape[-1]
    bits = {}
    for comp in ["identity", "topk", "toplek", "randseqk"]:
        cfg = FedNLConfig(compressor=comp, lam=1e-3)
        res = run_fednl(z, cfg, rounds=10)
        bits[comp] = float(np.sum(res.sent_bits))
    assert bits["topk"] < bits["identity"]
    assert bits["randseqk"] < bits["topk"]  # no index transfer (PRG seed)
    assert bits["toplek"] <= bits["topk"] + 32 * 10 * 8  # adaptive k' <= k


def test_triu_budget_math():
    d = 301
    assert triu_size(d) == d * (d + 1) // 2
    cfg = FedNLConfig(k_multiplier=8.0)
    assert cfg.k_for(d) == 8 * d  # the paper's K = 8d
