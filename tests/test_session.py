"""The Session API (DESIGN.md §10): open_session / step / observe / save / resume.

The acceptance bar of the api_redesign PR, pinned here:

  * step composability — ``step(k)`` then ``step(m)`` is bit-identical to
    ``step(k + m)`` and to sequential ``solve()`` on every session-capable
    backend (local, sharded, star-loopback; star-tcp under the net marker);
  * checkpointing — save -> restore mid-run is bit-identical to an
    uninterrupted run on every backend, including a faulted resampling
    FedNL-PP run whose clients rebuild their state purely from the spec +
    replayed PRNG spine (no client state on disk);
  * serialization — the FNLS1 checkpoint is byte-stable (save -> load ->
    save is the identity on bytes) across all registered algorithm x
    compressor pairs (hypothesis widens the sweep when installed);
  * validation — restore-incompatible spec/checkpoint combinations fail
    loudly with the mismatched fields named;
  * kill-and-resume — a star-tcp master process killed mid-run resumes from
    its checkpoint in a fresh process, bit-identical (net marker).
"""

import dataclasses
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (
    DataSpec,
    ExperimentSpec,
    FaultSpec,
    StopPolicy,
    load_state,
    open_session,
    save_state,
    solve,
    solve_many,
)

SHAPE = (12, 4, 20)  # d, n_clients, n_i — small enough for per-round stepping


def full_spec(**overrides) -> ExperimentSpec:
    base = dict(data=DataSpec(shape=SHAPE, seed=1), rounds=6, seed=0)
    base.update(overrides)
    return ExperimentSpec(**base)


def pp_spec(**overrides) -> ExperimentSpec:
    return full_spec(algorithm="fednl-pp", tau=3, **overrides)


def assert_reports_bit_identical(got, want):
    assert got.rounds == want.rounds
    for g, w in zip(got.records, want.records):
        assert (g.grad_norm is None) == (w.grad_norm is None)
        if g.grad_norm is not None:
            assert float(g.grad_norm).hex() == float(w.grad_norm).hex()
        assert g.sent_bits == w.sent_bits
        assert g.sent_bits_payload == w.sent_bits_payload
        assert g.sent_bits_wire == w.sent_bits_wire
        if g.x is not None or w.x is not None:
            np.testing.assert_array_equal(g.x, w.x)
        assert g.participants == w.participants
        assert g.dropped == w.dropped
    np.testing.assert_array_equal(got.x, want.x)


# ---------------------------------------------------------------------------
# step composability: step(k) + step(m) == step(k+m) == solve()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["local", "sharded", "star-loopback"])
def test_step_composability_full(backend):
    spec = full_spec(backend=backend)
    want = solve(spec)
    with open_session(spec) as s:
        s.step(2)
        s.step(3)
        s.step(1)
        got = s.report()
    assert_reports_bit_identical(got, want)


@pytest.mark.parametrize("backend", ["local", "star-loopback"])
def test_step_composability_pp(backend):
    spec = pp_spec(backend=backend)
    want = solve(spec)
    with open_session(spec) as s:
        s.step(1)
        s.step(5)
        got = s.report()
    assert_reports_bit_identical(got, want)
    np.testing.assert_array_equal(got.x_hist, want.x_hist)


def test_run_is_solve_and_reports_are_cumulative():
    spec = full_spec()
    want = solve(spec)
    with open_session(spec) as s:
        mid = s.run(until=3)
        assert mid.rounds == 3
        full = s.run()  # continues from round 3 under the spec budget
        assert full.rounds == spec.rounds
    assert_reports_bit_identical(full, want)
    # the mid-run report is exactly solve() of the 3-round prefix spec
    assert_reports_bit_identical(
        mid, solve(spec.replace(rounds=3))
    )


# ---------------------------------------------------------------------------
# observers + stop policies
# ---------------------------------------------------------------------------

def test_observer_streams_records_in_order():
    spec = full_spec()
    seen = []
    with open_session(spec) as s:
        s.on_round(lambda rec: seen.append(rec.round))
        s.step(2)
        s.run()
    assert seen == list(range(spec.rounds))


def test_run_until_tol_matches_solve_early_stop():
    spec = full_spec(rounds=40, tol=1e-10)
    want = solve(spec)
    with open_session(spec) as s:
        got = s.run()
    assert got.rounds == want.rounds < 40
    assert_reports_bit_identical(got, want)
    # explicit float `until` behaves like a spec tol
    with open_session(spec.replace(tol=0.0)) as s:
        got2 = s.run(until=1e-10)
    assert got2.rounds == want.rounds


def test_run_until_predicate_and_policy():
    spec = full_spec(rounds=30)
    stop_at = []
    with open_session(spec) as s:
        got = s.run(
            until=StopPolicy(
                predicate=lambda rec: stop_at.append(rec.round) or rec.round >= 3
            )
        )
    assert got.rounds == 4  # the stopping round is included
    with pytest.raises(TypeError, match="until must be"):
        with open_session(spec) as s:
            s.run(until="forever")


def test_run_until_tol_rejected_for_pp():
    with open_session(pp_spec()) as s:
        with pytest.raises(ValueError, match="partial participation"):
            s.run(until=1e-9)


def test_closed_session_refuses_steps():
    s = open_session(full_spec())
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.step()


# ---------------------------------------------------------------------------
# save -> restore mid-run == uninterrupted run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["local", "sharded", "star-loopback"])
def test_save_restore_midrun_full(tmp_path, backend):
    spec = full_spec(backend=backend)
    want = solve(spec)
    ck = tmp_path / "mid.fnlsess"
    with open_session(spec) as s:
        s.step(3)
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        assert s.round == 3 and len(s.records) == 3
        got = s.run()
    assert_reports_bit_identical(got, want)


@pytest.mark.parametrize("backend", ["local", "star-loopback"])
def test_save_restore_midrun_pp(tmp_path, backend):
    spec = pp_spec(backend=backend)
    want = solve(spec)
    ck = tmp_path / "mid.fnlsess"
    with open_session(spec) as s:
        s.step(4)
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)
    assert got.final_grad_norm == want.final_grad_norm


def test_save_restore_faulted_resample_pp(tmp_path):
    """Clients rebuild PRNG spine AND fault-injector state via replay: a
    resampling run with 30% dropout restores bit-identically."""
    spec = pp_spec(
        backend="star-loopback",
        rounds=10,
        fault=FaultSpec(drop_prob=0.3, seed=7),
        on_dropout="resample",
    )
    want = solve(spec)
    assert sum(len(d) for d in want.dropped) > 0, "fault injection was a no-op"
    ck = tmp_path / "faulted.fnlsess"
    with open_session(spec) as s:
        s.step(5)
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)
    assert got.participants == want.participants
    assert got.dropped == want.dropped


def test_restore_can_extend_rounds(tmp_path):
    """rounds is run control, not state: a checkpoint resumes under a larger
    budget and matches the long solve exactly."""
    short, long = full_spec(rounds=4), full_spec(rounds=9)
    want = solve(long)
    ck = tmp_path / "short.fnlsess"
    with open_session(short) as s:
        s.step(4)
        s.save(ck)
    with open_session(long, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)


# ---------------------------------------------------------------------------
# serialization: byte stability across algorithm x compressor pairs
# ---------------------------------------------------------------------------

ALGO_BACKEND = [("fednl", "local"), ("fednl-ls", "local"), ("fednl-pp", "local"),
                ("fednl", "star-loopback"), ("fednl-pp", "star-loopback")]
COMPRESSORS = ["topk", "randk", "randseqk", "toplek", "natural", "identity"]


def _roundtrip_bytes(spec, tmp_path, tag):
    p1 = tmp_path / f"{tag}.a"
    p2 = tmp_path / f"{tag}.b"
    with open_session(spec) as s:
        s.step(2)
        s.save(p1)
    save_state(load_state(p1), p2)
    return p1.read_bytes(), p2.read_bytes()


@pytest.mark.parametrize("algo,backend", ALGO_BACKEND)
@pytest.mark.parametrize("comp", COMPRESSORS)
def test_checkpoint_byte_stable_registered_pairs(tmp_path, algo, backend, comp):
    """save -> load -> save is the identity on bytes for every registered
    algorithm x compressor pair (the FNLS1 determinism contract)."""
    from repro.api import CompressorSpec

    spec = full_spec(
        algorithm=algo,
        backend=backend,
        compressor=CompressorSpec(comp),
        tau=3 if algo == "fednl-pp" else None,
        rounds=3,
    )
    a, b = _roundtrip_bytes(spec, tmp_path, f"{algo}-{backend}-{comp}")
    assert a == b
    # and the loaded state itself round-trips structurally
    st = load_state(tmp_path / f"{algo}-{backend}-{comp}.a")
    assert st.spec == spec and st.round == 2 and len(st.records) == 2


try:
    from hypothesis import given, settings, strategies as st_h

    @settings(max_examples=10, deadline=None)
    @given(
        comp=st_h.sampled_from(COMPRESSORS),
        algo=st_h.sampled_from(["fednl", "fednl-ls", "fednl-pp"]),
        seed=st_h.integers(min_value=0, max_value=2**31 - 1),
        steps=st_h.integers(min_value=0, max_value=3),
    )
    def test_checkpoint_byte_stable_property(tmp_path_factory, comp, algo, seed, steps):
        """hypothesis sweep: byte stability holds for arbitrary seeds and
        save points, not just the pinned grid above."""
        from repro.api import CompressorSpec

        tmp = tmp_path_factory.mktemp("fnlsess")
        spec = full_spec(
            algorithm=algo,
            compressor=CompressorSpec(comp),
            tau=2 if algo == "fednl-pp" else None,
            rounds=3,
            seed=seed,
        )
        p1, p2 = tmp / "a", tmp / "b"
        with open_session(spec) as s:
            s.step(steps)
            s.save(p1)
        save_state(load_state(p1), p2)
        assert p1.read_bytes() == p2.read_bytes()
except ImportError:  # property tests need hypothesis (requirements-dev.txt)
    pass


def test_load_rejects_foreign_files(tmp_path):
    p = tmp_path / "notacheckpoint"
    p.write_bytes(b"PK\x03\x04 definitely a zip")
    with pytest.raises(ValueError, match="bad magic"):
        load_state(p)


# ---------------------------------------------------------------------------
# restore validation: incompatible combinations fail loudly
# ---------------------------------------------------------------------------

def test_restore_incompatible_specs_rejected(tmp_path):
    spec = pp_spec(rounds=4)
    ck = tmp_path / "pp.fnlsess"
    with open_session(spec) as s:
        s.step(2)
        s.save(ck)
    # different tau: the checkpointed invariants assume the original cohort
    with pytest.raises(ValueError, match="tau"):
        open_session(spec.replace(tau=2), restore=ck)
    # different compressor: client H_i evolution would not match the spine
    from repro.api import CompressorSpec

    with pytest.raises(ValueError, match="compressor"):
        open_session(
            spec.replace(compressor=CompressorSpec("randk")), restore=ck
        )
    # different backend: checkpoint layouts are backend-specific
    with pytest.raises(ValueError, match="backend"):
        open_session(spec.replace(backend="star-loopback"), restore=ck)
    # different seed: a different trajectory altogether
    with pytest.raises(ValueError, match="seed"):
        open_session(spec.replace(seed=1), restore=ck)
    # the error is actionable: names the field and both values
    with pytest.raises(ValueError, match="checkpoint ran with"):
        open_session(spec.replace(seed=1), restore=ck)
    # rounds/tol ARE allowed to change (run control)
    with open_session(spec.replace(rounds=6), restore=ck) as s:
        assert s.run().rounds == 6


def test_restore_refuses_x0_override(tmp_path):
    spec = full_spec()
    ck = tmp_path / "f.fnlsess"
    with open_session(spec) as s:
        s.save(ck)
    with pytest.raises(ValueError, match="x0"):
        open_session(spec, x0=np.zeros(SHAPE[0]), restore=ck)


def test_session_on_legacy_backend_fails_loudly():
    from repro.api.registry import BACKENDS, Backend, register_backend

    class LegacyBackend(Backend):
        name = "legacy-test"
        needs_problem = False

        def run(self, spec, algo, z, x0):
            return "ran"

    register_backend(LegacyBackend())
    try:
        assert solve(ExperimentSpec(backend="legacy-test")) == "ran"
        with pytest.raises(ValueError, match="does not support sessions"):
            open_session(ExperimentSpec(backend="legacy-test"))
    finally:
        BACKENDS._entries.pop("legacy-test", None)


# ---------------------------------------------------------------------------
# sweep integration: warm-started rounds-prefix groups
# ---------------------------------------------------------------------------

def test_sweep_warm_start_reuses_sessions_bit_identically():
    base = full_spec()
    sweep = base.grid(rounds=[2, 4, 6])
    rep = solve_many(sweep)
    assert any("warm-start session reuse" in line for line in rep.log), rep.log
    for spec, got in zip(sweep.specs(), rep.reports):
        assert got.spec == spec and got.rounds == spec.rounds
        assert_reports_bit_identical(got, solve(spec))


def test_sweep_warm_start_skipped_when_not_a_prefix_group():
    base = full_spec()
    # tol early-stop and batch="never" must keep the historical per-spec path
    rep = solve_many([base.replace(rounds=2, tol=1e-30), base.replace(rounds=4, tol=1e-30)])
    assert not any("warm-start" in line for line in rep.log)
    rep = solve_many(base.grid(rounds=[2, 4], batch="never"))
    assert not any("warm-start" in line for line in rep.log)


# ---------------------------------------------------------------------------
# star-tcp: real sockets (net marker) + kill-and-resume subprocess test
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_tcp_session_step_and_restore(tmp_path):
    spec = full_spec(backend="star-tcp")
    want = solve(spec)
    ck = tmp_path / "tcp.fnlsess"
    with open_session(spec) as s:
        s.step(2)
        s.step(1)
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)


_KILL_SCRIPT = """
import sys, os

# the __main__ guard matters: star-tcp spawns worker processes that re-import
# this module under multiprocessing's spawn context
if __name__ == "__main__":
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.api import DataSpec, ExperimentSpec, open_session

    spec = ExperimentSpec(data=DataSpec(shape=(12, 4, 20), seed=1), rounds=6,
                          seed=0, backend="star-tcp")
    s = open_session(spec)
    s.step(3)
    s.save(sys.argv[1])
    # die without closing anything: no STOP broadcast, no cluster join — the
    # worker processes are daemonic children and fall with the master
    os._exit(17)
"""


@pytest.mark.net
def test_tcp_kill_and_resume_subprocess(tmp_path):
    """A star-tcp master killed mid-run resumes from its checkpoint in a
    fresh process tree, bit-identical to the uninterrupted run."""
    script = tmp_path / "kill_master.py"
    script.write_text(_KILL_SCRIPT)
    ck = tmp_path / "killed.fnlsess"
    env = dict(
        os_environ_minus_pythonpath(),
        PYTHONPATH=str(pathlib.Path(__file__).resolve().parent.parent / "src"),
    )
    proc = subprocess.run(
        [sys.executable, str(script), str(ck)],
        capture_output=True,
        text=True,
        timeout=300,
        env=env,
    )
    assert proc.returncode == 17, proc.stderr
    assert ck.exists()
    st = load_state(ck)
    assert st.round == 3 and st.backend == "star-tcp"

    spec = full_spec(backend="star-tcp")
    want = solve(spec)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)


def os_environ_minus_pythonpath():
    import os

    env = dict(os.environ)
    env.pop("PYTHONPATH", None)
    return env
