"""Property-based compressor CONTRACT tests (hypothesis).

tests/test_compressors.py pins the theory at fixed shapes; this module states
the contracts FedNL's convergence proof and the wire layer both rest on, and
lets hypothesis hunt the shape/seed space for violations:

  * contraction: E||C(u) - u||^2 <= (1 - delta) ||u||^2 for all six registry
    (scaled) compressors;
  * unbiasedness: E[C(u)] = u for the *unscaled* RandK / RandSeqK / Natural
    forms;
  * sparse/dense equivalence: compress_sparse + scatter_add_sparse rebuilds
    the dense compress output EXACTLY (bit equality — the property the
    sparse-collective aggregation and the wire codecs rely on);
  * TopLEK adaptivity edge cases: total == 0 and kept == 0 payloads survive
    the sparse form and the wire codec round trip.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.comm import wire
from repro.compressors import core as C

SPARSE = ["topk", "randk", "randseqk", "toplek"]
ALL = SPARSE + ["natural", "identity"]


def _rand_u(seed, t, scale=1.0):
    u = jax.random.normal(jax.random.PRNGKey(seed), (t,), dtype=jnp.float64)
    return u * scale


# ---------------------------------------------------------------------------
# contraction: the FedNL requirement E||C(u)-u||^2 <= (1-delta)||u||^2
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(min_value=4, max_value=150),
    frac=st.floats(min_value=0.02, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**20),
    scale=st.sampled_from([1.0, 1e-8, 1e8]),
    name=st.sampled_from(ALL),
)
def test_contraction_inequality_all_compressors(t, frac, seed, scale, name):
    k = max(1, int(frac * t))
    u = _rand_u(seed % 101, t, scale)
    comp = C.get_compressor(name, t, k)
    keys = jax.random.split(jax.random.PRNGKey(seed), 400)
    errs = jax.vmap(lambda key: jnp.sum((comp.compress(key, u)[0] - u) ** 2))(keys)
    lhs = float(jnp.mean(errs))
    rhs = (1 - comp.delta) * float(jnp.sum(u * u))
    # deterministic compressors (topk/identity) must satisfy it exactly;
    # randomized ones get Monte-Carlo slack
    slack = 1e-12 if name in ("topk", "identity") else 0.2 * rhs + 1e-12 * scale**2
    assert lhs <= rhs + slack


# ---------------------------------------------------------------------------
# unbiasedness of the unscaled forms
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(min_value=6, max_value=48),
    frac=st.floats(min_value=0.1, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**20),
    name=st.sampled_from(["randk", "randseqk"]),
)
def test_rand_unscaled_unbiased(t, frac, seed, name):
    """E[(T/k) * mask(u)] = u for RandK and its cache-aware sequential form."""
    k = max(1, int(frac * t))
    u = _rand_u(seed % 89, t)
    fn = C.randk if name == "randk" else C.randseqk
    n_mc = 3000
    keys = jax.random.split(jax.random.PRNGKey(seed), n_mc)
    samples = jax.vmap(lambda key: fn(key, u, k, scaled=False)[0])(keys)
    mean = np.asarray(jnp.mean(samples, axis=0))
    # CLT bound: sd of one coordinate is <= |u_j| T/k; 6-sigma tolerance
    tol = 6.0 * (t / k) * (np.abs(np.asarray(u)) + 1e-3) / np.sqrt(n_mc)
    np.testing.assert_array_less(np.abs(mean - np.asarray(u)), tol)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(min_value=8, max_value=64),
    seed=st.integers(min_value=0, max_value=2**20),
    scale=st.sampled_from([1.0, 1e-6, 1e6]),
)
def test_natural_unscaled_unbiased(t, seed, scale):
    """E[natural(u)] = u (probabilistic power-of-two rounding, omega = 1/8)."""
    u = _rand_u(seed % 97, t, scale)
    n_mc = 3000
    keys = jax.random.split(jax.random.PRNGKey(seed), n_mc)
    samples = jax.vmap(lambda key: C.natural(key, u, scaled=False)[0])(keys)
    mean = np.asarray(jnp.mean(samples, axis=0))
    u_np = np.asarray(u)
    # per-coordinate sd <= |u_j| / sqrt(8); 6-sigma + tiny absolute floor
    tol = 6.0 * np.abs(u_np) / np.sqrt(8 * n_mc) + 1e-12 * scale
    np.testing.assert_array_less(np.abs(mean - u_np), tol)


# ---------------------------------------------------------------------------
# sparse form == dense form, exactly (bit equality)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(min_value=4, max_value=160),
    frac=st.floats(min_value=0.02, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**20),
    scale=st.sampled_from([1.0, 1e-9, 1e9]),
    name=st.sampled_from(SPARSE),
)
def test_sparse_scatter_reproduces_dense_exactly(t, frac, seed, scale, name):
    """compress_sparse + scatter_add_sparse == compress, to the last bit —
    values travel verbatim, indices never collide, padding adds exact zeros."""
    k = max(1, int(frac * t))
    u = _rand_u(seed % 97, t, scale)
    comp = C.get_compressor(name, t, k)
    key = jax.random.PRNGKey(seed)
    dense, sent_d = comp.compress(key, u)
    idx, vals, sent_s = comp.compress_sparse(key, u)
    recon = C.scatter_add_sparse(idx, vals, t)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(recon))
    assert int(sent_d) == int(sent_s)


# ---------------------------------------------------------------------------
# TopLEK adaptivity edge cases: total == 0 and kept == 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,k", [(20, 5), (7, 7), (64, 1)])
def test_toplek_zero_vector_keeps_nothing(t, k):
    """total == 0: kept must be 0 and every path (dense, sparse, codec)
    must produce the all-zero message."""
    u = jnp.zeros(t, dtype=jnp.float64)
    key = jax.random.PRNGKey(0)
    comp = C.get_compressor("toplek", t, k)
    dense, kept = comp.compress(key, u)
    assert int(kept) == 0
    assert float(jnp.sum(jnp.abs(dense))) == 0.0
    idx, vals, kept_s = comp.compress_sparse(key, u)
    assert int(kept_s) == 0
    np.testing.assert_array_equal(
        np.asarray(C.scatter_add_sparse(idx, vals, t)), np.zeros(t)
    )
    # wire codec: 4-byte "kept = 0" header only, decodes to zeros
    codec = wire.make_codec(comp, t)
    enc = codec.encode(key, u)
    assert enc.sent_elems == 0 and len(enc.data) == 4
    np.testing.assert_array_equal(
        np.asarray(codec.decode(enc.data, 0)), np.zeros(t)
    )


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(min_value=4, max_value=100),
    frac=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**20),
)
def test_toplek_kept_range_and_codec_roundtrip(t, frac, seed):
    """0 <= kept <= k always, and the adaptive-length wire message rebuilds
    the dense output exactly whatever kept turns out to be."""
    k = max(1, int(frac * t))
    u = _rand_u(seed % 89, t)
    comp = C.get_compressor("toplek", t, k)
    key = jax.random.PRNGKey(seed)
    dense, kept = comp.compress(key, u)
    assert 0 <= int(kept) <= k
    assert int(jnp.sum(dense != 0)) <= int(kept)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(key, u)
    assert enc.sent_elems == int(kept)
    assert enc.bits == 32 + int(kept) * 96
    np.testing.assert_array_equal(
        np.asarray(codec.decode(enc.data, enc.sent_elems)), np.asarray(dense)
    )
