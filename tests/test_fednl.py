"""FedNL algorithm-family behaviour: superlinear convergence to the paper's
accuracy regime with every compressor, Option A/B parity at the solution,
FedNL-LS globalization, FedNL-PP partial participation, exact one-step
convergence on quadratics with the Identity compressor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedNLConfig,
    fednl_init,
    make_fednl_round,
    make_fednl_ls_round,
    fednl_pp_init,
    make_fednl_pp_round,
    run_fednl,
    newton_baseline,
    eval_full,
)
from repro.data import make_synthetic_logreg, add_intercept, partition_clients

LAM = 1e-3


def _tiny_problem(seed=1):
    x, y = make_synthetic_logreg("tiny", seed=seed)
    return jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=seed))


@pytest.fixture(scope="module")
def z():
    return _tiny_problem()


@pytest.mark.parametrize(
    "comp", ["identity", "topk", "randk", "randseqk", "toplek", "natural"]
)
def test_fednl_converges_all_compressors(z, comp):
    """Paper Table 1 regime: ||grad f(x_last)|| ~ 1e-15..1e-18 (FP64)."""
    cfg = FedNLConfig(compressor=comp, lam=LAM, option="B")
    res = run_fednl(z, cfg, rounds=80, tol=1e-14)
    assert res.grad_norms[-1] < 1e-13, res.grad_norms[-5:]


def test_fednl_superlinear_local_rate(z):
    """Once near the solution the error contraction factor keeps improving."""
    cfg = FedNLConfig(compressor="topk", lam=LAM, option="B")
    res = run_fednl(z, cfg, rounds=40, tol=1e-15)
    gn = res.grad_norms
    # pick the local phase: from first round with gn < 1e-2
    start = int(np.argmax(gn < 1e-2))
    ratios = gn[start + 1 :] / gn[start:-1]
    assert len(ratios) >= 4
    # superlinear: the contraction factor itself shrinks by orders of magnitude
    assert ratios[-1] < 1e-2
    assert ratios[-1] < ratios[0] / 10


def test_fednl_option_a_converges(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM, option="A", mu=LAM)
    res = run_fednl(z, cfg, rounds=80, tol=1e-13)
    assert res.grad_norms[-1] < 1e-12


def test_fednl_matches_newton_solution(z):
    cfg = FedNLConfig(compressor="randseqk", lam=LAM)
    res = run_fednl(z, cfg, rounds=60, tol=1e-14)
    nb = newton_baseline(z, LAM, tol=1e-14)
    np.testing.assert_allclose(res.x, nb.x, atol=1e-10)


def test_fednl_cold_start_converges(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM, hess0="zero")
    res = run_fednl(z, cfg, rounds=200, tol=1e-13)
    assert res.grad_norms[-1] < 1e-12


def test_fednl_ls_converges_and_counts_steps(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM, option="A", mu=LAM)
    state = fednl_init(z, cfg)
    round_fn = jax.jit(make_fednl_ls_round(z, cfg))
    ls_steps, gns = [], []
    for _ in range(40):
        state, m = round_fn(state)
        ls_steps.append(int(m.ls_steps))
        gns.append(float(m.grad_norm))
    assert float(m.grad_norm) < 1e-12
    steps = np.asarray(ls_steps)
    gns = np.asarray(gns)
    # paper: "the line search procedure requires almost always a 1 step" —
    # assessed on the rounds where the search is active, i.e. above the FP64
    # gradient plateau; at/below cfg.ls_tol the unit step is taken directly.
    active = gns > cfg.ls_tol
    assert active.sum() >= 4
    assert np.mean(steps[active] <= 1) > 0.8
    assert np.all(steps[~active] == 0)


def test_fednl_pp_converges(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    state = fednl_pp_init(z, cfg)
    round_fn = jax.jit(make_fednl_pp_round(z, cfg, tau=3))
    for _ in range(150):
        state, m = round_fn(state)
    _, g = eval_full(z, m.x, LAM)
    assert float(jnp.linalg.norm(g)) < 1e-10


def test_fednl_pp_only_selected_clients_change(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    state = fednl_pp_init(z, cfg)
    round_fn = jax.jit(make_fednl_pp_round(z, cfg, tau=3))
    new_state, _ = round_fn(state)
    changed = np.asarray(
        jnp.any(new_state.h_local != state.h_local, axis=1)
        | jnp.any(new_state.g_local != state.g_local, axis=1)
    )
    assert changed.sum() <= 3


def test_identity_quadratic_newton_equivalence():
    """With C = Identity and exact H0, FedNL(B) on a quadratic reaches the
    optimum to machine precision immediately after the Hessians sync."""
    key = jax.random.PRNGKey(0)
    d, n = 6, 4
    a = jax.random.normal(key, (n, d, d), dtype=jnp.float64)
    b = jnp.einsum("nij,nkj->nik", a, a) + jnp.eye(d)
    # encode the quadratic as logreg is not possible; instead check via the
    # master step directly: H = mean(B), grad at x0=0 is -mean(c)
    c = jax.random.normal(jax.random.fold_in(key, 1), (n, d), dtype=jnp.float64)
    h = jnp.mean(b, axis=0)
    g = -jnp.mean(c, axis=0)
    x1 = -jnp.linalg.solve(h, g)
    # optimum of 0.5 x'Hx - mean(c)'x
    np.testing.assert_allclose(np.asarray(h @ x1), np.asarray(jnp.mean(c, axis=0)), rtol=1e-10)


def test_round_metrics_bits_accounting(z):
    cfg = FedNLConfig(compressor="toplek", lam=LAM)
    state = fednl_init(z, cfg)
    round_fn = jax.jit(make_fednl_round(z, cfg))
    _, m = round_fn(state)
    d = z.shape[-1]
    t = d * (d + 1) // 2
    k = cfg.k_for(d)
    assert 0 <= int(m.sent_elems) <= k * z.shape[0]
    assert float(m.sent_bits) <= z.shape[0] * (k * 96 + 32)


@pytest.mark.parametrize("accounting", ["payload", "wire"])
def test_pp_round_metrics_honor_accounting(z, accounting):
    """PP sent_bits routes through make_pp_bits_fn: 'payload' prices the
    Algorithm-3 triple via pp_message_bits (Hessian section + (d+1) FP64
    deltas), 'wire' the full framed PP_UPDATE — no hard-coded constants."""
    import dataclasses

    from repro.comm.wire import pp_frame_bits, pp_message_bits
    from repro.compressors import get_compressor

    d = z.shape[-1]
    t = d * (d + 1) // 2
    tau = 3
    cfg = FedNLConfig(compressor="topk", lam=LAM, accounting=accounting)
    state = fednl_pp_init(z, cfg)
    round_fn = jax.jit(make_fednl_pp_round(z, cfg, tau=tau))
    _, m = round_fn(state)
    comp = get_compressor("topk", t, cfg.k_for(d))
    k = cfg.k_for(d)
    model = pp_message_bits if accounting == "payload" else pp_frame_bits
    want = tau * int(model(comp, jnp.asarray(k), d))
    assert int(m.sent_bits) == want
    # both accountings agree with the analytic models, differ from each other
    other = dataclasses.replace(
        cfg, accounting="wire" if accounting == "payload" else "payload"
    )
    _, m2 = jax.jit(make_fednl_pp_round(z, other, tau=tau))(fednl_pp_init(z, other))
    assert int(m2.sent_bits) != int(m.sent_bits)
