"""Distributed FedNL: shard_map round parity with the single-node round, both
aggregation strategies, and an 8-fake-device integration run in a subprocess
(device count must be set before jax initializes, so it cannot run in-process)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedNLConfig, fednl_init, make_fednl_round
from repro.data import make_synthetic_logreg, add_intercept, partition_clients
from repro.distributed import (
    make_sharded_fednl_round,
    shard_problem,
    sharded_fednl_init,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _z(n_clients=8, n_i=40, seed=1):
    x, y = make_synthetic_logreg((24, n_clients, n_i), seed=seed)
    return jnp.asarray(partition_clients(add_intercept(x), y, n_clients, n_i, seed=seed))


def test_sharded_round_matches_single_node_on_1_device_mesh():
    """On a 1-device mesh with the deterministic TopK compressor, the sharded
    round must be bit-compatible with the vmapped single-node round."""
    z = _z()
    mesh = jax.make_mesh((1,), ("data",))
    cfg = FedNLConfig(compressor="topk", lam=1e-3)

    st_ref = fednl_init(z, cfg, seed=0)
    ref_round = jax.jit(make_fednl_round(z, cfg))

    zs = shard_problem(z, mesh)
    st_sh = sharded_fednl_init(zs, cfg, mesh, seed=0)
    sh_round = jax.jit(make_sharded_fednl_round(zs, cfg, mesh))

    for _ in range(5):
        st_ref, m_ref = ref_round(st_ref)
        st_sh, m_sh = sh_round(st_sh)
    # PRNG streams differ (per-device fold_in) but TopK is deterministic:
    np.testing.assert_allclose(
        np.asarray(st_sh.x), np.asarray(st_ref.x), rtol=1e-12
    )
    np.testing.assert_allclose(float(m_sh["grad_norm"]), float(m_ref.grad_norm), rtol=1e-10)


@pytest.mark.parametrize("agg", ["dense_psum", "sparse_allgather"])
def test_aggregation_strategies_agree(agg):
    z = _z()
    mesh = jax.make_mesh((1,), ("data",))
    cfg = FedNLConfig(compressor="topk", lam=1e-3)
    zs = shard_problem(z, mesh)
    st = sharded_fednl_init(zs, cfg, mesh, seed=0)
    rf = jax.jit(make_sharded_fednl_round(zs, cfg, mesh, aggregate=agg))
    for _ in range(20):
        st, m = rf(st)
    assert float(m["grad_norm"]) < 1e-12


def test_sparse_allgather_rejects_dense_compressor():
    z = _z()
    mesh = jax.make_mesh((1,), ("data",))
    cfg = FedNLConfig(compressor="natural", lam=1e-3)
    zs = shard_problem(z, mesh)
    with pytest.raises(ValueError):
        make_sharded_fednl_round(zs, cfg, mesh, aggregate="sparse_allgather")


@pytest.mark.parametrize("agg", ["dense_psum", "sparse_allgather"])
def test_multidevice_integration_subprocess(agg):
    """Real 8-device shard_map execution (fake CPU devices, own process)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        from repro.data import make_synthetic_logreg, add_intercept, partition_clients
        from repro.core import FedNLConfig
        from repro.distributed import (
            make_sharded_fednl_round, shard_problem, sharded_fednl_init)

        assert jax.device_count() == 8
        x, y = make_synthetic_logreg((24, 8, 40), seed=1)
        z = jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=1))
        mesh = jax.make_mesh((8,), ("data",))
        zs = shard_problem(z, mesh)
        cfg = FedNLConfig(compressor="randseqk", lam=1e-3)
        st = sharded_fednl_init(zs, cfg, mesh, seed=0)
        rf = jax.jit(make_sharded_fednl_round(zs, cfg, mesh, aggregate="{agg}"))
        for _ in range(30):
            st, m = rf(st)
        gn = float(m["grad_norm"])
        assert gn < 1e-12, gn
        print("OK", gn)
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
