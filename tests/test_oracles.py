"""Logistic-regression oracle verification: analytic formulas (paper Eq. 3-5)
vs finite differences and vs jax autodiff; fused-oracle parity (§5.7)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.numerics import fd_grad, fd_hess
from repro.objectives import (
    logreg_f,
    logreg_grad,
    logreg_hess,
    logreg_oracles,
)
from repro.objectives.quadratic import quadratic_oracles

LAM = 1e-3


def _problem(n=30, d=7, seed=0):
    key = jax.random.PRNGKey(seed)
    z = jax.random.normal(key, (n, d), dtype=jnp.float64) / np.sqrt(d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (d,), dtype=jnp.float64)
    return z, x


def test_grad_matches_finite_differences():
    z, x = _problem()
    g = np.asarray(logreg_grad(z, x, LAM))
    g_fd = fd_grad(lambda v: logreg_f(z, jnp.asarray(v), LAM), np.asarray(x))
    np.testing.assert_allclose(g, g_fd, atol=1e-8)


def test_hess_matches_finite_differences():
    z, x = _problem()
    h = np.asarray(logreg_hess(z, x, LAM))
    h_fd = fd_hess(lambda v: logreg_f(z, jnp.asarray(v), LAM), np.asarray(x))
    np.testing.assert_allclose(h, h_fd, atol=5e-5)


def test_grad_hess_match_autodiff():
    z, x = _problem(seed=3)
    g_ad = jax.grad(lambda v: logreg_f(z, v, LAM))(x)
    h_ad = jax.hessian(lambda v: logreg_f(z, v, LAM))(x)
    np.testing.assert_allclose(
        np.asarray(logreg_grad(z, x, LAM)), np.asarray(g_ad), rtol=1e-10
    )
    np.testing.assert_allclose(
        np.asarray(logreg_hess(z, x, LAM)), np.asarray(h_ad), rtol=1e-8, atol=1e-12
    )


def test_fused_oracle_parity():
    """§5.7: the margin-reusing fused oracle equals the individual oracles."""
    z, x = _problem(seed=5)
    f, g, h = logreg_oracles(z, x, LAM)
    np.testing.assert_allclose(float(f), float(logreg_f(z, x, LAM)), rtol=1e-14)
    np.testing.assert_allclose(np.asarray(g), np.asarray(logreg_grad(z, x, LAM)), rtol=1e-14)
    np.testing.assert_allclose(np.asarray(h), np.asarray(logreg_hess(z, x, LAM)), rtol=1e-14)


def test_fused_oracle_with_pallas_kernel():
    """use_kernel=True routes the SYRK through the Pallas kernel wrapper."""
    z, x = _problem(n=50, d=11, seed=6)
    _, _, h_ref = logreg_oracles(z, x, LAM, use_kernel=False)
    _, _, h_kern = logreg_oracles(z, x, LAM, use_kernel=True)
    np.testing.assert_allclose(np.asarray(h_kern), np.asarray(h_ref), rtol=1e-10)


def test_hessian_is_psd_plus_lambda():
    z, x = _problem(seed=7)
    h = logreg_hess(z, x, LAM)
    w = jnp.linalg.eigvalsh(h)
    assert float(w.min()) >= LAM - 1e-12  # strong convexity floor (Assumption 1.1)


def test_quadratic_oracles():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (6, 6), dtype=jnp.float64)
    b = a @ a.T + jnp.eye(6)
    c = jnp.ones(6)
    x = jnp.zeros(6)
    f, g, h = quadratic_oracles(b, c, x)
    np.testing.assert_allclose(np.asarray(g), -np.asarray(c))
    np.testing.assert_allclose(np.asarray(h), np.asarray(b))
