"""Training substrate: AdamW behaviour, grad accumulation equivalence,
checkpoint roundtrip, loss decrease on a tiny LM."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_lm_params
from repro.train import (
    adamw_init,
    adamw_update,
    AdamWConfig,
    make_train_step,
    synthetic_batch,
    save_checkpoint,
    load_checkpoint,
)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 1e-3


def test_grad_clip_applied():
    params = {"w": jnp.asarray([1.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    _, _, gnorm = adamw_update(params, {"w": jnp.asarray([100.0])}, opt, cfg)
    assert float(gnorm) == 100.0  # reported pre-clip


def test_accumulation_matches_single_batch():
    cfg = get_config("granite-3-2b").reduced()
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, 8, 16, seed=0).items()}

    s1 = jax.jit(make_train_step(dataclasses.replace(cfg, accum_steps=1)))
    s4 = jax.jit(make_train_step(dataclasses.replace(cfg, accum_steps=4)))
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=2e-2)
    w1 = np.asarray(jax.tree.leaves(p1)[0], dtype=np.float64)
    w4 = np.asarray(jax.tree.leaves(p4)[0], dtype=np.float64)
    np.testing.assert_allclose(w1, w4, atol=3e-3)


def test_loss_decreases_on_learnable_stream():
    cfg = get_config("granite-3-2b").reduced()
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    losses = []
    for i in range(30):
        batch = {
            k: jnp.asarray(v) for k, v in synthetic_batch(cfg, 8, 32, seed=i).items()
        }
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("granite-3-2b").reduced()
    params = init_lm_params(jax.random.PRNGKey(7), cfg)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params)
    like = jax.tree.map(jnp.zeros_like, params)
    restored = load_checkpoint(path, like)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
        params,
        restored,
    )
