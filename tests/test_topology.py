"""The topology layer (DESIGN.md §13): tree-of-stars, async, membership.

The acceptance bar of the topology PR, pinned here:

  * tree parity — a sync tree-of-stars (loopback and TCP, depth >= 2)
    reproduces the single-star trajectory bit for bit for all six
    compressors, measured wire accounting included, at depth 2, depth 3 and
    under an explicit edge list — including mid-run checkpoint/resume
    through an aggregator;
  * async determinism — staleness=0 equals the sync barrier bit for bit;
    replay(schedule) is bit-identical over hypothesis-random arrival
    schedules, save/resume included;
  * elastic membership — a join+leave schedule converges, the joined
    client's uplink bits are accounted exactly (T*64-bit INIT_ACK), and a
    leave retires the client's contribution from the invariant exactly
    (recompute-from-mirrors, not approximate subtraction);
  * lifecycle — the `_LIVE` cluster registry reports zero leaks after
    depth-2 TCP trees tear down (the PR 6 refcount probe, one level deeper);
  * validation — TopologySpec shape mismatches are restore-incompatible
    with the exact subfield named; simulation backends and PP algorithms
    reject non-trivial topology/membership loudly.
"""

import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    MembershipEvent,
    MembershipSpec,
    TopologySpec,
    load_state,
    open_session,
    solve,
)
from repro.comm.topology import subtree_leaves

ALL_COMPRESSORS = ["identity", "topk", "randk", "randseqk", "toplek", "natural"]

SHAPE = (12, 4, 20)  # d, n_clients, n_i — small enough for per-round stepping
WIDE_SHAPE = (10, 8, 16)  # 8 clients: room for depth-3 trees + membership


def full_spec(**overrides) -> ExperimentSpec:
    base = dict(
        data=DataSpec(shape=SHAPE, seed=1),
        rounds=5,
        seed=0,
        backend="star-loopback",
    )
    base.update(overrides)
    return ExperimentSpec(**base)


def wide_spec(**overrides) -> ExperimentSpec:
    return full_spec(data=DataSpec(shape=WIDE_SHAPE, seed=1), **overrides)


def assert_reports_bit_identical(got, want):
    assert got.rounds == want.rounds
    for g, w in zip(got.records, want.records):
        assert float(g.grad_norm).hex() == float(w.grad_norm).hex()
        assert float(g.f).hex() == float(w.f).hex()
        assert g.sent_bits == w.sent_bits
        assert g.sent_bits_payload == w.sent_bits_payload
        assert g.sent_bits_wire == w.sent_bits_wire
    np.testing.assert_array_equal(got.x, want.x)


# ---------------------------------------------------------------------------
# TopologySpec / MembershipSpec: shape resolution + validation
# ---------------------------------------------------------------------------

def test_resolve_balanced_depth2():
    shape = TopologySpec(kind="tree", fanout=2, depth=2).resolve(8)
    assert shape == ((0, 1, 2, 3), (4, 5, 6, 7))


def test_resolve_balanced_depth3_partitions_leaves():
    shape = TopologySpec(kind="tree", fanout=2, depth=3).resolve(8)
    assert len(shape) == 2
    assert sorted(i for sub in shape for i in subtree_leaves(sub)) == list(
        range(8)
    )
    # depth 3: the root's children are themselves subtrees, not leaves
    assert all(isinstance(node, tuple) for sub in shape for node in sub)


def test_resolve_explicit_edges_must_partition():
    spec = TopologySpec(kind="tree", edges=((0, 2), (1, 3)))
    assert spec.resolve(4) == ((0, 2), (1, 3))
    with pytest.raises(ValueError, match="partition"):
        TopologySpec(kind="tree", edges=((0, 1), (1, 2))).resolve(3)
    with pytest.raises(ValueError, match="partition"):
        TopologySpec(kind="tree", edges=((0, 1),)).resolve(3)


def test_topology_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        TopologySpec(kind="ring")
    with pytest.raises(ValueError, match="fanout"):
        TopologySpec(kind="tree", fanout=1)
    with pytest.raises(ValueError, match="async"):
        TopologySpec(kind="tree", mode="async")
    with pytest.raises(ValueError, match="staleness"):
        TopologySpec(staleness=2)  # sync mode cannot bound staleness
    assert TopologySpec().trivial
    assert not TopologySpec(kind="tree").trivial
    assert not TopologySpec(mode="async").trivial


def test_membership_spec_validation():
    with pytest.raises(ValueError, match="action"):
        MembershipEvent(0, "pause", 1)
    mem = MembershipSpec(events=(MembershipEvent(2, "join", 3),))
    assert mem.initial_active(4) == [0, 1, 2]
    with pytest.raises(ValueError, match="outside"):
        mem.initial_active(2)
    with pytest.raises(ValueError, match="empty"):
        MembershipSpec(
            events=tuple(MembershipEvent(0, "join", i) for i in range(3))
        ).initial_active(3)


def test_simulation_backends_reject_topology():
    tree = TopologySpec(kind="tree", fanout=2, depth=2)
    for backend in ("local", "sharded"):
        with pytest.raises(ValueError, match="topology"):
            solve(full_spec(backend=backend, topology=tree))


def test_pp_rejects_topology_and_membership():
    with pytest.raises(ValueError, match="participation"):
        full_spec(
            algorithm="fednl-pp", tau=2,
            topology=TopologySpec(kind="tree", fanout=2, depth=2),
        )
    with pytest.raises(ValueError, match="participation"):
        full_spec(
            algorithm="fednl-pp", tau=2,
            membership=MembershipSpec(events=(MembershipEvent(1, "leave", 0),)),
        )


def test_membership_excludes_nontrivial_topology():
    with pytest.raises(ValueError, match="flat sync star"):
        full_spec(
            topology=TopologySpec(kind="tree", fanout=2, depth=2),
            membership=MembershipSpec(events=(MembershipEvent(1, "leave", 0),)),
        )


# ---------------------------------------------------------------------------
# tree-of-stars: star bit-parity (the tentpole acceptance bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compressor", ALL_COMPRESSORS)
def test_tree_loopback_matches_star_bitwise(compressor):
    """Depth-2 loopback tree == flat star, all six compressors: trajectory,
    analytic bits AND measured wire accounting, bit for bit."""
    spec = full_spec(compressor=CompressorSpec(compressor))
    want = solve(spec)
    got = solve(
        spec.replace(topology=TopologySpec(kind="tree", fanout=2, depth=2))
    )
    assert_reports_bit_identical(got, want)
    np.testing.assert_array_equal(
        got.extras["measured_payload_bits"],
        want.extras["measured_payload_bits"],
    )
    np.testing.assert_array_equal(
        got.extras["measured_frame_bytes"],
        want.extras["measured_frame_bytes"],
    )


@pytest.mark.parametrize(
    "topology",
    [
        TopologySpec(kind="tree", fanout=2, depth=3),
        TopologySpec(kind="tree", edges=((0, 3), (1, 2, 5), (4, 6, 7))),
    ],
    ids=["depth3", "edges"],
)
def test_tree_shapes_match_star_bitwise(topology):
    spec = wide_spec()
    want = solve(spec)
    got = solve(spec.replace(topology=topology))
    assert_reports_bit_identical(got, want)


def test_tree_sum_combine_is_close_not_bitwise():
    """combine='sum' re-associates the FP mean — documented ulp drift, same
    contract as the sweep engine's batch='vmap'."""
    spec = wide_spec()
    want = solve(spec)
    got = solve(
        spec.replace(
            topology=TopologySpec(kind="tree", fanout=4, depth=2, combine="sum")
        )
    )
    assert got.rounds == want.rounds
    np.testing.assert_allclose(got.x, want.x, rtol=1e-12, atol=1e-12)
    # the analytic uplink accounting is association-free and stays exact
    np.testing.assert_array_equal(got.sent_bits_payload, want.sent_bits_payload)


def test_tree_checkpoint_resume_through_aggregator(tmp_path):
    """Mid-run save under an aggregator topology resumes bit-identically —
    the broadcast replay crosses the aggregator layer."""
    spec = full_spec(topology=TopologySpec(kind="tree", fanout=2, depth=2))
    want = solve(spec)
    ck = tmp_path / "tree.fnlsess"
    with open_session(spec) as s:
        s.step(2)
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)


def test_tree_shape_is_restore_incompatible(tmp_path):
    """Restoring a tree checkpoint into a different tree shape fails loudly,
    naming the exact mismatched subfield (satellite: check_restore_from)."""
    spec = full_spec(topology=TopologySpec(kind="tree", fanout=2, depth=2))
    ck = tmp_path / "tree.fnlsess"
    with open_session(spec) as s:
        s.step(2)
        s.save(ck)
    with pytest.raises(ValueError, match=r"topology\.fanout"):
        open_session(
            spec.replace(topology=TopologySpec(kind="tree", fanout=3, depth=2)),
            restore=ck,
        )
    with pytest.raises(ValueError, match=r"topology"):
        open_session(spec.replace(topology=None), restore=ck)


# ---------------------------------------------------------------------------
# bounded-staleness async aggregation
# ---------------------------------------------------------------------------

def test_async_staleness_zero_equals_sync_bitwise():
    spec = full_spec()
    want = solve(spec)
    got = solve(spec.replace(topology=TopologySpec(mode="async")))
    assert_reports_bit_identical(got, want)


def test_async_converges_and_is_deterministic():
    topo = TopologySpec(mode="async", staleness=2, max_delay=3, schedule_seed=7)
    spec = full_spec(topology=topo, rounds=12)
    a = solve(spec)
    b = solve(spec)
    assert_reports_bit_identical(a, b)
    assert a.grad_norms[-1] < a.grad_norms[0]
    # staleness shows up as per-round participant sets, recorded in the report
    assert all(r.participants is not None for r in a.records)


def test_async_checkpoint_resume(tmp_path):
    topo = TopologySpec(mode="async", staleness=1, max_delay=2, schedule_seed=3)
    spec = full_spec(topology=topo, rounds=8)
    want = solve(spec)
    ck = tmp_path / "async.fnlsess"
    with open_session(spec) as s:
        s.step(4)  # checkpoint with updates still in flight
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)


def test_async_replay_determinism_property():
    """Hypothesis: for random (staleness, max_delay, schedule_seed), the run
    is a pure function of the spec — rerun and mid-run save/resume are both
    bit-identical (the arrival schedule is spec'd, not wall-clock)."""
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)",
    )
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=6, deadline=None)
    @given(
        staleness=st.integers(0, 3),
        max_delay=st.integers(0, 4),
        schedule_seed=st.integers(0, 1000),
    )
    def run(staleness, max_delay, schedule_seed):
        topo = TopologySpec(
            mode="async",
            staleness=staleness,
            max_delay=max_delay,
            schedule_seed=schedule_seed,
        )
        spec = full_spec(topology=topo, rounds=5)
        a = solve(spec)
        b = solve(spec)
        assert_reports_bit_identical(a, b)

    run()


# ---------------------------------------------------------------------------
# elastic membership
# ---------------------------------------------------------------------------

JOIN_LEAVE = MembershipSpec(
    events=(
        MembershipEvent(round=2, action="join", client=7),
        MembershipEvent(round=4, action="leave", client=0),
    )
)


def test_membership_join_leave_converges():
    spec = wide_spec(membership=JOIN_LEAVE, rounds=10)
    rep = solve(spec)
    assert rep.grad_norms[-1] < 1e-6
    assert rep.records[0].participants == tuple(range(7))  # 7 not joined yet
    assert rep.records[2].participants == tuple(range(8))  # joined at round 2
    assert rep.records[4].participants == tuple(range(1, 8))  # 0 left at r4


def test_membership_join_bits_accounted_exactly():
    """The joining client's state uplink is counted into that round's bits
    exactly: T*64 payload bits for the late INIT_ACK (T = d(d+1)/2), plus
    the 32-byte frame header in the framed accounting."""
    d = WIDE_SHAPE[0]
    t_bits = d * (d + 1) // 2 * 64
    spec = wide_spec(membership=JOIN_LEAVE, rounds=10)
    rep = solve(spec)
    base = solve(wide_spec(rounds=10))
    per_up_pay = base.records[1].sent_bits_payload // WIDE_SHAPE[1]
    per_up_frame = (8 * base.extras["measured_frame_bytes"][1]) // WIDE_SHAPE[1]
    # round 2 = 7 regular uplinks pre-join-count + the join ack + the new
    # member's own uplink; vs round 1 (7 uplinks): delta == one uplink + ack
    got_delta = (
        rep.records[2].sent_bits_payload - rep.records[1].sent_bits_payload
    )
    assert got_delta == per_up_pay + t_bits
    frame_delta = 8 * (
        rep.extras["measured_frame_bytes"][2]
        - rep.extras["measured_frame_bytes"][1]
    )
    assert frame_delta == per_up_frame + t_bits + 32 * 8


def test_membership_leave_retires_contribution_exactly():
    """After a leave, H_global is the mean of the REMAINING clients' mirrors
    — bitwise what a fresh aggregation over the survivors would give (exact
    retirement, not approximate subtraction)."""
    import jax.numpy as jnp

    from repro.comm.topology import open_loopback_master

    spec = wide_spec(membership=JOIN_LEAVE)
    z = spec.data.build()
    m = open_loopback_master(
        z, spec.fednl_config(), membership=JOIN_LEAVE, seed=spec.seed
    )
    m.init_handshake()
    for r in range(4):
        m.step_round(r)
    # the leave fires at the start of round 4: client 0's STOP goes out and
    # H_global is recomputed as the mean of the surviving mirrors
    survivors = [c for c in m.order if c != 0]
    want = jnp.mean(jnp.stack([m._mirrors[c] for c in survivors]), axis=0)
    m._apply_events(4, m.x)
    np.testing.assert_array_equal(np.asarray(m.h_global), np.asarray(want))
    assert m.order == survivors and 0 not in m._mirrors
    m.stop()


def test_membership_checkpoint_resume(tmp_path):
    spec = wide_spec(membership=JOIN_LEAVE, rounds=8)
    want = solve(spec)
    ck = tmp_path / "mem.fnlsess"
    with open_session(spec) as s:
        s.step(3)  # past the join, before the leave
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)
    assert got.records[4].participants == tuple(range(1, 8))


def test_membership_is_restore_incompatible_when_events_differ(tmp_path):
    spec = wide_spec(membership=JOIN_LEAVE, rounds=8)
    ck = tmp_path / "mem.fnlsess"
    with open_session(spec) as s:
        s.step(2)
        s.save(ck)
    with pytest.raises(ValueError, match="membership"):
        open_session(spec.replace(membership=None), restore=ck)


# ---------------------------------------------------------------------------
# star-tcp: real process trees (net marker)
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_tree_tcp_matches_star_bitwise_no_leaks():
    """Depth-2 TCP process tree == flat star bitwise, and the _LIVE cluster
    registry reports zero leaks after teardown (satellite: the PR 6 refcount
    probe extended to trees — aggregators release children before the root
    cluster closes)."""
    from repro.launch.multiproc import ClientCluster

    before = ClientCluster.live_count()
    spec = full_spec(rounds=4)
    want = solve(spec)
    got = solve(
        spec.replace(
            backend="star-tcp",
            topology=TopologySpec(kind="tree", fanout=2, depth=2),
        )
    )
    assert_reports_bit_identical(got, want)
    assert ClientCluster.live_count() == before


@pytest.mark.net
def test_tree_tcp_checkpoint_resume(tmp_path):
    spec = full_spec(
        backend="star-tcp",
        topology=TopologySpec(kind="tree", fanout=2, depth=2),
        rounds=4,
    )
    want = solve(spec)
    ck = tmp_path / "treetcp.fnlsess"
    with open_session(spec) as s:
        s.step(2)
        s.save(ck)
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)


_TREE_KILL_SCRIPT = """
import sys, os

# the __main__ guard matters: star-tcp spawns worker processes that re-import
# this module under multiprocessing's spawn context
if __name__ == "__main__":
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.api import DataSpec, ExperimentSpec, TopologySpec, open_session

    spec = ExperimentSpec(data=DataSpec(shape=(12, 4, 20), seed=1), rounds=5,
                          seed=0, backend="star-tcp",
                          topology=TopologySpec(kind="tree", fanout=2, depth=2))
    s = open_session(spec)
    s.step(2)
    s.save(sys.argv[1])
    # die without closing anything: no STOP fan-down, no cluster join — the
    # aggregators see EOF on their parent sockets and tear down their own
    # subtrees (leaves-first), so nothing outlives the master
    os._exit(17)
"""


@pytest.mark.net
def test_tree_tcp_kill_and_resume_subprocess(tmp_path):
    """A tree-of-stars master killed mid-run resumes from its checkpoint in
    a fresh process tree, bit-identical to the uninterrupted run (and
    bit-identical to the flat star, transitively)."""
    script = tmp_path / "kill_tree_master.py"
    script.write_text(_TREE_KILL_SCRIPT)
    ck = tmp_path / "killed_tree.fnlsess"
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = str(
        pathlib.Path(__file__).resolve().parent.parent / "src"
    )
    proc = subprocess.run(
        [sys.executable, str(script), str(ck)],
        capture_output=True,
        text=True,
        timeout=300,
        env=env,
    )
    assert proc.returncode == 17, proc.stderr
    assert ck.exists()
    st = load_state(ck)
    assert st.round == 2 and st.backend == "star-tcp"

    spec = full_spec(
        backend="star-tcp",
        topology=TopologySpec(kind="tree", fanout=2, depth=2),
        rounds=5,
    )
    want = solve(full_spec(rounds=5))
    with open_session(spec, restore=ck) as s:
        got = s.run()
    assert_reports_bit_identical(got, want)
