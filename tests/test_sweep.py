"""SweepSpec -> solve_many: expansion contracts and engine bit-parity.

Two layers of guarantees:

  * spec layer: ``ExperimentSpec.grid`` expansion is validated like a
    hand-built spec, and invalid axis values fail with the same
    registry-backed errors as ``solve()`` (the hypothesis-driven expansion
    properties live in tests/test_sweep_properties.py);
  * engine layer: ``solve_many`` over seeds x compressors grids on the local
    backend returns per-spec results BIT-identical to sequential ``solve()``
    (the acceptance criterion of the sweep engine), mixed-backend sweeps
    dispatch through pool/fallback without dropping specs, and the
    aggregation helpers reshape the per-round records faithfully.
"""

import numpy as np
import pytest

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    SweepSpec,
    solve,
    solve_many,
)

BASE = ExperimentSpec(data=DataSpec(dataset="tiny", seed=1), rounds=4)


def assert_bit_identical(got, want):
    assert [g.hex() for g in got.grad_norms] == [
        g.hex() for g in want.grad_norms
    ], "grad-norm trajectory drifted from sequential solve()"
    np.testing.assert_array_equal(got.x, want.x)
    assert list(got.sent_bits) == list(want.sent_bits)
    assert list(got.sent_bits_wire) == list(want.sent_bits_wire)


# ---------------------------------------------------------------------------
# expansion contracts (fixed cases; properties in test_sweep_properties.py)
# ---------------------------------------------------------------------------

def test_grid_expansion_fixed_case():
    sweep = BASE.grid(seed=[0, 1, 2], compressor=["topk", "randseqk"])
    specs = sweep.specs()
    assert len(specs) == sweep.n_specs == 6
    assert specs == BASE.grid(seed=[0, 1, 2], compressor=["topk", "randseqk"]).specs()
    assert len(set(specs)) == 6
    assert [(s.seed, s.compressor.name) for s in specs] == [
        (s, c) for s in [0, 1, 2] for c in ["topk", "randseqk"]
    ]


def test_grid_invalid_axis_values_fail_like_solve():
    # spec-level validation errors surface at expansion, identical to
    # hand-building the spec
    with pytest.raises(ValueError, match="unknown option"):
        BASE.grid(option=["A", "Z"]).specs()
    with pytest.raises(ValueError, match="accounting"):
        BASE.grid(accounting=["payload", "bytes"]).specs()
    with pytest.raises(ValueError, match="partial participation"):
        BASE.grid(tau=[2]).specs()  # tau on a full-participation algorithm
    # registry-backed errors surface from solve_many exactly as from solve()
    with pytest.raises(KeyError, match="unknown algorithm"):
        solve_many(BASE.grid(algorithm=["fednl", "fednl2"]))
    with pytest.raises(KeyError, match="unknown backend"):
        solve_many(BASE.grid(backend=["local", "ray"]))
    with pytest.raises(KeyError, match="unknown compressor"):
        solve_many(BASE.grid(compressor=["topk", "bzip2"], rounds=[1]))


def test_sweep_spec_shape_validation():
    with pytest.raises(ValueError, match="unknown sweep axis"):
        BASE.grid(compresor=["topk"])  # typo'd axis name
    with pytest.raises(ValueError, match="duplicate values"):
        BASE.grid(seed=[1, 1])
    with pytest.raises(ValueError, match="no values"):
        BASE.grid(seed=[])
    with pytest.raises(ValueError, match="unknown batch mode"):
        BASE.grid(seed=[0, 1], batch="eventually")
    with pytest.raises(ValueError, match="duplicate sweep axis"):
        SweepSpec(base=BASE, axes=(("seed", (0,)), ("seed", (1,))))
    with pytest.raises(ValueError, match="duplicate specs"):
        # distinct axis values that normalize to the same spec
        BASE.grid(compressor=["topk", CompressorSpec("topk")]).specs()
    # a SweepSpec is frozen data, like the ExperimentSpec it expands
    sweep = BASE.grid(seed=[0, 1])
    assert sweep.replace(batch="never").batch == "never"
    assert sweep.batch == "auto"


# ---------------------------------------------------------------------------
# engine bit-parity (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_solve_many_8_spec_grid_bit_identical_to_sequential():
    """>= 8 specs (seeds x compressors, local backend) through one batched
    program == sequential solve(), bit for bit."""
    sweep = BASE.grid(seed=[0, 1, 2, 3], compressor=["topk", "randseqk"])
    rep = solve_many(sweep)
    assert rep.extras["batched_specs"] == 8, rep.log
    assert len(rep.reports) == 8
    for spec, got in zip(sweep.specs(), rep.reports):
        assert_bit_identical(got, solve(spec))
        assert got.extras["sweep_batched"] is True
        assert got.extras["compressor_branch"] == spec.compressor.name


def test_solve_many_ls_and_data_axis_bit_identical():
    """FedNL-LS batches too (Armijo while_loop in the mapped region), and a
    data axis splits into per-dataset programs that stay bit-exact."""
    sweep = BASE.replace(algorithm="fednl-ls", option="A").grid(
        data_seed=[1, 2], compressor=["randseqk", "toplek"]
    )
    rep = solve_many(sweep)
    assert rep.extras["batched_specs"] == 4
    assert rep.extras["n_groups"] == 2  # one compiled program per DataSpec
    for spec, got in zip(sweep.specs(), rep.reports):
        ref = solve(spec)
        assert_bit_identical(got, ref)
        assert [r.ls_steps for r in got.records] == [
            r.ls_steps for r in ref.records
        ]


def test_solve_many_mixed_backend_dispatch():
    """Wire-backend specs go through the worker pool, local ones batch; no
    spec is dropped and every result matches its sequential run."""
    sweep = BASE.grid(backend=["local", "star-loopback"], seed=[0, 1])
    rep = solve_many(sweep)
    assert len(rep.reports) == 4
    assert rep.extras["batched_specs"] == 2
    assert any("pool" in line for line in rep.log)
    for spec, got in zip(sweep.specs(), rep.reports):
        assert got.backend == spec.backend
        assert_bit_identical(got, solve(spec))


def test_solve_many_fallbacks_are_logged_not_dropped():
    """Incompatible specs (PP on local, tol early-stop) fall back per spec
    with a logged reason."""
    specs = [
        BASE.replace(algorithm="fednl-pp", tau=3, rounds=3),
        BASE.replace(tol=1e-10, rounds=30),
        BASE.replace(seed=5),  # lone batchable spec -> sequential, logged
    ]
    rep = solve_many(specs)
    assert len(rep.reports) == 3 and all(r is not None for r in rep.reports)
    assert rep.extras["batched_specs"] == 0
    assert sum("fallback" in line for line in rep.log) == 3
    ref_pp = solve(specs[0])
    np.testing.assert_array_equal(rep.reports[0].x_hist, ref_pp.x_hist)
    assert rep.reports[1].rounds == solve(specs[1]).rounds  # early stop honored


def test_solve_many_batch_never_and_list_input():
    sweep = BASE.grid(seed=[0, 1], batch="never")
    rep = solve_many(sweep)
    assert rep.extras["batched_specs"] == 0
    for spec, got in zip(sweep.specs(), rep.reports):
        assert_bit_identical(got, solve(spec))
    # plain spec lists are accepted too
    as_list = solve_many(list(sweep.specs()))
    assert len(as_list.reports) == 2
    with pytest.raises(ValueError, match="empty sweep"):
        solve_many([])
    with pytest.raises(TypeError, match="SweepSpec or ExperimentSpecs"):
        solve_many(["fednl"])


def test_solve_many_vmap_mode_close_to_sequential():
    """The opt-in vmap layout waives bit-identity but must stay within
    float64 noise of the sequential trajectory."""
    sweep = BASE.grid(seed=[0, 1], compressor=["topk", "randseqk"], batch="vmap")
    rep = solve_many(sweep)
    assert rep.extras["batched_specs"] == 4
    for spec, got in zip(sweep.specs(), rep.reports):
        ref = solve(spec)
        np.testing.assert_allclose(got.x, ref.x, rtol=0, atol=1e-12)
        np.testing.assert_allclose(
            got.grad_norms, ref.grad_norms, rtol=1e-9, atol=1e-15
        )
        # the bit models are integer-exact in every layout
        assert list(got.sent_bits) == list(ref.sent_bits)


# ---------------------------------------------------------------------------
# SweepReport aggregation
# ---------------------------------------------------------------------------

def test_sweep_report_aggregation_helpers():
    sweep = BASE.grid(seed=[0, 1], compressor=["topk", "randseqk"])
    rep = solve_many(sweep)
    by_comp = rep.group_by("compressor.name")
    assert set(by_comp) == {("topk",), ("randseqk",)}
    assert all(len(v) == 2 for v in by_comp.values())
    rows = rep.table("seed", "compressor.name")
    assert len(rows) == 4
    assert rows[0]["compressor.name"] == "topk" and rows[0]["rounds"] == 4
    assert all(row["sent_bits_total"] > 0 for row in rows)
    gn = rep.round_table("grad_norm")
    assert gn.shape == (4, 4) and not np.isnan(gn).any()
    np.testing.assert_array_equal(gn[0], rep.reports[0].grad_norms)
    bits = rep.round_table("sent_bits")
    assert (bits > 0).all()
    assert "4 specs" in rep.summary()
    assert rep[0] is rep.reports[0] and len(rep) == 4
    assert [r for r in rep] == rep.reports


@pytest.mark.slow
def test_solve_many_shards_across_devices_bit_identical():
    """With multiple (forced host) devices the spec axis is sharded across
    the 1-D sweep mesh; trajectories stay bit-identical to sequential
    solve() on the default single device.  Runs in a subprocess because
    XLA_FLAGS must be set before jax initializes."""
    import json
    import os
    import subprocess
    import sys

    code = r"""
import json, os, sys
import jax
jax.config.update("jax_enable_x64", True)
from repro.api import DataSpec, ExperimentSpec, solve_many
assert jax.device_count() == 4, jax.device_count()
base = ExperimentSpec(data=DataSpec(dataset="tiny", seed=1), rounds=4)
rep = solve_many(base.grid(seed=[0, 1, 2, 3], compressor=["topk", "randseqk"]))
assert rep.reports[0].extras["devices"] == 4, rep.reports[0].extras
out = [[g.hex() for g in r.grad_norms] for r in rep.reports]
print(json.dumps(out))
"""
    env = dict(
        os.environ,
        XLA_FLAGS=os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4",
        JAX_PLATFORMS="cpu",
    )
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    sharded = json.loads(proc.stdout.strip().splitlines()[-1])
    base = ExperimentSpec(data=DataSpec(dataset="tiny", seed=1), rounds=4)
    for traj, spec in zip(
        sharded, base.grid(seed=[0, 1, 2, 3], compressor=["topk", "randseqk"]).specs()
    ):
        assert traj == [g.hex() for g in solve(spec).grad_norms], (
            "device-sharded sweep drifted from the single-device trajectory"
        )
