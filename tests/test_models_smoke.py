"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step and a two-token decode on
CPU, asserting output shapes and absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import init_lm_params, init_decode_cache
from repro.models.lm import lm_forward, padded_vocab
from repro.models.encdec import init_encdec_params, init_encdec_cache
from repro.train import make_train_step, make_serve_step, synthetic_batch
from repro.train.optimizer import adamw_init

ARCHS = list_archs()


def _init(cfg, key):
    if cfg.family == "encdec":
        return init_encdec_params(key, cfg)
    return init_lm_params(key, cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = _init(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, 4, 32, seed=0).items()}
    step = jax.jit(make_train_step(cfg))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, p2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = _init(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_serve_step(cfg))
    if cfg.family == "encdec":
        cache = init_encdec_cache(cfg, 2, 64, 16)
    else:
        cache = init_decode_cache(cfg, 2, 64)
    toks = jnp.zeros((2, 1), dtype=jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, toks)
    assert logits.shape == (2, 1, padded_vocab(cfg))
    assert int(cache["pos"]) == 3
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize(
    "arch", ["granite-3-2b", "mamba2-2.7b", "recurrentgemma-2b", "chatglm3-6b"]
)
def test_decode_matches_forward(arch):
    """Teacher-forced forward logits == step-by-step decode logits.

    MoE archs are excluded: bf16 noise can flip top-k routing between the
    batched-forward and decode paths, which changes logits legitimately.
    """
    cfg = get_config(arch).reduced()
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts after image prefix")
    params = init_lm_params(jax.random.PRNGKey(1), cfg)
    s = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, s), 0, cfg.vocab)
    full = lm_forward(params, cfg, toks)  # (1, s, Vp)
    step = jax.jit(make_serve_step(cfg))
    cache = init_decode_cache(cfg, 1, 32)
    outs = []
    for t in range(s):
        logits, cache = step(params, cache, toks[:, t : t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, dtype=np.float32),
        np.asarray(full, dtype=np.float32),
        atol=0.2,  # bf16 accumulation-order differences
        rtol=0.05,
    )


def test_vlm_concatenates_image_tokens():
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, 8), dtype=jnp.int32)
    img = jnp.ones((2, cfg.n_frontend_tokens, cfg.d_model), dtype=jnp.float32)
    out = lm_forward(params, cfg, toks, img_embeds=img)
    assert out.shape == (2, 8 + cfg.n_frontend_tokens, padded_vocab(cfg))


def test_hybrid_layer_pattern():
    from repro.models.lm import layer_types

    cfg = get_config("recurrentgemma-2b")
    types = layer_types(cfg)
    assert len(types) == 26
    # griffin 1:2 — every third layer is attention
    assert (types[2::3] == 0).all() and (types[0::3] == 1).all()


def test_full_configs_match_assignment():
    """The exact published dims from the assignment block."""
    import math

    checks = {
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for arch, (nl, d, h, kv, ff, v) in checks.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff, cfg.vocab) == (
            nl, d, h, kv, ff, v,
        ), arch
    m = get_config("mamba2-2.7b")
    assert (m.n_layers, m.d_model, m.vocab, m.ssm.d_state) == (64, 2560, 50280, 128)
    x = get_config("mixtral-8x22b")
    assert (x.moe.n_experts, x.moe.top_k) == (8, 2)
    g = get_config("granite-moe-1b-a400m")
    assert (g.moe.n_experts, g.moe.top_k) == (32, 8)
