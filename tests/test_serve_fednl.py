"""The multi-tenant serving engine (repro.serve_fednl) — DESIGN.md §11.

The acceptance bar is the §11 invariant: every tenant served through
``FedNLServer`` produces round records and a final model bit-identical to a
solo ``open_session(spec).run()`` — whatever it was batched with, however
the tenants arrived, and however often memory pressure spilled it to disk
in between.  Plus the engine mechanics: admission/eviction ordering under
capacity pressure, the spill file being an *ordinary* FNLS1 session
checkpoint, tenant-local failure isolation, and clean shutdown (no leaked
sessions or client process fleets).
"""

import numpy as np
import pytest

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    open_session,
    solve,
)
from repro.serve_fednl import FedNLServer, ServeConfig, serve_all

SHAPE = (12, 4, 20)  # d, n_clients, n_i — small enough for per-tick rounds


def spec_of(seed=0, comp="topk", rounds=6, algo="fednl", backend="local",
            data_seed=1, tol=0.0, km=8.0, **overrides):
    return ExperimentSpec(
        data=DataSpec(shape=SHAPE, seed=data_seed),
        algorithm=algo,
        compressor=CompressorSpec(comp, km),
        backend=backend,
        rounds=rounds,
        tol=tol,
        seed=seed,
        **overrides,
    )


_SOLO_CACHE: dict = {}


def solo_report(spec):
    """Reference trajectory: a solo session run (cached per spec)."""
    if spec not in _SOLO_CACHE:
        with open_session(spec) as s:
            _SOLO_CACHE[spec] = s.run()
    return _SOLO_CACHE[spec]


def assert_served_bit_identical(got, spec):
    want = solo_report(spec)
    assert got.rounds == want.rounds
    for g, w in zip(got.records, want.records):
        assert g.round == w.round
        assert (g.grad_norm is None) == (w.grad_norm is None)
        if g.grad_norm is not None:
            assert float(g.grad_norm).hex() == float(w.grad_norm).hex()
        if g.f is not None:
            assert float(g.f).hex() == float(w.f).hex()
        assert g.sent_bits == w.sent_bits
        assert g.sent_bits_payload == w.sent_bits_payload
        assert g.sent_bits_wire == w.sent_bits_wire
        if g.x is not None or w.x is not None:
            np.testing.assert_array_equal(g.x, w.x)
        assert g.participants == w.participants
    np.testing.assert_array_equal(got.x, want.x)


# ---------------------------------------------------------------------------
# bit parity: engine-served == solo, across everything that may co-batch
# ---------------------------------------------------------------------------

def test_parity_mixed_compressors_rounds_and_algorithms():
    # one shared problem, mixed compressors / k / seeds / round budgets and
    # both batched algorithms — maximal co-batching, per-slot stops
    specs = [
        spec_of(seed=0, comp="topk", rounds=6),
        spec_of(seed=1, comp="randk", rounds=4),
        spec_of(seed=2, comp="randseqk", rounds=7),
        spec_of(seed=3, comp="topk", km=4.0, rounds=5),
        spec_of(seed=4, comp="identity", rounds=3),
        spec_of(seed=5, comp="topk", rounds=5, algo="fednl-ls"),
    ]
    reports = serve_all(specs)
    for spec, rep in zip(specs, reports):
        assert_served_bit_identical(rep, spec)
        assert rep.extras["served"] is True


def test_parity_staggered_admission_and_mixed_data():
    # tenants arrive mid-flight at differing round indices, across TWO
    # problems (distinct data seeds -> distinct groups, z closed over)
    first = [spec_of(seed=0, rounds=8), spec_of(seed=1, rounds=8, data_seed=2)]
    late = [spec_of(seed=2, comp="randk", rounds=5),
            spec_of(seed=3, comp="randseqk", rounds=5, data_seed=2)]
    with FedNLServer() as srv:
        handles = [srv.submit(s) for s in first]
        srv.tick()
        srv.tick()  # first two are now at round >= 1
        handles += [srv.submit(s) for s in late]
        srv.serve_until_idle(max_ticks=100)
        for spec, h in zip(first + late, handles):
            assert_served_bit_identical(h.result(), spec)
        assert srv.stats()["groups"] == 2


def test_parity_tol_early_stop():
    # tol > 0 blocks the *sweep* batch lane but not the serve lane (the
    # tick loop host-syncs every round anyway); stop on the same record
    spec = spec_of(seed=0, rounds=40, tol=1e-10)
    rep = serve_all([spec, spec_of(seed=1, rounds=6)])[0]
    assert_served_bit_identical(rep, spec)
    assert rep.rounds < 40  # the tol actually fired


def test_parity_solo_lane_backends():
    # specs the batch lane cannot take: the wire protocol and PP run as
    # per-tenant sessions stepped one round per tick
    specs = [
        spec_of(seed=0, rounds=5, backend="star-loopback"),
        spec_of(seed=1, rounds=5, algo="fednl-pp", tau=3),
    ]
    for spec, rep in zip(specs, serve_all(specs)):
        assert_served_bit_identical(rep, spec)


def test_parity_under_memory_pressure():
    # 8 tenants through 3 resident slots: constant spill/resume churn must
    # not move a single bit
    specs = [
        spec_of(seed=i, comp=["topk", "randk", "randseqk"][i % 3],
                rounds=5 + i % 3)
        for i in range(8)
    ]
    with FedNLServer(ServeConfig(max_resident=3, admit_per_tick=2)) as srv:
        handles = [srv.submit(s) for s in specs]
        srv.serve_until_idle(max_ticks=500)
        st = srv.stats()
        assert st["spills"] > 0 and st["resumes"] > 0
        for spec, h in zip(specs, handles):
            assert_served_bit_identical(h.result(), spec)


@pytest.mark.parametrize("eviction", ["lru", "cost"])
def test_parity_under_pressure_both_victim_policies(eviction):
    specs = [spec_of(seed=i, rounds=4) for i in range(4)]
    cfg = ServeConfig(max_resident=2, admit_per_tick=2, eviction=eviction)
    with FedNLServer(cfg) as srv:
        handles = [srv.submit(s) for s in specs]
        srv.serve_until_idle(max_ticks=200)
        assert srv.stats()["spills"] > 0
        for spec, h in zip(specs, handles):
            assert_served_bit_identical(h.result(), spec)


def test_zero_round_spec_finishes_at_admission():
    spec = spec_of(seed=0, rounds=0)
    rep = serve_all([spec])[0]
    want = solve(spec)
    assert rep.rounds == want.rounds == 0
    np.testing.assert_array_equal(rep.x, want.x)


# ---------------------------------------------------------------------------
# admission / eviction ordering
# ---------------------------------------------------------------------------

def test_admission_is_fifo_and_capacity_bounded():
    specs = [spec_of(seed=i, rounds=30) for i in range(5)]
    cfg = ServeConfig(max_resident=2, admit_per_tick=2)
    with FedNLServer(cfg) as srv:
        handles = [srv.submit(s) for s in specs]
        assert [h.status for h in handles] == ["queued"] * 5
        srv.tick()
        # first two submitted are first admitted; capacity holds the rest
        assert [h.status for h in handles[:2]] == ["running", "running"]
        assert all(h.round >= 1 for h in handles[:2])
        running = sum(h.status == "running" for h in handles)
        assert running <= cfg.max_resident
        srv.tick()
        # pressure spills the LRU residents to admit the queue head, which
        # re-queues the victims: round-robin, nobody starves
        assert sum(h.status == "running" for h in handles) <= cfg.max_resident


def test_explicit_evict_checkpoint_roundtrip(tmp_path):
    spec = spec_of(seed=7, comp="randk", rounds=10)
    cfg = ServeConfig(spill_dir=tmp_path)
    with FedNLServer(cfg) as srv:
        h = srv.submit(spec)
        for _ in range(4):
            srv.tick()
        path = srv.evict(h.id)
        assert h.status == "evicted"
        assert path.exists()
        with pytest.raises(RuntimeError, match="evicted"):
            h.result()
        # the engine resumes its own eviction bit-identically
        h2 = srv.resume(path)
        assert h2.round == 4
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)
    # and the spill file is an ORDINARY session checkpoint: resumable
    # outside the engine entirely (the §11 spill contract)
    with open_session(spec, restore=path) as s:
        outside = s.run()
    assert_served_bit_identical(outside, spec)


def test_evict_solo_lane_tenant_releases_session(tmp_path):
    spec = spec_of(seed=0, rounds=10, backend="star-loopback")
    with FedNLServer(ServeConfig(spill_dir=tmp_path)) as srv:
        h = srv.submit(spec)
        srv.tick()
        srv.tick()
        path = srv.evict(h.id)
        assert path.exists() and h.status == "evicted"
        # resume through the engine: client state rebuilt by protocol replay
        h2 = srv.resume(path)
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)


def test_evict_queued_resume_tenant_persists_pending_state(tmp_path):
    spec = spec_of(seed=3, rounds=8)
    with FedNLServer(ServeConfig(spill_dir=tmp_path)) as srv:
        h = srv.submit(spec)
        for _ in range(3):
            srv.tick()
        ck = srv.evict(h.id)
        h2 = srv.resume(ck)  # queued with a pending restore...
        ck2 = srv.evict(h2.id)  # ...evicted before ever being admitted
        assert ck2.exists()
        h3 = srv.resume(ck2)
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h3.result(), spec)


# ---------------------------------------------------------------------------
# validation, failure isolation, lifecycle
# ---------------------------------------------------------------------------

def test_submit_validates_like_solve():
    with FedNLServer() as srv:
        with pytest.raises(ValueError, match="partial participation"):
            srv.submit(spec_of(algo="fednl-pp", tau=3), until=1e-8)
        with pytest.raises(KeyError):
            srv.submit(spec_of(comp="no-such-compressor"))
        with pytest.raises(Exception):
            srv.submit(spec_of(algo="fednl-ls", backend="star-loopback"))


def test_until_overrides_spec_stop():
    spec = spec_of(seed=0, rounds=9)
    with FedNLServer() as srv:
        h = srv.submit(spec, until=3)
        srv.serve_until_idle(max_ticks=50)
        rep = h.result()
    assert rep.rounds == 3
    want = solo_report(spec)
    for g, w in zip(rep.records, want.records[:3]):
        assert float(g.grad_norm).hex() == float(w.grad_norm).hex()


def test_shutdown_evicts_and_result_raises():
    srv = FedNLServer()
    h = srv.submit(spec_of(seed=0, rounds=50))
    srv.tick()
    srv.shutdown()
    assert h.status == "evicted"
    assert h.wait(timeout=1)  # shutdown resolves waiters
    with pytest.raises(RuntimeError):
        srv.tick()
    with pytest.raises(RuntimeError):
        srv.submit(spec_of(seed=1))


def test_shutdown_with_spill_leaves_resumable_checkpoints(tmp_path):
    spec = spec_of(seed=4, rounds=8)
    srv = FedNLServer(ServeConfig(spill_dir=tmp_path))
    h = srv.submit(spec)
    for _ in range(3):
        srv.tick()
    srv.shutdown(spill=True)
    (ck,) = tmp_path.glob(f"{h.id}.*")
    with FedNLServer(ServeConfig(spill_dir=tmp_path / "second")) as srv2:
        h2 = srv2.resume(ck)
        srv2.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)


def test_background_thread_serving():
    specs = [spec_of(seed=i, rounds=4) for i in range(3)]
    with FedNLServer() as srv:
        srv.start()
        handles = [srv.submit(s) for s in specs]
        for h in handles:
            assert h.wait(timeout=120)
        srv.stop()
        for spec, h in zip(specs, handles):
            assert_served_bit_identical(h.result(), spec)


def test_tick_program_reuse_across_reformed_groups():
    # same slot-count bucket -> the SAME compiled tick program serves
    # re-formed groups; compiles stay O(log n) per group key, not O(ticks)
    specs = [spec_of(seed=i, rounds=6) for i in range(4)]
    with FedNLServer(ServeConfig(max_resident=2, admit_per_tick=2)) as srv:
        for s in specs:
            srv.submit(s)
        srv.serve_until_idle(max_ticks=200)
        st = srv.stats()
        assert st["batch_launches"] > st["compiles"]
        assert st["compiles"] <= 3  # slot buckets {1, 2} x one branch growth
        assert 0 < st["batch_occupancy"] <= 1


# ---------------------------------------------------------------------------
# ClientCluster lifecycle (the refcounted teardown satellite)
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_star_tcp_tenant_evicted_mid_run_leaks_no_processes(tmp_path):
    from repro.launch.multiproc import ClientCluster

    assert ClientCluster.live_count() == 0
    spec = spec_of(seed=0, rounds=6, backend="star-tcp")
    with FedNLServer(ServeConfig(spill_dir=tmp_path)) as srv:
        h = srv.submit(spec)
        srv.tick()
        srv.tick()
        assert ClientCluster.live_count() == 1
        path = srv.evict(h.id)  # spill closes the session -> fleet torn down
        assert ClientCluster.live_count() == 0
        h2 = srv.resume(path)
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)
    assert ClientCluster.live_count() == 0


def test_cluster_refcounting_contract():
    # pure lifecycle logic, no sockets: exercise acquire/release/close on a
    # structurally empty cluster instance
    from repro.launch.multiproc import ClientCluster, _LIVE_CLUSTERS

    c = ClientCluster.__new__(ClientCluster)
    import threading

    c._refs = 1
    c._closed = False
    c._lifecycle_lock = threading.Lock()
    c.conns = {}
    c.procs = []

    class _FakeMaster:
        closed = 0

        def close(self):
            self.closed += 1

    c._master = _FakeMaster()
    c.acquire()
    assert c._refs == 2
    c.release()
    assert not c.closed
    c.release()  # last holder out -> teardown
    assert c.closed and c._master.closed == 1
    c.close()  # idempotent
    assert c._master.closed == 1
    with pytest.raises(RuntimeError):
        c.acquire()
    assert c not in _LIVE_CLUSTERS


# ---------------------------------------------------------------------------
# property test: random admit / evict / tick schedules (hypothesis)
# ---------------------------------------------------------------------------

try:  # only the property test needs hypothesis — the rest of the module
    # must run without it (requirements-dev.txt), so no importorskip here
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev-only dependency
    HAVE_HYPOTHESIS = False

# a small fixed pool so the solo references are computed once per session
_POOL = [
    spec_of(seed=0, comp="topk", rounds=4),
    spec_of(seed=1, comp="randk", rounds=5),
    spec_of(seed=2, comp="topk", rounds=3),
    spec_of(seed=3, comp="randseqk", rounds=6),
]

if HAVE_HYPOTHESIS:
    schedule_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, len(_POOL) - 1)),
            st.tuples(st.just("tick"), st.just(0)),
            st.tuples(st.just("evict_resume"), st.integers(0, len(_POOL) - 1)),
        ),
        min_size=1,
        max_size=12,
    )
else:  # a skipping stand-in keeps the test id visible in collection
    def given(**kw):  # noqa: D103
        return pytest.mark.skip(
            reason="property tests need hypothesis (requirements-dev.txt)"
        )

    def settings(**kw):  # noqa: D103
        return lambda fn: fn

    class HealthCheck:  # noqa: D101
        too_slow = None

    schedule_strategy = None


@settings(
    max_examples=8,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(schedule=schedule_strategy)
def test_random_admit_evict_tick_schedules_preserve_parity(schedule=None):
    """Whatever interleaving of admissions, ticks, and evict->resume cycles
    the engine is driven through, every tenant that reaches completion is
    bit-identical to its solo run."""
    with FedNLServer(ServeConfig(max_resident=2, admit_per_tick=2)) as srv:
        handles: dict[int, object] = {}
        for op, i in schedule:
            if op == "submit" and i not in handles:
                handles[i] = srv.submit(_POOL[i])
            elif op == "tick":
                srv.tick()
            elif op == "evict_resume" and i in handles:
                h = handles[i]
                if h.status in ("queued", "running", "spilled") and (
                    h.status != "queued" or h.round > 0
                ):
                    path = srv.evict(h.id)
                    handles[i] = srv.resume(path)
        srv.serve_until_idle(max_ticks=300)
        for i, h in handles.items():
            assert_served_bit_identical(h.result(), _POOL[i])
