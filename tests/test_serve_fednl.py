"""The multi-tenant serving engine (repro.serve_fednl) — DESIGN.md §11.

The acceptance bar is the §11 invariant: every tenant served through
``FedNLServer`` produces round records and a final model bit-identical to a
solo ``open_session(spec).run()`` — whatever it was batched with, however
the tenants arrived, and however often memory pressure spilled it to disk
in between.  Plus the engine mechanics: admission/eviction ordering under
capacity pressure, the spill file being an *ordinary* FNLS1 session
checkpoint, tenant-local failure isolation, and clean shutdown (no leaked
sessions or client process fleets).
"""

import numpy as np
import pytest

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    open_session,
    solve,
)
from repro.serve_fednl import FedNLServer, ServeConfig, serve_all

SHAPE = (12, 4, 20)  # d, n_clients, n_i — small enough for per-tick rounds


def spec_of(seed=0, comp="topk", rounds=6, algo="fednl", backend="local",
            data_seed=1, tol=0.0, km=8.0, **overrides):
    return ExperimentSpec(
        data=DataSpec(shape=SHAPE, seed=data_seed),
        algorithm=algo,
        compressor=CompressorSpec(comp, km),
        backend=backend,
        rounds=rounds,
        tol=tol,
        seed=seed,
        **overrides,
    )


_SOLO_CACHE: dict = {}


def solo_report(spec):
    """Reference trajectory: a solo session run (cached per spec)."""
    if spec not in _SOLO_CACHE:
        with open_session(spec) as s:
            _SOLO_CACHE[spec] = s.run()
    return _SOLO_CACHE[spec]


def assert_served_bit_identical(got, spec):
    want = solo_report(spec)
    assert got.rounds == want.rounds
    for g, w in zip(got.records, want.records):
        assert g.round == w.round
        assert (g.grad_norm is None) == (w.grad_norm is None)
        if g.grad_norm is not None:
            assert float(g.grad_norm).hex() == float(w.grad_norm).hex()
        if g.f is not None:
            assert float(g.f).hex() == float(w.f).hex()
        assert g.sent_bits == w.sent_bits
        assert g.sent_bits_payload == w.sent_bits_payload
        assert g.sent_bits_wire == w.sent_bits_wire
        if g.x is not None or w.x is not None:
            np.testing.assert_array_equal(g.x, w.x)
        assert g.participants == w.participants
    np.testing.assert_array_equal(got.x, want.x)


# ---------------------------------------------------------------------------
# bit parity: engine-served == solo, across everything that may co-batch
# ---------------------------------------------------------------------------

def test_parity_mixed_compressors_rounds_and_algorithms():
    # one shared problem, mixed compressors / k / seeds / round budgets and
    # both batched algorithms — maximal co-batching, per-slot stops
    specs = [
        spec_of(seed=0, comp="topk", rounds=6),
        spec_of(seed=1, comp="randk", rounds=4),
        spec_of(seed=2, comp="randseqk", rounds=7),
        spec_of(seed=3, comp="topk", km=4.0, rounds=5),
        spec_of(seed=4, comp="identity", rounds=3),
        spec_of(seed=5, comp="topk", rounds=5, algo="fednl-ls"),
    ]
    reports = serve_all(specs)
    for spec, rep in zip(specs, reports):
        assert_served_bit_identical(rep, spec)
        assert rep.extras["served"] is True


def test_parity_staggered_admission_and_mixed_data():
    # tenants arrive mid-flight at differing round indices, across TWO
    # problems (distinct data seeds -> distinct groups, z closed over)
    first = [spec_of(seed=0, rounds=8), spec_of(seed=1, rounds=8, data_seed=2)]
    late = [spec_of(seed=2, comp="randk", rounds=5),
            spec_of(seed=3, comp="randseqk", rounds=5, data_seed=2)]
    with FedNLServer() as srv:
        handles = [srv.submit(s) for s in first]
        srv.tick()
        srv.tick()  # first two are now at round >= 1
        handles += [srv.submit(s) for s in late]
        srv.serve_until_idle(max_ticks=100)
        for spec, h in zip(first + late, handles):
            assert_served_bit_identical(h.result(), spec)
        assert srv.stats()["groups"] == 2


def test_parity_tol_early_stop():
    # tol > 0 blocks the *sweep* batch lane but not the serve lane (the
    # tick loop host-syncs every round anyway); stop on the same record
    spec = spec_of(seed=0, rounds=40, tol=1e-10)
    rep = serve_all([spec, spec_of(seed=1, rounds=6)])[0]
    assert_served_bit_identical(rep, spec)
    assert rep.rounds < 40  # the tol actually fired


def test_parity_solo_lane_backends():
    # specs the batch lane cannot take: the wire protocol and PP run as
    # per-tenant sessions stepped one round per tick
    specs = [
        spec_of(seed=0, rounds=5, backend="star-loopback"),
        spec_of(seed=1, rounds=5, algo="fednl-pp", tau=3),
    ]
    for spec, rep in zip(specs, serve_all(specs)):
        assert_served_bit_identical(rep, spec)


def test_parity_under_memory_pressure():
    # 8 tenants through 3 resident slots: constant spill/resume churn must
    # not move a single bit
    specs = [
        spec_of(seed=i, comp=["topk", "randk", "randseqk"][i % 3],
                rounds=5 + i % 3)
        for i in range(8)
    ]
    with FedNLServer(ServeConfig(max_resident=3, admit_per_tick=2)) as srv:
        handles = [srv.submit(s) for s in specs]
        srv.serve_until_idle(max_ticks=500)
        st = srv.stats()
        assert st["spills"] > 0 and st["resumes"] > 0
        for spec, h in zip(specs, handles):
            assert_served_bit_identical(h.result(), spec)


@pytest.mark.parametrize("eviction", ["lru", "cost"])
def test_parity_under_pressure_both_victim_policies(eviction):
    specs = [spec_of(seed=i, rounds=4) for i in range(4)]
    cfg = ServeConfig(max_resident=2, admit_per_tick=2, eviction=eviction)
    with FedNLServer(cfg) as srv:
        handles = [srv.submit(s) for s in specs]
        srv.serve_until_idle(max_ticks=200)
        assert srv.stats()["spills"] > 0
        for spec, h in zip(specs, handles):
            assert_served_bit_identical(h.result(), spec)


def test_zero_round_spec_finishes_at_admission():
    spec = spec_of(seed=0, rounds=0)
    rep = serve_all([spec])[0]
    want = solve(spec)
    assert rep.rounds == want.rounds == 0
    np.testing.assert_array_equal(rep.x, want.x)


# ---------------------------------------------------------------------------
# admission / eviction ordering
# ---------------------------------------------------------------------------

def test_admission_is_fifo_and_capacity_bounded():
    specs = [spec_of(seed=i, rounds=30) for i in range(5)]
    cfg = ServeConfig(max_resident=2, admit_per_tick=2)
    with FedNLServer(cfg) as srv:
        handles = [srv.submit(s) for s in specs]
        assert [h.status for h in handles] == ["queued"] * 5
        srv.tick()
        # first two submitted are first admitted; capacity holds the rest
        assert [h.status for h in handles[:2]] == ["running", "running"]
        assert all(h.round >= 1 for h in handles[:2])
        running = sum(h.status == "running" for h in handles)
        assert running <= cfg.max_resident
        srv.tick()
        # pressure spills the LRU residents to admit the queue head, which
        # re-queues the victims: round-robin, nobody starves
        assert sum(h.status == "running" for h in handles) <= cfg.max_resident


def test_explicit_evict_checkpoint_roundtrip(tmp_path):
    spec = spec_of(seed=7, comp="randk", rounds=10)
    cfg = ServeConfig(spill_dir=tmp_path)
    with FedNLServer(cfg) as srv:
        h = srv.submit(spec)
        for _ in range(4):
            srv.tick()
        path = srv.evict(h.id)
        assert h.status == "evicted"
        assert path.exists()
        with pytest.raises(RuntimeError, match="evicted"):
            h.result()
        # the engine resumes its own eviction bit-identically
        h2 = srv.resume(path)
        assert h2.round == 4
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)
    # and the spill file is an ORDINARY session checkpoint: resumable
    # outside the engine entirely (the §11 spill contract)
    with open_session(spec, restore=path) as s:
        outside = s.run()
    assert_served_bit_identical(outside, spec)


def test_evict_solo_lane_tenant_releases_session(tmp_path):
    spec = spec_of(seed=0, rounds=10, backend="star-loopback")
    with FedNLServer(ServeConfig(spill_dir=tmp_path)) as srv:
        h = srv.submit(spec)
        srv.tick()
        srv.tick()
        path = srv.evict(h.id)
        assert path.exists() and h.status == "evicted"
        # resume through the engine: client state rebuilt by protocol replay
        h2 = srv.resume(path)
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)


def test_evict_queued_resume_tenant_persists_pending_state(tmp_path):
    spec = spec_of(seed=3, rounds=8)
    with FedNLServer(ServeConfig(spill_dir=tmp_path)) as srv:
        h = srv.submit(spec)
        for _ in range(3):
            srv.tick()
        ck = srv.evict(h.id)
        h2 = srv.resume(ck)  # queued with a pending restore...
        ck2 = srv.evict(h2.id)  # ...evicted before ever being admitted
        assert ck2.exists()
        h3 = srv.resume(ck2)
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h3.result(), spec)


# ---------------------------------------------------------------------------
# validation, failure isolation, lifecycle
# ---------------------------------------------------------------------------

def test_submit_validates_like_solve():
    with FedNLServer() as srv:
        with pytest.raises(ValueError, match="partial participation"):
            srv.submit(spec_of(algo="fednl-pp", tau=3), until=1e-8)
        with pytest.raises(KeyError):
            srv.submit(spec_of(comp="no-such-compressor"))
        with pytest.raises(Exception):
            srv.submit(spec_of(algo="fednl-ls", backend="star-loopback"))


def test_until_overrides_spec_stop():
    spec = spec_of(seed=0, rounds=9)
    with FedNLServer() as srv:
        h = srv.submit(spec, until=3)
        srv.serve_until_idle(max_ticks=50)
        rep = h.result()
    assert rep.rounds == 3
    want = solo_report(spec)
    for g, w in zip(rep.records, want.records[:3]):
        assert float(g.grad_norm).hex() == float(w.grad_norm).hex()


def test_shutdown_evicts_and_result_raises():
    srv = FedNLServer()
    h = srv.submit(spec_of(seed=0, rounds=50))
    srv.tick()
    srv.shutdown()
    assert h.status == "evicted"
    assert h.wait(timeout=1)  # shutdown resolves waiters
    with pytest.raises(RuntimeError):
        srv.tick()
    with pytest.raises(RuntimeError):
        srv.submit(spec_of(seed=1))


def test_shutdown_with_spill_leaves_resumable_checkpoints(tmp_path):
    spec = spec_of(seed=4, rounds=8)
    srv = FedNLServer(ServeConfig(spill_dir=tmp_path))
    h = srv.submit(spec)
    for _ in range(3):
        srv.tick()
    srv.shutdown(spill=True)
    (ck,) = tmp_path.glob(f"{h.id}.*")
    with FedNLServer(ServeConfig(spill_dir=tmp_path / "second")) as srv2:
        h2 = srv2.resume(ck)
        srv2.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)


def test_background_thread_serving():
    specs = [spec_of(seed=i, rounds=4) for i in range(3)]
    with FedNLServer() as srv:
        srv.start()
        handles = [srv.submit(s) for s in specs]
        for h in handles:
            assert h.wait(timeout=120)
        srv.stop()
        for spec, h in zip(specs, handles):
            assert_served_bit_identical(h.result(), spec)


def test_tick_program_reuse_across_reformed_groups():
    # same slot-count bucket -> the SAME compiled tick program serves
    # re-formed groups; compiles stay O(log n) per group key, not O(ticks)
    specs = [spec_of(seed=i, rounds=6) for i in range(4)]
    with FedNLServer(ServeConfig(max_resident=2, admit_per_tick=2)) as srv:
        for s in specs:
            srv.submit(s)
        srv.serve_until_idle(max_ticks=200)
        st = srv.stats()
        assert st["batch_launches"] > st["compiles"]
        assert st["compiles"] <= 3  # slot buckets {1, 2} x one branch growth
        assert 0 < st["batch_occupancy"] <= 1


# ---------------------------------------------------------------------------
# ClientCluster lifecycle (the refcounted teardown satellite)
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_star_tcp_tenant_evicted_mid_run_leaks_no_processes(tmp_path):
    from repro.launch.multiproc import ClientCluster

    assert ClientCluster.live_count() == 0
    spec = spec_of(seed=0, rounds=6, backend="star-tcp")
    with FedNLServer(ServeConfig(spill_dir=tmp_path)) as srv:
        h = srv.submit(spec)
        srv.tick()
        srv.tick()
        assert ClientCluster.live_count() == 1
        path = srv.evict(h.id)  # spill closes the session -> fleet torn down
        assert ClientCluster.live_count() == 0
        h2 = srv.resume(path)
        srv.serve_until_idle(max_ticks=100)
        assert_served_bit_identical(h2.result(), spec)
    assert ClientCluster.live_count() == 0


def test_cluster_refcounting_contract():
    # pure lifecycle logic, no sockets: exercise acquire/release/close on a
    # structurally empty cluster instance
    from repro.launch.multiproc import ClientCluster, _LIVE_CLUSTERS

    c = ClientCluster.__new__(ClientCluster)
    import threading

    c._refs = 1
    c._closed = False
    c._lifecycle_lock = threading.Lock()
    c.conns = {}
    c.procs = []

    class _FakeMaster:
        closed = 0

        def close(self):
            self.closed += 1

    c._master = _FakeMaster()
    c.acquire()
    assert c._refs == 2
    c.release()
    assert not c.closed
    c.release()  # last holder out -> teardown
    assert c.closed and c._master.closed == 1
    c.close()  # idempotent
    assert c._master.closed == 1
    with pytest.raises(RuntimeError):
        c.acquire()
    assert c not in _LIVE_CLUSTERS


# ---------------------------------------------------------------------------
# property test: random admit / evict / tick schedules (hypothesis)
# ---------------------------------------------------------------------------

try:  # only the property test needs hypothesis — the rest of the module
    # must run without it (requirements-dev.txt), so no importorskip here
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev-only dependency
    HAVE_HYPOTHESIS = False

# a small fixed pool so the solo references are computed once per session
_POOL = [
    spec_of(seed=0, comp="topk", rounds=4),
    spec_of(seed=1, comp="randk", rounds=5),
    spec_of(seed=2, comp="topk", rounds=3),
    spec_of(seed=3, comp="randseqk", rounds=6),
]

if HAVE_HYPOTHESIS:
    schedule_strategy = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, len(_POOL) - 1)),
            st.tuples(st.just("tick"), st.just(0)),
            st.tuples(st.just("evict_resume"), st.integers(0, len(_POOL) - 1)),
        ),
        min_size=1,
        max_size=12,
    )
else:  # a skipping stand-in keeps the test id visible in collection
    def given(**kw):  # noqa: D103
        return pytest.mark.skip(
            reason="property tests need hypothesis (requirements-dev.txt)"
        )

    def settings(**kw):  # noqa: D103
        return lambda fn: fn

    class HealthCheck:  # noqa: D101
        too_slow = None

    schedule_strategy = None


@settings(
    max_examples=8,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(schedule=schedule_strategy)
def test_random_admit_evict_tick_schedules_preserve_parity(schedule=None):
    """Whatever interleaving of admissions, ticks, and evict->resume cycles
    the engine is driven through, every tenant that reaches completion is
    bit-identical to its solo run."""
    with FedNLServer(ServeConfig(max_resident=2, admit_per_tick=2)) as srv:
        handles: dict[int, object] = {}
        for op, i in schedule:
            if op == "submit" and i not in handles:
                handles[i] = srv.submit(_POOL[i])
            elif op == "tick":
                srv.tick()
            elif op == "evict_resume" and i in handles:
                h = handles[i]
                if h.status in ("queued", "running", "spilled") and (
                    h.status != "queued" or h.round > 0
                ):
                    path = srv.evict(h.id)
                    handles[i] = srv.resume(path)
        srv.serve_until_idle(max_ticks=300)
        for i, h in handles.items():
            assert_served_bit_identical(h.result(), _POOL[i])


# ---------------------------------------------------------------------------
# fair-share admission: deficit round-robin over weighted priority classes
# ---------------------------------------------------------------------------

def test_fair_share_queue_admits_in_exact_weight_ratio():
    from repro.serve_fednl import FairShareQueue

    q = FairShareQueue({"high": 4.0, "normal": 2.0, "low": 1.0}, quantum=1.0)
    for i in range(40):
        for cls in ("high", "normal", "low"):
            q.push(f"{cls}-{i}", priority=cls)
    got = [q.pop() for _ in range(70)]  # 10 full DRR cycles of 4+2+1
    counts = {
        c: sum(1 for t in got if t.startswith(c))
        for c in ("high", "normal", "low")
    }
    assert counts == {"high": 40, "normal": 20, "low": 10}
    # FIFO within each class
    for c in ("high", "normal", "low"):
        mine = [t for t in got if t.startswith(c)]
        assert mine == [f"{c}-{i}" for i in range(len(mine))]
    assert len(q) == 120 - 70 and bool(q)


def test_fair_share_queue_single_class_degenerates_to_fifo():
    from repro.serve_fednl import FairShareQueue

    q = FairShareQueue({"only": 3.0})
    for i in range(10):
        q.push(i, priority="only")
    assert [q.pop() for _ in range(10)] == list(range(10))
    assert q.pop() is None and not q


def test_fair_share_queue_empty_class_hoards_no_credit():
    from repro.serve_fednl import FairShareQueue

    q = FairShareQueue({"high": 4.0, "low": 1.0}, quantum=1.0)
    # a long low-only phase: high's turns come and go while it is empty,
    # so its deficit must reset each pass, not accumulate
    for i in range(20):
        q.push(f"low-{i}", priority="low")
    for _ in range(20):
        assert q.pop().startswith("low")
    # now both classes backlogged: admissions snap straight to the 4:1
    # weights — history bought neither class a burst
    for i in range(20):
        q.push(f"high-{i}", priority="high")
        q.push(f"xlow-{i}", priority="low")
    got = [q.pop() for _ in range(20)]  # 4 DRR cycles of 4+1
    assert sum(1 for t in got if t.startswith("high")) == 16
    assert sum(1 for t in got if t.startswith("xlow")) == 4


def test_fair_share_queue_validates_classes_and_pushes():
    from repro.serve_fednl import FairShareQueue

    with pytest.raises(ValueError, match="at least one"):
        FairShareQueue({})
    with pytest.raises(ValueError, match="positive weight"):
        FairShareQueue({"bad": 0.0})
    with pytest.raises(ValueError, match="quantum"):
        FairShareQueue({"a": 1.0}, quantum=0.0)
    q = FairShareQueue({"a": 1.0})
    with pytest.raises(ValueError, match="unknown priority class"):
        q.push("x", priority="b")


def test_submit_options_validated_synchronously():
    from repro.serve_fednl import SubmitOptions

    with FedNLServer(ServeConfig(max_resident=2)) as srv:
        with pytest.raises(ValueError, match=r"options\.priority"):
            srv.submit(spec_of(), options=SubmitOptions(priority="vip"))
        with pytest.raises(TypeError):
            srv.submit(spec_of(), options={"priority": "high"})
        # failed submissions left nothing behind
        assert srv.stats()["tenants"] == 0
        # a valid class is accepted and recorded on the handle
        h = srv.submit(spec_of(rounds=2),
                       options=SubmitOptions(priority="low"))
        assert h.priority == "low"
        srv.serve_until_idle(max_ticks=50)
        assert_served_bit_identical(h.result(), spec_of(rounds=2))


def test_cancel_drops_tenant_and_isolates_neighbors():
    s1, s2 = spec_of(seed=30, rounds=8), spec_of(seed=31, rounds=4)
    with FedNLServer(ServeConfig(max_resident=2, admit_per_tick=2)) as srv:
        h1, h2 = srv.submit(s1), srv.submit(s2)
        srv.tick()
        srv.cancel(h1.id)
        assert h1.status == "cancelled" and h1.done
        with pytest.raises(RuntimeError, match="cancelled"):
            h1.result()
        srv.serve_until_idle(max_ticks=100)
        # the co-batched neighbor is untouched, bit for bit
        assert_served_bit_identical(h2.result(), s2)
        stats = srv.stats()
        assert stats["cancelled"] == 1
        # terminal tenants keep their outcome: cancelling again is an error
        with pytest.raises(ValueError, match="only queued"):
            srv.cancel(h2.id)
        with pytest.raises(KeyError):
            srv.cancel("t9999")


def test_engine_admissions_track_priority_weights_under_churn():
    # 3x oversubscription with max_resident == admit_per_tick keeps every
    # class backlogged and the resident set churning, so DRR admission
    # counts must track the configured 2:1 weights
    from repro.serve_fednl import SubmitOptions

    cfg = ServeConfig(
        max_resident=2,
        admit_per_tick=2,
        priorities={"gold": 2.0, "bronze": 1.0},
        quantum=1.0,
    )
    with FedNLServer(cfg) as srv:
        handles = []
        for i in range(3):
            handles.append(srv.submit(
                spec_of(seed=40 + i, rounds=60),
                options=SubmitOptions(priority="gold")))
            handles.append(srv.submit(
                spec_of(seed=50 + i, rounds=60),
                options=SubmitOptions(priority="bronze")))
        for _ in range(12):
            srv.tick()
        stats = srv.stats()
        adm = stats["admissions_by_class"]
        assert adm["gold"] + adm["bronze"] == 24  # 2 per tick, saturated
        assert abs(adm["gold"] - 2 * adm["bronze"]) <= 2
        assert sum(stats["backlog"].values()) > 0  # still saturated
        for h in handles:
            srv.cancel(h.id)
        assert srv.stats()["cancelled"] == len(handles)
        srv.tick()  # cancelled queue entries are discarded lazily at pop
        assert not srv._has_work()


def test_default_priority_used_when_no_options():
    # an engine with custom classes and no "normal": submit() without
    # options lands in the highest-weight class, deterministically
    cfg = ServeConfig(priorities={"fast": 3.0, "slow": 1.0})
    with FedNLServer(cfg) as srv:
        h = srv.submit(spec_of(rounds=2))
        assert h.priority == "fast"
        srv.serve_until_idle(max_ticks=50)
        assert_served_bit_identical(h.result(), spec_of(rounds=2))


# the DRR starvation bound, as a property over random weight tables and
# push/pop schedules: while a class stays backlogged, the number of foreign
# admissions between two of its own admissions (or before its first) never
# exceeds FairShareQueue.starvation_bound
if HAVE_HYPOTHESIS:
    _CLS = ("a", "b", "c", "d")
    # dyadic weights/quanta keep the deficit arithmetic exact in binary
    # floating point, so the analytic bound applies without rounding slack
    _DYADIC = [0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 8.0]
    drr_strategy = st.tuples(
        st.lists(st.sampled_from(_DYADIC), min_size=1, max_size=4),
        st.sampled_from([0.25, 0.5, 1.0, 2.0, 4.0]),
        st.lists(
            st.one_of(
                st.tuples(st.just("push"), st.integers(0, 3)),
                st.tuples(st.just("pop"), st.just(0)),
            ),
            min_size=1, max_size=300,
        ),
    )
else:
    drr_strategy = None


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(wqs=drr_strategy)
def test_drr_starvation_bound_holds(wqs=None):
    from repro.serve_fednl import FairShareQueue

    weights_list, quantum, schedule = wqs
    classes = {c: w for c, w in zip(_CLS, weights_list)}
    names = sorted(classes)
    q = FairShareQueue(classes, quantum=quantum)
    bound = {c: q.starvation_bound(c) for c in classes}
    foreign = {c: 0 for c in classes}
    pushed = 0
    for op, i in schedule:
        if op == "push":
            c = names[i % len(names)]
            q.push(f"{c}#{pushed}", priority=c)
            pushed += 1
            continue
        backlogged = {c for c, n in q.backlog().items() if n > 0}
        t = q.pop()
        if t is None:
            continue
        winner = t.split("#")[0]
        for c in backlogged:
            if c == winner:
                foreign[c] = 0
            else:
                foreign[c] += 1
                assert foreign[c] <= bound[c], (
                    f"class {c!r} (w={classes[c]}, Q={quantum}) waited "
                    f"{foreign[c]} foreign admissions; bound {bound[c]}"
                )
        for c in classes:
            if c not in backlogged:
                foreign[c] = 0  # not waiting; worst case restarts
