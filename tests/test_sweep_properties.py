"""Property-based SweepSpec/grid contracts (hypothesis).

tests/test_sweep.py pins the engine parity and fixed-shape expansion cases;
this module lets hypothesis hunt the axis space for violations of the
expansion contracts: count = axis product, determinism, duplicate-freedom,
and declared-order variation.  Skips cleanly when hypothesis is absent
(requirements-dev.txt / `pip install -e .[test]`).
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.api import DataSpec, ExperimentSpec

BASE = ExperimentSpec(data=DataSpec(dataset="tiny", seed=1), rounds=4)

COMPRESSORS = ["identity", "topk", "randk", "randseqk", "toplek", "natural"]

axes_strategy = st.fixed_dictionaries(
    {},
    optional={
        "seed": st.lists(
            st.integers(0, 10_000), min_size=1, max_size=5, unique=True
        ),
        "compressor": st.lists(
            st.sampled_from(COMPRESSORS), min_size=1, max_size=6, unique=True
        ),
        "k_multiplier": st.lists(
            st.sampled_from([1.0, 2.0, 4.0, 8.0]), min_size=1, max_size=3,
            unique=True,
        ),
        "rounds": st.lists(
            st.integers(0, 50), min_size=1, max_size=3, unique=True
        ),
        "option": st.lists(
            st.sampled_from(["A", "B"]), min_size=1, max_size=2, unique=True
        ),
        "data_seed": st.lists(
            st.integers(0, 100), min_size=1, max_size=3, unique=True
        ),
    },
)


@settings(max_examples=40, deadline=None)
@given(axes=axes_strategy)
def test_grid_expansion_count_is_axis_product(axes):
    sweep = BASE.grid(**axes)
    expected = 1
    for values in axes.values():
        expected *= len(values)
    specs = sweep.specs()
    assert len(specs) == expected == sweep.n_specs == len(sweep)


@settings(max_examples=40, deadline=None)
@given(axes=axes_strategy)
def test_grid_expansion_deterministic_and_duplicate_free(axes):
    first, second = BASE.grid(**axes).specs(), BASE.grid(**axes).specs()
    assert first == second, "expansion must be deterministic"
    assert len(set(first)) == len(first), "expansion must be duplicate-free"


@settings(max_examples=40, deadline=None)
@given(
    seeds=st.lists(st.integers(0, 100), min_size=1, max_size=4, unique=True),
    comps=st.lists(
        st.sampled_from(COMPRESSORS), min_size=1, max_size=4, unique=True
    ),
)
def test_grid_axis_order_later_axes_vary_fastest(seeds, comps):
    specs = BASE.grid(seed=seeds, compressor=comps).specs()
    expected = [(s, c) for s in seeds for c in comps]
    assert [(sp.seed, sp.compressor.name) for sp in specs] == expected
