"""The gateway RPC layer (repro.gateway) — DESIGN.md §14.

The acceptance bar extends §11 across a socket: every trajectory observed
through the gateway — streamed RECORD frames, RESULT reports, resumes of a
killed gateway's spill files — is bit-identical to a solo
``open_session(spec).run()``.  Plus the transport mechanics the gateway is
accountable for: synchronous submission errors naming the offending field,
bounded observer queues with counted drops that never stall the engine
tick, and strict versioned spec serialization.
"""

import os
import pathlib
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    decode_spec,
    encode_spec,
    open_session,
)
from repro.gateway import (
    GatewayClient,
    GatewayConfig,
    GatewayError,
    GatewayServer,
)
from repro.gateway import protocol as gw
from repro.comm.protocol import Frame, MsgType
from repro.serve_fednl import ServeConfig, SubmitOptions

SHAPE = (12, 4, 20)
REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def spec_of(seed=0, comp="topk", rounds=6, km=8.0, **overrides):
    return ExperimentSpec(
        data=DataSpec(shape=SHAPE, seed=1),
        algorithm="fednl",
        compressor=CompressorSpec(comp, km),
        rounds=rounds,
        seed=seed,
        **overrides,
    )


_SOLO_CACHE: dict = {}


def solo_report(spec):
    if spec not in _SOLO_CACHE:
        with open_session(spec) as s:
            _SOLO_CACHE[spec] = s.run()
    return _SOLO_CACHE[spec]


def hex_traj(records):
    return [
        (
            float(r.grad_norm).hex() if r.grad_norm is not None else None,
            r.sent_bits,
            r.sent_bits_payload,
            r.sent_bits_wire,
        )
        for r in records
    ]


@pytest.fixture
def gateway():
    """An in-process gateway on an ephemeral localhost port."""
    server = GatewayServer(
        GatewayConfig(
            port=0, serve=ServeConfig(max_resident=2, admit_per_tick=2)
        )
    )
    ready = threading.Event()
    addr = {}

    def announce(host, port):
        addr["host"], addr["port"] = host, port
        ready.set()

    thread = threading.Thread(
        target=server.run, kwargs={"ready": announce}, daemon=True
    )
    thread.start()
    assert ready.wait(60), "gateway did not bind"
    yield addr["host"], addr["port"], server
    server.request_stop()
    thread.join(30)


# ---------------------------------------------------------------------------
# the wire itself: versioned spec serialization (tier-1, no sockets)
# ---------------------------------------------------------------------------

def test_specwire_roundtrip_is_exact():
    spec = spec_of(seed=3, comp="randk", rounds=7, lam=1e-3, mu=0.0)
    back = decode_spec(encode_spec(spec))
    assert back == spec  # frozen dataclass equality covers every float


def test_specwire_rejects_unknown_fields_by_dotted_name():
    import json

    payload = json.loads(encode_spec(spec_of()).decode())
    payload["spec"]["frobnicate"] = 1
    with pytest.raises(ValueError, match="frobnicate"):
        gw.decode_spec_dict(payload)

    payload = json.loads(encode_spec(spec_of()).decode())
    payload["spec"]["data"]["warp"] = 9
    with pytest.raises(ValueError, match=r"data\.warp"):
        gw.decode_spec_dict(payload)


def test_specwire_rejects_version_skew():
    import json

    payload = json.loads(encode_spec(spec_of()).decode())
    payload["spec_wire_version"] = 99
    with pytest.raises(ValueError, match="version 99"):
        gw.decode_spec_dict(payload)
    with pytest.raises(ValueError, match="spec_wire_version"):
        decode_spec(b'{"spec": {}}')
    with pytest.raises(ValueError, match="not valid JSON"):
        decode_spec(b"\xff\xfe not json")


def test_record_and_report_payloads_roundtrip_bit_exact():
    spec = spec_of(seed=0, rounds=4)
    want = solo_report(spec)
    for i, rec in enumerate(want.records):
        frame = gw.pack_record("t0000", i, rec)
        tid, idx, back = gw.unpack_record(frame.payload)
        assert (tid, idx) == ("t0000", i)
        assert hex_traj([back]) == hex_traj([rec])
    report = gw.unpack_report(gw.pack_report(want))
    assert report.spec == spec
    assert hex_traj(report.records) == hex_traj(want.records)
    np.testing.assert_array_equal(report.x, want.x)
    assert float(report.wall_time_s).hex() == float(want.wall_time_s).hex()


# ---------------------------------------------------------------------------
# RPC round trips over real TCP
# ---------------------------------------------------------------------------

def test_submit_stream_result_bit_parity(gateway):
    host, port, _server = gateway
    specs = [
        spec_of(seed=0, comp="topk", rounds=6),
        spec_of(seed=1, comp="randk", rounds=4),
        spec_of(seed=2, comp="randseqk", rounds=7),
    ]
    prios = ["high", "normal", "low"]
    with GatewayClient(host, port) as gwc:
        handles = [
            gwc.submit(s, priority=p) for s, p in zip(specs, prios)
        ]
        assert [h.priority for h in handles] == prios
        # stream one tenant on a second connection while results arrive
        with GatewayClient(host, port) as obs:
            streamed = list(obs.stream(handles[0].id))
            assert obs.stream_drops == 0
        reports = [gwc.result(h.id) for h in handles]
    for spec, rep in zip(specs, reports):
        want = solo_report(spec)
        assert hex_traj(rep.records) == hex_traj(want.records)
        np.testing.assert_array_equal(rep.x, want.x)
        assert rep.spec == spec
    want0 = solo_report(specs[0])
    assert hex_traj(streamed) == hex_traj(want0.records)


def test_submit_errors_are_synchronous_and_name_the_field(gateway):
    host, port, _server = gateway
    with GatewayClient(host, port) as gwc:
        # unknown priority class -> names options.priority
        with pytest.raises(GatewayError, match="unknown priority class"):
            gwc.submit(spec_of(), priority="platinum")
        try:
            gwc.submit(spec_of(), priority="platinum")
        except GatewayError as e:
            assert e.field == "options.priority"
        # unknown spec field injected at the wire level -> names it
        import json

        raw = json.loads(encode_spec(spec_of()).decode())
        raw["spec"]["frobnicate"] = 1
        payload = gw._pack(
            {
                "spec_wire_version": raw["spec_wire_version"],
                "spec": raw["spec"],
                "until": None,
                "tenant_id": None,
                "options": None,
            }
        )
        with pytest.raises(GatewayError, match="frobnicate"):
            gwc._rpc(Frame(type=MsgType.SUBMIT, payload=payload))
        # bad compressor k: rejected at SUBMIT, not ticks later
        with pytest.raises(GatewayError):
            gwc.submit(spec_of(comp="no-such-compressor"))
        # the engine is still healthy after all those rejections
        h = gwc.submit(spec_of(seed=5, rounds=3))
        rep = gwc.result(h.id)
        assert rep.rounds == 3


def test_status_cancel_evict_over_the_wire(gateway):
    host, port, server = gateway
    with GatewayClient(host, port) as gwc:
        h1 = gwc.submit(spec_of(seed=0, rounds=60))
        h2 = gwc.submit(spec_of(seed=1, rounds=60))
        st = gwc.status(h1.id)
        assert st["tenant_id"] == h1.id
        assert st["status"] in ("queued", "running", "spilled")
        gwc.cancel(h1.id)
        with pytest.raises(GatewayError, match="cancelled"):
            gwc.result(h1.id)
        path = gwc.evict(h2.id)
        with pytest.raises(GatewayError, match="evicted"):
            gwc.result(h2.id)
        stats = gwc.status()
        assert stats["cancelled"] == 1 and stats["evicted"] == 1
        with pytest.raises(GatewayError, match="no tenant"):
            gwc.status("t9999")
    # the evicted checkpoint resumes bit-identically server-side; the
    # gateway's own tick loop (still running) drives it to completion
    spec = spec_of(seed=1, rounds=60)
    h3 = server.engine.resume(path)
    assert h3.wait(180), "resumed tenant never finished"
    want = solo_report(spec)
    got = h3.result()
    assert hex_traj(got.records) == hex_traj(want.records)
    np.testing.assert_array_equal(got.x, want.x)


# ---------------------------------------------------------------------------
# backpressure: bounded observer queues never stall the engine
# ---------------------------------------------------------------------------

def test_slow_observer_bounded_queue_counts_drops():
    # subscription layer driven synchronously: a stalled writer (never
    # drains) must cost the tick exactly O(1) deque appends — bounded
    # memory, newest records kept, drops counted
    from repro.gateway.server import _Subscription

    rounds = 30
    server = GatewayServer(GatewayConfig(stream_queue=4))
    try:
        h = server.engine.submit(spec_of(seed=0, rounds=rounds))
        sub = _Subscription(h.id, maxlen=4)
        server._subs.append(sub)
        pump_wall = []
        while server.engine._has_work():
            server.engine.tick()
            t0 = time.perf_counter()
            server._pump()
            pump_wall.append(time.perf_counter() - t0)
        assert h.result().rounds == rounds  # engine never waited
        assert sub.closed
        assert len(sub.queue) == 4  # bounded
        assert sub.drops == rounds - 4  # every drop counted
        # the queue holds exactly the NEWEST records (drop-oldest)
        assert [i for i, _ in sub.queue] == list(range(rounds - 4, rounds))
        # pumping a stalled subscription is queue bookkeeping, not I/O
        assert max(pump_wall) < 0.05
    finally:
        server.engine.shutdown()


def test_stalled_tcp_observer_does_not_block_completion(gateway):
    host, port, _server = gateway
    rounds = 12
    with GatewayClient(host, port) as gwc:
        h = gwc.submit(spec_of(seed=0, rounds=rounds))
        # subscribe on a second connection and then stall: read NOTHING
        obs = GatewayClient(host, port)
        obs._rpc(
            gw.pack_json(
                MsgType.STREAM, {"tenant_id": h.id, "from_start": True}
            )
        )
        # the engine must finish while the observer is stalled
        rep = gwc.result(h.id)
        assert rep.rounds == rounds
        # the stalled observer can still drain everything afterwards
        got = []
        from repro.comm.protocol import recv_frame

        while True:
            frame = recv_frame(obs._conn)
            if frame.type == MsgType.STREAM_END:
                end = gw.unpack_stream_end(frame.payload)
                break
            got.append(gw.unpack_record(frame.payload)[2])
        obs.close()
        assert len(got) + end["drops"] == rounds
        want = solo_report(spec_of(seed=0, rounds=rounds))
        assert hex_traj(got) == hex_traj(want.records[rounds - len(got):])


# ---------------------------------------------------------------------------
# kill the gateway, resume from its spills (net: subprocess + TCP)
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_kill_gateway_resume_from_spill_dir(tmp_path):
    from repro.serve_fednl import FedNLServer

    spill_dir = tmp_path / "spills"
    proc = subprocess.Popen(
        [
            sys.executable,
            "scripts/gateway_serve.py",
            "--port", "0",
            "--max-resident", "1",  # constant spill churn
            "--admit-per-tick", "1",
            "--spill-dir", str(spill_dir),
        ],
        stdout=subprocess.PIPE,
        text=True,
        cwd=REPO_ROOT,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
    )
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("LISTENING"), line
        _, host, port = line.split()
        specs = [spec_of(seed=0, rounds=30), spec_of(seed=1, rounds=30)]
        with GatewayClient(host, int(port), connect_retry_s=30) as gwc:
            handles = [gwc.submit(s) for s in specs]
            ids = [h.id for h in handles]
            # wait until both tenants have made progress AND spilled at
            # least once (max_resident=1 guarantees churn)
            deadline = time.monotonic() + 120
            while time.monotonic() < deadline:
                stats = gwc.status()
                rounds = [gwc.status(t)["round"] for t in ids]
                if stats["spills"] >= 2 and all(r >= 3 for r in rounds):
                    break
                time.sleep(0.2)
            else:
                pytest.fail("tenants never progressed/spilled")
        proc.kill()  # SIGKILL: no graceful spill, only what's on disk
        proc.wait(30)

        # resume each tenant's NEWEST checkpoint locally, bit-identically
        with FedNLServer() as srv:
            resumed = []
            for tid, spec in zip(ids, specs):
                cks = sorted(
                    spill_dir.glob(f"{tid}.r*.fnlsess"),
                    key=lambda p: int(p.name.split(".r")[1].split(".")[0]),
                )
                assert cks, f"no spill files for {tid}"
                h = srv.resume(cks[-1])
                assert h.round >= 1
                resumed.append(h)
            srv.serve_until_idle(max_ticks=500)
            for h, spec in zip(resumed, specs):
                want = solo_report(spec)
                got = h.result()
                assert hex_traj(got.records) == hex_traj(want.records)
                np.testing.assert_array_equal(got.x, want.x)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(10)


# ---------------------------------------------------------------------------
# observability at the gateway: drop accounting + the METRICS verb (§15)
# ---------------------------------------------------------------------------

def test_stream_drops_counted_in_obs_and_surfaced_to_client():
    # subscribing from_start AFTER the run finished replays the whole
    # history through the bounded per-subscription queue synchronously
    # (the catch-up path), so all but the newest ``stream_queue`` records
    # are deterministically dropped; the loss must be (a) counted in the
    # process recorder (gateway.stream.dropped) and (b) surfaced to the
    # caller as GatewayClient.dropped_records — never silent
    from repro import obs

    rounds = 20
    queue = 4
    rec = obs.enable(span_capacity=256)
    try:
        server = GatewayServer(
            GatewayConfig(
                port=0,
                stream_queue=queue,
                serve=ServeConfig(max_resident=2, admit_per_tick=2),
            )
        )
        ready = threading.Event()
        addr = {}

        def announce(host, port):
            addr["host"], addr["port"] = host, port
            ready.set()

        thread = threading.Thread(
            target=server.run, kwargs={"ready": announce}, daemon=True
        )
        thread.start()
        assert ready.wait(60), "gateway did not bind"
        try:
            with GatewayClient(addr["host"], addr["port"]) as gwc:
                h = gwc.submit(spec_of(seed=0, rounds=rounds))
                rep = gwc.result(h.id)
                assert rep.rounds == rounds
                with GatewayClient(addr["host"], addr["port"]) as sub:
                    got = list(sub.stream(h.id, from_start=True))
                    # bounded queue: newest records kept, loss accounted
                    assert len(got) == queue
                    assert sub.stream_drops == rounds - queue
                    assert sub.dropped_records == sub.stream_drops
                    want = solo_report(spec_of(seed=0, rounds=rounds))
                    assert hex_traj(got) == hex_traj(
                        want.records[rounds - queue:]
                    )
                    # a second, keeping-up stream accumulates (cumulative
                    # per-client counter, per-stream count in stream_drops)
                    got2 = list(sub.stream(h.id, from_start=True))
                    assert len(got2) == queue  # catch-up replay again
                    assert sub.dropped_records == 2 * (rounds - queue)
                    drops = 2 * (rounds - queue)

                # the METRICS verb sees the same count, live over TCP
                snap = gwc.metrics()
                assert snap["enabled"] is True
                assert (
                    snap["metrics"]["counters"]["gateway.stream.dropped"]
                    == drops
                )
                prom = gwc.metrics(format="prometheus")
                assert (
                    f"gateway_stream_dropped_total {drops}"
                    in prom["prometheus"]
                )
        finally:
            server.request_stop()
            thread.join(30)
    finally:
        obs.disable()
    assert rec.value("gateway.stream.dropped") == drops


def test_metrics_verb_with_recorder_disabled(gateway):
    # the verb must answer (not error) when observability is off
    host, port, _server = gateway
    with GatewayClient(host, port) as gwc:
        snap = gwc.metrics()
    assert snap["enabled"] is False
