"""Packed-triu representation and Newton-solve tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro import linalg as LA


@settings(max_examples=30, deadline=None)
@given(d=st.integers(min_value=1, max_value=40), seed=st.integers(0, 1000))
def test_pack_unpack_roundtrip(d, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (d, d), dtype=jnp.float64)
    m = a + a.T
    u = LA.pack_triu(m)
    assert u.shape == (LA.triu_size(d),)
    np.testing.assert_allclose(np.asarray(LA.unpack_triu(u, d)), np.asarray(m), rtol=1e-14)


@settings(max_examples=30, deadline=None)
@given(d=st.integers(min_value=1, max_value=40), seed=st.integers(0, 1000))
def test_frob_norm_from_packed(d, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (d, d), dtype=jnp.float64)
    m = a + a.T
    got = float(LA.frob_norm_from_packed(LA.pack_triu(m), d))
    want = float(jnp.linalg.norm(m))
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_pack_triu_batched():
    ms = jax.random.normal(jax.random.PRNGKey(0), (5, 8, 8), dtype=jnp.float64)
    ms = ms + jnp.swapaxes(ms, -1, -2)
    u = LA.pack_triu(ms)
    assert u.shape == (5, LA.triu_size(8))
    back = LA.unpack_triu(u, 8)
    np.testing.assert_allclose(np.asarray(back), np.asarray(ms), rtol=1e-14)


def test_psd_project_clips_eigenvalues():
    a = jnp.diag(jnp.asarray([5.0, 0.5, -3.0]))
    p = LA.psd_project(a, 1.0)
    w = jnp.linalg.eigvalsh(p)
    assert float(w.min()) >= 1.0 - 1e-12
    np.testing.assert_allclose(float(w.max()), 5.0, rtol=1e-12)


def test_cholesky_solve_matches_linalg_solve():
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (20, 20), dtype=jnp.float64)
    spd = a @ a.T + 20 * jnp.eye(20)
    b = jax.random.normal(jax.random.fold_in(key, 1), (20,), dtype=jnp.float64)
    np.testing.assert_allclose(
        np.asarray(LA.cholesky_solve(spd, b)),
        np.asarray(jnp.linalg.solve(spd, b)),
        rtol=1e-9,
    )


def test_newton_solves_option_a_and_b():
    key = jax.random.PRNGKey(2)
    a = jax.random.normal(key, (12, 12), dtype=jnp.float64)
    h = a @ a.T + 0.5 * jnp.eye(12)
    g = jax.random.normal(jax.random.fold_in(key, 3), (12,), dtype=jnp.float64)
    dx_a = LA.newton_solve_optionA(h, g, 1e-3)
    np.testing.assert_allclose(np.asarray(h @ dx_a), np.asarray(g), rtol=1e-8)
    dx_b = LA.newton_solve_optionB(h, g, jnp.asarray(0.7))
    np.testing.assert_allclose(
        np.asarray((h + 0.7 * jnp.eye(12)) @ dx_b), np.asarray(g), rtol=1e-8
    )
