"""repro.comm: wire codec round trips, exact bit-parity with the analytic
message_bits model, frame protocol, loopback star runs reproducing the
single-node run_fednl trajectory, and the TCP-localhost multi-process run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import protocol, wire
from repro.comm.cost import CommCostModel
from repro.comm.star import run_loopback
from repro.comm.transport import loopback_pair
from repro.compressors import get_compressor
from repro.compressors.core import message_bits
from repro.core import FedNLConfig, run_fednl
from repro.data import add_intercept, make_synthetic_logreg, partition_clients

ALL_COMPRESSORS = ["identity", "topk", "randk", "randseqk", "toplek", "natural"]

LAM = 1e-3


def _rand_u(seed, t, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (t,), dtype=jnp.float64) * scale


@pytest.fixture(scope="module")
def z():
    x, y = make_synthetic_logreg("tiny", seed=1)
    return jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=1))


# ---------------------------------------------------------------------------
# codec round trips (satellite: decode(encode(m)) == m for all six)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_COMPRESSORS)
@pytest.mark.parametrize("t,k,seed,scale", [
    (300, 37, 0, 1.0),
    (55, 1, 1, 1e-6),
    (10, 10, 2, 1e8),
    (496, 128, 3, 1e-3),
])
def test_codec_roundtrip_matches_dense_compressor(name, t, k, seed, scale):
    """decode(encode(key, u)) must equal comp.compress(key, u)[0] BIT-exactly
    — including RandK/RandSeqK seed-reconstruction and Natural's replayed
    sign+exponent format (this is what makes a TCP run reproduce the
    simulation trajectory)."""
    u = _rand_u(seed, t, scale)
    key = jax.random.PRNGKey(seed + 1000)
    comp = get_compressor(name, t, k)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(key, u)
    dec = codec.decode(enc.data, enc.sent_elems)
    dense, _ = comp.compress(key, u)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(dense))


@pytest.mark.parametrize("name", ALL_COMPRESSORS)
@pytest.mark.parametrize("t,k,seed", [(300, 37, 0), (45, 45, 1), (128, 5, 2)])
def test_codec_bits_match_analytic_model(name, t, k, seed):
    """Measured encoded bits == message_bits(comp, sent_elems), and the byte
    buffer is exactly the bit count rounded up (Natural is bit-packed)."""
    u = _rand_u(seed, t)
    comp = get_compressor(name, t, k)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(jax.random.PRNGKey(seed), u)
    assert enc.bits == int(message_bits(comp, jnp.asarray(enc.sent_elems)))
    assert len(enc.data) == (enc.bits + 7) // 8


def test_randseqk_seed_reconstruction_equality():
    """Only a 32-bit start index travels; the receiver rebuilds the window."""
    t, k = 210, 17
    u = _rand_u(5, t)
    comp = get_compressor("randseqk", t, k)
    codec = wire.make_codec(comp, t)
    key = jax.random.PRNGKey(9)
    enc = codec.encode(key, u)
    assert len(enc.data) == 4 + 8 * k  # u32 start + k FP64 values, nothing else
    np.testing.assert_array_equal(
        np.asarray(codec.decode(enc.data, k)), np.asarray(comp.compress(key, u)[0])
    )


def test_randk_wire_carries_no_indices():
    t, k = 210, 17
    comp = get_compressor("randk", t, k)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(jax.random.PRNGKey(3), _rand_u(6, t))
    assert len(enc.data) == 8 + 8 * k  # 64-bit PRG key + values only


def test_natural_exponent_only_lossiness_bound():
    """The 12-bit format is exact on the compressor OUTPUT; vs the original
    vector the loss is the power-of-two rounding itself: ratio in (1/2, 2]
    times the 8/9 scale."""
    t = 400
    u = _rand_u(7, t, scale=1e-2)
    comp = get_compressor("natural", t, 0)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(jax.random.PRNGKey(8), u)
    dec = np.asarray(codec.decode(enc.data, t))
    u_np = np.asarray(u)
    nz = u_np != 0
    ratio = np.abs(dec[nz] / u_np[nz])
    lo, hi = wire.NATURAL_SCALE / 2, wire.NATURAL_SCALE * 2
    assert (ratio > lo - 1e-12).all() and (ratio <= hi + 1e-12).all()
    assert np.sign(dec[nz]).tolist() == np.sign(u_np[nz]).tolist()
    assert enc.bits == 12 * t


# ---------------------------------------------------------------------------
# protocol framing
# ---------------------------------------------------------------------------

def test_frame_pack_unpack_roundtrip():
    a, b = loopback_pair()
    frame = protocol.Frame(
        type=protocol.MsgType.UPLINK, round=7, client=3, comp_id=4,
        sent_elems=12, payload_bits=1184, payload=b"\x01\x02\x03",
    )
    sent = protocol.send_frame(a, frame)
    assert sent == protocol.HEADER_SIZE + 3
    got = protocol.recv_frame(b)
    assert got == frame


def test_frame_rejects_bad_magic():
    with pytest.raises(ValueError, match="magic"):
        protocol.unpack_header(b"XXXX" + bytes(protocol.HEADER_SIZE - 4))


def test_uplink_payload_roundtrip():
    d = 11
    grad = _rand_u(1, d)
    enc = wire.EncodedMessage(b"\xaa" * 9, 72, 3)
    payload = protocol.pack_uplink(grad, 0.25, 1.5, enc)
    g2, l2, f2, hess = protocol.unpack_uplink(payload, d)
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(grad))
    assert float(l2) == 0.25 and float(f2) == 1.5 and hess == enc.data


@pytest.mark.parametrize("name", ALL_COMPRESSORS)
def test_frame_bits_model_matches_real_frame(name):
    """wire.frame_bits (the FedNLConfig accounting='wire' model) equals the
    byte length of an actually-assembled UPLINK frame."""
    t, k, d = 78, 9, 12
    comp = get_compressor(name, t, k)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(jax.random.PRNGKey(0), _rand_u(2, t))
    frame = protocol.Frame(
        type=protocol.MsgType.UPLINK, sent_elems=enc.sent_elems,
        payload_bits=enc.bits,
        payload=protocol.pack_uplink(_rand_u(3, d), 0.0, 0.0, enc),
    )
    assert 8 * frame.wire_bytes == int(wire.frame_bits(comp, enc.sent_elems, d))


# ---------------------------------------------------------------------------
# star topology: loopback end-to-end vs the single-node simulation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", ["topk", "randseqk", "natural"])
def test_loopback_reproduces_single_node_trajectory(z, comp):
    """The full encode->frame->decode star run must track run_fednl to <=1e-8
    (in practice it is bit-identical — same oracles, same PRG schedule, exact
    codecs, same jnp aggregation)."""
    cfg = FedNLConfig(compressor=comp, lam=LAM)
    ref = run_fednl(z, cfg, rounds=12, seed=0)
    lb = run_loopback(z, cfg, rounds=12, seed=0)
    np.testing.assert_allclose(lb.x, ref.x, atol=1e-8)
    np.testing.assert_allclose(lb.grad_norms, ref.grad_norms, atol=1e-8)
    np.testing.assert_allclose(lb.f_vals, ref.f_vals, atol=1e-8)
    assert lb.grad_norms[-1] < 1e-10  # still converges through the wire


@pytest.mark.parametrize("comp", ALL_COMPRESSORS)
def test_loopback_measured_bits_equal_analytic(z, comp):
    """Acceptance: measured wire bytes == the analytic message_bits model for
    every compressor, and the framed bytes match the frame_bits model."""
    cfg = FedNLConfig(compressor=comp, lam=LAM)
    lb = run_loopback(z, cfg, rounds=2, seed=0)
    np.testing.assert_array_equal(lb.measured_payload_bits, lb.sent_bits)
    # cross-check against the jitted simulation's analytic accounting
    ref = run_fednl(z, cfg, rounds=2, seed=0)
    np.testing.assert_array_equal(ref.sent_bits.astype(np.int64), lb.sent_bits)


def test_wire_accounting_option_matches_measured_frames(z):
    """FedNLConfig(accounting='wire') makes the simulation's sent_bits equal
    the real framed byte stream of the transport run."""
    cfg = FedNLConfig(compressor="toplek", lam=LAM, accounting="wire")
    ref = run_fednl(z, cfg, rounds=3, seed=0)
    lb = run_loopback(z, dataclasses.replace(cfg, accounting="payload"),
                      rounds=3, seed=0)
    np.testing.assert_array_equal(
        ref.sent_bits.astype(np.int64), 8 * lb.measured_frame_bytes
    )


def test_loopback_hess0_zero_cold_start(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM, hess0="zero")
    ref = run_fednl(z, cfg, rounds=10, seed=0)
    lb = run_loopback(z, cfg, rounds=10, seed=0)
    np.testing.assert_allclose(lb.grad_norms, ref.grad_norms, atol=1e-8)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_round_time():
    cm = CommCostModel(bandwidth_bps=1e9, latency_s=1e-4)
    # 8 clients x 1 Mbit uplink + 1 Mbit broadcast = 9 ms wire + 2 latencies
    got = cm.round_s(8e6, 1e6, n_clients=8)
    assert got == pytest.approx(2e-4 + 9e-3)
    # parallel-uplink variant is bounded by one client's share
    cm_p = CommCostModel(bandwidth_bps=1e9, latency_s=1e-4, master_shared_nic=False)
    assert cm_p.round_s(8e6, 1e6, n_clients=8) == pytest.approx(2e-4 + 2e-3)


def test_star_roofline_dominance():
    from repro.roofline import star_roofline

    r = star_roofline(1e-3, 8e9, 1e6, n_clients=8)  # 8 Gbit uplink: comm-bound
    assert r["dominant"] == "comm" and r["round_s"] >= r["comm_s"]
    r2 = star_roofline(1.0, 8e3, 1e3, n_clients=8)
    assert r2["dominant"] == "compute"


# ---------------------------------------------------------------------------
# TCP localhost, real client processes (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_tcp_multiproc_reproduces_single_node_trajectory():
    """master + n client processes over TCP localhost track run_fednl <=1e-8."""
    from repro.launch.multiproc import _build_problem, run_multiproc

    shape = (16, 4, 30)  # d, n_clients, n_i — small: 4 jax client processes
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    try:
        res = run_multiproc(cfg, shape=shape, rounds=8, seed=0)
    except (OSError, PermissionError) as e:  # pragma: no cover
        pytest.skip(f"multiprocess TCP unavailable in this sandbox: {e}")
    z = _build_problem("", shape, 0)
    ref = run_fednl(z, cfg, rounds=8, seed=0)
    np.testing.assert_allclose(res.x, ref.x, atol=1e-8)
    np.testing.assert_allclose(res.grad_norms, ref.grad_norms, atol=1e-8)
    np.testing.assert_array_equal(res.measured_payload_bits, res.sent_bits)
