"""FedNL-PP over the star transport: SELECT/PP_UPDATE framing, the
pp_message_bits model vs measured wire bytes, loopback runs reproducing the
single-node make_fednl_pp_round trajectory bit-for-bit (tau = n and tau < n),
dropout/straggler fault injection with both Algorithm-3 fallback policies,
and the TCP multi-process PP run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import protocol, wire
from repro.comm.star_pp import run_pp_loopback
from repro.comm.transport import FaultSpec
from repro.compressors import get_compressor
from repro.core import FedNLConfig, eval_full, run_fednl_pp
from repro.core.fednl_pp import make_pp_bits_fn
from repro.data import add_intercept, make_synthetic_logreg, partition_clients

ALL_COMPRESSORS = ["identity", "topk", "randk", "randseqk", "toplek", "natural"]

LAM = 1e-3


def _rand_u(seed, t, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (t,), dtype=jnp.float64) * scale


@pytest.fixture(scope="module")
def z():
    x, y = make_synthetic_logreg("tiny", seed=1)
    return jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=1))


# ---------------------------------------------------------------------------
# PP payload framing
# ---------------------------------------------------------------------------

def test_select_payload_roundtrip():
    x = _rand_u(0, 13)
    payload = protocol.pack_select(slot=3, tau=7, x=x)
    slot, tau, x2 = protocol.unpack_select(payload)
    assert (slot, tau) == (3, 7)
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x))


def test_pp_state_payload_roundtrip():
    d = 9
    t = d * (d + 1) // 2
    h, g = _rand_u(1, t), _rand_u(2, d)
    payload = protocol.pack_pp_state(h, 0.625, g)
    h2, l2, g2 = protocol.unpack_pp_state(payload, d)
    np.testing.assert_array_equal(np.asarray(h2), np.asarray(h))
    assert float(l2) == 0.625
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(g))


def test_pp_update_payload_roundtrip():
    d = 11
    enc = wire.EncodedMessage(b"\xab" * 17, 136, 4)
    dg = _rand_u(3, d)
    payload = protocol.pack_pp_update(enc, -0.25, dg)
    hess_bytes, dl, dg2 = protocol.unpack_pp_update(payload, d)
    assert hess_bytes == enc.data
    assert float(dl) == -0.25
    np.testing.assert_array_equal(np.asarray(dg2), np.asarray(dg))


# ---------------------------------------------------------------------------
# pp_message_bits model: analytic == assembled payload, exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_COMPRESSORS)
def test_pp_message_bits_matches_assembled_payload(name):
    """pp_message_bits == Hessian enc bits + (d+1)*64, and the assembled
    PP_UPDATE payload is exactly that bit count rounded up to bytes."""
    t, k, d = 120, 11, 15
    comp = get_compressor(name, t, k)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(jax.random.PRNGKey(0), _rand_u(4, t))
    want = int(wire.pp_message_bits(comp, jnp.asarray(enc.sent_elems), d))
    assert want == enc.bits + (d + 1) * 64
    payload = protocol.pack_pp_update(enc, 0.5, _rand_u(5, d))
    assert len(payload) == (want + 7) // 8


@pytest.mark.parametrize("name", ALL_COMPRESSORS)
def test_pp_frame_bits_matches_real_frame(name):
    """wire.pp_frame_bits (the accounting='wire' PP model) equals the byte
    length of an actually-assembled PP_UPDATE frame."""
    t, k, d = 78, 9, 12
    comp = get_compressor(name, t, k)
    codec = wire.make_codec(comp, t)
    enc = codec.encode(jax.random.PRNGKey(1), _rand_u(6, t))
    frame = protocol.Frame(
        type=protocol.MsgType.PP_UPDATE,
        sent_elems=enc.sent_elems,
        payload_bits=enc.bits + (d + 1) * 64,
        payload=protocol.pack_pp_update(enc, 0.0, jnp.zeros(d)),
    )
    assert 8 * frame.wire_bytes == int(wire.pp_frame_bits(comp, enc.sent_elems, d))


def test_make_pp_bits_fn_payload_equals_wire_model(z):
    d = z.shape[-1]
    t = d * (d + 1) // 2
    comp = get_compressor("toplek", t, 3 * d)
    payload_fn = make_pp_bits_fn(comp, d, "payload")
    for s_e in [0, 1, 3 * d]:
        assert int(payload_fn(jnp.asarray(s_e))) == int(
            wire.pp_message_bits(comp, jnp.asarray(s_e), d)
        )
    with pytest.raises(ValueError, match="accounting"):
        make_pp_bits_fn(comp, d, "nope")


# ---------------------------------------------------------------------------
# loopback PP vs the single-node simulation (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", ["topk", "randseqk", "natural"])
def test_pp_loopback_tau_n_bit_identical(z, comp):
    """tau = n over the full encode->frame->decode wire reproduces
    make_fednl_pp_round BIT-FOR-BIT (exact array equality, not atol)."""
    n = z.shape[0]
    cfg = FedNLConfig(compressor=comp, lam=LAM)
    ref = run_fednl_pp(z, cfg, tau=n, rounds=10, seed=0)
    lb = run_pp_loopback(z, cfg, tau=n, rounds=10, seed=0)
    np.testing.assert_array_equal(lb.x_hist, ref.x_hist)
    np.testing.assert_array_equal(lb.x, ref.x)  # post-run model too
    np.testing.assert_array_equal(lb.l_hist, ref.l_vals)
    np.testing.assert_array_equal(lb.sent_bits, ref.sent_bits.astype(np.int64))


def test_pp_loopback_tau_lt_n_bit_identical(z):
    """Partial sampling stays seed-aligned: tau < n is bit-exact too."""
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    ref = run_fednl_pp(z, cfg, tau=3, rounds=15, seed=0)
    lb = run_pp_loopback(z, cfg, tau=3, rounds=15, seed=0)
    np.testing.assert_array_equal(lb.x_hist, ref.x_hist)
    # exactly tau contributions per round, no drops
    assert all(len(p) == 3 for p in lb.participants)
    assert all(len(d) == 0 for d in lb.dropped)


@pytest.mark.parametrize("comp", ALL_COMPRESSORS)
def test_pp_loopback_measured_bits_equal_analytic(z, comp):
    """Acceptance: measured PP uplink bits == the analytic pp_message_bits
    model exactly, for every compressor — and both equal the simulation's
    sent_bits accounting."""
    cfg = FedNLConfig(compressor=comp, lam=LAM)
    lb = run_pp_loopback(z, cfg, tau=4, rounds=3, seed=0)
    np.testing.assert_array_equal(lb.measured_payload_bits, lb.sent_bits)
    ref = run_fednl_pp(z, cfg, tau=4, rounds=3, seed=0)
    np.testing.assert_array_equal(ref.sent_bits.astype(np.int64), lb.sent_bits)


def test_pp_wire_accounting_matches_measured_frames(z):
    """FedNLConfig(accounting='wire') prices the simulation's PP sent_bits as
    full framed PP_UPDATE bytes — equal to the real transport byte stream."""
    import dataclasses

    cfg = FedNLConfig(compressor="toplek", lam=LAM, accounting="wire")
    ref = run_fednl_pp(z, cfg, tau=5, rounds=3, seed=0)
    lb = run_pp_loopback(
        z, dataclasses.replace(cfg, accounting="payload"), tau=5, rounds=3, seed=0
    )
    np.testing.assert_array_equal(
        ref.sent_bits.astype(np.int64), 8 * lb.measured_frame_bytes
    )


def test_pp_loopback_hess0_zero_cold_start(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM, hess0="zero")
    ref = run_fednl_pp(z, cfg, tau=4, rounds=10, seed=0)
    lb = run_pp_loopback(z, cfg, tau=4, rounds=10, seed=0)
    np.testing.assert_array_equal(lb.x_hist, ref.x_hist)


# ---------------------------------------------------------------------------
# fault injection: dropout + straggler (Algorithm-3 replaceable clients)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("policy", ["partial", "resample"])
def test_pp_dropout_still_converges(z, policy):
    """Acceptance: a dropout-injected run (tau < n, nonzero drop probability)
    still converges to grad_norm < 1e-9 under both fallback policies."""
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    fault = FaultSpec(drop_prob=0.25, seed=7)
    res = run_pp_loopback(
        z, cfg, tau=4, rounds=100, seed=0, on_dropout=policy, fault=fault
    )
    assert sum(len(d) for d in res.dropped) > 0, "fault injection never fired"
    _, g = eval_full(z, jnp.asarray(res.x), LAM)
    assert float(jnp.linalg.norm(g)) < 1e-9
    # bits accounting stays exact under faults
    np.testing.assert_array_equal(res.measured_payload_bits, res.sent_bits)


def test_pp_resample_refills_slots(z):
    """With spare clients, resample keeps tau contributions per round."""
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    fault = FaultSpec(drop_prob=0.3, seed=3)
    res = run_pp_loopback(
        z, cfg, tau=2, rounds=25, seed=0, on_dropout="resample", fault=fault
    )
    dropped = sum(len(d) for d in res.dropped)
    assert dropped > 0
    # every round ends with a full tau of contributions unless the whole
    # pool dropped (8 clients, 30% drop: never exhausts here)
    assert all(len(p) == 2 for p in res.participants)


def test_pp_partial_proceeds_with_survivors(z):
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    fault = FaultSpec(drop_prob=0.3, seed=5)
    res = run_pp_loopback(
        z, cfg, tau=4, rounds=25, seed=0, on_dropout="partial", fault=fault
    )
    per_round = [len(p) + len(d) for p, d in zip(res.participants, res.dropped)]
    assert all(c == 4 for c in per_round)  # every slot accounted for
    assert any(len(p) < 4 for p in res.participants)  # some rounds degraded


def test_pp_straggler_delay_only_delays(z):
    import time

    from repro.comm.transport import FaultInjector

    # the injector really stalls the configured delay
    inj = FaultInjector(
        FaultSpec(straggler_prob=1.0, straggler_delay_s=0.02, seed=1), 0
    )
    t0 = time.perf_counter()
    assert inj.maybe_stall() == 0.02
    assert time.perf_counter() - t0 >= 0.018
    # ... and at the protocol level stragglers delay but never diverge
    # (wall-clock comparisons across runs are jit-compile-cache noise, so
    # the trajectory equality is the meaningful run-level assertion)
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    base = run_pp_loopback(z, cfg, tau=2, rounds=4, seed=0)
    fault = FaultSpec(straggler_prob=1.0, straggler_delay_s=0.02, seed=1)
    slow = run_pp_loopback(z, cfg, tau=2, rounds=4, seed=0, fault=fault)
    np.testing.assert_array_equal(slow.x_hist, base.x_hist)
    assert all(len(d) == 0 for d in slow.dropped)


def test_pp_master_rejects_bad_args(z):
    from repro.comm.star_pp import StarPPMaster

    with pytest.raises(ValueError, match="on_dropout"):
        StarPPMaster({0: None}, 4, FedNLConfig(), tau=1, on_dropout="retry")
    with pytest.raises(ValueError, match="tau"):
        StarPPMaster({0: None}, 4, FedNLConfig(), tau=2)


# ---------------------------------------------------------------------------
# TCP localhost, real client processes
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_tcp_multiproc_pp_bit_identical():
    """master + n client processes over TCP localhost reproduce the
    single-node FedNL-PP trajectory bit-for-bit at tau = n."""
    from repro.launch.multiproc import _build_problem, run_multiproc_pp

    shape = (16, 4, 30)  # d, n_clients, n_i — small: 4 jax client processes
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    try:
        res = run_multiproc_pp(cfg, tau=4, shape=shape, rounds=8, seed=0)
    except (OSError, PermissionError) as e:  # pragma: no cover
        pytest.skip(f"multiprocess TCP unavailable in this sandbox: {e}")
    z = _build_problem("", shape, 0)
    ref = run_fednl_pp(z, cfg, tau=4, rounds=8, seed=0)
    np.testing.assert_array_equal(res.x_hist, ref.x_hist)
    np.testing.assert_array_equal(res.measured_payload_bits, res.sent_bits)


@pytest.mark.net
def test_tcp_multiproc_pp_dropout_converges():
    from repro.launch.multiproc import _build_problem, run_multiproc_pp

    shape = (16, 4, 30)
    cfg = FedNLConfig(compressor="topk", lam=LAM)
    fault = FaultSpec(drop_prob=0.2, seed=11)
    try:
        res = run_multiproc_pp(
            cfg, tau=2, shape=shape, rounds=60, seed=0,
            on_dropout="resample", fault=fault,
        )
    except (OSError, PermissionError) as e:  # pragma: no cover
        pytest.skip(f"multiprocess TCP unavailable in this sandbox: {e}")
    z = _build_problem("", shape, 0)
    _, g = eval_full(z, jnp.asarray(res.x), LAM)
    assert float(jnp.linalg.norm(g)) < 1e-9
