"""repro.api: the declarative ExperimentSpec -> solve() facade.

Parity contract (the point of the facade): solve(spec) adds *zero* numerical
surface on top of the drivers it wraps —

  * local + star-loopback backends reproduce tests/golden/fednl_traces.json
    BIT-for-bit (float.hex comparison, same as test_golden_traces.py);
  * the PP backends reproduce ``run_fednl_pp`` bit-for-bit fault-free, and
    the faulted star path reproduces ``run_pp_loopback`` with the same
    FaultSpec exactly;
  * a spec re-runs on a different backend by changing only the ``backend``
    field (the acceptance criterion of the API redesign).

Plus registry contracts: unknown names fail loudly, registration makes
custom algorithms/backends/compressors first-class.
"""

import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    FaultSpec,
    list_algorithms,
    list_backends,
    register_compressor,
    solve,
)
from repro.api.accounting import make_bits_fn as unified_bits_fn
from repro.api.registry import Algorithm, get_algorithm, get_backend

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fednl_traces.json"


@pytest.fixture(scope="module")
def golden():
    return json.loads(GOLDEN.read_text())


def golden_spec(comp: str, rounds: int) -> ExperimentSpec:
    """The exact problem/config the golden traces pin (see gen_golden_traces)."""
    return ExperimentSpec(
        data=DataSpec(dataset="tiny", seed=1),
        compressor=CompressorSpec(comp),
        rounds=rounds,
        seed=0,
    )


@pytest.fixture(scope="module")
def z_tiny():
    return DataSpec(dataset="tiny", seed=1).build()


# ---------------------------------------------------------------------------
# golden-trace parity: local + star-loopback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["local", "star-loopback"])
@pytest.mark.parametrize("comp", ["topk", "randseqk", "toplek"])
def test_solve_matches_golden_bitwise(golden, comp, backend):
    spec = golden_spec(comp, golden["rounds"]).replace(backend=backend)
    rep = solve(spec)
    got_gn = [float(g).hex() for g in rep.grad_norms]
    got_bits = [int(b) for b in rep.sent_bits]
    assert got_gn == golden["traces"][comp]["grad_norms_hex"], (
        f"solve(spec) on {backend} drifted from the golden grad-norm pin"
    )
    assert got_bits == golden["traces"][comp]["sent_bits"], (
        f"solve(spec) on {backend} drifted from the golden sent_bits pin"
    )


def test_backend_swap_is_one_field(golden):
    """Acceptance criterion: same spec, different backend, same trajectory."""
    spec = golden_spec("topk", golden["rounds"])
    local = solve(spec)
    swapped = solve(spec.replace(backend="star-loopback"))
    assert spec.replace(backend="star-loopback").backend == "star-loopback"
    np.testing.assert_array_equal(local.x, swapped.x)
    assert [g.hex() for g in local.grad_norms] == [
        g.hex() for g in swapped.grad_norms
    ]


# ---------------------------------------------------------------------------
# PP parity: local + star-loopback vs run_fednl_pp, with and without faults
# ---------------------------------------------------------------------------

def pp_spec(**overrides) -> ExperimentSpec:
    base = dict(
        algorithm="fednl-pp",
        data=DataSpec(dataset="tiny", seed=1),
        compressor=CompressorSpec("topk"),
        rounds=8,
        seed=0,
        tau=3,
    )
    base.update(overrides)
    return ExperimentSpec(**base)


@pytest.mark.parametrize("backend", ["local", "star-loopback"])
def test_pp_solve_matches_run_fednl_pp_bitwise(z_tiny, backend):
    from repro.core import run_fednl_pp

    spec = pp_spec(backend=backend)
    ref = run_fednl_pp(z_tiny, spec.fednl_config(), tau=3, rounds=8, seed=0)
    rep = solve(spec)
    np.testing.assert_array_equal(rep.x_hist, ref.x_hist)
    np.testing.assert_array_equal(rep.x, ref.x)
    np.testing.assert_array_equal(rep.l_vals, ref.l_vals)
    np.testing.assert_array_equal(
        rep.sent_bits, ref.sent_bits.astype(np.int64)
    )


def test_pp_solve_with_faults_matches_direct_driver(z_tiny):
    """The facade adds nothing on the faulted path either: same FaultSpec,
    same trajectory/participation as calling run_pp_loopback directly."""
    from repro.comm.star_pp import run_pp_loopback

    fault = FaultSpec(drop_prob=0.25, seed=7)
    spec = pp_spec(backend="star-loopback", rounds=12, fault=fault)
    rep = solve(spec)
    direct = run_pp_loopback(
        z_tiny, spec.fednl_config(), tau=3, rounds=12, seed=0,
        on_dropout="partial", fault=fault,
    )
    np.testing.assert_array_equal(rep.x_hist, direct.x_hist)
    assert rep.participants == direct.participants
    assert rep.dropped == direct.dropped
    assert sum(len(d) for d in rep.dropped) > 0, "fault injection was a no-op"
    # faults change the trajectory but not convergence (12 rounds at 25%
    # drop: superlinear phase not yet entered — order-of-magnitude check)
    assert rep.final_grad_norm < 1e-3


def test_pp_local_records_participation(z_tiny):
    rep = solve(pp_spec())
    assert all(len(r.participants) == 3 for r in rep.records)
    assert rep.rounds == 8 and rep.final_grad_norm is not None


# ---------------------------------------------------------------------------
# sharded backend: converges and reports both accounting models
# ---------------------------------------------------------------------------

def test_sharded_backend_runs_and_accounts(z_tiny):
    spec = golden_spec("topk", 20).replace(backend="sharded", tol=1e-10)
    rep = solve(spec)
    assert rep.records[-1].grad_norm < 1e-10
    assert rep.records[0].sent_bits == rep.records[0].sent_bits_payload
    assert rep.records[0].sent_bits_wire > rep.records[0].sent_bits_payload


# ---------------------------------------------------------------------------
# unified accounting (satellite: one bits model, shims preserved)
# ---------------------------------------------------------------------------

def test_accounting_shims_delegate_to_unified():
    from repro.compressors import get_compressor
    from repro.core.fednl import make_bits_fn as legacy_full
    from repro.core.fednl_pp import make_pp_bits_fn as legacy_pp
    from repro.linalg import triu_size

    d = 24
    comp = get_compressor("topk", triu_size(d), 8 * d)
    for acc in ("payload", "wire"):
        assert int(legacy_full(comp, d, acc)(100)) == int(
            unified_bits_fn(comp, d, acc)(100)
        )
        assert int(legacy_pp(comp, d, acc)(100)) == int(
            unified_bits_fn(comp, d, acc, pp=True)(100)
        )
    with pytest.raises(ValueError):
        unified_bits_fn(comp, d, "nope")


def test_report_carries_both_accountings():
    rep = solve(golden_spec("topk", 2))
    wire = solve(golden_spec("topk", 2).replace(accounting="wire"))
    # selected column honors the accounting field; both models always present
    np.testing.assert_array_equal(rep.sent_bits, rep.sent_bits_payload)
    np.testing.assert_array_equal(wire.sent_bits, wire.sent_bits_wire)
    np.testing.assert_array_equal(rep.sent_bits_wire, wire.sent_bits_wire)


# ---------------------------------------------------------------------------
# spec + registry contracts
# ---------------------------------------------------------------------------

def test_builtin_registries_populated():
    assert set(list_algorithms()) >= {"fednl", "fednl-ls", "fednl-pp"}
    assert set(list_backends()) >= {
        "local", "sharded", "star-loopback", "star-tcp",
    }


def test_unknown_names_fail_loudly():
    with pytest.raises(KeyError, match="unknown algorithm"):
        solve(ExperimentSpec(algorithm="fednl2"))
    with pytest.raises(KeyError, match="unknown backend"):
        solve(ExperimentSpec(backend="ray"))
    with pytest.raises(ValueError, match="accounting"):
        ExperimentSpec(accounting="bytes")
    with pytest.raises(ValueError, match="objective"):
        ExperimentSpec(objective="lasso")
    with pytest.raises(ValueError, match="partial participation"):
        ExperimentSpec(algorithm="fednl", tau=3)
    # PP never sees the global gradient: a tol early stop must be rejected
    # rather than silently ignored
    with pytest.raises(ValueError, match="rounds instead"):
        ExperimentSpec(algorithm="fednl-pp", tau=3, tol=1e-9)


def test_backend_capability_is_checked():
    # no LS wire protocol: star backends must refuse fednl-ls
    with pytest.raises(ValueError, match="does not support"):
        solve(ExperimentSpec(algorithm="fednl-ls", backend="star-loopback"))
    # fault injection is transport-level: the local simulation must refuse a
    # FaultSpec loudly rather than silently run the experiment fault-free
    with pytest.raises(ValueError, match="cannot inject faults"):
        solve(pp_spec(fault=FaultSpec(drop_prob=0.5, seed=7)))


def test_wire_backends_refuse_overwritten_builtin():
    """supports() is identity-based: re-registering 'fednl' with a custom
    round must make the wire backends refuse loudly, not silently run the
    builtin protocol under the custom algorithm's name."""
    from repro.api import register_algorithm

    base = get_algorithm("fednl")
    custom = Algorithm(
        name="fednl", kind="full", init=base.init, make_round=base.make_round
    )
    register_algorithm(custom, overwrite=True)
    try:
        with pytest.raises(ValueError, match="does not support"):
            solve(golden_spec("topk", 1).replace(backend="star-loopback"))
        with pytest.raises(ValueError, match="does not support"):
            solve(golden_spec("topk", 1).replace(backend="sharded"))
    finally:
        register_algorithm(base, overwrite=True)


def test_spec_is_frozen_and_replaceable():
    spec = golden_spec("topk", 3)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.rounds = 7
    assert spec.replace(rounds=7).rounds == 7
    assert spec.rounds == 3


def test_register_custom_algorithm_and_backend():
    from repro.api.registry import ALGORITHMS, BACKENDS, Backend, register_backend

    class EchoBackend(Backend):
        name = "echo-test"
        needs_problem = False

        def run(self, spec, algo, z, x0):
            return (spec, algo.name)

    register_backend(EchoBackend())
    try:
        spec = ExperimentSpec(backend="echo-test", rounds=1)
        got_spec, got_algo = solve(spec)
        assert got_spec is spec and got_algo == "fednl"
        with pytest.raises(ValueError, match="already registered"):
            register_backend(EchoBackend())
    finally:
        BACKENDS._entries.pop("echo-test", None)

    algo = Algorithm(
        name="fednl-echo", kind="full",
        init=get_algorithm("fednl").init,
        make_round=get_algorithm("fednl").make_round,
    )
    from repro.api import register_algorithm

    register_algorithm(algo)
    try:
        rep = solve(ExperimentSpec(algorithm="fednl-echo", rounds=2,
                                   data=DataSpec(dataset="tiny", seed=1)))
        assert rep.rounds == 2
    finally:
        ALGORITHMS._entries.pop("fednl-echo", None)


def test_register_custom_compressor_end_to_end():
    from repro.compressors.core import COMPRESSORS, Compressor, identity

    def make_id2(t, k):
        return Compressor("identity2", lambda key, u: identity(u), alpha=1.0,
                          delta=1.0, bits_per_elem=64, header_bits=0)

    register_compressor("identity2", make_id2)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_compressor("identity2", make_id2)
        rep = solve(ExperimentSpec(
            data=DataSpec(dataset="tiny", seed=1),
            compressor=CompressorSpec("identity2"), rounds=3,
        ))
        ref = solve(golden_spec("topk", 3).replace(
            compressor=CompressorSpec("identity")
        ))
        # identity2 is identity by another name: identical trajectory
        np.testing.assert_array_equal(rep.grad_norms, ref.grad_norms)
    finally:
        COMPRESSORS.pop("identity2", None)


def test_x0_and_z_overrides(z_tiny):
    x0 = np.full(z_tiny.shape[-1], 0.1)
    rep = solve(golden_spec("topk", 3), z=z_tiny, x0=x0)
    cold = solve(golden_spec("topk", 3), z=z_tiny)
    assert not np.array_equal(rep.grad_norms, cold.grad_norms)
    with pytest.raises(ValueError, match="x0"):
        solve(golden_spec("topk", 2).replace(backend="star-loopback"), x0=x0)
    with pytest.raises(ValueError, match="pre-built z"):
        solve(golden_spec("topk", 2).replace(backend="star-tcp"), z=z_tiny)


# ---------------------------------------------------------------------------
# star-tcp through the facade (real sockets -> net marker)
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_tcp_backend_matches_local_backend():
    spec = ExperimentSpec(
        data=DataSpec(shape=(12, 4, 20), seed=3),
        compressor=CompressorSpec("topk"),
        backend="star-tcp",
        rounds=6,
        seed=0,
    )
    tcp = solve(spec)
    local = solve(spec.replace(backend="local"))
    # full-participation TCP contract is <=1e-8 (test_comm.py); the PP star
    # client is the bit-exact path (vmap-of-1 regime, test below)
    np.testing.assert_allclose(tcp.x, local.x, atol=1e-8)
    np.testing.assert_allclose(tcp.grad_norms, local.grad_norms, atol=1e-8)


@pytest.mark.net
def test_tcp_pp_backend_matches_local_backend():
    spec = ExperimentSpec(
        algorithm="fednl-pp",
        data=DataSpec(shape=(12, 4, 20), seed=3),
        compressor=CompressorSpec("topk"),
        backend="star-tcp",
        rounds=6,
        tau=4,
        seed=0,
    )
    tcp = solve(spec)
    local = solve(spec.replace(backend="local"))
    np.testing.assert_array_equal(tcp.x_hist, local.x_hist)
    np.testing.assert_array_equal(tcp.x, local.x)
