"""Compressor theory tests: unbiasedness, contraction, variance identities,
TopLEK tight equality, Natural omega <= 1/8 — the properties FedNL's
convergence proof rests on (paper Section 8, Appendices C & D)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.compressors import core as C


def _rand_u(seed, t):
    return jax.random.normal(jax.random.PRNGKey(seed), (t,), dtype=jnp.float64)


# ---------------------------------------------------------------------------
# TopK
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,k", [(10, 3), (100, 8), (45, 45), (64, 1)])
def test_topk_contraction_deterministic(t, k):
    u = _rand_u(t + k, t)
    u_hat, sent = C.topk(u, k)
    assert int(sent) == k
    # deterministic contraction with delta = k/t
    lhs = float(jnp.sum((u_hat - u) ** 2))
    rhs = (1 - k / t) * float(jnp.sum(u**2))
    assert lhs <= rhs + 1e-12
    assert int(jnp.sum(u_hat != 0)) <= k


def test_topk_keeps_largest():
    u = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2])
    u_hat, _ = C.topk(u, 2)
    np.testing.assert_allclose(np.asarray(u_hat), [0, -5.0, 0, 2.0, 0])


# ---------------------------------------------------------------------------
# RandK / RandSeqK
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["randk", "randseqk"])
def test_rand_unbiased(name):
    t, k, n_mc = 24, 6, 4000
    u = _rand_u(0, t)
    fn = C.randk if name == "randk" else C.randseqk
    keys = jax.random.split(jax.random.PRNGKey(1), n_mc)
    samples = jax.vmap(lambda key: fn(key, u, k, scaled=False)[0])(keys)
    mean = jnp.mean(samples, axis=0)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(u), atol=0.4)


@pytest.mark.parametrize("name", ["randk", "randseqk"])
def test_rand_variance_identity(name):
    """E||C(u) - u||^2 = omega ||u||^2 with omega = t/k - 1 (Appendix C)."""
    t, k, n_mc = 24, 6, 6000
    u = _rand_u(3, t)
    fn = C.randk if name == "randk" else C.randseqk
    keys = jax.random.split(jax.random.PRNGKey(2), n_mc)
    errs = jax.vmap(
        lambda key: jnp.sum((fn(key, u, k, scaled=False)[0] - u) ** 2)
    )(keys)
    omega = t / k - 1
    want = omega * float(jnp.sum(u**2))
    got = float(jnp.mean(errs))
    assert abs(got - want) / want < 0.1


def test_randseqk_selects_contiguous_window():
    t, k = 32, 5
    u = jnp.arange(1.0, t + 1)
    u_hat, _ = C.randseqk(jax.random.PRNGKey(7), u, k)
    idx = np.nonzero(np.asarray(u_hat))[0]
    assert len(idx) == k
    gaps = np.diff(np.sort(idx))
    # contiguous mod t: all gaps 1 except possibly one wraparound gap
    assert np.sum(gaps != 1) <= 1


def test_randseqk_single_prg_call_matches_sparse_form():
    t, k = 40, 7
    u = _rand_u(9, t)
    key = jax.random.PRNGKey(11)
    dense, _ = C.randseqk(key, u, k)
    idx, vals, _ = C.randseqk_sparse(key, u, k)
    recon = C.scatter_add_sparse(idx, vals, t)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(recon), rtol=1e-12)


# ---------------------------------------------------------------------------
# TopLEK
# ---------------------------------------------------------------------------

def test_toplek_sends_at_most_k_and_contracts():
    t, k = 60, 12
    for seed in range(5):
        u = _rand_u(seed, t)
        u_hat, kept = C.toplek(jax.random.PRNGKey(seed), u, k)
        assert int(kept) <= k
        lhs = float(jnp.sum((u_hat - u) ** 2))
        rhs = (1 - k / t) * float(jnp.sum(u**2))
        # per-sample contraction may exceed the bound only via the randomized
        # j-branch; the EXPECTATION is tight (next test).  The i-branch holds
        # deterministically; allow the randomized slack here.
        assert int(jnp.sum(u_hat != 0)) <= k


def test_toplek_tight_equality_in_expectation():
    """E||C(x)-x||^2 == (1 - k/t) ||x||^2 exactly (Appendix D)."""
    t, k, n_mc = 30, 6, 6000
    u = _rand_u(4, t)
    keys = jax.random.split(jax.random.PRNGKey(5), n_mc)
    errs = jax.vmap(lambda key: jnp.sum((C.toplek(key, u, k)[0] - u) ** 2))(keys)
    want = (1 - k / t) * float(jnp.sum(u**2))
    got = float(jnp.mean(errs))
    assert abs(got - want) / want < 0.05


def test_toplek_zero_input():
    u = jnp.zeros(20)
    u_hat, kept = C.toplek(jax.random.PRNGKey(0), u, 5)
    assert int(kept) == 0
    assert float(jnp.sum(jnp.abs(u_hat))) == 0.0


# ---------------------------------------------------------------------------
# Natural
# ---------------------------------------------------------------------------

def test_natural_unbiased_and_powers_of_two():
    u = _rand_u(8, 50)
    keys = jax.random.split(jax.random.PRNGKey(9), 4000)
    samples = jax.vmap(lambda key: C.natural(key, u, scaled=False)[0])(keys)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(samples, axis=0)), np.asarray(u), rtol=0.06, atol=1e-3
    )
    one = np.asarray(samples[0])
    nz = one[one != 0]
    m, e = np.frexp(np.abs(nz))
    np.testing.assert_allclose(m, 0.5, rtol=0, atol=0)  # exact powers of two


def test_natural_variance_bound():
    """omega = E||C(u)-u||^2 / ||u||^2 <= 1/8 (Horvath et al.)."""
    u = _rand_u(10, 64)
    keys = jax.random.split(jax.random.PRNGKey(11), 4000)
    errs = jax.vmap(lambda key: jnp.sum((C.natural(key, u, scaled=False)[0] - u) ** 2))(keys)
    omega = float(jnp.mean(errs)) / float(jnp.sum(u**2))
    assert omega <= 1.0 / 8.0 + 0.01


# ---------------------------------------------------------------------------
# sparse forms & registry — property-based
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(min_value=4, max_value=120),
    frac=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**20),
    name=st.sampled_from(["topk", "randk", "randseqk", "toplek"]),
)
def test_sparse_dense_equivalence(t, frac, seed, name):
    k = max(1, int(frac * t))
    u = _rand_u(seed % 97, t)
    comp = C.get_compressor(name, t, k)
    key = jax.random.PRNGKey(seed)
    dense, _ = comp.compress(key, u)
    idx, vals, _ = comp.compress_sparse(key, u)
    recon = C.scatter_add_sparse(idx, vals, t)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(recon), rtol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(min_value=4, max_value=120),
    frac=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**20),
    name=st.sampled_from(["topk", "randk", "randseqk", "toplek", "natural", "identity"]),
)
def test_scaled_compressors_are_contractive_in_expectation(t, frac, seed, name):
    """All registry compressors (scaled form) satisfy
    E||C(u)-u||^2 <= (1-delta)||u||^2 — the FedNL requirement."""
    k = max(1, int(frac * t))
    u = _rand_u(seed % 89, t)
    comp = C.get_compressor(name, t, k)
    keys = jax.random.split(jax.random.PRNGKey(seed), 300)
    errs = jax.vmap(lambda key: jnp.sum((comp.compress(key, u)[0] - u) ** 2))(keys)
    lhs = float(jnp.mean(errs))
    rhs = (1 - comp.delta) * float(jnp.sum(u**2))
    assert lhs <= rhs * 1.15 + 1e-9  # MC slack


def test_registry_rejects_bad_k():
    with pytest.raises(ValueError):
        C.get_compressor("topk", 10, 0)
    with pytest.raises(ValueError):
        C.get_compressor("randk", 10, 11)
    with pytest.raises(KeyError):
        C.get_compressor("nope", 10, 1)
