"""Structural sharding-spec validation for every architecture (no mesh
needed): every PartitionSpec axis must divide the corresponding parameter
dimension on the production meshes — catching config/spec drift without a
512-device compile."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch.specs import _init_fn, sanitize_specs
from repro.models.encdec import encdec_cache_specs, init_encdec_cache
from repro.models.lm import cache_specs, init_decode_cache

AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _axis_size(entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for e in entry:
            n *= AXIS_SIZES[e]
        return n
    return AXIS_SIZES[entry]


def _check(tree_abs, tree_spec, where):
    leaves_a = jax.tree.leaves(tree_abs)
    leaves_s = jax.tree.leaves(
        tree_spec, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(leaves_a) == len(leaves_s), where
    for arr, spec in zip(leaves_a, leaves_s):
        assert len(spec) <= len(arr.shape), (where, arr.shape, spec)
        for dim, entry in zip(arr.shape, spec):
            size = _axis_size(entry)
            assert dim % size == 0, (where, arr.shape, spec, dim, size)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("serve_tp2d", [False, True])
def test_param_specs_divide_mesh(arch, serve_tp2d):
    cfg = get_config(arch)
    init, spec_fn = _init_fn(cfg)
    params = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))
    specs = sanitize_specs(
        params, spec_fn(cfg, serve_tp2d=serve_tp2d),
        {k: v for k, v in AXIS_SIZES.items() if k != "pod"},
    )
    _check(params, specs, f"{arch} tp2d={serve_tp2d}")


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize(
    "batch,seq,batch_axis,seq_axis",
    [(128, 32768, "data", None), (1, 524288, None, "data")],
)
def test_cache_specs_divide_mesh(arch, batch, seq, batch_axis, seq_axis):
    cfg = get_config(arch)
    if seq == 524288 and not cfg.sublquadratic:
        pytest.skip("long_500k skipped for quadratic attention")
    if cfg.family == "encdec":
        cache = jax.eval_shape(lambda: init_encdec_cache(cfg, batch, seq, 4096))
        specs = encdec_cache_specs(cfg, batch_axis=batch_axis, seq_axis=seq_axis)
    else:
        cache = jax.eval_shape(lambda: init_decode_cache(cfg, batch, seq))
        specs = cache_specs(cfg, batch_axis=batch_axis, seq_axis=seq_axis)
    _check(cache, specs, f"{arch} cache {batch}x{seq}")
