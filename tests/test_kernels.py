"""Kernel-layer validation (tier-1, socket-free).

Three layers are pinned against each other (DESIGN.md §12):

  * the Pallas kernels (interpret mode on CPU — bit-accurate vs the TPU
    semantics) vs the pure-jnp oracles in repro.kernels.ref;
  * the fused XLA round-hot-path programs (`hessian_syrk_xla` /
    `hessian_syrk_packed` / the masked selection forms) vs the reference
    jnp formulations — bit-identical where the contract says so;
  * the selection contract itself: f32 rank keys, lowest-index tie-break,
    identical sets from the sorted and threshold-mask formulations,
    including adversarial f64-distinct/f32-equal near-ties.

Only the hypothesis property test needs hypothesis; everything else runs
under the plain tier-1 suite.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compressors import select as csel
from repro.compressors.core import randseqk, topk, toplek
from repro.kernels import ops
from repro.kernels.compressor_select import (
    select_randseqk_pallas,
    select_topk_pallas,
    select_toplek_pallas,
)
from repro.kernels.ref import flash_attention_ref, hessian_syrk_ref

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is a dev extra; only the property test needs it
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# hessian_syrk (Pallas wrapper, interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(8, 8), (64, 48), (348, 301), (130, 257), (1, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_hessian_syrk_sweep(n, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + d)
    z = jax.random.normal(key, (n, d), dtype=dtype)
    h = jax.random.uniform(jax.random.fold_in(key, 1), (n,), dtype=dtype)
    got = ops.hessian_syrk(z, h)
    want = hessian_syrk_ref(z, h)
    tol = 2e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


def test_hessian_syrk_symmetric_output():
    z = jax.random.normal(jax.random.PRNGKey(0), (100, 37), dtype=jnp.float64)
    h = jnp.ones(100) / 100
    out = np.asarray(ops.hessian_syrk(z, h))
    np.testing.assert_allclose(out, out.T, atol=1e-13)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=200),
        d=st.integers(min_value=1, max_value=160),
        seed=st.integers(0, 999),
    )
    def test_hessian_syrk_property(n, d, seed):
        key = jax.random.PRNGKey(seed)
        z = jax.random.normal(key, (n, d), dtype=jnp.float64)
        h = jax.random.uniform(jax.random.fold_in(key, 1), (n,), dtype=jnp.float64)
        np.testing.assert_allclose(
            np.asarray(ops.hessian_syrk(z, h)),
            np.asarray(hessian_syrk_ref(z, h)),
            atol=1e-10,
        )

else:

    @pytest.mark.skip(reason="property tests need hypothesis (requirements-dev.txt)")
    def test_hessian_syrk_property():
        pass


def test_hessian_syrk_blocks():
    """Different BlockSpec tilings agree."""
    z = jax.random.normal(jax.random.PRNGKey(3), (96, 80), dtype=jnp.float32)
    h = jnp.ones(96) * 0.5
    a = ops.hessian_syrk(z, h, block_d=128, block_n=128)
    b = ops.hessian_syrk(z, h, block_d=32, block_n=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# hessian_syrk_xla / hessian_syrk_packed — the fused round's CPU hot path
# ---------------------------------------------------------------------------

XLA_SHAPES = [(8, 8), (64, 48), (348, 301), (130, 257), (1, 5), (40, 129), (200, 128)]


@pytest.mark.parametrize("n,d", XLA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_hessian_syrk_xla_parity(n, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + d)
    z = jax.random.normal(key, (n, d), dtype=dtype)
    h = jax.random.uniform(jax.random.fold_in(key, 1), (n,), dtype=dtype)
    got = np.asarray(jax.jit(ops.hessian_syrk_xla)(z, h))
    # both sides jitted: the bit-exactness contract is between the compiled
    # round programs (an eager op-by-op reference differs bitwise in f32)
    want = np.asarray(jax.jit(hessian_syrk_ref)(z, h))
    if d <= 128:
        # single tile: the fused program IS the reference expression
        # (including its f32 gemm asymmetry of a few ulp — no extra claim)
        np.testing.assert_array_equal(got, want)
    else:
        tol = 2e-3 if dtype == jnp.float32 else 1e-12
        np.testing.assert_allclose(got, want, atol=tol, rtol=tol)
        # off-diagonal blocks are mirrored exactly; diagonal blocks hold two
        # independently-computed triangles (ulp-level asymmetry, like the
        # reference gemm) — the round consumes pack_triu(·), never the lower
        np.testing.assert_allclose(got, got.T, atol=tol, rtol=tol)


def test_hessian_syrk_xla_zero_weight_rows():
    """Zero-weight rows (padded samples) are exact no-ops for the strips."""
    key = jax.random.PRNGKey(7)
    z = jax.random.normal(key, (50, 200), dtype=jnp.float64)
    h = jax.random.uniform(jax.random.fold_in(key, 1), (50,), dtype=jnp.float64)
    h = h.at[30:].set(0.0)
    got = np.asarray(jax.jit(ops.hessian_syrk_xla)(z, h))
    want = np.asarray(jax.jit(ops.hessian_syrk_xla)(z[:30], h[:30]))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,d", XLA_SHAPES)
def test_hessian_syrk_packed_bit_identical_to_full(n, d):
    """pack_triu straight off the strips == pack_triu of the mirrored matrix."""
    from repro.linalg import pack_triu

    key = jax.random.PRNGKey(n + d)
    z = jax.random.normal(key, (n, d), dtype=jnp.float64)
    h = jax.random.uniform(jax.random.fold_in(key, 1), (n,), dtype=jnp.float64)
    got = np.asarray(jax.jit(lambda z, h: ops.hessian_syrk_packed(z, h))(z, h))
    want = np.asarray(jax.jit(lambda z, h: pack_triu(ops.hessian_fused(z, h)))(z, h))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("hessian", ["jnp", "fused", "pallas"])
def test_logreg_oracles_packed_matches_full(hessian):
    """The packed client oracle == pack_triu of the full oracle, bitwise."""
    from repro.linalg import pack_triu
    from repro.objectives.logreg import logreg_oracles, logreg_oracles_packed

    for n, d in [(30, 24), (60, 150)]:
        key = jax.random.PRNGKey(d)
        z = jax.random.normal(key, (n, d), dtype=jnp.float64)
        x = jax.random.normal(jax.random.fold_in(key, 1), (d,), dtype=jnp.float64)
        f1, g1, hp = jax.jit(
            lambda z, x: logreg_oracles_packed(z, x, 1e-3, hessian=hessian)
        )(z, x)
        f2, g2, hess = jax.jit(
            lambda z, x: logreg_oracles(z, x, 1e-3, hessian=hessian)
        )(z, x)
        assert float(f1) == float(f2)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        np.testing.assert_array_equal(np.asarray(hp), np.asarray(pack_triu(hess)))


# ---------------------------------------------------------------------------
# the selection contract (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _near_tie_vector(t: int, seed: int) -> jax.Array:
    """f64 entries that are pairwise distinct but collide when rounded to f32
    — the adversarial case for mixed-width ranking (the satellite-2 bug)."""
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(t // 4).astype(np.float32).astype(np.float64)
    # four f64-distinct perturbations of each f32 value, all rounding back
    # to the same f32 key
    eps = np.array([0.0, 1e-12, 2.5e-12, -1e-12])
    u = (base[:, None] * (1.0 + eps[None, :])).reshape(-1)
    exact = np.asarray(
        jnp.abs(jnp.asarray(u)).astype(jnp.float32), dtype=np.float32
    )
    collide = len(np.unique(exact)) < len(np.unique(np.abs(u)))
    assert collide, "fixture must contain f32 key collisions"
    return jnp.asarray(rng.permutation(u))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_selection_contract_near_ties(seed):
    """Sorted top_k, the threshold mask, and the Pallas kernel select the
    SAME index set on adversarial near-ties, with lowest-index tie-break."""
    t, k = 512, 100
    u = _near_tie_vector(t, seed)
    keys = np.asarray(csel.rank_keys(u))

    idx_sorted = np.sort(np.asarray(csel.topk_indices(u, k)))
    mask = np.asarray(csel.threshold_keep_mask(csel.rank_keys(u), k))
    idx_mask = np.flatnonzero(mask)
    u_pal, sent_pal = select_topk_pallas(u, k, interpret=True)
    idx_pal = np.flatnonzero(np.asarray(u_pal))

    np.testing.assert_array_equal(idx_sorted, idx_mask)
    np.testing.assert_array_equal(idx_sorted, idx_pal)
    assert int(sent_pal[0]) == k

    # lowest-index tie-break, verified independently with numpy: stable
    # descending sort of the f32 keys by (−key, index)
    order = np.lexsort((np.arange(t), -keys))
    np.testing.assert_array_equal(idx_sorted, np.sort(order[:k]))


@pytest.mark.parametrize("t,k", [(300, 24), (1000, 64), (257, 1), (130, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_masked_formulations_bit_identical(t, k, dtype):
    """topk_dense_masked / randseqk_dense_masked == the sorted/rolled forms."""
    key = jax.random.PRNGKey(t * 31 + k)
    u = jax.random.normal(key, (t,), dtype=dtype)
    np.testing.assert_array_equal(
        np.asarray(csel.topk_dense_masked(u, k)),
        np.asarray(csel.topk_dense(u, k)),
    )
    s = jax.random.randint(jax.random.fold_in(key, 1), (), 0, t)
    np.testing.assert_array_equal(
        np.asarray(csel.randseqk_dense_masked(u, k, s)),
        np.asarray(csel.randseqk_dense(u, k, s)),
    )


@pytest.mark.parametrize("t,k", [(300, 24), (1000, 64), (257, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_select_kernels_interpret_parity(t, k, dtype):
    """The Pallas selection kernels (interpret) are bit-identical to the
    routed compressor primitives, T a non-multiple of 128 included."""
    key = jax.random.PRNGKey(t + k)
    u = jax.random.normal(key, (t,), dtype=dtype)

    want, _ = topk(u, k)
    got, sent = select_topk_pallas(u, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(sent[0]) == k

    rk = jax.random.fold_in(key, 1)
    want, _ = randseqk(rk, u, k)
    s = jax.random.randint(rk, (), 0, t)
    got, sent = select_randseqk_pallas(u, k, s, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(sent[0]) == k

    tk = jax.random.fold_in(key, 2)
    want, kept = toplek(tk, u, k)
    unif = csel.toplek_uniform(tk, u.dtype)
    got, sent = select_toplek_pallas(u, k, unif, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(sent[0]) == int(kept)


def test_toplek_uniform_replays_bernoulli():
    """The hoisted uniform draw consumes the PRNG stream exactly as
    jax.random.bernoulli(key, p) — the fused/unfused PRNG-parity pin."""
    for seed in range(50):
        key = jax.random.PRNGKey(seed)
        for dtype in (jnp.float32, jnp.float64):
            p = jnp.asarray(0.37, dtype=dtype)
            unif = csel.toplek_uniform(key, dtype)
            assert bool(unif < p) == bool(jax.random.bernoulli(key, p))


@pytest.mark.parametrize("comp", ["topk", "randk", "randseqk", "toplek",
                                  "natural", "identity"])
def test_fused_round_bit_parity(comp):
    """hessian='fused' replays hessian='jnp' bit-for-bit on tiny: state,
    metrics, and the integer bit accounting — for all six compressors."""
    from repro.core.fednl import FedNLConfig, fednl_init, make_fednl_round
    from repro.data import (
        add_intercept,
        make_synthetic_logreg,
        partition_clients,
        DATASET_SHAPES,
    )

    _, nc, ni = DATASET_SHAPES["tiny"]
    x, y = make_synthetic_logreg("tiny", seed=1)
    z = jnp.asarray(partition_clients(add_intercept(x), y, nc, ni, seed=1))

    results = {}
    for hessian in ("jnp", "fused"):
        cfg = FedNLConfig(compressor=comp, hessian=hessian)
        state = fednl_init(z, cfg, seed=1)
        round_fn = jax.jit(make_fednl_round(z, cfg))
        metrics = []
        for _ in range(3):
            state, m = round_fn(state)
            metrics.append(m)
        results[hessian] = (state, metrics)

    sj, mj = results["jnp"]
    sf, mf = results["fused"]
    np.testing.assert_array_equal(np.asarray(sj.x), np.asarray(sf.x))
    np.testing.assert_array_equal(np.asarray(sj.h_global), np.asarray(sf.h_global))
    np.testing.assert_array_equal(np.asarray(sj.h_local), np.asarray(sf.h_local))
    for a, b in zip(mj, mf):
        assert float(a.grad_norm) == float(b.grad_norm)
        assert int(a.sent_bits) == int(b.sent_bits)
        assert int(a.sent_elems) == int(b.sent_elems)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "sq,sk,hn,dh,causal,window",
    [
        (128, 128, 2, 64, True, None),
        (256, 256, 4, 64, True, 64),
        (200, 200, 2, 32, True, None),  # padded seq
        (96, 96, 1, 16, False, None),  # bidirectional + padding
        (256, 256, 2, 64, False, 128),
        (64, 256, 1, 32, False, None),  # cross-attention shape
    ],
)
def test_flash_attention_sweep(sq, sk, hn, dh, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(sq + sk + hn), 3)
    q = jax.random.normal(ks[0], (sq, hn, dh), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (sk, hn, dh), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (sk, hn, dh), dtype=jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (128, 2, 64), dtype=jnp.bfloat16)
    k = jax.random.normal(ks[1], (128, 2, 64), dtype=jnp.bfloat16)
    v = jax.random.normal(ks[2], (128, 2, 64), dtype=jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True,
    )
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), atol=0.05
    )


def test_flash_matches_models_chunked_attention():
    """The Pallas kernel and the models' jnp chunked attention agree."""
    from repro.models.layers import chunked_attention

    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, s, h, dh = 2, 256, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, dh), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, dh), dtype=jnp.float32)
    jnp_out = chunked_attention(q, k, v, causal=True, window=96, q_chunk=64)
    kern_out = jnp.stack([
        ops.flash_attention(q[i], k[i], v[i], causal=True, window=96,
                            block_q=64, block_k=64)
        for i in range(b)
    ])
    np.testing.assert_allclose(
        np.asarray(kern_out), np.asarray(jnp_out), atol=2e-5
    )
