"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
in repro.kernels.ref (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref, hessian_syrk_ref


# ---------------------------------------------------------------------------
# hessian_syrk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(8, 8), (64, 48), (348, 301), (130, 257), (1, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_hessian_syrk_sweep(n, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + d)
    z = jax.random.normal(key, (n, d), dtype=dtype)
    h = jax.random.uniform(jax.random.fold_in(key, 1), (n,), dtype=dtype)
    got = ops.hessian_syrk(z, h)
    want = hessian_syrk_ref(z, h)
    tol = 2e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


def test_hessian_syrk_symmetric_output():
    z = jax.random.normal(jax.random.PRNGKey(0), (100, 37), dtype=jnp.float64)
    h = jnp.ones(100) / 100
    out = np.asarray(ops.hessian_syrk(z, h))
    np.testing.assert_allclose(out, out.T, atol=1e-13)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=200),
    d=st.integers(min_value=1, max_value=160),
    seed=st.integers(0, 999),
)
def test_hessian_syrk_property(n, d, seed):
    key = jax.random.PRNGKey(seed)
    z = jax.random.normal(key, (n, d), dtype=jnp.float64)
    h = jax.random.uniform(jax.random.fold_in(key, 1), (n,), dtype=jnp.float64)
    np.testing.assert_allclose(
        np.asarray(ops.hessian_syrk(z, h)),
        np.asarray(hessian_syrk_ref(z, h)),
        atol=1e-10,
    )


def test_hessian_syrk_blocks():
    """Different BlockSpec tilings agree."""
    z = jax.random.normal(jax.random.PRNGKey(3), (96, 80), dtype=jnp.float32)
    h = jnp.ones(96) * 0.5
    a = ops.hessian_syrk(z, h, block_d=128, block_n=128)
    b = ops.hessian_syrk(z, h, block_d=32, block_n=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "sq,sk,hn,dh,causal,window",
    [
        (128, 128, 2, 64, True, None),
        (256, 256, 4, 64, True, 64),
        (200, 200, 2, 32, True, None),  # padded seq
        (96, 96, 1, 16, False, None),  # bidirectional + padding
        (256, 256, 2, 64, False, 128),
        (64, 256, 1, 32, False, None),  # cross-attention shape
    ],
)
def test_flash_attention_sweep(sq, sk, hn, dh, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(sq + sk + hn), 3)
    q = jax.random.normal(ks[0], (sq, hn, dh), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (sk, hn, dh), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (sk, hn, dh), dtype=jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (128, 2, 64), dtype=jnp.bfloat16)
    k = jax.random.normal(ks[1], (128, 2, 64), dtype=jnp.bfloat16)
    v = jax.random.normal(ks[2], (128, 2, 64), dtype=jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True,
    )
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), atol=0.05
    )


def test_flash_matches_models_chunked_attention():
    """The Pallas kernel and the models' jnp chunked attention agree."""
    from repro.models.layers import chunked_attention

    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, s, h, dh = 2, 256, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, dh), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, dh), dtype=jnp.float32)
    jnp_out = chunked_attention(q, k, v, causal=True, window=96, q_chunk=64)
    kern_out = jnp.stack([
        ops.flash_attention(q[i], k[i], v[i], causal=True, window=96,
                            block_q=64, block_k=64)
        for i in range(b)
    ])
    np.testing.assert_allclose(
        np.asarray(kern_out), np.asarray(jnp_out), atol=2e-5
    )
