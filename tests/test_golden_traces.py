"""Golden-trace regression tests: pinned first-10-round trajectories.

The pins are BIT-exact (float.hex() comparison): the moment any refactor of
the round body, a compressor, or a codec changes a single ulp of the
grad-norm trajectory — or a single bit of the sent_bits accounting — these
fail with a side-by-side diff.  That is the point: the star transports and
the PP protocol are proven against `run_fednl`/`run_fednl_pp` by exact
equality, so silent drift in the simulation would silently re-baseline the
whole wire stack.

Deliberate numerical changes: regenerate with
    PYTHONPATH=src python scripts/gen_golden_traces.py
and call the re-baselining out in the commit message.
"""

import json
import pathlib

import jax.numpy as jnp
import pytest

from repro.core import FedNLConfig, run_fednl
from repro.data import add_intercept, make_synthetic_logreg, partition_clients

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fednl_traces.json"


@pytest.fixture(scope="module")
def golden():
    return json.loads(GOLDEN.read_text())


@pytest.fixture(scope="module")
def z():
    x, y = make_synthetic_logreg("tiny", seed=1)
    return jnp.asarray(partition_clients(add_intercept(x), y, 8, 40, seed=1))


@pytest.mark.parametrize("comp", ["topk", "randseqk", "toplek"])
def test_fednl_trace_matches_golden(golden, z, comp):
    pins = golden["traces"][comp]
    res = run_fednl(
        z, FedNLConfig(compressor=comp, lam=1e-3),
        rounds=golden["rounds"], seed=0,
    )
    got_gn = [float(g).hex() for g in res.grad_norms]
    got_bits = [int(b) for b in res.sent_bits]
    assert got_gn == pins["grad_norms_hex"], (
        f"{comp}: grad_norm trajectory drifted from the golden pin.\n"
        f"  pinned: {pins['grad_norms_hex']}\n"
        f"  got:    {got_gn}\n"
        "If this change is deliberate, regenerate via "
        "scripts/gen_golden_traces.py and say so in the commit message."
    )
    assert got_bits == pins["sent_bits"], (
        f"{comp}: sent_bits accounting drifted from the golden pin.\n"
        f"  pinned: {pins['sent_bits']}\n  got:    {got_bits}"
    )


def test_golden_file_shape(golden):
    """The pin file itself stays well-formed (each trace pins every round)."""
    assert set(golden["traces"]) == {"topk", "randseqk", "toplek"}
    for comp, pins in golden["traces"].items():
        assert len(pins["grad_norms_hex"]) == golden["rounds"], comp
        assert len(pins["sent_bits"]) == golden["rounds"], comp
        # hex round-trips to finite floats
        assert all(
            float.fromhex(h) == float.fromhex(h) for h in pins["grad_norms_hex"]
        )
