import jax

# FedNL is an FP64 algorithm (the paper runs FP64 end-to-end); the LM zoo uses
# explicit f32/bf16 dtypes so enabling x64 globally is safe for all tests.
jax.config.update("jax_enable_x64", True)
