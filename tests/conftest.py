import jax
import pytest

# FedNL is an FP64 algorithm (the paper runs FP64 end-to-end); the LM zoo uses
# explicit f32/bf16 dtypes so enabling x64 globally is safe for all tests.
jax.config.update("jax_enable_x64", True)


def pytest_collection_modifyitems(config, items):
    """Tiering: anything not explicitly `slow` or `net` is tier1.

    The default invocation (`pytest -q`, the ROADMAP tier-1 verify) still
    runs everything; CI splits into a fast `-m "not net and not slow"` job
    and a separate job exercising the real-socket / long-running paths
    (.github/workflows/ci.yml).
    """
    for item in items:
        if "net" not in item.keywords and "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)
