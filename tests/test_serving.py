"""Serving engine + EF21 gradient compression tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_lm_params
from repro.serving import ServeEngine, Request
from repro.train.grad_compress import ef21_init, ef21_step


def test_engine_serves_batch_of_requests():
    cfg = get_config("granite-3-2b").reduced()
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, batch_size=3, max_len=64)
    reqs = [Request(prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=5) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    assert len(done) == 5
    for r in done:
        assert r.done and len(r.generated) == 5
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_engine_greedy_is_deterministic():
    cfg = get_config("mamba2-2.7b").reduced()
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    outs = []
    for _ in range(2):
        engine = ServeEngine(params, cfg, batch_size=2, max_len=32)
        engine.submit(Request(prompt=[5, 6, 7], max_new_tokens=4))
        outs.append(engine.run()[0].generated)
    assert outs[0] == outs[1]


def test_ef21_estimator_tracks_gradient():
    """EF21 contraction: the estimator error shrinks geometrically on a fixed
    gradient (the FedNL Hessian-learning rule applied to vectors)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,), dtype=jnp.float64)}
    est = ef21_init(g)
    errs = []
    for _ in range(20):
        est, _ = ef21_step(g, est, frac=0.25)
        errs.append(float(jnp.linalg.norm(est["w"] - g["w"])))
    assert errs[-1] < errs[0] * 1e-2
    assert all(b <= a + 1e-12 for a, b in zip(errs, errs[1:]))


def test_ef21_optimizes_quadratic():
    from repro.train import adamw_init, adamw_update, AdamWConfig

    params = {"w": jnp.asarray([4.0, -2.0, 1.0])}
    opt = adamw_init(params)
    est = ef21_init(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        est, g_hat = ef21_step(grads, est, frac=0.34)
        params, opt, _ = adamw_update(params, g_hat, opt, cfg)
    # adam + 1-of-3 compressed grads hovers near the optimum rather than
    # converging exactly (stale coordinates); 1e-2 of the initial 21.0
    assert float(loss(params)) < 1e-2
