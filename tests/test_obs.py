"""Tests for repro.obs — the zero-overhead observability subsystem (§15).

Covers the contracts DESIGN.md §15 pins: the no-op default (and that the
disabled guard allocates nothing), the log2 histogram bucket geometry,
span nesting + JSONL round-trip, recorder install/restore, the Prometheus
exposition format, and — the bar everything else hangs off — bit parity:
running with the recorder enabled never changes a single bit of any
trajectory, solo or engine-served.
"""

import gc
import json

import numpy as np
import pytest

from repro import obs
from repro.obs import core


@pytest.fixture(autouse=True)
def _null_recorder():
    """Every test starts and ends at the process default (NULL)."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------- no-op path


def test_default_recorder_is_null_singleton():
    assert core.CURRENT is obs.NULL
    assert obs.CURRENT is obs.NULL
    assert obs.get() is obs.NULL
    assert obs.NULL.enabled is False
    assert isinstance(obs.NULL, obs.NullRecorder)


def test_null_recorder_accepts_full_api_and_returns_singletons():
    n = obs.NULL
    n.add("c", 3, cls="x")
    n.gauge("g", 1.5)
    n.observe("h", 0.25, verb="SUBMIT")
    assert n.counter("c") is n.counter("other")  # shared no-op instrument
    n.counter("c").add(5)
    n.histogram("h").observe(1.0)
    with n.span("s", tenant=7) as sp:
        assert sp.set(x=1) is sp  # chainable, still no-op
        with n.span("inner") as sp2:
            assert sp2 is sp  # the one shared null span


def test_disabled_guard_is_allocation_free():
    # the instrumentation idiom is `if rec.enabled: rec.add(...)` — with the
    # NULL recorder the guard must not allocate (no closures, no dicts)
    rec = core.CURRENT
    assert not rec.enabled
    gc.collect()
    gc.disable()
    try:
        before = len(gc.get_objects())
        for _ in range(1000):
            if rec.enabled:  # pragma: no cover - disabled path
                rec.add("x", cls="normal")
        after = len(gc.get_objects())
    finally:
        gc.enable()
    assert after == before


# ---------------------------------------------------------- histogram buckets


def test_bucket_geometry_pins():
    # 64 log2 buckets, bucket i upper bound = 2**(HIST_LO_EXP + i)
    assert obs.HIST_BUCKETS == 64
    assert obs.HIST_LO_EXP == -30
    assert obs.bucket_index(1.0) == 31  # frexp(1.0) -> (0.5, 1)
    assert obs.bucket_index(0.75) == 30
    assert obs.bucket_index(2.0**-31) == 0  # clamped at the low end
    assert obs.bucket_index(0.0) == 0
    assert obs.bucket_index(-1.0) == 0
    assert obs.bucket_index(1e300) == 63  # clamped overflow bucket
    assert obs.bucket_le(31) == 2.0
    assert obs.bucket_le(0) == 2.0**-30
    assert obs.bucket_le(63) == float("inf")


def test_bucket_index_brackets_value():
    # every positive value lands in a bucket whose upper bound covers it
    # and is at most one octave above (exact powers of two land in the
    # bucket ABOVE their own bound: frexp(0.5) == (0.5, 0))
    for v in [1e-12, 3e-7, 0.001, 0.02, 0.5, 1.0, 7.3, 1e6]:
        i = obs.bucket_index(v)
        assert v <= obs.bucket_le(i)
        if 0 < i < obs.HIST_BUCKETS - 1:
            assert v >= obs.bucket_le(i) / 2


def test_histogram_exact_and_approx_stats():
    rec = obs.Recorder()
    for v in [0.001, 0.002, 0.004, 0.004, 1.5]:
        rec.observe("lat", v, verb="STEP")
    h = rec.hist("lat", verb="STEP")
    assert h.count == 5
    assert h.sum == pytest.approx(1.511)
    assert h.min == 0.001 and h.max == 1.5
    # quantile_le returns a bucket upper bound covering >= q of the mass
    assert h.quantile_le(0.5) >= 0.004
    assert h.quantile_le(1.0) >= 1.5
    assert rec.hist("lat", verb="OTHER") is None
    assert rec.hists("lat") == [h]


def test_counter_gauge_value_and_label_keying():
    rec = obs.Recorder()
    rec.add("rounds", 3, lane="batch")
    rec.add("rounds", 1, lane="batch")
    rec.add("rounds", 1, lane="solo")
    rec.gauge("depth", 7, cls="normal")
    assert rec.value("rounds", lane="batch") == 4
    assert rec.value("rounds", lane="solo") == 1
    assert rec.value("rounds", lane="nope") is None
    assert rec.value("depth", cls="normal") == 7
    # bound handles hit the same series as the convenience calls
    rec.counter("rounds", lane="batch").add(2)
    assert rec.value("rounds", lane="batch") == 6


# ------------------------------------------------------------------- spans


def test_span_nesting_parent_and_depth():
    rec = obs.Recorder()
    with rec.span("outer", round=1):
        with rec.span("inner", tenant=3) as sp:
            sp.set(extra=9)
    inner, outer = rec.spans("inner")[0], rec.spans("outer")[0]
    assert inner.parent == "outer" and inner.depth == 1
    assert outer.parent is None and outer.depth == 0
    assert inner.labels == {"tenant": 3, "extra": 9}
    assert outer.labels == {"round": 1}
    assert 0 <= inner.dur_s <= outer.dur_s
    # inner closed first: ring is completion-ordered
    assert [s.name for s in rec.spans()] == ["inner", "outer"]
    # each span exit feeds the label-free duration histogram (§15: high-
    # cardinality labels ride on spans, never on metric series)
    assert rec.hist("inner").count == 1
    assert rec.hists("inner") == [rec.hist("inner")]


def test_span_ring_bounded_drop_oldest_counted():
    rec = obs.Recorder(span_capacity=4)
    for i in range(10):
        with rec.span("s", i=i):
            pass
    kept = rec.spans("s")
    assert len(kept) == 4
    assert [s.labels["i"] for s in kept] == [6, 7, 8, 9]  # newest kept
    assert rec.spans_dropped == 6
    assert rec.snapshot()["spans_dropped"] == 6


def test_span_jsonl_round_trip(tmp_path):
    rec = obs.Recorder()
    with rec.span("a", round=2, backend="local"):
        with rec.span("b", tenant=11):
            pass
    path = tmp_path / "spans.jsonl"
    n = rec.dump_spans_jsonl(path)
    assert n == 2
    lines = path.read_text().splitlines()
    assert len(lines) == 2
    assert all(isinstance(json.loads(ln), dict) for ln in lines)
    back = obs.load_spans_jsonl(path)
    assert back == rec.spans()
    assert back[0].labels == {"tenant": 11}


def test_exception_inside_span_still_records_and_propagates():
    rec = obs.Recorder()
    with pytest.raises(ValueError):
        with rec.span("boom"):
            raise ValueError("x")
    assert len(rec.spans("boom")) == 1
    # the thread-local stack unwound: a new span is root again
    with rec.span("after"):
        pass
    assert rec.spans("after")[0].depth == 0


# ------------------------------------------------- install/restore + export


def test_enable_disable_swaps_both_module_attrs():
    rec = obs.enable(span_capacity=16)
    assert core.CURRENT is rec and obs.CURRENT is rec
    assert rec.enabled
    assert obs.disable() is obs.NULL
    assert core.CURRENT is obs.NULL and obs.CURRENT is obs.NULL


def test_set_current_restores_previous():
    mine = obs.Recorder()
    prev = core.CURRENT
    obs.set_current(mine)
    try:
        core.CURRENT.add("x")
        assert mine.value("x") == 1
    finally:
        obs.set_current(prev)
    assert core.CURRENT is prev


def test_snapshot_formats_series_keys():
    rec = obs.Recorder()
    rec.add("engine.rounds", 2, lane="batch")
    rec.gauge("engine.resident", 3)
    rec.observe("engine.tick", 0.5)
    snap = rec.snapshot()
    assert snap["counters"]["engine.rounds{lane=batch}"] == 2
    assert snap["gauges"]["engine.resident"] == 3
    h = snap["histograms"]["engine.tick"]
    assert h["count"] == 1
    assert h["p50_le"] >= 0.5 and h["p99_le"] >= 0.5


def test_prometheus_text_format():
    from repro.obs.export import prometheus_text

    rec = obs.Recorder()
    rec.add("engine.rounds", 5, lane="batch")
    rec.gauge("engine.resident", 2)
    for v in [0.001, 0.5, 2.0]:
        rec.observe("gateway.rpc.s", v, verb="SUBMIT")
    text = prometheus_text(rec)
    assert 'engine_rounds_total{lane="batch"} 5' in text
    assert "engine_resident 2" in text
    assert '# TYPE gateway_rpc_s histogram' in text
    assert 'gateway_rpc_s_bucket{verb="SUBMIT",le="+Inf"} 3' in text
    assert 'gateway_rpc_s_count{verb="SUBMIT"} 3' in text
    # exactly one +Inf bucket per series (the overflow bucket is not
    # rendered twice)
    assert text.count('le="+Inf"') == 1
    assert "obs_spans_dropped_total 0" in text
    # cumulative counts are monotone non-decreasing
    counts = [
        int(ln.rsplit(" ", 1)[1])
        for ln in text.splitlines()
        if ln.startswith("gateway_rpc_s_bucket")
    ]
    assert counts == sorted(counts)
    assert counts[-1] == 3


def test_reset_clears_everything():
    rec = obs.Recorder()
    rec.add("c", 1)
    rec.observe("h", 1.0)
    with rec.span("s"):
        pass
    rec.reset()
    assert rec.value("c") is None
    assert rec.spans() == []
    assert rec.snapshot()["counters"] == {}


# ------------------------------------------------------------- bit parity


def _spec(seed, comp, rounds):
    from repro.api import CompressorSpec, DataSpec, ExperimentSpec

    return ExperimentSpec(
        data=DataSpec(shape=(8, 4, 12), seed=1),
        compressor=CompressorSpec(comp, 6.0),
        rounds=rounds,
        seed=seed,
    )


def _traj(report):
    return (
        [float(r.grad_norm).hex() for r in report.records],
        [r.sent_bits for r in report.records],
    )


def test_bit_parity_solo_session_obs_on_vs_off():
    from repro.api import open_session

    spec = _spec(0, "topk", 5)
    with open_session(spec) as s:
        off = s.run()
    obs.enable()
    try:
        with open_session(spec) as s:
            on = s.run()
    finally:
        obs.disable()
    assert _traj(on) == _traj(off)
    assert np.array_equal(on.x, off.x)


def test_bit_parity_engine_served_obs_on_vs_off_solo():
    from repro.api import open_session
    from repro.serve_fednl import FedNLServer, ServeConfig

    specs = [_spec(0, "topk", 5), _spec(1, "randk", 6), _spec(2, "identity", 4)]
    solos = []
    for spec in specs:
        with open_session(spec) as s:
            solos.append(s.run())
    rec = obs.enable(span_capacity=512)
    try:
        with FedNLServer(ServeConfig(max_resident=2, admit_per_tick=3)) as srv:
            handles = [srv.submit(spec) for spec in specs]
            srv.serve_until_idle()
            served = [h.result() for h in handles]
    finally:
        obs.disable()
    for got, want in zip(served, solos):
        assert _traj(got) == _traj(want)
        assert np.array_equal(got.x, want.x)
    # and the recorder actually observed the run
    assert rec.spans("engine.tick")
    assert rec.value("engine.rounds", lane="batch") or rec.value(
        "engine.rounds", lane="solo"
    )


def test_session_step_metrics_recorded():
    from repro.api import open_session

    spec = _spec(3, "randseqk", 4)
    rec = obs.enable()
    try:
        with open_session(spec) as s:
            s.step(2)
            s.step(2)
    finally:
        obs.disable()
    assert rec.value("session.rounds", backend="local") == 4
    assert rec.value("session.host_syncs", backend="local") == 2
    assert rec.hist("session.step.s", backend="local").count == 2
