"""Encoder-decoder backbone (seamless-m4t-large-v2, arXiv:2308.11596).

Per the assignment carve-out, the audio frontend (mel-spectrogram + conv
feature extractor) is a STUB: `input_specs` supplies precomputed frame
embeddings (B, S_src, d_model); a learned adapter projection stands in for the
modality bridge.  This module implements the transformer that consumes them:
bidirectional encoder + causal decoder with cross-attention (both GQA-capable,
both scanned/stacked/remat'd like the decoder-only LM).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import (
    COMPUTE_DTYPE,
    chunked_attention,
    constrain,
    decode_attention,
    mlp_apply,
    rms_norm,
    rope,
)
from repro.models.lm import (
    _attn_block_init,
    _dense_init,
    _mlp_block_init,
    _norm_init,
    padded_vocab,
    _head_matrix,
)


def _enc_blocks_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": _norm_init(cfg.encoder_layers, cfg.d_model),
        "ln2": _norm_init(cfg.encoder_layers, cfg.d_model),
        "attn": {
            k: v[: cfg.encoder_layers]
            for k, v in _attn_block_init(ks[0], cfg).items()
        },
        "mlp": {
            k: v[: cfg.encoder_layers]
            for k, v in _mlp_block_init(ks[1], cfg).items()
        },
    }


def init_encdec_params(key: jax.Array, cfg: ArchConfig) -> dict:
    vp = padded_vocab(cfg)
    nl = cfg.n_layers
    keys = jax.random.split(key, 8)
    dec: dict[str, Any] = {
        "ln1": _norm_init(nl, cfg.d_model),
        "ln2": _norm_init(nl, cfg.d_model),
        "lnc": _norm_init(nl, cfg.d_model),
        "attn": _attn_block_init(keys[0], cfg),
        "cross": _attn_block_init(keys[1], cfg),
        "mlp": _mlp_block_init(keys[2], cfg),
    }
    return {
        "embed": jax.random.normal(keys[3], (vp, cfg.d_model), dtype=jnp.float32)
        * 0.02,
        "frontend_proj": _dense_init(keys[4], 1, (cfg.d_model, cfg.d_model))[0],
        "enc_blocks": _enc_blocks_init(keys[5], cfg),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "dec_blocks": dec,
        "final_norm": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
    }


def encdec_param_specs(cfg: ArchConfig, serve_tp2d: bool = False) -> dict:
    both = ("data", "model")
    if serve_tp2d:
        d2 = P(None, None, both)
        d2t = P(None, both, None)
        embed_spec = P(both, None)
        fp = P(None, both)
    else:
        d2 = P(None, "data", "model")
        d2t = P(None, "model", "data")
        embed_spec = P("model", "data")
        fp = P("data", "model")
    attn_spec = {"wq": d2, "wk": d2, "wv": d2, "wo": d2t}
    mlp_spec = {"w1": d2, "w2": d2t}
    if cfg.activation == "silu_glu":
        mlp_spec = dict(mlp_spec, w1g=d2)
    return {
        "embed": embed_spec,
        "frontend_proj": fp,
        "enc_blocks": {
            "ln1": P(None, None),
            "ln2": P(None, None),
            "attn": dict(attn_spec),
            "mlp": dict(mlp_spec),
        },
        "enc_norm": P(None),
        "dec_blocks": {
            "ln1": P(None, None),
            "ln2": P(None, None),
            "lnc": P(None, None),
            "attn": dict(attn_spec),
            "cross": dict(attn_spec),
            "mlp": dict(mlp_spec),
        },
        "final_norm": P(None),
    }


def _proj_qkv(h, attn_p, cfg: ArchConfig, heads: int):
    b, s, _ = h.shape
    out = (h @ attn_p.astype(h.dtype)).reshape(b, s, heads, cfg.head_dim)
    return constrain(out, "dp", None, None, "tp")


def encode(params, cfg: ArchConfig, src_embeds):
    """src_embeds: (B, S_src, D) frontend-stub frame embeddings."""
    x = src_embeds.astype(COMPUTE_DTYPE) @ params["frontend_proj"].astype(
        COMPUTE_DTYPE
    )
    positions = jnp.arange(x.shape[1])

    def body(carry, bp):
        def block(c):
            c = constrain(c, "dp", None, None)
            h = rms_norm(c, bp["ln1"], cfg.norm_eps)
            q = _proj_qkv(h, bp["attn"]["wq"], cfg, cfg.n_heads)
            k = _proj_qkv(h, bp["attn"]["wk"], cfg, cfg.n_kv)
            v = _proj_qkv(h, bp["attn"]["wv"], cfg, cfg.n_kv)
            q = rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
            o = chunked_attention(q, k, v, causal=False, q_chunk=cfg.q_chunk, unroll=cfg.unroll_layers)
            c = c + o.reshape(c.shape[0], c.shape[1], cfg.attn_dim) @ bp["attn"][
                "wo"
            ].astype(h.dtype)
            return constrain(c + mlp_apply(
                rms_norm(c, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg.activation
            ), "dp", None, None)

        return jax.checkpoint(block)(carry), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=cfg.unroll_layers)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_blocks(x, params, cfg: ArchConfig, enc_out, positions):
    def body(carry, bp):
        def block(c):
            c = constrain(c, "dp", None, None)
            # causal self-attention
            h = rms_norm(c, bp["ln1"], cfg.norm_eps)
            q = _proj_qkv(h, bp["attn"]["wq"], cfg, cfg.n_heads)
            k = _proj_qkv(h, bp["attn"]["wk"], cfg, cfg.n_kv)
            v = _proj_qkv(h, bp["attn"]["wv"], cfg, cfg.n_kv)
            q = rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
            o = chunked_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk, unroll=cfg.unroll_layers)
            c = c + o.reshape(c.shape[0], c.shape[1], cfg.attn_dim) @ bp["attn"][
                "wo"
            ].astype(h.dtype)
            # cross-attention over encoder output
            h = rms_norm(c, bp["lnc"], cfg.norm_eps)
            q = _proj_qkv(h, bp["cross"]["wq"], cfg, cfg.n_heads)
            k = _proj_qkv(enc_out, bp["cross"]["wk"], cfg, cfg.n_kv)
            v = _proj_qkv(enc_out, bp["cross"]["wv"], cfg, cfg.n_kv)
            o = chunked_attention(q, k, v, causal=False, q_chunk=cfg.q_chunk, unroll=cfg.unroll_layers)
            c = c + o.reshape(c.shape[0], c.shape[1], cfg.attn_dim) @ bp["cross"][
                "wo"
            ].astype(h.dtype)
            return constrain(c + mlp_apply(
                rms_norm(c, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg.activation
            ), "dp", None, None)

        return jax.checkpoint(block)(carry), None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"], unroll=cfg.unroll_layers)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def encdec_loss(params, cfg: ArchConfig, batch, *, loss_chunk: int = 1024):
    enc_out = encode(params, cfg, batch["src_embeds"])
    tokens, labels = batch["tokens"], batch["labels"]
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    positions = jnp.arange(x.shape[1])
    h = _decoder_blocks(x, params, cfg, enc_out, positions)
    head = _head_matrix(params).astype(h.dtype)

    s = h.shape[1]
    chunk = loss_chunk if s % loss_chunk == 0 else s

    def chunk_loss(ci):
        hs = jax.lax.dynamic_slice_in_dim(h, ci * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, ci * chunk, chunk, axis=1)
        logits = constrain(hs @ head.T, "dp", None, "tp").astype(jnp.float32)
        lsf = jnp.where(ls < cfg.vocab, ls, -1)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lsf, 0)[..., None], axis=-1)[
            ..., 0
        ]
        mask = (lsf >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    if s // chunk == 1:
        num, den = chunk_loss(jnp.asarray(0))
    else:
        from repro.models.layers import chunked_map
        nums, dens = chunked_map(chunk_loss, s // chunk, cfg.unroll_layers)
        num, den = jnp.sum(nums), jnp.sum(dens)
    return num / jnp.maximum(den, 1.0)


def encdec_prefill(params, cfg: ArchConfig, src_embeds, tokens):
    """Encode the source and run the decoder context; last-position logits."""
    enc_out = encode(params, cfg, src_embeds)
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    positions = jnp.arange(x.shape[1])
    h = _decoder_blocks(x, params, cfg, enc_out, positions)
    return h[:, -1] @ _head_matrix(params).astype(h.dtype).T


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ArchConfig, batch: int, seq_len: int, src_len: int):
    nl = cfg.n_layers
    return {
        "pos": jnp.zeros((), dtype=jnp.int32),
        "k": jnp.zeros((nl, batch, seq_len, cfg.n_kv, cfg.head_dim), COMPUTE_DTYPE),
        "v": jnp.zeros((nl, batch, seq_len, cfg.n_kv, cfg.head_dim), COMPUTE_DTYPE),
        # cross K/V are computed once from the encoder output at prefill
        "ck": jnp.zeros((nl, batch, src_len, cfg.n_kv, cfg.head_dim), COMPUTE_DTYPE),
        "cv": jnp.zeros((nl, batch, src_len, cfg.n_kv, cfg.head_dim), COMPUTE_DTYPE),
    }


def encdec_cache_specs(cfg: ArchConfig, *, batch_axis, seq_axis=None) -> dict:
    return {
        "pos": P(),
        "k": P(None, batch_axis, seq_axis, None, "model"),
        "v": P(None, batch_axis, seq_axis, None, "model"),
        "ck": P(None, batch_axis, None, None, "model"),
        "cv": P(None, batch_axis, None, None, "model"),
    }


def encdec_decode_step(params, cfg: ArchConfig, cache, tokens):
    """One decoder token against cached self/cross K/V."""
    pos = cache["pos"]
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    src_len = cache["ck"].shape[2]

    def body(carry, scanned):
        bp, kc, vc, ck, cv = scanned
        b = carry.shape[0]
        h = rms_norm(carry, bp["ln1"], cfg.norm_eps)
        q = _proj_qkv(h, bp["attn"]["wq"], cfg, cfg.n_heads)
        k = _proj_qkv(h, bp["attn"]["wk"], cfg, cfg.n_kv)
        v = _proj_qkv(h, bp["attn"]["wv"], cfg, cfg.n_kv)
        posv = jnp.full((1,), pos, dtype=jnp.int32)
        q = rope(q, posv, cfg.rope_fraction, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_fraction, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        o = decode_attention(q, kc, vc, pos + 1)
        c = carry + o.reshape(b, 1, cfg.attn_dim) @ bp["attn"]["wo"].astype(h.dtype)

        h = rms_norm(c, bp["lnc"], cfg.norm_eps)
        q = _proj_qkv(h, bp["cross"]["wq"], cfg, cfg.n_heads)
        o = decode_attention(q, ck, cv, jnp.asarray(src_len))
        c = c + o.reshape(b, 1, cfg.attn_dim) @ bp["cross"]["wo"].astype(h.dtype)

        c = c + mlp_apply(
            rms_norm(c, bp["ln2"], cfg.norm_eps), bp["mlp"], cfg.activation
        )
        return c, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body,
        x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["ck"], cache["cv"]),
        unroll=cfg.unroll_layers,
    )
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = h @ _head_matrix(params).astype(h.dtype).T
    return logits, dict(cache, k=k_new, v=v_new, pos=pos + 1)
