"""Unified decoder LM covering the dense / moe / ssm / hybrid / vlm families.

Engineering choices (production-dry-run driven):
  * layer params are STACKED with a leading n_layers axis and applied with
    `jax.lax.scan` -> compile time is depth-independent (yi-34b's 60 layers
    compile as fast as 2);
  * each scan step is wrapped in `jax.checkpoint` (full remat) so the residual
    stream is the only per-layer activation stash;
  * the LM head + cross-entropy run in sequence chunks so (B, S, V) logits are
    never materialized (vocab 256k x 4k seq would be GBs per device);
  * params are f32, compute casts to bf16 (COMPUTE_DTYPE), losses in f32;
  * `lm_param_specs` returns a parallel pytree of PartitionSpecs — the 2D
    FSDP x TP scheme of DESIGN.md §5 (feature dims over "model", the other
    large dim over "data"; vocab padded to a multiple of 256 so both mesh
    axes divide it).

Hybrid (RecurrentGemma) layers keep BOTH branch params per layer and select
the branch with `lax.cond` on a static-per-layer type array — simple and
scan-compatible at the cost of some unused weights (noted in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import (
    COMPUTE_DTYPE,
    chunked_attention,
    constrain,
    cross_entropy,
    decode_attention,
    mlp_apply,
    rms_norm,
    rope,
)
from repro.models.moe import moe_apply
from repro.models.rglru import rglru_apply, rglru_decode_step
from repro.models.ssm import ssd_apply, ssd_decode_step

VOCAB_ALIGN = 256  # pad vocab so 16 (model) and 16 (data) both divide it


def padded_vocab(cfg: ArchConfig) -> int:
    return (cfg.vocab + VOCAB_ALIGN - 1) // VOCAB_ALIGN * VOCAB_ALIGN


def layer_types(cfg: ArchConfig) -> np.ndarray:
    """0 = attention layer, 1 = recurrent (rglru) layer."""
    if cfg.family != "hybrid":
        return np.zeros(cfg.n_layers, dtype=np.int32)
    pat = cfg.hybrid.pattern
    return np.asarray(
        [0 if pat[i % len(pat)] == "attn" else 1 for i in range(cfg.n_layers)],
        dtype=np.int32,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(nl, d):
    return jnp.zeros((nl, d), dtype=jnp.float32)


def _dense_init(key, nl, shape, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = (1.0 / np.sqrt(fan_in)) if scale is None else scale
    return jax.random.normal(key, (nl, *shape), dtype=jnp.float32) * s


def _attn_block_init(key, cfg: ArchConfig, window_only: bool = False):
    nl = cfg.n_layers
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], nl, (cfg.d_model, cfg.attn_dim)),
        "wk": _dense_init(ks[1], nl, (cfg.d_model, cfg.kv_dim)),
        "wv": _dense_init(ks[2], nl, (cfg.d_model, cfg.kv_dim)),
        "wo": _dense_init(ks[3], nl, (cfg.attn_dim, cfg.d_model)),
    }


def _mlp_block_init(key, cfg: ArchConfig):
    nl = cfg.n_layers
    ks = jax.random.split(key, 3)
    p = {
        "w1": _dense_init(ks[0], nl, (cfg.d_model, cfg.d_ff)),
        "w2": _dense_init(ks[1], nl, (cfg.d_ff, cfg.d_model)),
    }
    if cfg.activation == "silu_glu":
        p["w1g"] = _dense_init(ks[2], nl, (cfg.d_model, cfg.d_ff))
    return p


def _moe_block_init(key, cfg: ArchConfig):
    nl, m = cfg.n_layers, cfg.moe
    ks = jax.random.split(key, 4)
    p = {
        "router": _dense_init(ks[0], nl, (cfg.d_model, m.n_experts)),
        "w1": _dense_init(ks[1], nl, (m.n_experts, cfg.d_model, cfg.d_ff)),
        "w2": _dense_init(ks[2], nl, (m.n_experts, cfg.d_ff, cfg.d_model)),
    }
    if cfg.activation == "silu_glu":
        p["w1g"] = _dense_init(ks[3], nl, (m.n_experts, cfg.d_model, cfg.d_ff))
    return p


def _ssm_block_init(key, cfg: ArchConfig):
    nl, s = cfg.n_layers, cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    conv_dim = di + 2 * s.d_state
    d_in = 2 * di + 2 * s.d_state + nh
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense_init(ks[0], nl, (cfg.d_model, d_in)),
        "conv_w": _dense_init(ks[1], nl, (s.conv_width, conv_dim), scale=0.3),
        "conv_b": jnp.zeros((nl, conv_dim), dtype=jnp.float32),
        "dt_bias": jnp.zeros((nl, nh), dtype=jnp.float32),
        "A_log": jnp.zeros((nl, nh), dtype=jnp.float32),
        "D": jnp.ones((nl, nh), dtype=jnp.float32),
        "gate_norm": _norm_init(nl, di),
        "out_proj": _dense_init(ks[2], nl, (di, cfg.d_model)),
    }


def _rglru_block_init(key, cfg: ArchConfig):
    nl = cfg.n_layers
    lru = cfg.hybrid.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_x": _dense_init(ks[0], nl, (cfg.d_model, lru)),
        "w_gate": _dense_init(ks[1], nl, (cfg.d_model, lru)),
        "conv_w": _dense_init(ks[2], nl, (4, lru), scale=0.3),
        "conv_b": jnp.zeros((nl, lru), dtype=jnp.float32),
        "w_r": _dense_init(ks[3], nl, (lru, lru)),
        "b_r": jnp.zeros((nl, lru), dtype=jnp.float32),
        "w_i": _dense_init(ks[4], nl, (lru, lru)),
        "b_i": jnp.zeros((nl, lru), dtype=jnp.float32),
        "lambda": jnp.full((nl, lru), 0.5, dtype=jnp.float32),
        "w_out": _dense_init(ks[5], nl, (lru, cfg.d_model)),
    }


def init_lm_params(key: jax.Array, cfg: ArchConfig) -> dict:
    vp = padded_vocab(cfg)
    nl = cfg.n_layers
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (vp, cfg.d_model), dtype=jnp.float32)
        * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
        "blocks": {"ln1": _norm_init(nl, cfg.d_model)},
    }
    blocks = params["blocks"]
    if cfg.family == "ssm":
        blocks["ssm"] = _ssm_block_init(keys[1], cfg)
    else:
        blocks["attn"] = _attn_block_init(keys[1], cfg)
        blocks["ln2"] = _norm_init(nl, cfg.d_model)
        if cfg.family == "moe":
            blocks["moe"] = _moe_block_init(keys[2], cfg)
        else:
            blocks["mlp"] = _mlp_block_init(keys[2], cfg)
        if cfg.family == "hybrid":
            blocks["rglru"] = _rglru_block_init(keys[3], cfg)
    if not cfg.tie_embeddings:
        params["head"] = (
            jax.random.normal(keys[4], (vp, cfg.d_model), dtype=jnp.float32) * 0.02
        )
    if cfg.frontend == "vision":
        params["img_proj"] = _dense_init(keys[5], 1, (cfg.d_model, cfg.d_model))[0]
    return params


def lm_param_specs(cfg: ArchConfig, serve_tp2d: bool = False) -> dict:
    """PartitionSpec pytree matching init_lm_params (DESIGN.md §5 scheme).

    serve_tp2d=True (decode-time, cfg.serve_sharding == "tp2d"): feature dims
    shard over BOTH mesh axes and nothing shards over d_model, so per-layer
    matmuls need no weight all-gathers — decode psums activations instead.
    """
    both = ("data", "model")
    if serve_tp2d:
        d2 = lambda: P(None, None, both)  # (L, D, F): F over 256 ways
        d2t = lambda: P(None, both, None)  # (L, F, D): contract -> psum
        vec = lambda: P(None, both)
        embed_spec = P(both, None)  # padded vocab divides 256
    else:
        d2 = lambda: P(None, "data", "model")  # (L, D, F)-like
        d2t = lambda: P(None, "model", "data")  # (L, F, D)-like
        vec = lambda: P(None, "model")
        embed_spec = P("model", "data")
    specs: dict[str, Any] = {
        "embed": embed_spec,
        "final_norm": P(None),
        "blocks": {"ln1": P(None, None)},
    }
    blocks = specs["blocks"]
    if cfg.family == "ssm":
        blocks["ssm"] = {
            "in_proj": d2(),
            "conv_w": P(None, None, both if serve_tp2d else "model"),
            "conv_b": vec(),
            "dt_bias": P(None, None),
            "A_log": P(None, None),
            "D": P(None, None),
            "gate_norm": vec(),
            "out_proj": d2t(),
        }
    else:
        blocks["attn"] = {"wq": d2(), "wk": d2(), "wv": d2(), "wo": d2t()}
        blocks["ln2"] = P(None, None)
        if cfg.family == "moe":
            moe_d2 = P(None, None, None, both) if serve_tp2d else P(None, None, "data", "model")
            moe_d2t = P(None, None, both, None) if serve_tp2d else P(None, None, "model", "data")
            blocks["moe"] = {
                "router": P(None, None, None),
                "w1": moe_d2,
                "w2": moe_d2t,
            }
            if cfg.activation == "silu_glu":
                blocks["moe"]["w1g"] = moe_d2
        else:
            blocks["mlp"] = {"w1": d2(), "w2": d2t()}
            if cfg.activation == "silu_glu":
                blocks["mlp"]["w1g"] = d2()
        if cfg.family == "hybrid":
            blocks["rglru"] = {
                "w_x": d2(),
                "w_gate": d2(),
                "conv_w": P(None, None, both if serve_tp2d else "model"),
                "conv_b": vec(),
                "w_r": d2(),
                "b_r": vec(),
                "w_i": d2(),
                "b_i": vec(),
                "lambda": vec(),
                "w_out": d2t(),
            }
    if not cfg.tie_embeddings:
        specs["head"] = embed_spec
    if cfg.frontend == "vision":
        specs["img_proj"] = P(None, both) if serve_tp2d else P("data", "model")
    return specs


# ---------------------------------------------------------------------------
# forward (full sequence, teacher-forced)
# ---------------------------------------------------------------------------

def _attn_apply(x, bp, cfg: ArchConfig, positions, window):
    b, s, _ = x.shape
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q = (h @ bp["attn"]["wq"].astype(h.dtype)).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (h @ bp["attn"]["wk"].astype(h.dtype)).reshape(b, s, cfg.n_kv, cfg.head_dim)
    v = (h @ bp["attn"]["wv"].astype(h.dtype)).reshape(b, s, cfg.n_kv, cfg.head_dim)
    # heads over tp where divisible (falls back per-dim inside constrain)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, None, "tp")
    v = constrain(v, "dp", None, None, "tp")
    q = rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True, window=window, q_chunk=cfg.q_chunk, unroll=cfg.unroll_layers)
    o = constrain(o, "dp", None, "tp", None)
    return o.reshape(b, s, cfg.attn_dim) @ bp["attn"]["wo"].astype(h.dtype)


def _ffn_apply(x, bp, cfg: ArchConfig):
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        if cfg.moe_dense_decode and x.shape[1] == 1:
            from repro.models.moe import moe_apply_dense

            return moe_apply_dense(
                h, bp["moe"], n_experts=cfg.moe.n_experts,
                top_k=cfg.moe.top_k, activation=cfg.activation,
            )
        return moe_apply(
            h,
            bp["moe"],
            n_experts=cfg.moe.n_experts,
            top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            activation=cfg.activation,
        )
    return mlp_apply(h, bp["mlp"], cfg.activation)


def _block_apply(x, bp, layer_type, cfg: ArchConfig, positions):
    """One transformer block; bp is the per-layer slice of the stacked params."""
    if cfg.family == "ssm":
        x = constrain(x, "dp", None, None)
        return x + ssd_apply(
            rms_norm(x, bp["ln1"], cfg.norm_eps),
            bp["ssm"],
            d_state=cfg.ssm.d_state,
            head_dim=cfg.ssm.head_dim,
            expand=cfg.ssm.expand,
            chunk=cfg.ssm.chunk,
            norm_eps=cfg.norm_eps,
        )
    if cfg.family == "hybrid":
        def attn_branch(x):
            return _attn_apply(x, bp, cfg, positions, cfg.hybrid.local_window)

        def rec_branch(x):
            return rglru_apply(rms_norm(x, bp["ln1"], cfg.norm_eps), bp["rglru"])

        x = constrain(x, "dp", None, None)
        mix = jax.lax.cond(layer_type == 0, attn_branch, rec_branch, x)
        x = x + mix
        return constrain(x + _ffn_apply(x, bp, cfg), "dp", None, None)
    # dense / moe / vlm
    x = constrain(x, "dp", None, None)
    x = x + _attn_apply(x, bp, cfg, positions, cfg.window)
    return constrain(x + _ffn_apply(x, bp, cfg), "dp", None, None)


def _remat(fn, cfg: ArchConfig):
    """Per-layer rematerialization policy (hillclimb knob, EXPERIMENTS §Perf)."""
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # "full"


def _run_blocks(x, params, cfg: ArchConfig, positions):
    types = jnp.asarray(layer_types(cfg))

    def body(carry, scanned):
        bp, lt = scanned
        out = _remat(lambda c: _block_apply(c, bp, lt, cfg, positions), cfg)(carry)
        return out, None

    x, _ = jax.lax.scan(body, x, (params["blocks"], types), unroll=cfg.unroll_layers)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _head_matrix(params):
    return params.get("head", params["embed"])


def lm_forward(params, cfg: ArchConfig, tokens, img_embeds=None):
    """Full-sequence logits (used by smoke tests on reduced configs)."""
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    if img_embeds is not None:
        img = img_embeds.astype(COMPUTE_DTYPE) @ params["img_proj"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([img, x], axis=1)
    positions = jnp.arange(x.shape[1])
    h = _run_blocks(x, params, cfg, positions)
    return h @ _head_matrix(params).astype(h.dtype).T


def lm_loss(params, cfg: ArchConfig, batch, *, loss_chunk: int = 1024):
    """Masked next-token CE; head+CE evaluated in sequence chunks."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    img = batch.get("img_embeds")
    x = constrain(params["embed"].astype(COMPUTE_DTYPE)[tokens], "dp", None, None)
    if img is not None:
        proj = img.astype(COMPUTE_DTYPE) @ params["img_proj"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([proj, x], axis=1)
        labels = jnp.concatenate(
            [jnp.full(img.shape[:2], -1, dtype=labels.dtype), labels], axis=1
        )
    positions = jnp.arange(x.shape[1])
    h = _run_blocks(x, params, cfg, positions)  # (B, S, D)
    head = _head_matrix(params).astype(h.dtype)

    s = h.shape[1]
    chunk = loss_chunk if s % loss_chunk == 0 else s
    n_chunks = s // chunk

    def chunk_loss(ci):
        hs = jax.lax.dynamic_slice_in_dim(h, ci * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, ci * chunk, chunk, axis=1)
        logits = constrain(hs @ head.T, "dp", None, "tp")
        lsf = jnp.where(ls < cfg.vocab, ls, -1)  # mask padded-vocab labels
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), jnp.maximum(lsf, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lsf >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    if n_chunks == 1:
        num, den = chunk_loss(jnp.asarray(0))
    else:
        nums, dens = L.chunked_map(chunk_loss, n_chunks, cfg.unroll_layers)
        num, den = jnp.sum(nums), jnp.sum(dens)
    return num / jnp.maximum(den, 1.0)


def lm_prefill(params, cfg: ArchConfig, tokens, img_embeds=None):
    """Prefill: run the full context, return last-position logits (B, Vp).

    This is the compute-dominant portion of inference prefill (the per-layer
    K/V cache writes are an O(S*D) byproduct; see DESIGN.md).
    """
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    if img_embeds is not None:
        img = img_embeds.astype(COMPUTE_DTYPE) @ params["img_proj"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([img, x], axis=1)
    positions = jnp.arange(x.shape[1])
    h = _run_blocks(x, params, cfg, positions)
    return h[:, -1] @ _head_matrix(params).astype(h.dtype).T


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def cache_window(cfg: ArchConfig, seq_len: int) -> int:
    """KV-cache length: full context, or the ring window for SWA archs."""
    if cfg.window is not None:
        return min(cfg.window, seq_len)
    if cfg.family == "hybrid":
        return min(cfg.hybrid.local_window, seq_len)
    return seq_len


def init_decode_cache(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """Abstract-friendly cache init (all jnp.zeros; works under eval_shape)."""
    nl = cfg.n_layers
    cache: dict[str, Any] = {"pos": jnp.zeros((), dtype=jnp.int32)}
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        nh = di // s.head_dim
        cache["conv"] = jnp.zeros(
            (nl, batch, s.conv_width - 1, di + 2 * s.d_state), dtype=COMPUTE_DTYPE
        )
        cache["ssm"] = jnp.zeros(
            (nl, batch, nh, s.head_dim, s.d_state), dtype=jnp.float32
        )
        return cache
    w = cache_window(cfg, seq_len)
    cache["k"] = jnp.zeros((nl, batch, w, cfg.n_kv, cfg.head_dim), dtype=COMPUTE_DTYPE)
    cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.family == "hybrid":
        lru = cfg.hybrid.lru_width or cfg.d_model
        cache["conv"] = jnp.zeros((nl, batch, 3, lru), dtype=COMPUTE_DTYPE)
        cache["h"] = jnp.zeros((nl, batch, lru), dtype=jnp.float32)
    return cache


def cache_specs(cfg: ArchConfig, *, batch_axis, seq_axis=None) -> dict:
    """PartitionSpecs for the cache (batch over `batch_axis`; for batch=1
    long-context shapes pass batch_axis=None and seq_axis="data")."""
    specs: dict[str, Any] = {"pos": P()}
    if cfg.family == "ssm":
        specs["conv"] = P(None, batch_axis, None, "model")
        specs["ssm"] = P(None, batch_axis, "model", None, None)
        return specs
    # head_dim is sharded over "model" (kv head COUNT can be < mesh axis, the
    # 64..256-wide head_dim always divides 16): keeps the 100s-of-GB decode
    # caches at ~1 GB/device.
    specs["k"] = P(None, batch_axis, seq_axis, None, "model")
    specs["v"] = P(None, batch_axis, seq_axis, None, "model")
    if cfg.family == "hybrid":
        specs["conv"] = P(None, batch_axis, None, "model")
        specs["h"] = P(None, batch_axis, "model")
    return specs


def _attn_decode(x, bp, cfg: ArchConfig, k_cache, v_cache, pos, window):
    b = x.shape[0]
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q = (h @ bp["attn"]["wq"].astype(h.dtype)).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = (h @ bp["attn"]["wk"].astype(h.dtype)).reshape(b, 1, cfg.n_kv, cfg.head_dim)
    v = (h @ bp["attn"]["wv"].astype(h.dtype)).reshape(b, 1, cfg.n_kv, cfg.head_dim)
    posv = jnp.full((1,), pos, dtype=jnp.int32)
    q = rope(q, posv, cfg.rope_fraction, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_fraction, cfg.rope_theta)
    # Align q/k/v head_dim sharding with the dh-sharded cache: the QK
    # contraction then partial-sums over dh (a psum of small (B,H,S) logits)
    # instead of all-gathering the cache every step.  Probe-measured ~2x on
    # the decode dominant term for every attention arch (mixtral 0.324 ->
    # 0.162 s, granite 0.914 -> 0.457 s, nemotron 1.46 -> 0.72 s); see
    # EXPERIMENTS §Perf — including the methodology trap we fell into when
    # first evaluating it against a rolled (loop-undercounted) baseline.
    q = constrain(q, "dp", None, None, "tp")
    k = constrain(k, "dp", None, None, "tp")
    v = constrain(v, "dp", None, None, "tp")
    s_cache = k_cache.shape[1]
    ring = window is not None and s_cache == window
    slot = (pos % window) if ring else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    o = decode_attention(q, k_cache, v_cache, pos + 1, ring=ring)
    out = o.reshape(b, 1, cfg.attn_dim) @ bp["attn"]["wo"].astype(h.dtype)
    return out, k_cache, v_cache


def lm_decode_step(params, cfg: ArchConfig, cache, tokens):
    """One decode step: tokens (B, 1) -> (logits (B, 1, Vp), new cache)."""
    pos = cache["pos"]
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    types = jnp.asarray(layer_types(cfg))

    if cfg.family == "ssm":
        def body(carry, scanned):
            bp, conv, ssm = scanned
            h = rms_norm(carry, bp["ln1"], cfg.norm_eps)
            out, st = ssd_decode_step(
                h, {"conv": conv, "ssm": ssm}, bp["ssm"],
                d_state=cfg.ssm.d_state, head_dim=cfg.ssm.head_dim,
                expand=cfg.ssm.expand, norm_eps=cfg.norm_eps,
            )
            return carry + out, (st["conv"], st["ssm"])

        x, (conv_new, ssm_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["conv"], cache["ssm"]),
            unroll=cfg.unroll_layers,
        )
        new_cache = dict(cache, conv=conv_new, ssm=ssm_new, pos=pos + 1)
    elif cfg.family == "hybrid":
        def body(carry, scanned):
            bp, lt, kc, vc, conv, hst = scanned

            def attn_branch(c):
                out, k2, v2 = _attn_decode(
                    c, bp, cfg, kc, vc, pos, cfg.hybrid.local_window
                )
                return out, k2, v2, conv, hst

            def rec_branch(c):
                h = rms_norm(c, bp["ln1"], cfg.norm_eps)
                out, st = rglru_decode_step(h, {"conv": conv, "h": hst}, bp["rglru"])
                return out, kc, vc, st["conv"], st["h"]

            out, k2, v2, c2, h2 = jax.lax.cond(lt == 0, attn_branch, rec_branch, carry)
            mid = carry + out
            new = mid + _ffn_apply(mid, bp, cfg)
            return new, (k2, v2, c2, h2)

        x, (k_new, v_new, conv_new, h_new) = jax.lax.scan(
            body,
            x,
            (params["blocks"], types, cache["k"], cache["v"], cache["conv"], cache["h"]),
            unroll=cfg.unroll_layers,
        )
        new_cache = dict(
            cache, k=k_new, v=v_new, conv=conv_new, h=h_new, pos=pos + 1
        )
    else:  # dense / moe / vlm
        def body(carry, scanned):
            bp, kc, vc = scanned
            out, k2, v2 = _attn_decode(carry, bp, cfg, kc, vc, pos, cfg.window)
            mid = carry + out
            new = mid + _ffn_apply(mid, bp, cfg)
            return new, (k2, v2)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]),
            unroll=cfg.unroll_layers,
        )
        new_cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = h @ _head_matrix(params).astype(h.dtype).T
    return logits, new_cache
