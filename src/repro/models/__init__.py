from repro.models.lm import (
    init_lm_params,
    lm_loss,
    lm_forward,
    init_decode_cache,
    lm_decode_step,
)
from repro.models.encdec import (
    init_encdec_params,
    encdec_loss,
    init_encdec_cache,
    encdec_decode_step,
)

__all__ = [
    "init_lm_params",
    "lm_loss",
    "lm_forward",
    "init_decode_cache",
    "lm_decode_step",
    "init_encdec_params",
    "encdec_loss",
    "init_encdec_cache",
    "encdec_decode_step",
]
