"""Shared transformer building blocks (pure JAX, shape-polymorphic).

Everything here works on (B, S, ...) activations in bf16 compute with f32
params, takes explicit param dicts (no module framework — params are plain
pytrees so pjit sharding specs can be zipped against them), and avoids
materializing (S, S) score matrices: attention is computed with a query-chunked
online pass (`chunked_attention`), which is the jnp twin of the Pallas flash
kernel in repro.kernels (validated against the same reference).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16

# ---------------------------------------------------------------------------
# activation-sharding constraints.  GSPMD propagation alone replicates large
# intermediates ("involuntary full rematerialization" warnings, 30-75 GB/device
# temp) — the launch layer registers the mesh axes and the model code pins the
# canonical megatron-style activation shardings at block boundaries.
# Single-device paths (smoke tests) leave this unset: constrain() is a no-op.
# ---------------------------------------------------------------------------

_MESH_AXES: dict | None = None


def set_sharding_axes(dp, tp: str, sizes: dict[str, int]) -> None:
    """dp: axis name (or tuple) for batch/FSDP; tp: tensor axis; sizes: name->size."""
    global _MESH_AXES
    dp_t = dp if isinstance(dp, tuple) else (dp,)
    _MESH_AXES = {
        "dp": dp,
        "tp": tp,
        "dp_size": int(np.prod([sizes[a] for a in dp_t])) if dp else 1,
        "tp_size": sizes.get(tp, 1),
    }


def clear_sharding_axes() -> None:
    global _MESH_AXES
    _MESH_AXES = None


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint on logical axes 'dp'/'tp'/None per dimension.

    Axes whose mesh size does not divide the dimension are dropped (e.g. the
    batch=1 long-context decode cannot shard batch over 16 devices).
    """
    if _MESH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = []
    for dim, a in zip(x.shape, axes):
        if a is None:
            spec.append(None)
        else:
            size = _MESH_AXES[f"{a}_size"]
            spec.append(_MESH_AXES[a] if size and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings (full or fractional — chatglm applies RoPE to
# half the head dims: rope_fraction = 0.5)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, fraction: float = 1.0,
         theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    d_rot = int(dh * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    half = d_rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
        ang = ang[None, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

_NEG = -1e30


def chunked_map(f, n: int, unroll: bool = False):
    """lax.map over range(n), or a fully-unrolled python loop.

    The dry-run/roofline pass unrolls every loop: XLA's HLO cost analysis does
    not multiply FLOPs/collective bytes by while-loop trip counts, so scanned
    programs under-report.  Runtime paths keep the rolled loop (fast compiles).
    """
    if n == 1:
        return jax.tree.map(lambda x: x[None], f(jnp.asarray(0)))
    if unroll:
        outs = [f(jnp.asarray(i)) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return jax.lax.map(f, jnp.arange(n))


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Kv, Dh) -> (B, S, Kv*n_rep, Dh) for GQA."""
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, dh)).reshape(
        b, s, kv * n_rep, dh
    )


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Memory-bounded attention: q (B,Sq,H,Dh), k/v (B,Sk,Kv,Dh) -> (B,Sq,H,Dh).

    Processes queries in chunks of q_chunk; never materializes (Sq, Sk).
    GQA is handled by broadcasting kv heads.  For sliding-window attention the
    key range per chunk is sliced to [chunk_start - window + 1, chunk_end],
    so the work is O(Sq * (window + q_chunk)) instead of O(Sq * Sk).
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    n_rep = h // kv
    scale = dh**-0.5
    kf = _repeat_kv(k, n_rep)
    vf = _repeat_kv(v, n_rep)

    if sq % q_chunk:
        q_chunk = sq  # fall back to a single chunk for odd lengths
    n_chunks = sq // q_chunk

    kpos_all = jnp.arange(sk)

    def one_chunk(ci):
        q_start = ci * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q, q_start, q_chunk, axis=1)
        qpos = q_start + jnp.arange(q_chunk)
        if window is not None:
            # only the last (window + q_chunk - 1) keys can be visible
            span = min(sk, window + q_chunk - 1)
            k_start = jnp.clip(q_start + q_chunk - span, 0, sk - span)
            kc = jax.lax.dynamic_slice_in_dim(kf, k_start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vf, k_start, span, axis=1)
            kpos = k_start + jnp.arange(span)
        else:
            kc, vc, kpos = kf, vf, kpos_all
        logits = jnp.einsum(
            "bqhd,bkhd->bhqk", qc, kc, preferred_element_type=jnp.float32
        ) * scale
        mask = jnp.ones((q_chunk, kpos.shape[0]), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, _NEG)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vc).astype(q.dtype)

    if n_chunks == 1:
        return one_chunk(jnp.asarray(0))
    out = chunked_map(one_chunk, n_chunks, unroll)  # (n, B, qc, H, Dh)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, dh)


def decode_attention(
    q: jax.Array,  # (B, 1, H, Dh)
    k_cache: jax.Array,  # (B, S_cache, Kv, Dh)
    v_cache: jax.Array,
    cur_len: jax.Array,  # scalar: number of valid cache entries
    *,
    ring: bool = False,  # True when the cache is a sliding-window ring buffer
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffered) KV cache."""
    b, _, h, dh = q.shape
    s_cache, kv = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kv
    kf = _repeat_kv(k_cache, n_rep)
    vf = _repeat_kv(v_cache, n_rep)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q, kf, preferred_element_type=jnp.float32
    ) * dh**-0.5
    if ring:
        # every slot is valid once the ring has wrapped
        valid = jnp.arange(s_cache) < jnp.minimum(cur_len, s_cache)
    else:
        valid = jnp.arange(s_cache) < cur_len
    logits = jnp.where(valid[None, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_apply(x: jax.Array, p: dict, activation: str) -> jax.Array:
    """x: (B, S, D).  p: {"w1": (D,F), "w2": (F,D)[, "w1g": (D,F)]}."""
    w1 = p["w1"].astype(x.dtype)
    w2 = p["w2"].astype(x.dtype)
    if activation == "silu_glu":
        g = x @ p["w1g"].astype(x.dtype)
        h = jax.nn.silu(x @ w1) * g
    elif activation == "sq_relu":  # nemotron: squared ReLU
        h = jnp.square(jax.nn.relu(x @ w1))
    elif activation == "gelu":
        h = jax.nn.gelu(x @ w1)
    else:
        raise ValueError(activation)
    h = constrain(h, *(("dp",) + (None,) * (h.ndim - 2) + ("tp",)))
    return h @ w2


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over positions with label >= 0 (negative labels are masked)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
