"""Mixture-of-Experts block with top-k routing and capacity-bounded dispatch.

Honest-FLOP implementation: tokens are sorted by expert assignment and
scatter-packed into (E, C, D) capacity buffers, so the expert matmuls compute
exactly top_k * tokens * capacity_factor worth of work — NOT n_experts x.
This matters for the roofline analysis (MODEL_FLOPS for MoE uses N_active).

Under pjit the scatter/gather over token-sharded activations lowers to the
expert-parallel all-to-all pattern; the collective term in the roofline tables
comes from exactly these ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_apply


def moe_apply(x: jax.Array, p: dict, *, n_experts: int, top_k: int,
              capacity_factor: float, activation: str) -> jax.Array:
    """x: (B, S, D).  p: router (D, E), w1/w1g (E, D, F), w2 (E, F, D)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    capacity = max(1, int(capacity_factor * t * top_k / n_experts))

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, top_k)  # (t, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)  # renormalize

    # flatten (token, slot) assignments and sort by expert id
    flat_e = gate_i.reshape(-1)  # (t*k,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_e)  # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each assignment within its expert's queue
    pos = jnp.arange(t * top_k) - jnp.searchsorted(se, se, side="left")
    keep = pos < capacity  # overflow tokens are dropped (standard capacity MoE)
    pos_c = jnp.where(keep, pos, 0)

    # dispatch: (E, C, D) buffers
    buf = jnp.zeros((n_experts, capacity, d), dtype=x.dtype)
    buf = buf.at[se, pos_c].add(jnp.where(keep[:, None], xf[st], 0.0))

    # expert FFNs, batched over E
    h = jax.vmap(
        lambda xe, w1, w1g, w2: mlp_apply(
            xe[None], {"w1": w1, "w1g": w1g, "w2": w2}, activation
        )[0]
    )(buf, p["w1"], p.get("w1g", p["w1"]), p["w2"])  # (E, C, D)

    # combine: weighted scatter back to tokens
    out = jnp.zeros((t, d), dtype=jnp.float32)
    vals = h[se, pos_c].astype(jnp.float32) * jnp.where(keep, sw, 0.0)[:, None]
    out = out.at[st].add(vals)
    return out.astype(x.dtype).reshape(b, s, d)


def moe_apply_dense(x: jax.Array, p: dict, *, n_experts: int, top_k: int,
                    activation: str) -> jax.Array:
    """Dense-fallback MoE for tiny token counts (decode): run ALL experts on
    all tokens and combine with the (renormalized) top-k gate weights.

    E/top_k x more FLOPs per token, but zero dispatch scatter/gather — at
    decode (1 token/seq) this trades a trivial amount of MXU work for the
    removal of the all-to-all-shaped collectives (EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, top_k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)
    w_full = jnp.zeros((t, n_experts), dtype=jnp.float32)
    w_full = w_full.at[jnp.arange(t)[:, None], gate_i].set(gate_w)

    h = jax.vmap(
        lambda w1, w1g, w2: mlp_apply(
            xf[None], {"w1": w1, "w1g": w1g, "w2": w2}, activation
        )[0]
    )(p["w1"], p.get("w1g", p["w1"]), p["w2"])  # (E, t, d)
    out = jnp.einsum("te,etd->td", w_full, h.astype(jnp.float32))
    return out.astype(x.dtype).reshape(b, s, d)


def moe_aux_loss(x: jax.Array, router: jax.Array, *, n_experts: int,
                 top_k: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean over tokens)."""
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_i = jax.lax.top_k(probs, top_k)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i, n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)
