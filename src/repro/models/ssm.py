"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD algorithm: the sequence is split into chunks of length Q; the
quadratic "attention-like" part runs within chunks only (O(S*Q) work), and a
linear scan over chunk summary states carries information across chunks.
Decoding is the O(1)-state recurrence h' = exp(dt*A) h + dt * B (x) — this is
why mamba2 runs the long_500k decode shape that quadratic-attention archs skip.

Single SSM group (B/C shared across heads), scalar-per-head A — the mamba2
default.  Shapes: d_inner = expand*d_model, H = d_inner/head_dim heads,
state size N.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, constrain


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: (B, S, C), w: (cw, C), b: (C,)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    # stack cw shifted views: (B, S, cw, C)
    views = jnp.stack([xp[:, i : i + x.shape[1]] for i in range(cw)], axis=2)
    return jnp.einsum("bswc,wc->bsc", views, w.astype(x.dtype)) + b.astype(x.dtype)


def ssd_apply(x_res: jax.Array, p: dict, *, d_state: int, head_dim: int,
              expand: int, chunk: int, norm_eps: float = 1e-6) -> jax.Array:
    """Full-sequence SSD mixer.  x_res: (B, S, D) block input (post-norm)."""
    bsz, s, d_model = x_res.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    n = d_state

    proj = constrain(
        x_res @ p["in_proj"].astype(x_res.dtype), "dp", None, "tp"
    )  # (B,S, 2*di + 2N + H)
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x_in, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    da = dt * a  # (B,S,H) log-decay per step

    q = chunk if s % chunk == 0 else s
    nc = s // q
    xh = constrain(
        x_in.reshape(bsz, nc, q, n_heads, head_dim).astype(jnp.float32),
        "dp", None, None, "tp", None,
    )
    bh = b_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    ch = c_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, n_heads)
    dac = da.reshape(bsz, nc, q, n_heads)
    ca = jnp.cumsum(dac, axis=2)  # inclusive within-chunk cumulative log decay
    xw = xh * dtc[..., None]  # dt-weighted inputs

    # ---- intra-chunk (quadratic within chunk)
    g = jnp.einsum("bcin,bcjn->bcij", ch, bh)  # (B,nc,Q,Q)
    decay = jnp.exp(ca[:, :, :, None, :] - ca[:, :, None, :, :])  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), dtype=bool))
    att = constrain(
        jnp.where(tri[None, None, :, :, None], g[..., None] * decay, 0.0),
        "dp", None, None, None, "tp",
    )
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xw)

    # ---- chunk summary states and inter-chunk scan
    decay_to_end = jnp.exp(ca[:, :, -1:, :] - ca)  # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bh, decay_to_end, xw)
    chunk_decay = jnp.exp(ca[:, :, -1, :])  # (B,nc,H) total chunk decay

    def scan_fn(h_state, inp):
        s_c, dec = inp  # (B,H,P,N), (B,H)
        h_out = h_state  # state BEFORE this chunk
        h_next = h_state * dec[..., None, None] + s_c
        return h_next, h_out

    h0 = jnp.zeros((bsz, n_heads, head_dim, n), dtype=jnp.float32)
    _, h_in = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,P,N) state entering each chunk

    y_inter = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", ch, h_in, jnp.exp(ca)
    )

    y = y_intra + y_inter + p["D"].astype(jnp.float32)[None, None, None, :, None] * xh
    y = constrain(y.reshape(bsz, s, d_inner).astype(x_res.dtype), "dp", None, "tp")
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["gate_norm"], eps=norm_eps)
    return y @ p["out_proj"].astype(x_res.dtype)


def ssd_decode_step(x_tok: jax.Array, state: dict, p: dict, *, d_state: int,
                    head_dim: int, expand: int, norm_eps: float = 1e-6):
    """One-token recurrence.  x_tok: (B, 1, D); state: {conv: (B,cw-1,C), ssm: (B,H,P,N)}."""
    bsz, _, d_model = x_tok.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    n = d_state

    proj = x_tok @ p["in_proj"].astype(x_tok.dtype)
    z, xbc, dt_raw = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)

    conv_state = state["conv"]  # (B, cw-1, C)
    cw = conv_state.shape[1] + 1
    window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, cw, C)
    xbc_t = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(window.dtype))
    xbc_t = jax.nn.silu(xbc_t + p["conv_b"].astype(window.dtype))[:, None, :]
    conv_state_new = window[:, 1:]

    x_in, b_in, c_in = jnp.split(xbc_t, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )[:, 0]  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)  # (B,H)

    xh = x_in[:, 0].reshape(bsz, n_heads, head_dim).astype(jnp.float32)
    bh = b_in[:, 0].astype(jnp.float32)  # (B,N)
    ch = c_in[:, 0].astype(jnp.float32)
    xw = xh * dt[..., None]

    ssm = state["ssm"] * dec[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xw, bh
    )
    y = jnp.einsum("bn,bhpn->bhp", ch, ssm) + p["D"].astype(jnp.float32)[
        None, :, None
    ] * xh
    y = y.reshape(bsz, 1, d_inner).astype(x_tok.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["gate_norm"], eps=norm_eps)
    out = y @ p["out_proj"].astype(x_tok.dtype)
    return out, {"conv": conv_state_new, "ssm": ssm}
