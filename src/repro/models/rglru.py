"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_r x_t)                  (recurrence gate)
    i_t = sigmoid(W_i x_t)                  (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The full-sequence form runs as a `jax.lax.associative_scan` over the affine
maps h -> a*h + b (log-depth on TPU); decode is the O(1) recurrence.  The
block follows Griffin: input projection D -> 2*lru (branch x + gelu gate),
short causal conv on the recurrent branch, RG-LRU, gated merge, out proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import _causal_conv
from repro.models.layers import constrain

_C = 8.0


def _rglru_core(x: jax.Array, p: dict, h0: jax.Array | None = None):
    """x: (B, S, L) recurrent-branch input -> (y, h_last)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r  # (B,S,L)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_apply(x_res: jax.Array, p: dict) -> jax.Array:
    """Griffin recurrent block over a full sequence.  x_res: (B, S, D)."""
    branch = constrain(x_res @ p["w_x"].astype(x_res.dtype), "dp", None, "tp")
    gate = jax.nn.gelu(x_res @ p["w_gate"].astype(x_res.dtype))
    branch = jax.nn.silu(_causal_conv(branch, p["conv_w"], p["conv_b"]))
    h, _ = _rglru_core(branch, p)
    return (h * gate) @ p["w_out"].astype(x_res.dtype)


def rglru_decode_step(x_tok: jax.Array, state: dict, p: dict):
    """One token.  state: {conv: (B, cw-1, L), h: (B, L)}."""
    branch = x_tok @ p["w_x"].astype(x_tok.dtype)  # (B,1,L)
    gate = jax.nn.gelu(x_tok @ p["w_gate"].astype(x_tok.dtype))

    conv_state = state["conv"]
    window = jnp.concatenate([conv_state, branch], axis=1)
    b_t = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(window.dtype))
    b_t = jax.nn.silu(b_t + p["conv_b"].astype(window.dtype))  # (B,L)
    conv_new = window[:, 1:]

    xf = b_t.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    a = jnp.exp(-_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r)
    h = a * state["h"].astype(jnp.float32) + jnp.sqrt(
        jnp.maximum(1.0 - a * a, 1e-12)
    ) * (i * xf)

    out = (h.astype(x_tok.dtype)[:, None, :] * gate) @ p["w_out"].astype(x_tok.dtype)
    return out, {"conv": conv_new, "h": h}
