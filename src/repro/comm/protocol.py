"""Message framing for the FedNL star-topology protocol (DESIGN.md §4).

Every message is one frame: a fixed 32-byte little-endian header followed by
``payload_len`` payload bytes.

    offset  size  field
    0       4     magic  b"FNL1" (protocol version folded into the magic)
    4       1     msg type (MsgType)
    5       1     compressor id (wire.COMPRESSOR_IDS)
    6       1     dtype tag (0 = float64; the only FedNL dtype)
    7       1     flags (reserved, 0)
    8       4     round index
    12      4     client id
    16      4     sent_elems (payload elements of the Hessian section)
    20      8     payload_bits (exact Section-7 bit count of the Hessian section)
    28      4     payload_len (bytes that follow)

Frame kinds:

    HELLO     client -> master on connect; identifies `client id`.  No payload.
    INIT      master -> clients: x0 (d FP64).  Clients reply INIT_ACK.
    INIT_ACK  client -> master: packed initial Hessian H_i^0 (T FP64).
              FedNL-PP extends the payload to H_i^0 || l_i^0 || g_i^0 (the
              server invariants are means of all three; see pack_pp_state).
    ROUND     master -> clients: current iterate x (d FP64).
    UPLINK    client -> master: grad (d FP64) || l (FP64) || f_i (FP64) ||
              encoded Hessian payload (wire.py codecs).
    STOP      master -> clients: end of run.  No payload.

Partial-participation frames (FedNL-PP, Algorithm 3; DESIGN.md §5a):

    SELECT    master -> one *sampled* client: u32 slot || u32 tau || x
              (d FP64).  `slot` is the client's position in this round's
              sample — it indexes the round's split(k_comp, tau) key fan-out,
              so compression randomness stays seed-aligned with the
              single-node simulation without key bytes on the wire.
    PP_UPDATE client -> master: encode(S_i) || dl_i (FP64) || dg_i (d FP64)
              — the Algorithm-3 uplink triple.  The Hessian section reuses
              the Section-7 codecs; the exact bit count of the whole payload
              is wire.pp_message_bits.
    DROP      client -> master: fault-injected dropout NACK for one SELECT.
              A real deployment detects failures by timeout; the explicit
              NACK keeps the loopback schedule synchronous while exercising
              the master's replaceable-client fallback paths.

Topology frames (tree-of-stars, repro.comm.topology; DESIGN.md §13):

    AGG       aggregator -> parent: one combined uplink per subtree.
              combine="exact" payload: the subtree's per-leaf uplink
              sections, verbatim (pack_agg_entries) — the root re-runs the
              star master's aggregation ops over the reassembled leaf list,
              so the tree trajectory is the star trajectory bit for bit.
              combine="sum" payload: dense partial sums over the subtree
              (pack_agg_hsum for the INIT phase, pack_agg_roundsum for
              rounds) — bandwidth-optimal, documented ulp drift.
    SUBTREE   master -> aggregator: coverage handshake before INIT —
              combine mode + the leaf ids this subtree is expected to own
              (pack_subtree).  The aggregator recursively queries its own
              aggregator children, verifies the union of owned leaves, and
              acks with the actual set; the root asserts the acks partition
              client ids exactly (a mis-wired process tree fails loudly
              before any algorithm state exists).

Gateway RPC frames (repro.gateway; DESIGN.md §14).  Same 32-byte header,
payloads defined by ``repro.gateway.protocol`` (versioned JSON header +
raw little-endian array blobs, the FNLS1 idiom).  The ``round`` header
field carries the round index on RECORD frames and is 0 elsewhere;
``client`` is unused (tenant ids are strings and live in the payload):

    SUBMIT      client -> gateway: serialized ExperimentSpec + SubmitOptions
                (repro.api.specwire versioned encoding — unknown fields are
                rejected loudly, naming the field).
    STATUS      client -> gateway: one tenant's status, or engine stats.
    STREAM      client -> gateway: subscribe to a tenant's RoundRecords;
                the gateway replies GW_OK then streams RECORD frames and
                closes the stream with STREAM_END.
    EVICT       client -> gateway: checkpoint the tenant to the gateway's
                spill dir and remove it from scheduling (path in the reply).
    CANCEL      client -> gateway: drop the tenant without a checkpoint.
    RESULT      client -> gateway: block until the tenant finishes, then
                return its full serialized RunReport (records with hex-exact
                floats + the final iterate as a raw f64 blob — bit-identical
                across the wire).
    RECORD      gateway -> client: one streamed RoundRecord.
    RESULT      (reply direction) the packed report payload.
    STREAM_END  gateway -> client: end of a record stream, carrying the
                counted-drops notice of the bounded observer queue.
    GW_OK       gateway -> client: generic success reply (JSON payload).
    GW_ERR      gateway -> client: failure reply naming the offending field
                where derivable ({"error": ..., "field": ...}).
"""

from __future__ import annotations

import dataclasses
import enum
import struct

import jax
import numpy as np

from repro.comm.wire import EncodedMessage
from repro.obs import core as _obs

MAGIC = b"FNL1"
HEADER_FMT = "<4sBBBBIIIQI"
HEADER_SIZE = struct.calcsize(HEADER_FMT)
assert HEADER_SIZE == 32, HEADER_SIZE

DTYPE_F64 = 0


class MsgType(enum.IntEnum):
    HELLO = 1
    INIT = 2
    INIT_ACK = 3
    ROUND = 4
    UPLINK = 5
    STOP = 6
    # partial participation (FedNL-PP)
    SELECT = 7
    PP_UPDATE = 8
    DROP = 9
    # hierarchical topology (repro.comm.topology)
    AGG = 10
    SUBTREE = 11
    # gateway RPC (repro.gateway; DESIGN.md §14)
    SUBMIT = 12
    STATUS = 13
    STREAM = 14
    EVICT = 15
    CANCEL = 16
    RESULT = 17
    RECORD = 18
    STREAM_END = 19
    GW_OK = 20
    GW_ERR = 21
    # observability (repro.obs; DESIGN.md §15)
    METRICS = 22


@dataclasses.dataclass(frozen=True)
class Frame:
    type: MsgType
    round: int = 0
    client: int = 0
    comp_id: int = 0
    dtype: int = DTYPE_F64
    sent_elems: int = 0
    payload_bits: int = 0
    payload: bytes = b""

    @property
    def wire_bytes(self) -> int:
        return HEADER_SIZE + len(self.payload)


def pack_frame(frame: Frame) -> bytes:
    header = struct.pack(
        HEADER_FMT,
        MAGIC,
        int(frame.type),
        frame.comp_id,
        frame.dtype,
        0,
        frame.round,
        frame.client,
        frame.sent_elems,
        frame.payload_bits,
        len(frame.payload),
    )
    return header + frame.payload


def unpack_header(header: bytes) -> tuple[Frame, int]:
    """Parse a header; returns the (payload-less) Frame and the payload length."""
    magic, mtype, comp_id, dtype, _flags, rnd, client, sent, pbits, plen = (
        struct.unpack(HEADER_FMT, header)
    )
    if magic != MAGIC:
        raise ValueError(f"bad magic {magic!r}; protocol mismatch")
    frame = Frame(
        type=MsgType(mtype),
        round=rnd,
        client=client,
        comp_id=comp_id,
        dtype=dtype,
        sent_elems=sent,
        payload_bits=pbits,
    )
    return frame, plen


def send_frame(conn, frame: Frame) -> int:
    """Write one frame to a transport connection; returns bytes sent."""
    data = pack_frame(frame)
    conn.send(data)
    rec = _obs.CURRENT
    if rec.enabled:
        rec.add("comm.frames.sent", type=frame.type.name)
        rec.add("comm.bytes.sent", len(data), type=frame.type.name)
    return len(data)


def recv_frame(conn) -> Frame:
    """Read exactly one frame from a transport connection."""
    frame, plen = unpack_header(conn.recv_exact(HEADER_SIZE))
    payload = conn.recv_exact(plen) if plen else b""
    rec = _obs.CURRENT
    if rec.enabled:
        rec.add("comm.frames.recv", type=frame.type.name)
        rec.add("comm.bytes.recv", HEADER_SIZE + plen, type=frame.type.name)
    return dataclasses.replace(frame, payload=payload)


# ---------------------------------------------------------------------------
# payload helpers
# ---------------------------------------------------------------------------

def pack_vector(x) -> bytes:
    return np.asarray(x, dtype="<f8").tobytes()


def unpack_vector(data: bytes):
    import jax.numpy as jnp

    return jnp.asarray(np.frombuffer(data, dtype="<f8").copy())


def pack_uplink(grad: jax.Array, l, f, enc: EncodedMessage) -> bytes:
    """grad (d FP64) || l || f_i || encoded Hessian message."""
    return (
        pack_vector(grad)
        + struct.pack("<dd", float(l), float(f))
        + enc.data
    )


def unpack_uplink(payload: bytes, d: int):
    """Inverse of pack_uplink -> (grad, l, f, hessian_payload_bytes)."""
    import jax.numpy as jnp

    grad = unpack_vector(payload[: 8 * d])
    l, f = struct.unpack("<dd", payload[8 * d : 8 * d + 16])
    return grad, jnp.float64(l), jnp.float64(f), payload[8 * d + 16 :]


# ---------------------------------------------------------------------------
# partial-participation payloads (FedNL-PP)
# ---------------------------------------------------------------------------

def pack_select(slot: int, tau: int, x) -> bytes:
    """SELECT: the client's slot in this round's sample, tau, the iterate."""
    return struct.pack("<II", slot, tau) + pack_vector(x)


def unpack_select(payload: bytes) -> tuple[int, int, "jax.Array"]:
    slot, tau = struct.unpack("<II", payload[:8])
    return slot, tau, unpack_vector(payload[8:])


def pack_pp_state(h, l, g) -> bytes:
    """PP INIT_ACK: H_i^0 (T FP64) || l_i^0 (FP64) || g_i^0 (d FP64)."""
    return pack_vector(h) + struct.pack("<d", float(l)) + pack_vector(g)


def unpack_pp_state(payload: bytes, d: int):
    """Inverse of pack_pp_state -> (h, l, g)."""
    import jax.numpy as jnp

    t_bytes = len(payload) - 8 - 8 * d
    h = unpack_vector(payload[:t_bytes])
    (l,) = struct.unpack("<d", payload[t_bytes : t_bytes + 8])
    g = unpack_vector(payload[t_bytes + 8 :])
    return h, jnp.float64(l), g


def pack_pp_update(enc: EncodedMessage, dl, dg) -> bytes:
    """Algorithm-3 uplink triple: encode(S_i) || dl_i || dg_i (d FP64)."""
    return enc.data + struct.pack("<d", float(dl)) + pack_vector(dg)


# ---------------------------------------------------------------------------
# topology payloads (tree-of-stars; repro.comm.topology)
# ---------------------------------------------------------------------------

# one per-leaf uplink section inside an exact-combine AGG payload:
# (client id, sent_elems, payload_bits, original frame wire bytes, payload)
_AGG_ENTRY_FMT = "<IIQII"
_AGG_ENTRY_SIZE = struct.calcsize(_AGG_ENTRY_FMT)


def pack_agg_entries(entries) -> bytes:
    """combine="exact" AGG payload: the subtree's leaf uplink sections,
    verbatim.  ``entries`` is a list of ``(client, sent_elems, payload_bits,
    frame_bytes, payload)`` tuples; ``frame_bytes`` preserves each leaf
    frame's original wire size so the root's measured accounting matches a
    flat star exactly.  Sub-aggregator entry lists simply concatenate — the
    payload is depth-agnostic."""
    out = [struct.pack("<I", len(entries))]
    for client, sent_elems, payload_bits, frame_bytes, payload in entries:
        out.append(
            struct.pack(
                _AGG_ENTRY_FMT,
                client, sent_elems, payload_bits, frame_bytes, len(payload),
            )
        )
        out.append(payload)
    return b"".join(out)


def unpack_agg_entries(payload: bytes):
    """Inverse of pack_agg_entries -> list of entry tuples."""
    (n,) = struct.unpack("<I", payload[:4])
    off = 4
    entries = []
    for _ in range(n):
        client, sent, pbits, fbytes, plen = struct.unpack(
            _AGG_ENTRY_FMT, payload[off : off + _AGG_ENTRY_SIZE]
        )
        off += _AGG_ENTRY_SIZE
        entries.append((client, sent, pbits, fbytes, payload[off : off + plen]))
        off += plen
    if off != len(payload):
        raise ValueError(
            f"AGG payload has {len(payload) - off} trailing bytes "
            f"after {n} entries"
        )
    return entries


def pack_agg_hsum(count: int, h_sum) -> bytes:
    """combine="sum" INIT-phase AGG payload: subtree leaf count + the dense
    sum of the subtree's packed initial Hessians (T FP64)."""
    return struct.pack("<I", count) + pack_vector(h_sum)


def unpack_agg_hsum(payload: bytes):
    (count,) = struct.unpack("<I", payload[:4])
    return count, unpack_vector(payload[4:])


_AGG_SUM_FMT = "<IIQQQdd"
_AGG_SUM_SIZE = struct.calcsize(_AGG_SUM_FMT)


def pack_agg_roundsum(
    count: int, d: int, abits: int, pbits: int, fbytes: int,
    l_sum, f_sum, grad_sum, s_sum,
) -> bytes:
    """combine="sum" round AGG payload: dense partial sums over the subtree
    — leaf count, summed bit counters (analytic / measured payload / frame
    bytes), l/f sums, grad sum (d FP64) and decoded Hessian-correction sum
    (T FP64)."""
    return (
        struct.pack(
            _AGG_SUM_FMT, count, d, abits, pbits, fbytes,
            float(l_sum), float(f_sum),
        )
        + pack_vector(grad_sum)
        + pack_vector(s_sum)
    )


def unpack_agg_roundsum(payload: bytes):
    count, d, abits, pbits, fbytes, l_sum, f_sum = struct.unpack(
        _AGG_SUM_FMT, payload[:_AGG_SUM_SIZE]
    )
    grad_sum = unpack_vector(payload[_AGG_SUM_SIZE : _AGG_SUM_SIZE + 8 * d])
    s_sum = unpack_vector(payload[_AGG_SUM_SIZE + 8 * d :])
    return count, abits, pbits, fbytes, l_sum, f_sum, grad_sum, s_sum


def pack_subtree(combine_id: int, leaf_ids) -> bytes:
    """SUBTREE handshake payload: combine mode (0 exact | 1 sum) + the leaf
    client ids (expected set downstream, actual owned set in the ack)."""
    ids = sorted(int(i) for i in leaf_ids)
    return struct.pack("<BI", combine_id, len(ids)) + struct.pack(
        f"<{len(ids)}I", *ids
    )


def unpack_subtree(payload: bytes) -> tuple[int, tuple]:
    combine_id, n = struct.unpack("<BI", payload[:5])
    return combine_id, struct.unpack(f"<{n}I", payload[5 : 5 + 4 * n])


def unpack_pp_update(payload: bytes, d: int):
    """Inverse of pack_pp_update -> (hessian_payload_bytes, dl, dg)."""
    import jax.numpy as jnp

    tail = 8 * (d + 1)
    (dl,) = struct.unpack("<d", payload[-tail : -tail + 8])
    dg = unpack_vector(payload[len(payload) - 8 * d :])
    return payload[:-tail], jnp.float64(dl), dg
