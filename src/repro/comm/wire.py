"""Section-7 wire-format codecs for compressed packed-triu FedNL messages.

The paper's multi-node implementation ships each client's compressed Hessian
correction ``S_i = C(D_i - H_i)`` over TCP using compressor-specific byte
encodings (paper Section 7).  This module implements those encodings as
byte-level encoder/decoder pairs whose *exact* bit cost agrees with the
analytic :func:`repro.compressors.core.message_bits` model — so the simulated
``sent_bits`` accounting and the measured wire bytes are provably the same
quantity (asserted in ``tests/test_comm.py``).

Per-compressor formats (little-endian throughout; DESIGN.md §3):

  identity   T x FP64 raw values.                       bits = 64 T
  topk       k x (u32 index || FP64 value).             bits = 96 k
  randk      8-byte PRG key || k x FP64 value.          bits = 64 + 64 k
             The receiver re-runs the PRG (uniform keys + top_k) to
             reconstruct the index set — "PRG-seed reconstruction": indices
             never travel on the wire.
  randseqk   u32 start index s || k x FP64 value.       bits = 32 + 64 k
             The k kept slots are {s, .., s+k-1 mod T}: one 32-bit integer
             replaces the whole index vector (paper Appendix C).
  toplek     u32 kept count k' || k' x (u32 || FP64).   bits = 32 + 96 k'
             Data-dependent payload (paper Appendix D adaptivity).
  natural    T x 12-bit (sign || 11-bit biased exponent), bit-packed.
                                                        bits = 12 T
             Values of the scaled Natural compressor are exactly
             ``sign * 2^p * (8/9)``; the 8/9 factor is a *protocol constant*
             so only sign+exponent travel.  Exponents below FP64-normal
             (p < -1022) encode as zero — a <=2^-1022 absolute loss.

Decoding reproduces the client's dense compressed vector ``u_hat``
*bit-exactly* (including Natural: the decoder replays the identical float64
multiply chain), which is what lets a TCP run reproduce the single-node
``run_fednl`` trajectory.

Codecs run on host (numpy + eager jax for the PRG paths); they are the
serialization boundary, not a jit-traced computation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compressors.core import (
    FP_BITS,
    IDX_BITS,
    NATURAL_BITS,
    Compressor,
    message_bits,
    randk_sparse,
    randseqk_sparse,
    scatter_add_sparse,
    topk_sparse,
    toplek_sparse,
)

# stable on-the-wire compressor ids (protocol header `comp_id` field)
COMPRESSOR_IDS = {
    "identity": 0,
    "topk": 1,
    "randk": 2,
    "randseqk": 3,
    "toplek": 4,
    "natural": 5,
}
COMPRESSOR_NAMES = {v: k for k, v in COMPRESSOR_IDS.items()}

NATURAL_SCALE = 8.0 / 9.0  # protocol constant: registry Natural is the scaled form
_EXP_BIAS = 1023  # FP64 exponent bias; code 0 means value == 0.0


@dataclasses.dataclass(frozen=True)
class EncodedMessage:
    """One compressed Hessian message as it travels on the wire.

    ``bits`` is the exact Section-7 bit count — ``len(data) == ceil(bits/8)``
    (Natural is the only format whose bit count is not byte-aligned).
    """

    data: bytes
    bits: int
    sent_elems: int


def _key_to_bytes(key: jax.Array) -> bytes:
    """Serialize a jax PRNG key (legacy uint32[2] or typed) to 8 wire bytes."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)  # typed keys refuse np.asarray directly
    kd = np.asarray(key)
    if kd.size != 2:
        raise ValueError(f"expected a 64-bit PRNG key, got shape {kd.shape}")
    return kd.astype("<u4").tobytes()


def _key_from_bytes(data: bytes) -> jax.Array:
    return jnp.asarray(np.frombuffer(data, dtype="<u4").copy())


def _f64_bytes(a) -> bytes:
    return np.asarray(a, dtype="<f8").tobytes()


def _f64_from(data: bytes) -> jax.Array:
    return jnp.asarray(np.frombuffer(data, dtype="<f8").copy())


def _u32_bytes(a) -> bytes:
    return np.asarray(a, dtype="<u4").tobytes()


def _u32_from(data: bytes) -> np.ndarray:
    return np.frombuffer(data, dtype="<u4").copy()


class WireCodec:
    """encode(key, u) -> EncodedMessage; decode(data, sent_elems) -> dense (T,).

    ``encode`` consumes the *uncompressed* packed-triu vector (plus the
    client's per-round PRG key) and performs compression + serialization in
    one step, guaranteeing that ``decode(encode(key, u)) ==
    Compressor.compress(key, u)[0]`` bit-for-bit.
    """

    def __init__(self, comp: Compressor, t: int):
        self.comp = comp
        self.t = t

    @property
    def name(self) -> str:
        return self.comp.name

    @property
    def comp_id(self) -> int:
        return COMPRESSOR_IDS[self.comp.name]

    def encode(self, key: jax.Array, u: jax.Array) -> EncodedMessage:
        raise NotImplementedError

    def decode(self, data: bytes, sent_elems: int) -> jax.Array:
        raise NotImplementedError


class IdentityCodec(WireCodec):
    def encode(self, key, u):
        del key
        return EncodedMessage(_f64_bytes(u), self.t * FP_BITS, self.t)

    def decode(self, data, sent_elems):
        del sent_elems
        return _f64_from(data)


class TopKCodec(WireCodec):
    def encode(self, key, u):
        del key
        k = self.comp.k
        idx, vals, _ = topk_sparse(u, k)
        data = _u32_bytes(idx) + _f64_bytes(vals)
        return EncodedMessage(data, k * (IDX_BITS + FP_BITS), k)

    def decode(self, data, sent_elems):
        k = sent_elems
        idx = _u32_from(data[: 4 * k]).astype(np.int32)
        vals = _f64_from(data[4 * k :])
        return scatter_add_sparse(jnp.asarray(idx), vals, self.t)


class RandKCodec(WireCodec):
    """Values + the 8-byte PRG key; the index set is reconstructed by
    replaying the PRG on the receiver (never transmitted)."""

    def encode(self, key, u):
        k = self.comp.k
        _, vals, _ = randk_sparse(key, u, k)
        data = _key_to_bytes(key) + _f64_bytes(vals)
        return EncodedMessage(data, FP_BITS + k * FP_BITS, k)

    def _indices(self, key: jax.Array) -> jax.Array:
        # identical op sequence to compressors.core.randk_sparse
        keys = jax.random.uniform(key, (self.t,), dtype=jnp.float32)
        _, idx = jax.lax.top_k(keys, self.comp.k)
        return idx.astype(jnp.int32)

    def decode(self, data, sent_elems):
        k = sent_elems
        key = _key_from_bytes(data[:8])
        vals = _f64_from(data[8 : 8 + 8 * k])
        return scatter_add_sparse(self._indices(key), vals, self.t)


class RandSeqKCodec(WireCodec):
    """Contiguous window: one u32 start index + k values (Appendix C)."""

    def encode(self, key, u):
        k = self.comp.k
        idx, vals, _ = randseqk_sparse(key, u, k)
        s = int(np.asarray(idx)[0])
        data = _u32_bytes([s]) + _f64_bytes(vals)
        return EncodedMessage(data, IDX_BITS + k * FP_BITS, k)

    def decode(self, data, sent_elems):
        k = sent_elems
        s = int(_u32_from(data[:4])[0])
        vals = _f64_from(data[4 : 4 + 8 * k])
        idx = jnp.asarray(((s + np.arange(k)) % self.t).astype(np.int32))
        return scatter_add_sparse(idx, vals, self.t)


class TopLEKCodec(WireCodec):
    """Adaptive payload: u32 kept-count header + kept (idx, val) pairs."""

    def encode(self, key, u):
        idx, vals, kept = toplek_sparse(key, u, self.comp.k)
        kept = int(kept)
        idx_np = np.asarray(idx)[:kept]
        vals_np = np.asarray(vals)[:kept]
        data = _u32_bytes([kept]) + _u32_bytes(idx_np) + _f64_bytes(vals_np)
        return EncodedMessage(data, IDX_BITS + kept * (IDX_BITS + FP_BITS), kept)

    def decode(self, data, sent_elems):
        kept = int(_u32_from(data[:4])[0])
        if kept != sent_elems:
            raise ValueError(f"toplek header kept={kept} != sent_elems={sent_elems}")
        idx = _u32_from(data[4 : 4 + 4 * kept]).astype(np.int32)
        vals = _f64_from(data[4 + 4 * kept :])
        return scatter_add_sparse(jnp.asarray(idx), vals, self.t)


class NaturalCodec(WireCodec):
    """Bit-packed sign + 11-bit exponent per entry (12 bits, paper Section 7).

    The scaled Natural compressor emits exactly ``sign * 2^p * NATURAL_SCALE``
    (the power-of-two multiply is exact in FP64), so frexp recovers ``p``
    without rounding ambiguity and the decoder replays the same multiply
    chain, giving a bit-exact round trip of the compressed vector.
    """

    def encode(self, key, u):
        u_hat, _ = self.comp.compress(key, u)  # probabilistic pow2 rounding
        u_np = np.asarray(u_hat, dtype=np.float64)
        sm, se = np.frexp(NATURAL_SCALE)  # NATURAL_SCALE = sm * 2^se, sm in [.5, 1)
        mant, ex = np.frexp(np.abs(u_np))
        p = ex - se  # |u| = 2^p * NATURAL_SCALE  (mant == sm exactly)
        biased = np.clip(p + _EXP_BIAS, 0, 2046)
        codes = np.where(u_np == 0.0, 0, biased).astype(np.uint16)
        codes |= (np.signbit(u_np) & (u_np != 0.0)).astype(np.uint16) << 11
        # pack T x 12 bits MSB-first
        be = codes[:, None].view(np.uint8).reshape(-1, 2)[:, ::-1]  # big-endian pairs
        bits16 = np.unpackbits(be, axis=1)  # (T, 16)
        data = np.packbits(bits16[:, 4:].reshape(-1)).tobytes()
        return EncodedMessage(data, self.t * NATURAL_BITS, self.t)

    def decode(self, data, sent_elems):
        t = self.t
        if sent_elems != t:
            raise ValueError(f"natural sends all T={t} entries, got {sent_elems}")
        flat = np.unpackbits(np.frombuffer(data, dtype=np.uint8))[: 12 * t]
        bits16 = np.zeros((t, 16), dtype=np.uint8)
        bits16[:, 4:] = flat.reshape(t, 12)
        pairs = np.packbits(bits16, axis=1)  # (T, 2) big-endian
        codes = (pairs[:, 0].astype(np.uint16) << 8) | pairs[:, 1]
        biased = (codes & 0x7FF).astype(np.int64)
        sign = np.where(codes >> 11 & 1, -1.0, 1.0)
        pow2 = np.ldexp(np.ones(t), biased - _EXP_BIAS)
        # replay the compressor's float sequence: (sign * 2^p) * (8/9)
        vals = np.where(biased == 0, 0.0, sign * pow2) * NATURAL_SCALE
        return jnp.asarray(vals)


_CODECS = {
    "identity": IdentityCodec,
    "topk": TopKCodec,
    "randk": RandKCodec,
    "randseqk": RandSeqKCodec,
    "toplek": TopLEKCodec,
    "natural": NaturalCodec,
}


def make_codec(comp: Compressor, t: int) -> WireCodec:
    """Wire codec for a configured compressor on packed-triu length ``t``."""
    if comp.name not in _CODECS:
        raise KeyError(f"no wire codec for compressor {comp.name!r}")
    return _CODECS[comp.name](comp, t)


def payload_bits(comp: Compressor, sent_elems) -> jax.Array:
    """Exact wire bits of the Hessian payload — by construction identical to
    the analytic :func:`message_bits` model (single source of truth)."""
    return message_bits(comp, sent_elems)


def frame_bits(comp: Compressor, sent_elems, d: int):
    """Wire bits of one full client UPLINK frame (jit-compatible arithmetic).

    frame = protocol header + grad (d FP64) + l + f_i (FP64 each) + the
    byte-padded Hessian payload.  This is the "measured" accounting option of
    ``FedNLConfig.accounting='wire'`` and matches ``len(frame)`` of the real
    transport byte stream exactly (asserted in tests/test_comm.py).
    """
    from repro.comm.protocol import HEADER_SIZE  # no import cycle: protocol is leaf

    pb = sent_elems * int(comp.bits_per_elem) + int(comp.header_bits)
    payload_bytes = (pb + 7) // 8
    return 8 * (payload_bytes + HEADER_SIZE + (d + 2) * 8)


def pp_message_bits(comp: Compressor, sent_elems, d: int):
    """Exact payload bits of one FedNL-PP uplink triple
    ``encode(S_i) || dl_i || dg_i``: the Section-7 Hessian bits plus the
    (d + 1) FP64 delta section.  Jit-compatible; single source of truth for
    both the simulation's sent_bits accounting
    (:func:`repro.core.fednl_pp.make_pp_bits_fn`) and the measured
    ``PP_UPDATE`` payloads (asserted equal in tests/test_comm_pp.py)."""
    return message_bits(comp, sent_elems) + (d + 1) * FP_BITS


def pp_frame_bits(comp: Compressor, sent_elems, d: int):
    """Wire bits of one full framed PP_UPDATE (header + byte-padded Hessian
    payload + dl/dg section) — the ``accounting='wire'`` model for FedNL-PP."""
    from repro.comm.protocol import HEADER_SIZE

    pb = sent_elems * int(comp.bits_per_elem) + int(comp.header_bits)
    payload_bytes = (pb + 7) // 8
    return 8 * (payload_bytes + HEADER_SIZE + (d + 1) * 8)
