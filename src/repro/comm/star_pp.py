"""Star-topology FedNL-PP: partial participation over the wire (DESIGN.md §5a).

Algorithm 3 of FedNL as a real master/client protocol: the server stores only
the invariants ``H^k (packed), l^k, g^k`` and recovers the model as
``x^{k+1} = (H^k + l^k I)^{-1} g^k``; each round it samples tau clients
u.a.r., sends each a ``SELECT`` frame (slot in the sample, tau, the iterate),
and the sampled clients — *only* them; nobody else receives or computes
anything — uplink the Algorithm-3 triple ``encode(S_i) || dl_i || dg_i``
through the Section-7 codecs (``PP_UPDATE``).  The master maintains

    H += (alpha/n) * sum_i S_i,   l += sum_i dl_i / n,   g += sum_i dg_i / n

which keeps ``l^k = mean_i l_i^k`` and ``g^k = mean_i g_i^k`` exact because
non-participants contribute zero delta.

Seed alignment (the property tested against ``make_fednl_pp_round``): the
single-node simulation draws ``key, k_sel, k_comp = split(state.key, 3)``
per round, samples with ``k_sel`` and fans compression keys out as
``split(k_comp, tau)[slot]``.  The master owns that exact chain; each client
replays the ``key -> split(key, 3)[0]`` spine lazily up to the round index in
the SELECT header and derives ``split(k_comp, tau)[slot]`` from the slot the
master assigned — no key material travels, and a fault-free tau = n run
reproduces the simulation trajectory bit-for-bit (tests/test_comm_pp.py).

Fault model (FedML-style, arXiv:2007.13518): a ``transport.FaultSpec`` makes
clients drop a SELECT (explicit ``DROP`` NACK — the synchronous stand-in for
a detection timeout) or stall before replying.  Two master fallbacks, both
per Algorithm 3's replaceable-client semantics:

    on_dropout="partial"   proceed with the survivors' partial sum (the /n
                           normalization never changes, so the invariants
                           stay exact means);
    on_dropout="resample"  draw replacement clients from the not-yet-selected
                           pool; a replacement inherits the dropped client's
                           slot, hence its compression key.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import protocol, wire
from repro.obs import core as _obs
from repro.comm.protocol import Frame, MsgType, recv_frame, send_frame
from repro.comm.transport import (
    Connection,
    FaultInjector,
    FaultSpec,
    loopback_pair,
)
from repro.compressors import get_compressor
from repro.core.fednl import FedNLConfig, _client_oracles
from repro.linalg import (
    cholesky_solve,
    frob_norm_from_packed,
    triu_size,
    unpack_triu,
)


@dataclasses.dataclass
class StarPPRunResult:
    """Per-round trajectory + measured wire accounting of a PP star run."""

    x: np.ndarray  # final model (from the post-run invariants)
    x_hist: np.ndarray  # (rounds, d): the model produced each round
    l_hist: np.ndarray  # (rounds,): server l^k before each update
    rounds: int
    participants: list[list[int]]  # client ids that contributed, per round
    dropped: list[list[int]]  # client ids that dropped, per round
    sent_bits: np.ndarray  # per-round analytic pp_message_bits total
    measured_payload_bits: np.ndarray  # per-round bits counted on the wire
    measured_frame_bytes: np.ndarray  # per-round framed PP_UPDATE bytes
    wall_time_s: float


class StarPPClient:
    """One PP client worker: owns a shard and its local (H_i, l_i, g_i).

    State changes *only* on SELECT — an unselected client's round costs it
    nothing, matching a real cross-device deployment.
    """

    def __init__(
        self,
        client_id: int,
        n_clients: int,
        z_i: jax.Array,
        cfg: FedNLConfig,
        conn: Connection,
        seed: int = 0,
        fault: FaultSpec | None = None,
    ):
        self.client_id = client_id
        self.n_clients = n_clients
        self.z_i = jnp.asarray(z_i)
        self.cfg = cfg
        self.conn = conn
        self.d = int(self.z_i.shape[-1])
        self.t = triu_size(self.d)
        self.comp = get_compressor(cfg.compressor, self.t, cfg.k_for(self.d))
        self.codec = wire.make_codec(self.comp, self.t)
        self.alpha = self.comp.alpha if cfg.alpha is None else cfg.alpha
        self.eye = jnp.eye(self.d, dtype=self.z_i.dtype)
        self.fault = FaultInjector(fault, client_id) if fault and fault.active else None
        # lazy replay of the master's per-round PRNG spine
        self._key = jax.random.PRNGKey(seed)
        self._round = 0
        self.h = jnp.zeros(self.t, dtype=self.z_i.dtype)
        self.l = jnp.float64(0.0)
        self.g = jnp.zeros(self.d, dtype=self.z_i.dtype)
        # Bit-exactness vs make_fednl_pp_round requires matching the
        # simulation's execution regime op-for-op, not just its math
        # (tests/test_comm_pp.py asserts the trajectory equal to the last
        # bit).  Three rules, each worth 1 ulp if broken:
        #   * the ROUND body runs as a jitted vmap-of-1 — XLA's batched
        #     kernels accumulate differently from single-sample forms but
        #     are invariant to batch size, so vmap-of-1 rows == the
        #     simulation's vmap-of-tau rows;
        #   * the INIT body runs as plain eager single-client ops —
        #     fednl_pp_init's vmap is eager (op-by-op), and an eager batched
        #     matmul executes each row exactly like the unbatched call;
        #   * z rides as a jit ARGUMENT, not a closure — a closed-over batch
        #     is a foldable constant XLA lays out differently, while the
        #     simulation's closed-over z reaches clients through a traced
        #     gather and so stays runtime data.
        self._z_b = self.z_i[None]
        d, alpha, eye = self.d, self.alpha, self.eye

        def oracle_one(zi, x):
            return _client_oracles(zi, x, cfg.lam, cfg.hessian_impl)

        self._oracles_b = jax.jit(
            lambda z_b, x: jax.vmap(lambda zi: oracle_one(zi, x))(z_b)
        )

        def init_one(zi, x):
            # fednl_pp_init.init_client, verbatim
            _, grad_i, hess_packed = oracle_one(zi, x)
            if cfg.hess0 == "exact":
                h_i = hess_packed
            elif cfg.hess0 == "zero":
                h_i = jnp.zeros_like(hess_packed)
            else:
                raise ValueError(f"unknown hess0 {cfg.hess0!r}")
            l_i = frob_norm_from_packed(h_i - hess_packed, d)
            g_i = (unpack_triu(h_i, d) + l_i * eye) @ x - grad_i
            return h_i, l_i, g_i

        # fednl_pp_init applies its vmap EAGERLY (op-by-op dispatch); an
        # eager batched matmul executes each batch row exactly like the
        # unbatched call, so plain single-client eager ops reproduce the
        # simulation's init rows bitwise (a jitted or vmap-of-1 form does
        # not: XLA fuses/squeezes those differently and drifts by 1 ulp).
        self._init_b = lambda x: init_one(self.z_i, x)

        def tail_one(h_i, s_i, d_i, grad_i, x):
            # make_fednl_pp_round.participate lines after compression
            h_new = h_i + alpha * s_i
            l_new = frob_norm_from_packed(h_new - d_i, d)
            g_new = (unpack_triu(h_new, d) + l_new * eye) @ x - grad_i
            return h_new, l_new, g_new

        self._tail_b = jax.jit(
            lambda h_b, s_b, d_b, g_b, x: jax.vmap(
                lambda h_i, s_i, d_i, grad_i: tail_one(h_i, s_i, d_i, grad_i, x)
            )(h_b, s_b, d_b, g_b)
        )

    def _comp_key(self, rnd: int, slot: int, tau: int) -> jax.Array:
        """split(k_comp^rnd, tau)[slot] — identical to the simulation's
        per-round fan-out, reached by replaying the key spine to `rnd`."""
        while self._round < rnd:
            self._key = jax.random.split(self._key, 3)[0]
            self._round += 1
        _, _, k_comp = jax.random.split(self._key, 3)
        return jax.random.split(k_comp, tau)[slot]

    def _handle_init(self, frame: Frame) -> None:
        """fednl_pp_init's client body: H_i^0 per hess0 policy, l_i^0, g_i^0."""
        x0 = protocol.unpack_vector(frame.payload)
        self.h, self.l, self.g = self._init_b(x0)
        send_frame(
            self.conn,
            Frame(
                type=MsgType.INIT_ACK,
                client=self.client_id,
                payload=protocol.pack_pp_state(self.h, self.l, self.g),
            ),
        )

    def _handle_select(self, frame: Frame) -> None:
        """Algorithm 3 lines 9-13 for one sampled client, or a fault."""
        if self.fault is not None:
            if self.fault.should_drop():
                send_frame(
                    self.conn,
                    Frame(
                        type=MsgType.DROP,
                        round=frame.round,
                        client=self.client_id,
                    ),
                )
                return
            self.fault.maybe_stall()
        slot, tau, x = protocol.unpack_select(frame.payload)
        key_i = self._comp_key(frame.round, slot, tau)
        _, grad_b, d_b = self._oracles_b(self._z_b, x)
        enc = self.codec.encode(key_i, d_b[0] - self.h)
        # decode our own message so local H_i uses exactly the dense
        # correction the master reconstructs (state stays in sync)
        s_i = self.codec.decode(enc.data, enc.sent_elems)
        h_b, l_b, g_b = self._tail_b(
            self.h[None], s_i[None], d_b, grad_b, x
        )
        h_new, l_new, g_new = h_b[0], l_b[0], g_b[0]
        dl = l_new - self.l
        dg = g_new - self.g
        self.h, self.l, self.g = h_new, l_new, g_new
        send_frame(
            self.conn,
            Frame(
                type=MsgType.PP_UPDATE,
                round=frame.round,
                client=self.client_id,
                comp_id=self.codec.comp_id,
                sent_elems=enc.sent_elems,
                payload_bits=enc.bits + (self.d + 1) * wire.FP_BITS,
                payload=protocol.pack_pp_update(enc, dl, dg),
            ),
        )

    def serve_once(self) -> bool:
        """Process one master frame; returns False on STOP."""
        frame = recv_frame(self.conn)
        if frame.type == MsgType.STOP:
            return False
        if frame.type == MsgType.INIT:
            self._handle_init(frame)
        elif frame.type == MsgType.SELECT:
            self._handle_select(frame)
        else:
            raise ValueError(f"PP client got unexpected frame {frame.type}")
        return True

    def run(self) -> None:
        """Blocking serve loop (TCP client processes)."""
        try:
            while self.serve_once():
                pass
        finally:
            self.conn.close()


class StarPPMaster:
    """The PP hub: owns the invariants, samples, collects, aggregates."""

    def __init__(
        self,
        conns: dict[int, Connection],
        d: int,
        cfg: FedNLConfig,
        tau: int,
        seed: int = 0,
        x0: jax.Array | None = None,
        on_dropout: str = "partial",
        drive=None,
    ):
        if on_dropout not in ("partial", "resample"):
            raise ValueError(f"unknown on_dropout {on_dropout!r}")
        if not 0 < tau <= len(conns):
            raise ValueError(f"need 0 < tau <= n, got tau={tau}, n={len(conns)}")
        self.conns = conns
        self.order = sorted(conns)
        self.n_clients = len(conns)
        self.d = d
        self.t = triu_size(d)
        self.cfg = cfg
        self.tau = tau
        self.on_dropout = on_dropout
        self.drive = drive
        self.comp = get_compressor(cfg.compressor, self.t, cfg.k_for(d))
        self.codec = wire.make_codec(self.comp, self.t)
        self.alpha = self.comp.alpha if cfg.alpha is None else cfg.alpha
        self.eye = jnp.eye(d, dtype=jnp.float64)
        self.key = jax.random.PRNGKey(seed)
        self.x0 = jnp.zeros(d, dtype=jnp.float64) if x0 is None else jnp.asarray(x0)
        self.h_global = None
        self.l_global = None
        self.g_global = None

    def _drive(self) -> None:
        if self.drive is not None:
            self.drive()

    def _init_handshake(self) -> None:
        """INIT broadcast; every client reports (H_i^0, l_i^0, g_i^0)."""
        for cid in self.order:
            send_frame(
                self.conns[cid],
                Frame(type=MsgType.INIT, payload=protocol.pack_vector(self.x0)),
            )
        self._drive()
        h_list, l_list, g_list = [], [], []
        for cid in self.order:
            frame = recv_frame(self.conns[cid])
            if frame.type != MsgType.INIT_ACK or frame.client != cid:
                raise ValueError(
                    f"master expected INIT_ACK from {cid}, got "
                    f"{frame.type} from {frame.client}"
                )
            h_i, l_i, g_i = protocol.unpack_pp_state(frame.payload, self.d)
            h_list.append(h_i)
            l_list.append(l_i)
            g_list.append(g_i)
        # identical jnp aggregation ops to fednl_pp_init
        self.h_global = jnp.mean(jnp.stack(h_list), axis=0)
        self.l_global = jnp.mean(jnp.stack(l_list))
        self.g_global = jnp.mean(jnp.stack(g_list), axis=0)

    def _solve_x(self) -> jax.Array:
        """x = (H + l I)^{-1} g — Algorithm 3 line 4, same ops as the sim."""
        h = unpack_triu(self.h_global, self.d)
        return cholesky_solve(h + self.l_global * self.eye, self.g_global)

    def _select(self, cid: int, rnd: int, slot: int, x: jax.Array) -> None:
        send_frame(
            self.conns[cid],
            Frame(
                type=MsgType.SELECT,
                round=rnd,
                client=cid,
                payload=protocol.pack_select(slot, self.tau, x),
            ),
        )

    def _sample_round(self, r: int, x) -> tuple[list[int], jax.Array]:
        """Advance the PRNG spine one round and SELECT the sampled cohort —
        identical split chain to ``make_fednl_pp_round``."""
        key, k_sel, _k_comp = jax.random.split(self.key, 3)
        self.key = key
        idx = [
            int(i)
            for i in np.asarray(
                jax.random.choice(
                    k_sel, self.n_clients, shape=(self.tau,), replace=False
                )
            )
        ]
        for slot, cid in enumerate(idx):
            self._select(cid, r, slot, x)
        self._drive()
        return idx, k_sel

    def _collect_round(self, r: int, x, idx: list[int], k_sel, decode: bool):
        """Collect one round's PP_UPDATE/DROP responses slot by slot,
        resampling replacements per ``on_dropout``.  With ``decode=False``
        (checkpoint replay) uplinks are consumed but not decoded — the frame
        traffic drives the clients; the master state comes from elsewhere."""
        pool = [c for c in self.order if c not in set(idx)]
        attempt = 0
        s_list, dl_list, dg_list = [], [], []
        participants, dropped = [], []
        round_abits = round_mbits = round_fbytes = 0
        for slot, cid in enumerate(idx):
            cur = cid
            while True:
                fr = recv_frame(self.conns[cur])
                if fr.type == MsgType.PP_UPDATE:
                    if decode:
                        hess_bytes, dl, dg = protocol.unpack_pp_update(
                            fr.payload, self.d
                        )
                        s_list.append(self.codec.decode(hess_bytes, fr.sent_elems))
                        dl_list.append(dl)
                        dg_list.append(dg)
                    participants.append(cur)
                    round_abits += int(
                        wire.pp_message_bits(self.comp, fr.sent_elems, self.d)
                    )
                    round_mbits += fr.payload_bits
                    round_fbytes += fr.wire_bytes
                    break
                if fr.type != MsgType.DROP:
                    raise ValueError(
                        f"master expected PP_UPDATE/DROP, got {fr.type}"
                    )
                dropped.append(cur)
                if self.on_dropout == "resample" and pool:
                    # replacement inherits the slot (and its comp key)
                    rk = jax.random.fold_in(k_sel, 1 + attempt)
                    attempt += 1
                    j = int(jax.random.randint(rk, (), 0, len(pool)))
                    cur = pool.pop(j)
                    self._select(cur, r, slot, x)
                    self._drive()
                    continue
                break  # partial: this slot contributes nothing
        return (s_list, dl_list, dg_list, participants, dropped,
                round_abits, round_mbits, round_fbytes)

    def step_round(self, r: int) -> dict:
        """One Algorithm-3 round: solve x from the invariants, sample tau
        clients, collect their deltas (dropout fallbacks included), update
        the invariants.  Returns the round's record data."""
        with _obs.CURRENT.span(
            "comm.round", master=type(self).__name__
        ) as sp:
            m = self._step_round_inner(r)
            sp.set(
                round=r,
                participants=m["participants"],
                dropped=m["dropped"],
                wire_bytes=m["measured_frame_bytes"],
                payload_bits=m["measured_payload_bits"],
            )
            return m

    def _step_round_inner(self, r: int) -> dict:
        n = self.n_clients
        x = self._solve_x()
        l_pre = float(jnp.asarray(self.l_global))
        idx, k_sel = self._sample_round(r, x)
        (s_list, dl_list, dg_list, participants, dropped,
         round_abits, round_mbits, round_fbytes) = self._collect_round(
            r, x, idx, k_sel, decode=True
        )

        # Algorithm 3 lines 18-20 — identical jnp ops to the simulation;
        # the /n normalization is fault-independent (zero-delta absentees)
        if s_list:
            self.h_global = self.h_global + (self.alpha / n) * jnp.sum(
                jnp.stack(s_list), axis=0
            )
            self.l_global = self.l_global + jnp.sum(jnp.stack(dl_list)) / n
            self.g_global = self.g_global + jnp.sum(
                jnp.stack(dg_list), axis=0
            ) / n

        return {
            "x": np.asarray(x),
            "l": l_pre,
            "participants": participants,
            "dropped": dropped,
            "sent_bits": round_abits,
            "measured_payload_bits": round_mbits,
            "measured_frame_bytes": round_fbytes,
        }

    def replay_round(self, r: int, x_rec: np.ndarray) -> None:
        """Resume support: re-drive round ``r`` with the RECORDED iterate so
        freshly spawned clients replay their Algorithm-3 bodies (PRNG spine,
        fault draws, H_i/l_i/g_i evolution) exactly as the original run —
        the uplinks are consumed undecoded and the master invariants stay
        untouched (they are restored from the checkpoint instead)."""
        x = jnp.asarray(x_rec)
        idx, k_sel = self._sample_round(r, x)
        self._collect_round(r, x, idx, k_sel, decode=False)

    def stop(self) -> None:
        """Broadcast STOP so client loops exit cleanly (idempotent)."""
        if getattr(self, "_stopped", False):
            return
        self._stopped = True
        for cid in self.order:
            send_frame(self.conns[cid], Frame(type=MsgType.STOP))
        self._drive()

    def run(self, rounds: int) -> StarPPRunResult:
        self._init_handshake()
        x_hist, l_hist = [], []
        parts_hist, drops_hist = [], []
        bits_analytic, bits_measured, frame_bytes = [], [], []
        t_start = _obs.now()
        for r in range(rounds):
            m = self.step_round(r)
            x_hist.append(m["x"])
            l_hist.append(m["l"])
            parts_hist.append(m["participants"])
            drops_hist.append(m["dropped"])
            bits_analytic.append(m["sent_bits"])
            bits_measured.append(m["measured_payload_bits"])
            frame_bytes.append(m["measured_frame_bytes"])

        self.stop()
        wall = _obs.now() - t_start
        return StarPPRunResult(
            x=np.asarray(self._solve_x()),
            x_hist=np.asarray(x_hist),
            l_hist=np.asarray(l_hist),
            rounds=rounds,
            participants=parts_hist,
            dropped=drops_hist,
            sent_bits=np.asarray(bits_analytic, dtype=np.int64),
            measured_payload_bits=np.asarray(bits_measured, dtype=np.int64),
            measured_frame_bytes=np.asarray(frame_bytes, dtype=np.int64),
            wall_time_s=wall,
        )


def make_pp_loopback_clients(
    z: jax.Array,
    cfg: FedNLConfig,
    seed: int = 0,
    fault: FaultSpec | None = None,
) -> tuple[dict[int, Connection], Callable[[], None]]:
    """In-process PP client fleet: master-side conns + the on-demand ``drive``
    hook (only SELECTed clients have pending frames in a PP round).  Shared
    by ``run_pp_loopback`` and the star-loopback session backend."""
    n_clients = z.shape[0]
    master_conns: dict[int, Connection] = {}
    clients: list[StarPPClient] = []
    for i in range(n_clients):
        a, b = loopback_pair()
        master_conns[i] = a
        clients.append(
            StarPPClient(i, n_clients, z[i], cfg, b, seed=seed, fault=fault)
        )

    def drive() -> None:
        for c in clients:
            while c.conn.pending():
                if not c.serve_once():
                    break

    return master_conns, drive


def run_pp_loopback(
    z: jax.Array,
    cfg: FedNLConfig,
    tau: int,
    rounds: int = 100,
    seed: int = 0,
    on_dropout: str = "partial",
    fault: FaultSpec | None = None,
) -> StarPPRunResult:
    """Full FedNL-PP protocol run over in-process loopback transport.

    Every message crosses encode -> frame -> decode; only sockets are
    replaced by synchronous buffers.
    """
    d = z.shape[-1]
    master_conns, drive = make_pp_loopback_clients(z, cfg, seed=seed, fault=fault)
    master = StarPPMaster(
        master_conns,
        d,
        cfg,
        tau,
        seed=seed,
        on_dropout=on_dropout,
        drive=drive,
    )
    return master.run(rounds)
