"""repro.comm — real wire formats + star-topology transport for FedNL.

Layering (DESIGN.md §§3-6):

    wire.py       Section-7 byte codecs, exact-bit parity with message_bits;
                  PP payload bit models (pp_message_bits / pp_frame_bits)
    protocol.py   frame header + uplink payload layouts (full + PP)
    transport.py  Connection interface: in-process loopback and TCP sockets;
                  FaultSpec dropout/straggler injection
    star.py       full-participation master loop + client workers
    star_pp.py    partial-participation (FedNL-PP) StarPPMaster/StarPPClient
                  (run_pp_loopback here; TCP entry in repro.launch.multiproc)
    topology.py   hierarchical layer above the star: tree-of-stars
                  AggregatorNodes (AGG/SUBTREE frames), bounded-staleness
                  async aggregation, elastic join/leave membership; masters
                  are built through its make_master/open_loopback_master
                  seams (migration rule 6)
    cost.py       bandwidth/latency cost model for the star exchange

``star``/``star_pp`` and ``transport`` are imported lazily as submodules
(``from repro.comm.star import run_loopback``) — keeping this package
importable from ``repro.core`` without a cycle.
"""

from repro.comm.cost import CommCostModel, DEFAULT_COST
from repro.comm.wire import (
    COMPRESSOR_IDS,
    EncodedMessage,
    WireCodec,
    frame_bits,
    make_codec,
    payload_bits,
    pp_frame_bits,
    pp_message_bits,
)

__all__ = [
    "CommCostModel",
    "DEFAULT_COST",
    "COMPRESSOR_IDS",
    "EncodedMessage",
    "WireCodec",
    "frame_bits",
    "make_codec",
    "payload_bits",
    "pp_frame_bits",
    "pp_message_bits",
]
