"""repro.comm — real wire formats + star-topology transport for FedNL.

Layering (DESIGN.md §§3-6):

    wire.py       Section-7 byte codecs, exact-bit parity with message_bits
    protocol.py   frame header + uplink payload layout
    transport.py  Connection interface: in-process loopback and TCP sockets
    star.py       master event loop + client workers (run_loopback here;
                  multi-process TCP entry point in repro.launch.multiproc)
    cost.py       bandwidth/latency cost model for the star exchange

``star`` and ``transport`` are imported lazily as submodules (``from
repro.comm.star import run_loopback``) — keeping this package importable from
``repro.core`` without a cycle.
"""

from repro.comm.cost import CommCostModel, DEFAULT_COST
from repro.comm.wire import (
    COMPRESSOR_IDS,
    EncodedMessage,
    WireCodec,
    frame_bits,
    make_codec,
    payload_bits,
)

__all__ = [
    "CommCostModel",
    "DEFAULT_COST",
    "COMPRESSOR_IDS",
    "EncodedMessage",
    "WireCodec",
    "frame_bits",
    "make_codec",
    "payload_bits",
]
