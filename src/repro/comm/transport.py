"""Transports for the FedNL star topology (DESIGN.md §5).

Two implementations behind one byte-stream ``Connection`` interface:

  * loopback — in-process buffered pipes.  The master and its clients run in
    one thread with a synchronous schedule (broadcast, drive clients, read
    replies), so every byte still crosses the full encode -> frame -> decode
    path; this is the deterministic test double for the TCP transport.

  * TCP — real sockets over localhost or a LAN.  ``TCPMaster`` binds, accepts
    ``n_clients`` connections, and identifies each peer by its HELLO frame;
    ``connect_to_master`` retries while the master socket comes up (client
    processes race the master's bind in ``launch/multiproc.py``).

TCP_NODELAY is set on every socket: FedNL rounds are latency-bound
request/response exchanges of small frames — exactly the Nagle pathology.
"""

from __future__ import annotations

import dataclasses
import socket
import time

import numpy as np

from repro.comm import protocol
from repro.obs import core as _obs


class Connection:
    """A reliable, ordered byte stream."""

    def send(self, data: bytes) -> None:
        raise NotImplementedError

    def recv_exact(self, n: int) -> bytes:
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - trivial
        pass


# ---------------------------------------------------------------------------
# fault injection (FedNL-PP dropout / straggler model)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-client fault model for partial-participation runs.

    ``drop_prob``: probability a SELECTed client drops the round (it NACKs
    with a DROP frame — the synchronous stand-in for a detection timeout).
    ``straggler_prob`` / ``straggler_delay_s``: probability and duration of a
    pre-reply stall (visible as wall-clock over TCP).  Draws come from a
    client-scoped deterministic PRG so multi-process runs are reproducible.
    """

    drop_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_delay_s: float = 0.0
    seed: int = 0

    @property
    def active(self) -> bool:
        return self.drop_prob > 0.0 or self.straggler_prob > 0.0


class FaultInjector:
    """Deterministic per-client fault source (one per StarPPClient)."""

    def __init__(self, spec: FaultSpec, client_id: int):
        self.spec = spec
        self._rng = np.random.default_rng((spec.seed, client_id))

    def should_drop(self) -> bool:
        return bool(self._rng.random() < self.spec.drop_prob)

    def maybe_stall(self) -> float:
        """Sleep the configured straggler delay; returns seconds stalled."""
        if self._rng.random() < self.spec.straggler_prob:
            time.sleep(self.spec.straggler_delay_s)
            return self.spec.straggler_delay_s
        return 0.0


# ---------------------------------------------------------------------------
# loopback
# ---------------------------------------------------------------------------

class LoopbackConnection(Connection):
    def __init__(self):
        self._peer: LoopbackConnection | None = None
        self._buf = bytearray()
        self.bytes_sent = 0

    def send(self, data: bytes) -> None:
        assert self._peer is not None, "unpaired loopback connection"
        self._peer._buf.extend(data)
        self.bytes_sent += len(data)

    def recv_exact(self, n: int) -> bytes:
        if len(self._buf) < n:
            raise RuntimeError(
                f"loopback underrun: want {n} bytes, have {len(self._buf)} "
                "(master/client schedule out of sync)"
            )
        out = bytes(self._buf[:n])
        del self._buf[:n]
        return out

    def pending(self) -> int:
        """Buffered bytes awaiting recv (PP drive loops poll this: only
        SELECTed clients have frames to serve in a partial-participation
        round, so driving everyone unconditionally would underrun)."""
        return len(self._buf)


def loopback_pair() -> tuple[LoopbackConnection, LoopbackConnection]:
    a, b = LoopbackConnection(), LoopbackConnection()
    a._peer, b._peer = b, a
    return a, b


# ---------------------------------------------------------------------------
# TCP
# ---------------------------------------------------------------------------

class SocketConnection(Connection):
    def __init__(self, sock: socket.socket):
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock
        self.bytes_sent = 0

    def send(self, data: bytes) -> None:
        self._sock.sendall(data)
        self.bytes_sent += len(data)

    def recv_exact(self, n: int) -> bytes:
        chunks = []
        got = 0
        while got < n:
            chunk = self._sock.recv(min(n - got, 1 << 20))
            if not chunk:
                raise ConnectionError(f"peer closed after {got}/{n} bytes")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


class TCPMaster:
    """The hub of the star: binds, then accepts and identifies n clients."""

    def __init__(self, n_clients: int, host: str = "127.0.0.1", port: int = 0):
        self.n_clients = n_clients
        self._listener = socket.create_server((host, port), backlog=n_clients)
        self.host, self.port = self._listener.getsockname()[:2]

    def accept_clients(self, timeout: float = 120.0) -> dict[int, SocketConnection]:
        """Accept exactly n_clients connections; map them by HELLO client id."""
        self._listener.settimeout(timeout)
        conns: dict[int, SocketConnection] = {}
        while len(conns) < self.n_clients:
            sock, _addr = self._listener.accept()
            conn = SocketConnection(sock)
            hello = protocol.recv_frame(conn)
            if hello.type != protocol.MsgType.HELLO:
                conn.close()
                raise ConnectionError(f"expected HELLO, got {hello.type}")
            if hello.client in conns:
                conn.close()
                raise ConnectionError(f"duplicate client id {hello.client}")
            conns[hello.client] = conn
        return conns

    def close(self) -> None:
        self._listener.close()


def connect_to_master(
    host: str, port: int, client_id: int, timeout: float = 120.0
) -> SocketConnection:
    """Dial the master, retrying until it is listening; send HELLO."""
    deadline = _obs.monotonic() + timeout
    while True:
        try:
            sock = socket.create_connection((host, port), timeout=timeout)
            break
        except (ConnectionRefusedError, OSError):
            if _obs.monotonic() >= deadline:
                raise
            time.sleep(0.05)
    conn = SocketConnection(sock)
    protocol.send_frame(
        conn, protocol.Frame(type=protocol.MsgType.HELLO, client=client_id)
    )
    return conn
