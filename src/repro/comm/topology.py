"""Topology layer above the star transports (DESIGN.md §13).

The flat star of ``repro.comm.star`` assumes every client dials one master
and every round barriers on all of them.  Real fleets aggregate
hierarchically (edge -> regional -> root) and tolerate stragglers; this
module adds that layer *above* the existing framed protocol, without
touching the client:

  * **Tree-of-stars** (:class:`TopologySpec` kind="tree") — intermediate
    :class:`AggregatorNode` s each own a subtree, run the server invariant
    on partial sums (H_sub += alpha * sum_i S_i), and forward ONE combined
    uplink per subtree (AGG frames).  ``combine="exact"`` (default) carries
    the subtree's per-leaf uplink sections verbatim so the root re-runs the
    flat star's aggregation ops over the reassembled leaf list — the tree
    trajectory replays the star bit for bit, at any depth.
    ``combine="sum"`` carries dense partial sums instead — bandwidth-optimal
    (one T-vector per subtree instead of per client), with documented
    ulp-level drift from FP addition reassociation (the same opt-in contract
    as the sweep engine's ``batch="vmap"``).

  * **Bounded-staleness async aggregation** (mode="async") — the root
    assigns work to idle clients each round and applies updates as they
    arrive under the contract that an update computed against x^r is folded
    into the invariant no later than commit ``r + staleness``; staleness=0
    degenerates to the sync barrier bit for bit.  Arrival delays are a pure
    function of ``(schedule_seed, round, client)``, so a run — and its
    checkpoint/resume replay — is deterministic given the spec alone.

  * **Elastic membership** (:class:`MembershipSpec`) — join/leave as
    first-class spec'd events on the PR-5 replay spine: a joining client
    rebuilds H_i from the spec via a late INIT at the current iterate (its
    T*64-bit ack is counted into that round's uplink accounting exactly), a
    leaving client's contribution is retired by recomputing the invariant
    from the master's per-client mirrors (H_global = mean of the remaining
    H_i, exact — not an approximate subtraction).  Distinct from FedNL-PP:
    PP samples a fixed cohort per round; membership changes the cohort.

Construction goes through :func:`make_master` / :func:`open_loopback_master`
— the only supported seams (scripts/check_api_migration.py rule 6 flags
direct ``StarMaster`` / ``AggregatorNode`` construction outside repro.comm).
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import protocol, wire
from repro.comm.protocol import Frame, MsgType, recv_frame, send_frame
from repro.comm.star import StarClient, StarMaster, UplinkEntry
from repro.obs import core as _obs
from repro.comm.transport import Connection, loopback_pair
from repro.compressors import get_compressor
from repro.compressors.core import message_bits
from repro.core.fednl import FedNLConfig, master_step
from repro.linalg import triu_size

_COMBINE_IDS = {"exact": 0, "sum": 1}


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """How client updates reach the root, declaratively.

    kind="star" is the flat PR-1 topology; kind="tree" inserts aggregators:
    either a balanced tree (``fanout`` children per node, ``depth`` hops from
    root to leaf — depth=2 is root -> aggregators -> clients) or an explicit
    ``edges`` grouping (a tuple of client-id tuples, one per depth-2
    aggregator).  ``combine`` picks the AGG payload: "exact" preserves star
    bit-parity, "sum" trades it for O(fanout) uplink bandwidth at the root.

    mode="async" (star kind only) replaces the round barrier with bounded
    staleness: an update computed against x^r is applied no later than
    commit r + ``staleness``; per-(round, client) arrival delays are drawn
    from ``numpy.default_rng((schedule_seed, round, client))`` over
    [0, max_delay], so the schedule is part of the spec, not the wall clock.
    """

    kind: str = "star"  # "star" | "tree"
    fanout: int = 2  # balanced tree: children per internal node
    depth: int = 2  # hops root -> leaf (2 = one aggregator layer)
    edges: tuple[tuple[int, ...], ...] | None = None  # explicit depth-2 groups
    combine: str = "exact"  # "exact" (bit-parity) | "sum" (partial sums)
    mode: str = "sync"  # "sync" | "async" (bounded staleness; star only)
    staleness: int = 0  # async: max commits an in-flight update may lag
    max_delay: int = 0  # async: schedule draws delays from [0, max_delay]
    schedule_seed: int = 0  # async: arrival-schedule PRNG seed

    def __post_init__(self):
        if self.kind not in ("star", "tree"):
            raise ValueError(f"unknown topology kind {self.kind!r}")
        if self.combine not in _COMBINE_IDS:
            raise ValueError(
                f"unknown combine {self.combine!r}; use 'exact' | 'sum'"
            )
        if self.mode not in ("sync", "async"):
            raise ValueError(f"unknown topology mode {self.mode!r}")
        if self.kind == "tree":
            if self.mode == "async":
                raise ValueError(
                    "async aggregation composes with the star kind only "
                    "(an async tree would need per-subtree staleness "
                    "contracts; spec one layer at a time)"
                )
            if self.edges is None and (self.fanout < 2 or self.depth < 2):
                raise ValueError(
                    f"a balanced tree needs fanout >= 2 and depth >= 2, got "
                    f"fanout={self.fanout}, depth={self.depth}"
                )
        if self.staleness < 0 or self.max_delay < 0:
            raise ValueError("staleness and max_delay must be >= 0")
        if self.mode == "sync" and self.staleness > 0:
            raise ValueError("staleness > 0 requires mode='async'")

    @property
    def trivial(self) -> bool:
        """True when this spec describes the plain flat sync star (the
        TopologySpec() default — equivalent to topology=None)."""
        return self.kind == "star" and self.mode == "sync"

    def resolve(self, n_clients: int) -> tuple:
        """The root's children as a tuple of subtrees; each subtree is a
        tuple whose elements are leaf client ids (ints) or nested subtrees.
        Balanced trees split the id range contiguously; explicit ``edges``
        must partition ``range(n_clients)`` exactly."""
        if self.kind != "tree":
            raise ValueError("resolve() applies to tree topologies only")
        if self.edges is not None:
            groups = tuple(tuple(int(i) for i in g) for g in self.edges)
            flat = sorted(i for g in groups for i in g)
            if flat != list(range(n_clients)) or any(not g for g in groups):
                raise ValueError(
                    f"edges must partition client ids 0..{n_clients - 1} "
                    f"into non-empty groups, got {self.edges!r}"
                )
            return groups

        def build(ids: list[int], depth: int) -> tuple:
            if depth <= 1:
                return tuple(ids)
            k = min(self.fanout, len(ids))
            chunks = [list(c) for c in np.array_split(ids, k) if len(c)]
            return tuple(build(c, depth - 1) for c in chunks)

        if n_clients < self.fanout:
            raise ValueError(
                f"tree fanout {self.fanout} exceeds n_clients={n_clients}"
            )
        return build(list(range(n_clients)), self.depth)


def subtree_leaves(subtree) -> list[int]:
    """Flatten a resolve() subtree into its sorted leaf client ids."""
    out: list[int] = []
    for node in subtree:
        if isinstance(node, (tuple, list)):
            out.extend(subtree_leaves(node))
        else:
            out.append(int(node))
    return sorted(out)


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One elastic-membership event, applied at the START of ``round``."""

    round: int
    action: str  # "join" | "leave"
    client: int

    def __post_init__(self):
        if self.action not in ("join", "leave"):
            raise ValueError(f"unknown membership action {self.action!r}")
        if self.round < 0 or self.client < 0:
            raise ValueError("membership round and client must be >= 0")


@dataclasses.dataclass(frozen=True)
class MembershipSpec:
    """A declarative join/leave schedule.  Clients with a ``join`` event sit
    out (connected, idle) until their round; ``leave`` retires a client's
    contribution from the invariant exactly.  Events are part of the spec,
    so a restored session replays the identical cohort history."""

    events: tuple[MembershipEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    @property
    def trivial(self) -> bool:
        return not self.events

    def initial_active(self, n_clients: int) -> list[int]:
        """Clients active from round 0: everyone without a join event."""
        joiners = {e.client for e in self.events if e.action == "join"}
        bad = [e.client for e in self.events if e.client >= n_clients]
        if bad:
            raise ValueError(
                f"membership events name clients {sorted(set(bad))} outside "
                f"0..{n_clients - 1}"
            )
        active = [i for i in range(n_clients) if i not in joiners]
        if not active:
            raise ValueError("membership schedule leaves round 0 empty")
        return active

    def events_at(self, r: int) -> list[MembershipEvent]:
        return [e for e in self.events if e.round == r]


# ---------------------------------------------------------------------------
# AggregatorNode: one subtree's hub
# ---------------------------------------------------------------------------

class AggregatorNode:
    """An intermediate hub: serves its parent like a client, drives its
    children like a master.

    Per round it fans the broadcast down, collects one frame per child
    (UPLINK from leaves, AGG from sub-aggregators), maintains the server
    invariant on its partial sums (h_sub += alpha * sum_i S_i — the FedNL
    master recursion restricted to the subtree), and uplinks one AGG frame.
    In combine="exact" that frame carries the leaf sections verbatim; in
    combine="sum" it carries the decoded dense sums.

    ``agg_children`` names which child connections are sub-aggregators
    (needed to route the SUBTREE coverage handshake; leaves never see
    SUBTREE frames).  ``drive`` is the loopback hook that lets in-process
    children consume fanned-down frames.
    """

    def __init__(
        self,
        node_id: int,
        parent: Connection,
        children: dict[int, Connection],
        d: int,
        cfg: FedNLConfig,
        combine: str = "exact",
        agg_children: frozenset[int] | set[int] = frozenset(),
        drive: Callable[[], None] | None = None,
    ):
        self.node_id = node_id
        self.parent = parent
        self.children = children
        self.corder = sorted(children)
        self.d = d
        self.cfg = cfg
        self.combine = combine
        self.agg_children = frozenset(agg_children)
        self.drive = drive
        t = triu_size(d)
        self.t = t
        self.comp = get_compressor(cfg.compressor, t, cfg.k_for(d))
        self.codec = wire.make_codec(self.comp, t)
        self.alpha = self.comp.alpha if cfg.alpha is None else cfg.alpha
        self.h_sub = None  # sum of subtree H_i (invariant on partial sums)
        self.leaf_count = 0

    def _fan_down(self, frame: Frame) -> None:
        for c in self.corder:
            send_frame(self.children[c], frame)
        if self.drive is not None:
            self.drive()

    def _collect_entries(self, leaf_type: MsgType) -> list[tuple]:
        """One frame per child -> flat leaf entry list in client-id order
        (sub-aggregator AGG entry lists concatenate in)."""
        entries: list[tuple] = []
        for c in self.corder:
            fr = recv_frame(self.children[c])
            if fr.type == MsgType.AGG:
                entries.extend(protocol.unpack_agg_entries(fr.payload))
            elif fr.type == leaf_type:
                entries.append(
                    (fr.client, fr.sent_elems, fr.payload_bits,
                     fr.wire_bytes, fr.payload)
                )
            else:
                raise ValueError(
                    f"aggregator {self.node_id} expected {leaf_type} | AGG "
                    f"from child {c}, got {fr.type}"
                )
        entries.sort(key=lambda e: e[0])
        return entries

    def _reply(self, frame_round: int, payload: bytes) -> None:
        send_frame(
            self.parent,
            Frame(
                type=MsgType.AGG,
                round=frame_round,
                client=self.node_id,
                payload=payload,
            ),
        )

    def _handle_subtree(self, frame: Frame) -> None:
        combine_id, expected = protocol.unpack_subtree(frame.payload)
        if combine_id != _COMBINE_IDS[self.combine]:
            raise ValueError(
                f"aggregator {self.node_id} wired combine={self.combine!r} "
                f"but the master announced combine id {combine_id}"
            )
        owned: list[int] = []
        for c in self.corder:
            if c in self.agg_children:
                send_frame(
                    self.children[c],
                    Frame(type=MsgType.SUBTREE,
                          payload=protocol.pack_subtree(combine_id, ())),
                )
            else:
                owned.append(c)  # leaf conns are keyed by client id
        if self.drive is not None:
            self.drive()
        for c in self.corder:
            if c in self.agg_children:
                ack = recv_frame(self.children[c])
                if ack.type != MsgType.SUBTREE:
                    raise ValueError(
                        f"aggregator {self.node_id} expected SUBTREE ack "
                        f"from child {c}, got {ack.type}"
                    )
                _, sub_owned = protocol.unpack_subtree(ack.payload)
                owned.extend(sub_owned)
        owned = sorted(owned)
        if expected and list(expected) != owned:
            raise ValueError(
                f"subtree {self.node_id} owns leaves {owned} but the master "
                f"expected {sorted(expected)} — mis-wired process tree"
            )
        self.leaf_count = len(owned)
        send_frame(
            self.parent,
            Frame(
                type=MsgType.SUBTREE,
                client=self.node_id,
                payload=protocol.pack_subtree(combine_id, owned),
            ),
        )

    def _handle_init(self, frame: Frame) -> None:
        self._fan_down(frame)
        if self.combine == "exact":
            entries = self._collect_entries(MsgType.INIT_ACK)
            h_list = [protocol.unpack_vector(e[4]) for e in entries]
            self.h_sub = jnp.sum(jnp.stack(h_list), axis=0)
            self._reply(frame.round, protocol.pack_agg_entries(entries))
            return
        # combine="sum": fold leaf vectors / sub-agg hsums into one dense sum
        count = 0
        h_list = []
        for c in self.corder:
            fr = recv_frame(self.children[c])
            if fr.type == MsgType.AGG:
                sub_count, sub_h = protocol.unpack_agg_hsum(fr.payload)
                count += sub_count
                h_list.append(sub_h)
            elif fr.type == MsgType.INIT_ACK:
                count += 1
                h_list.append(protocol.unpack_vector(fr.payload))
            else:
                raise ValueError(
                    f"aggregator {self.node_id} expected INIT_ACK | AGG, "
                    f"got {fr.type}"
                )
        self.h_sub = jnp.sum(jnp.stack(h_list), axis=0)
        self._reply(frame.round, protocol.pack_agg_hsum(count, self.h_sub))

    def _handle_round(self, frame: Frame) -> None:
        # per-hop latency span: fan-down + child collection + combined reply
        # (host-side timing only; the aggregation ops are untouched)
        with _obs.CURRENT.span(
            "comm.hop",
            node=self.node_id,
            round=frame.round,
            children=len(self.corder),
            combine=self.combine,
        ):
            self._handle_round_inner(frame)

    def _handle_round_inner(self, frame: Frame) -> None:
        self._fan_down(frame)
        if self.combine == "exact":
            entries = self._collect_entries(MsgType.UPLINK)
            s_list = [
                self.codec.decode(
                    protocol.unpack_uplink(e[4], self.d)[3], e[1]
                )
                for e in entries
            ]
            # the subtree's server invariant on partial sums
            self.h_sub = self.h_sub + self.alpha * jnp.sum(
                jnp.stack(s_list), axis=0
            )
            self._reply(frame.round, protocol.pack_agg_entries(entries))
            return
        count = abits = pbits = fbytes = 0
        grad_list, s_list, l_parts, f_parts = [], [], [], []
        for c in self.corder:
            fr = recv_frame(self.children[c])
            if fr.type == MsgType.AGG:
                (sub_n, sub_a, sub_p, sub_f, sub_l, sub_fv, sub_grad, sub_s) = (
                    protocol.unpack_agg_roundsum(fr.payload)
                )
                count += sub_n
                abits += sub_a
                pbits += sub_p
                fbytes += sub_f
                l_parts.append(jnp.float64(sub_l))
                f_parts.append(jnp.float64(sub_fv))
                grad_list.append(sub_grad)
                s_list.append(sub_s)
            elif fr.type == MsgType.UPLINK:
                grad_i, l_i, f_i, hess_bytes = protocol.unpack_uplink(
                    fr.payload, self.d
                )
                count += 1
                abits += int(message_bits(self.comp, fr.sent_elems))
                pbits += fr.payload_bits
                fbytes += fr.wire_bytes
                l_parts.append(l_i)
                f_parts.append(f_i)
                grad_list.append(grad_i)
                s_list.append(self.codec.decode(hess_bytes, fr.sent_elems))
            else:
                raise ValueError(
                    f"aggregator {self.node_id} expected UPLINK | AGG, "
                    f"got {fr.type}"
                )
        grad_sum = jnp.sum(jnp.stack(grad_list), axis=0)
        s_sum = jnp.sum(jnp.stack(s_list), axis=0)
        self.h_sub = self.h_sub + self.alpha * s_sum
        self._reply(
            frame.round,
            protocol.pack_agg_roundsum(
                count, self.d, abits, pbits, fbytes,
                jnp.sum(jnp.stack(l_parts)), jnp.sum(jnp.stack(f_parts)),
                grad_sum, s_sum,
            ),
        )

    def serve_once(self) -> bool:
        """Process one parent frame; returns False on STOP."""
        frame = recv_frame(self.parent)
        if frame.type == MsgType.STOP:
            self._fan_down(frame)
            return False
        if frame.type == MsgType.SUBTREE:
            self._handle_subtree(frame)
        elif frame.type == MsgType.INIT:
            self._handle_init(frame)
        elif frame.type == MsgType.ROUND:
            self._handle_round(frame)
        else:
            raise ValueError(
                f"aggregator {self.node_id} got unexpected frame {frame.type}"
            )
        return True

    def run(self) -> None:
        """Blocking serve loop (TCP aggregator processes)."""
        while self.serve_once():
            pass


def build_aggregator(
    node_id: int,
    parent: Connection,
    children: dict[int, Connection],
    d: int,
    cfg: FedNLConfig,
    combine: str = "exact",
    agg_children: frozenset[int] | set[int] = frozenset(),
    drive: Callable[[], None] | None = None,
) -> AggregatorNode:
    """The construction seam for aggregators living outside repro.comm
    (launch/multiproc spawns them in their own processes; migration rule 6
    keeps ``AggregatorNode(...)`` itself comm-internal)."""
    return AggregatorNode(
        node_id, parent, children, d, cfg,
        combine=combine, agg_children=agg_children, drive=drive,
    )


# ---------------------------------------------------------------------------
# TreeMaster: the root of a tree-of-stars
# ---------------------------------------------------------------------------

class TreeMaster(StarMaster):
    """StarMaster whose connections lead to aggregators instead of clients.

    combine="exact": AGG payloads are reassembled into the flat leaf entry
    list (client-id order) and fed to the inherited aggregation tail — the
    identical jnp ops over the identical operands, so the trajectory AND the
    measured bit accounting reproduce the flat star exactly.
    combine="sum": dense partial sums are folded with one final division by
    n (documented ulp drift; bandwidth-optimal).
    """

    uplink_type = MsgType.AGG

    def __init__(
        self,
        conns: dict[int, Connection],
        d: int,
        cfg: FedNLConfig,
        topology: TopologySpec,
        n_clients: int,
        x0: jax.Array | None = None,
        drive: Callable[[], None] | None = None,
    ):
        super().__init__(conns, d, cfg, x0=x0, drive=drive)
        self.topology = topology
        self.n_clients = n_clients
        self.combine = topology.combine
        shape = topology.resolve(n_clients)
        if len(shape) != len(conns):
            raise ValueError(
                f"topology resolves to {len(shape)} root subtrees but "
                f"{len(conns)} aggregator connections are wired"
            )
        self._expected = {i: subtree_leaves(shape[i]) for i in self.order}

    def _subtree_handshake(self) -> None:
        combine_id = _COMBINE_IDS[self.combine]
        for i in self.order:
            send_frame(
                self.conns[i],
                Frame(
                    type=MsgType.SUBTREE,
                    payload=protocol.pack_subtree(
                        combine_id, self._expected[i]
                    ),
                ),
            )
        if self.drive is not None:
            self.drive()
        covered: list[int] = []
        for i in self.order:
            ack = recv_frame(self.conns[i])
            if ack.type != MsgType.SUBTREE or ack.client != i:
                raise ValueError(
                    f"expected SUBTREE ack from aggregator {i}, got "
                    f"{ack.type} from {ack.client}"
                )
            _, owned = protocol.unpack_subtree(ack.payload)
            covered.extend(owned)
        if sorted(covered) != list(range(self.n_clients)):
            raise ValueError(
                f"subtree acks cover leaves {sorted(covered)}, not the "
                f"client id partition 0..{self.n_clients - 1}"
            )

    def _entries_from_aggs(self, frames: dict[int, Frame]) -> list[UplinkEntry]:
        entries = [
            UplinkEntry(*e)
            for i in self.order
            for e in protocol.unpack_agg_entries(frames[i].payload)
        ]
        entries.sort(key=lambda e: e.client)
        ids = [e.client for e in entries]
        if ids != list(range(self.n_clients)):
            raise ValueError(
                f"AGG entries cover clients {ids}, expected "
                f"0..{self.n_clients - 1}"
            )
        return entries

    def init_handshake(self) -> None:
        self._subtree_handshake()
        self._broadcast(
            Frame(type=MsgType.INIT, payload=protocol.pack_vector(self.x))
        )
        frames = self._collect(MsgType.AGG)
        if self.combine == "exact":
            h_list = []
            for e in self._entries_from_aggs(frames):
                h_i = protocol.unpack_vector(e.payload)
                self._on_init_ack(e.client, h_i)
                h_list.append(h_i)
            # the flat star's init aggregation, op for op
            self.h_global = jnp.mean(jnp.stack(h_list), axis=0)
            return
        count = 0
        h_sums = []
        for i in self.order:
            sub_count, sub_h = protocol.unpack_agg_hsum(frames[i].payload)
            count += sub_count
            h_sums.append(sub_h)
        if count != self.n_clients:
            raise ValueError(
                f"AGG hsums cover {count} leaves, expected {self.n_clients}"
            )
        self.h_global = jnp.sum(jnp.stack(h_sums), axis=0) / self.n_clients

    def _gather_uplinks(self, r: int) -> list[UplinkEntry]:
        return self._entries_from_aggs(self._collect(MsgType.AGG))

    def step_round(self, r: int) -> dict:
        if self.combine == "exact":
            return super().step_round(r)
        self._broadcast(
            Frame(type=MsgType.ROUND, round=r,
                  payload=protocol.pack_vector(self.x))
        )
        self.x_hist.append(np.asarray(self.x))
        frames = self._collect(MsgType.AGG)
        count = abits = pbits = fbytes = 0
        grad_sums, s_sums, l_sums, f_sums = [], [], [], []
        for i in self.order:
            (sub_n, sub_a, sub_p, sub_f, sub_l, sub_fv, sub_grad, sub_s) = (
                protocol.unpack_agg_roundsum(frames[i].payload)
            )
            count += sub_n
            abits += sub_a
            pbits += sub_p
            fbytes += sub_f
            l_sums.append(jnp.float64(sub_l))
            f_sums.append(jnp.float64(sub_fv))
            grad_sums.append(sub_grad)
            s_sums.append(sub_s)
        n = self.n_clients
        if count != n:
            raise ValueError(f"AGG sums cover {count} leaves, expected {n}")
        grad = jnp.sum(jnp.stack(grad_sums), axis=0) / n
        s = jnp.sum(jnp.stack(s_sums), axis=0) / n
        l = jnp.sum(jnp.stack(l_sums)) / n
        f = jnp.sum(jnp.stack(f_sums)) / n
        x_new = master_step(self.x, self.h_global, grad, l, self.cfg)
        self.h_global = self.h_global + self.alpha * s
        self.x = x_new
        return {
            "grad_norm": float(jnp.linalg.norm(grad)),
            "f": float(f),
            "sent_bits": abits,
            "measured_payload_bits": pbits,
            "measured_frame_bytes": fbytes,
        }


# ---------------------------------------------------------------------------
# AsyncStarMaster: bounded-staleness aggregation
# ---------------------------------------------------------------------------

class AsyncStarMaster(StarMaster):
    """Flat star without the barrier: commits fold in whatever arrived.

    Per commit r: every idle client is assigned the current iterate (one
    ROUND frame); an assignment made at round a becomes *visible* at round
    ``a + min(delay(a, i), staleness)`` where the delay is drawn from the
    spec'd arrival schedule (a client's very first assignment is always
    visible immediately — the fleet starts synchronized).  The commit then
    averages the latest known gradients of ALL clients (stale entries
    included) and folds the freshly arrived corrections into H (absent
    clients contribute S_i = 0 — exactly the "master keeps H_i for silent
    clients" reading of the invariant).  At staleness=0 every client is
    fresh every round and the ops degenerate to StarMaster.step_round
    literally.

    Determinism: the schedule is a pure function of (schedule_seed, round,
    client), the master performs all transport ops in (round, client-id)
    order, and clients advance their PRNG spine once per ROUND received —
    so replaying the broadcast history reproduces every table, bit for bit,
    which is what checkpoint/resume rides on.
    """

    def __init__(
        self,
        conns: dict[int, Connection],
        d: int,
        cfg: FedNLConfig,
        topology: TopologySpec,
        x0: jax.Array | None = None,
        drive: Callable[[], None] | None = None,
    ):
        super().__init__(conns, d, cfg, x0=x0, drive=drive)
        self.staleness = topology.staleness
        self.max_delay = topology.max_delay
        self.schedule_seed = topology.schedule_seed
        # in-flight assignments: client -> (assigned round, visible round)
        self._inflight: dict[int, tuple[int, int]] = {}
        # last visible assignment round per client (-1 = never)
        self._last: dict[int, int] = {cid: -1 for cid in self.order}
        self._grad_tab: dict[int, jax.Array] = {}
        self._l_tab: dict[int, jax.Array] = {}
        self._f_tab: dict[int, jax.Array] = {}

    def _delay(self, cid: int, r: int) -> int:
        if self.staleness == 0 or self.max_delay == 0:
            return 0
        rng = np.random.default_rng((self.schedule_seed, r, cid))
        return int(rng.integers(0, self.max_delay + 1))

    def _exec_round(self, r: int, x_bcast: jax.Array, commit: bool):
        # assign idle clients (client-id order; first assignment lands now)
        for cid in self.order:
            if cid not in self._inflight:
                send_frame(
                    self.conns[cid],
                    Frame(type=MsgType.ROUND, round=r,
                          payload=protocol.pack_vector(x_bcast)),
                )
                lag = 0 if self._last[cid] < 0 else min(
                    self._delay(cid, r), self.staleness
                )
                self._inflight[cid] = (r, r + lag)
        if self.drive is not None:
            self.drive()
        self.x_hist.append(np.asarray(x_bcast))

        # deliveries visible at this commit, in client-id order
        arrived = sorted(
            cid for cid, (_, due) in self._inflight.items() if due <= r
        )
        s_new: dict[int, jax.Array] = {}
        pbits = abits = fbytes = 0
        for cid in arrived:
            a, _ = self._inflight.pop(cid)
            fr = recv_frame(self.conns[cid])
            if fr.type != MsgType.UPLINK or fr.client != cid:
                raise ValueError(
                    f"async master expected UPLINK from {cid}, got "
                    f"{fr.type} from {fr.client}"
                )
            grad_i, l_i, f_i, hess_bytes = protocol.unpack_uplink(
                fr.payload, self.d
            )
            s_i = self.codec.decode(hess_bytes, fr.sent_elems)
            self._on_decoded(cid, s_i)
            self._grad_tab[cid] = grad_i
            self._l_tab[cid] = l_i
            self._f_tab[cid] = f_i
            self._last[cid] = a
            s_new[cid] = s_i
            pbits += fr.payload_bits
            abits += int(message_bits(self.comp, fr.sent_elems))
            fbytes += fr.wire_bytes

        if not commit:
            return None
        t = triu_size(self.d)
        zero_s = jnp.zeros(t, dtype=jnp.float64)
        grads = [self._grad_tab[cid] for cid in self.order]
        l_list = [self._l_tab[cid] for cid in self.order]
        f_list = [self._f_tab[cid] for cid in self.order]
        s_full = [s_new.get(cid, zero_s) for cid in self.order]
        # at staleness=0 these are the StarMaster aggregation ops verbatim
        grad = jnp.mean(jnp.stack(grads), axis=0)
        s = jnp.mean(jnp.stack(s_full), axis=0)
        l = jnp.mean(jnp.stack(l_list))
        f = jnp.mean(jnp.stack(f_list))
        x_new = master_step(self.x, self.h_global, grad, l, self.cfg)
        self.h_global = self.h_global + self.alpha * s
        self.x = x_new
        return {
            "grad_norm": float(jnp.linalg.norm(grad)),
            "f": float(f),
            "sent_bits": abits,
            "measured_payload_bits": pbits,
            "measured_frame_bytes": fbytes,
            "participants": tuple(arrived),
        }

    def step_round(self, r: int) -> dict:
        return self._exec_round(r, self.x, commit=True)

    def replay_round(self, r: int, x_bcast: np.ndarray) -> None:
        """Re-execute assignment/delivery bookkeeping under the recorded
        broadcast (tables, in-flight set and the clients' PRNG spines all
        advance exactly as the original run's); the commit math is skipped —
        x and H come from the checkpoint."""
        self._exec_round(r, jnp.asarray(x_bcast), commit=False)


# ---------------------------------------------------------------------------
# ElasticStarMaster: join/leave membership
# ---------------------------------------------------------------------------

class ElasticStarMaster(StarMaster):
    """Flat sync star over a round-varying cohort.

    The master mirrors each active client's H_i (seeded by its INIT_ACK,
    advanced by the same ``+ alpha * S_i`` update the client applies — the
    mirror is bitwise the client's state).  Membership events apply at the
    start of their round: ``leave`` sends the client STOP, drops it from the
    cohort and RECOMPUTES H_global as the mean of the remaining mirrors —
    exact retirement, not an approximate subtraction; ``join`` sends a late
    INIT at the *current* iterate (the client builds H_i there, per
    ``hess0``), folds the mirror in the same way, and counts the T*64-bit
    INIT_ACK into the round's uplink accounting exactly.
    """

    def __init__(
        self,
        conns: dict[int, Connection],
        d: int,
        cfg: FedNLConfig,
        membership: MembershipSpec,
        n_clients: int,
        x0: jax.Array | None = None,
        drive: Callable[[], None] | None = None,
    ):
        super().__init__(conns, d, cfg, x0=x0, drive=drive)
        if sorted(conns) != list(range(n_clients)):
            raise ValueError(
                "elastic membership needs a connection per client id "
                f"0..{n_clients - 1} (idle joiners stay connected), got "
                f"{sorted(conns)}"
            )
        self.membership = membership
        self.n_clients = n_clients
        self._mirrors: dict[int, jax.Array] = {}
        self._left: set[int] = set()
        # base broadcast/collect/aggregate iterate self.order — point it at
        # the active cohort and membership events mutate it in place
        self.order = membership.initial_active(n_clients)

    def _on_init_ack(self, cid: int, h_i: jax.Array) -> None:
        self._mirrors[cid] = h_i

    def _on_decoded(self, cid: int, s_i: jax.Array) -> None:
        # the client's own H_i update, op for op (star.StarClient._handle_round)
        self._mirrors[cid] = self._mirrors[cid] + self.alpha * s_i

    def _recompute_invariant(self) -> None:
        self.h_global = jnp.mean(
            jnp.stack([self._mirrors[c] for c in self.order]), axis=0
        )

    def _apply_events(self, r: int, x_bcast: jax.Array) -> dict:
        joined, left = [], []
        join_pbits = join_fbytes = 0
        for ev in self.membership.events_at(r):
            if ev.action == "leave":
                if ev.client not in self.order:
                    raise ValueError(
                        f"round {r}: client {ev.client} cannot leave — "
                        "not active"
                    )
                send_frame(self.conns[ev.client], Frame(type=MsgType.STOP))
                if self.drive is not None:
                    self.drive()
                self.order.remove(ev.client)
                self._left.add(ev.client)
                del self._mirrors[ev.client]
                if not self.order:
                    raise ValueError(
                        f"round {r}: membership schedule empties the cohort"
                    )
                self._recompute_invariant()
                left.append(ev.client)
            else:  # join
                if ev.client in self.order or ev.client in self._left:
                    raise ValueError(
                        f"round {r}: client {ev.client} cannot join — "
                        "already active or already departed"
                    )
                send_frame(
                    self.conns[ev.client],
                    Frame(type=MsgType.INIT,
                          payload=protocol.pack_vector(x_bcast)),
                )
                if self.drive is not None:
                    self.drive()
                ack = recv_frame(self.conns[ev.client])
                if ack.type != MsgType.INIT_ACK or ack.client != ev.client:
                    raise ValueError(
                        f"expected INIT_ACK from joining client "
                        f"{ev.client}, got {ack.type} from {ack.client}"
                    )
                h_i = protocol.unpack_vector(ack.payload)
                self._on_init_ack(ev.client, h_i)
                bisect.insort(self.order, ev.client)
                self._recompute_invariant()
                # the joined client's uplink, accounted exactly: T FP64
                # state bits (payload == analytic) + the framed ack bytes
                join_pbits += 8 * len(ack.payload)
                join_fbytes += ack.wire_bytes
                joined.append(ev.client)
        return {
            "joined": joined,
            "left": left,
            "pbits": join_pbits,
            "fbytes": join_fbytes,
        }

    def step_round(self, r: int) -> dict:
        ev = self._apply_events(r, self.x)
        m = super().step_round(r)
        m["sent_bits"] += ev["pbits"]  # T*64 state bits per join, exact
        m["measured_payload_bits"] += ev["pbits"]
        m["measured_frame_bytes"] += ev["fbytes"]
        m["participants"] = tuple(self.order)
        return m

    def replay_round(self, r: int, x_bcast: np.ndarray) -> None:
        """Replay the cohort history AND the mirror updates: events re-apply
        (STOP/late-INIT traffic included), the round's uplinks are decoded
        only to advance the mirrors — x and H come from the checkpoint."""
        x_b = jnp.asarray(x_bcast)
        self._apply_events(r, x_b)
        self._broadcast(
            Frame(type=MsgType.ROUND, round=r,
                  payload=protocol.pack_vector(x_b))
        )
        self.x_hist.append(np.asarray(x_bcast))
        self._decode_entries(self._gather_uplinks(r))

    def stop(self) -> None:
        """STOP every still-connected client — active or never-joined (a
        plain broadcast would strand idle joiners on a blocking recv)."""
        if not self._stopped:
            self._stopped = True
            for cid in sorted(self.conns):
                if cid not in self._left:
                    send_frame(self.conns[cid], Frame(type=MsgType.STOP))
            if self.drive is not None:
                self.drive()


# ---------------------------------------------------------------------------
# construction seams
# ---------------------------------------------------------------------------

def make_master(
    conns: dict[int, Connection],
    d: int,
    cfg: FedNLConfig,
    topology: TopologySpec | None = None,
    membership: MembershipSpec | None = None,
    n_clients: int | None = None,
    x0: jax.Array | None = None,
    drive: Callable[[], None] | None = None,
) -> StarMaster:
    """The one master factory: spec -> StarMaster | TreeMaster |
    AsyncStarMaster | ElasticStarMaster.  ``conns`` lead to clients for star
    kinds and to root aggregators for trees; ``n_clients`` is the leaf count
    (required whenever it differs from ``len(conns)``)."""
    n = len(conns) if n_clients is None else n_clients
    if membership is not None and not membership.trivial:
        if topology is not None and not topology.trivial:
            raise ValueError(
                "membership events compose with the flat sync star only"
            )
        return ElasticStarMaster(
            conns, d, cfg, membership, n_clients=n, x0=x0, drive=drive
        )
    if topology is not None and topology.kind == "tree":
        return TreeMaster(
            conns, d, cfg, topology, n_clients=n, x0=x0, drive=drive
        )
    if topology is not None and topology.mode == "async":
        return AsyncStarMaster(conns, d, cfg, topology, x0=x0, drive=drive)
    return StarMaster(conns, d, cfg, x0=x0, drive=drive)


def _selective_drive(clients: list[StarClient]) -> Callable[[], None]:
    """Drive in-process clients by buffered-frame polling (the star_pp
    discipline): only clients with pending frames are served, so partial
    broadcasts (async assignment, membership events) never deadlock, and a
    full broadcast serves everyone exactly once — same frames, same order,
    bit-identical to the unconditional star drive."""
    done = [False] * len(clients)

    def drive() -> None:
        for i, c in enumerate(clients):
            while not done[i] and c.conn.pending():
                if not c.serve_once():
                    done[i] = True

    return drive


def make_selective_loopback_clients(
    z: jax.Array, cfg: FedNLConfig, seed: int = 0
) -> tuple[dict[int, Connection], Callable[[], None]]:
    """In-process client fleet with the selective (pending-poll) drive —
    the wiring for async/elastic masters, whose broadcasts are partial."""
    n_clients = z.shape[0]
    master_conns: dict[int, Connection] = {}
    clients: list[StarClient] = []
    for i in range(n_clients):
        a, b = loopback_pair()
        master_conns[i] = a
        clients.append(StarClient(i, n_clients, z[i], cfg, b, seed=seed))
    return master_conns, _selective_drive(clients)


def _wire_subtree(
    node_id: int,
    subtree: tuple,
    z: jax.Array,
    cfg: FedNLConfig,
    combine: str,
    seed: int,
) -> tuple[Connection, AggregatorNode]:
    """Recursively build one in-process subtree; returns the parent-side
    connection + the aggregator (its children drive hangs off it)."""
    n_clients, _, d = z.shape
    children: dict[int, Connection] = {}
    agg_children: set[int] = set()
    leaf_clients: list[StarClient] = []
    sub_drives: list[Callable[[], None]] = []
    for pos, node in enumerate(subtree):
        if isinstance(node, (tuple, list)):
            parent_side, sub_agg = _wire_subtree(
                pos, tuple(node), z, cfg, combine, seed
            )
            children[pos] = parent_side
            agg_children.add(pos)
            sub_drives.append(_agg_drive(parent_side, sub_agg))
        else:
            cid = int(node)
            a, b = loopback_pair()
            children[cid] = a
            leaf_clients.append(
                StarClient(cid, n_clients, z[cid], cfg, b, seed=seed)
            )
    leaf_drive = _selective_drive(leaf_clients)

    def drive() -> None:
        leaf_drive()
        for sub in sub_drives:
            sub()

    parent_a, parent_b = loopback_pair()
    node = AggregatorNode(
        node_id, parent_b, children, d, cfg,
        combine=combine, agg_children=agg_children, drive=drive,
    )
    return parent_a, node


def _agg_drive(
    parent_side: Connection, node: AggregatorNode
) -> Callable[[], None]:
    """Serve an in-process aggregator whenever its parent-side buffer holds
    frames (each serve_once consumes exactly one parent frame end-to-end)."""
    done = [False]

    def drive() -> None:
        while not done[0] and node.parent.pending():
            if not node.serve_once():
                done[0] = True

    return drive


def make_loopback_tree(
    z: jax.Array, cfg: FedNLConfig, topology: TopologySpec, seed: int = 0
) -> tuple[dict[int, Connection], Callable[[], None]]:
    """In-process tree-of-stars: one AggregatorNode per subtree, loopback
    buffers everywhere; returns (root conns keyed by subtree index, drive)."""
    shape = topology.resolve(z.shape[0])
    conns: dict[int, Connection] = {}
    drives: list[Callable[[], None]] = []
    for i, subtree in enumerate(shape):
        parent_side, agg = _wire_subtree(i, subtree, z, cfg,
                                         topology.combine, seed)
        conns[i] = parent_side
        drives.append(_agg_drive(parent_side, agg))

    def drive() -> None:
        for sub in drives:
            sub()

    return conns, drive


def open_loopback_master(
    z: jax.Array,
    cfg: FedNLConfig,
    topology: TopologySpec | None = None,
    membership: MembershipSpec | None = None,
    seed: int = 0,
) -> StarMaster:
    """Wire an in-process fleet for (topology, membership) and return its
    master, drive attached — the loopback construction seam the session
    backend uses (rule 6: masters are built here, not at call sites)."""
    from repro.comm.star import make_loopback_clients

    n_clients, _, d = z.shape
    if topology is not None and topology.kind == "tree":
        if membership is not None and not membership.trivial:
            raise ValueError(
                "membership events compose with the flat sync star only"
            )
        conns, drive = make_loopback_tree(z, cfg, topology, seed=seed)
        return make_master(
            conns, d, cfg, topology=topology, n_clients=n_clients, drive=drive
        )
    needs_selective = (
        (membership is not None and not membership.trivial)
        or (topology is not None and topology.mode == "async")
    )
    if needs_selective:
        conns, drive = make_selective_loopback_clients(z, cfg, seed=seed)
    else:
        # the PR-1 wiring, untouched: plain star runs keep their exact
        # historical drive discipline
        conns, drive = make_loopback_clients(z, cfg, seed=seed)
    return make_master(
        conns, d, cfg,
        topology=topology, membership=membership,
        n_clients=n_clients, drive=drive,
    )
