"""Star-topology FedNL: a real master event loop + client workers (DESIGN.md §5).

This is the paper's Section-7 multi-node setting: n clients connect to one
master; every round the master broadcasts the iterate x, each client runs the
Algorithm-1 client body on its own shard and uplinks
``grad_i || l_i || f_i || encode(S_i)`` through a wire codec; the master
decodes, averages, and takes the Newton-type step.

Seed alignment (the property tested against ``run_fednl``): the single-node
simulation draws ``key, sub = split(state.key); client_keys = split(sub, n)``
each round.  Every client replays that exact split chain locally from the
shared run seed and uses ``client_keys[client_id]`` — no key material needs to
travel, and the per-client compression randomness is identical to the
simulation's.  Combined with bit-exact codecs (wire.py) and the master
replaying the same jnp aggregation ops, a TCP run reproduces the single-node
iterate trajectory.

The same master loop runs over any transport; ``run_loopback`` drives in-
process clients synchronously (tests, smoke), ``launch/multiproc.py`` runs it
against real TCP client processes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import protocol, wire
from repro.obs import core as _obs
from repro.comm.protocol import Frame, MsgType, recv_frame, send_frame
from repro.comm.transport import Connection, loopback_pair
from repro.compressors import get_compressor
from repro.compressors.core import message_bits
from repro.core.fednl import FedNLConfig, _client_oracles, master_step
from repro.linalg import frob_norm_from_packed, triu_size


@dataclasses.dataclass(frozen=True)
class UplinkEntry:
    """One client's uplink as the master aggregates it: the wire metadata
    (bit counters + original frame size) and the raw uplink payload.  A flat
    star builds one per UPLINK frame; a tree master reassembles them from
    AGG payloads — same type, same aggregation tail, no op drift."""

    client: int
    sent_elems: int
    payload_bits: int
    frame_bytes: int
    payload: bytes


@dataclasses.dataclass
class StarRunResult:
    """Trajectory + *measured* wire accounting of a star-topology run."""

    x: np.ndarray
    grad_norms: np.ndarray
    f_vals: np.ndarray
    rounds: int
    sent_bits: np.ndarray  # per-round analytic payload bits (message_bits model)
    measured_payload_bits: np.ndarray  # per-round Section-7 bits counted on the wire
    measured_frame_bytes: np.ndarray  # per-round full uplink frame bytes incl. framing
    wall_time_s: float


class StarClient:
    """One client worker: owns a data shard, serves master frames."""

    def __init__(
        self,
        client_id: int,
        n_clients: int,
        z_i: jax.Array,
        cfg: FedNLConfig,
        conn: Connection,
        seed: int = 0,
    ):
        self.client_id = client_id
        self.n_clients = n_clients
        self.z_i = jnp.asarray(z_i)
        self.cfg = cfg
        self.conn = conn
        self.d = int(self.z_i.shape[-1])
        self.t = triu_size(self.d)
        self.comp = get_compressor(cfg.compressor, self.t, cfg.k_for(self.d))
        self.codec = wire.make_codec(self.comp, self.t)
        self.alpha = self.comp.alpha if cfg.alpha is None else cfg.alpha
        self.key = jax.random.PRNGKey(seed)
        self.h = jnp.zeros(self.t, dtype=self.z_i.dtype)
        # jit the oracle once; compression/serialization stay eager (host code)
        self._oracles = jax.jit(
            lambda x: _client_oracles(self.z_i, x, cfg.lam, cfg.hessian_impl)
        )

    def _round_key(self) -> jax.Array:
        """Replay the simulation's per-round key schedule for this client."""
        self.key, sub = jax.random.split(self.key)
        return jax.random.split(sub, self.n_clients)[self.client_id]

    def _handle_init(self, frame: Frame) -> None:
        x0 = protocol.unpack_vector(frame.payload)
        if self.cfg.hess0 == "exact":
            _, _, self.h = self._oracles(x0)
        elif self.cfg.hess0 == "zero":
            self.h = jnp.zeros(self.t, dtype=self.z_i.dtype)
        else:
            raise ValueError(f"unknown hess0 {self.cfg.hess0!r}")
        send_frame(
            self.conn,
            Frame(
                type=MsgType.INIT_ACK,
                client=self.client_id,
                payload=protocol.pack_vector(self.h),
            ),
        )

    def _handle_round(self, frame: Frame) -> None:
        x = protocol.unpack_vector(frame.payload)
        key_i = self._round_key()
        f_i, grad_i, hess_p = self._oracles(x)
        delta = hess_p - self.h
        enc = self.codec.encode(key_i, delta)
        # decode our own message so the local H_i update uses exactly the
        # dense correction the master will reconstruct (state stays in sync)
        s_i = self.codec.decode(enc.data, enc.sent_elems)
        l_i = frob_norm_from_packed(delta, self.d)
        self.h = self.h + self.alpha * s_i
        send_frame(
            self.conn,
            Frame(
                type=MsgType.UPLINK,
                round=frame.round,
                client=self.client_id,
                comp_id=self.codec.comp_id,
                sent_elems=enc.sent_elems,
                payload_bits=enc.bits,
                payload=protocol.pack_uplink(grad_i, l_i, f_i, enc),
            ),
        )

    def serve_once(self) -> bool:
        """Process one master frame; returns False on STOP."""
        frame = recv_frame(self.conn)
        if frame.type == MsgType.STOP:
            return False
        if frame.type == MsgType.INIT:
            self._handle_init(frame)
        elif frame.type == MsgType.ROUND:
            self._handle_round(frame)
        else:
            raise ValueError(f"client got unexpected frame {frame.type}")
        return True

    def run(self) -> None:
        """Blocking serve loop (TCP client processes)."""
        try:
            while self.serve_once():
                pass
        finally:
            self.conn.close()


class StarMaster:
    """Round-granular hub driver: INIT handshake, then one FedNL round per
    :meth:`step_round` call.

    ``run_star_master`` composes these into the classic closed event loop
    (op-for-op what it always did); the session backends instead hold a
    StarMaster open, stepping/pausing at will, serializing its master-side
    state (x, H, the broadcast history) and replaying broadcasts so freshly
    spawned clients rebuild their state from the spec + PRNG spine alone
    (:meth:`replay_round` — no client state is ever written to disk).

    ``drive`` is the loopback hook — called after every broadcast to let the
    in-process clients consume their frames (a no-op over TCP, where clients
    run in their own processes).

    Subclass seams (repro.comm.topology): ``uplink_type`` is the frame kind
    one round of collection expects (AGG for a tree master),
    ``_gather_uplinks`` turns the collected frames into :class:`UplinkEntry`
    rows in client-id order, and ``_on_init_ack`` / ``_on_decoded`` observe
    per-client state as it crosses the master (membership mirrors).  The
    aggregation tail itself (``_aggregate``) is shared — every master that
    claims star bit-parity runs literally the same jnp ops.
    """

    #: frame type one round of uplink collection expects from self.conns
    uplink_type = MsgType.UPLINK

    def __init__(
        self,
        conns: dict[int, Connection],
        d: int,
        cfg: FedNLConfig,
        x0: jax.Array | None = None,
        drive: Callable[[], None] | None = None,
    ):
        self.conns = conns
        self.order = sorted(conns)  # aggregation order == sim's client axis
        self.d = d
        self.cfg = cfg
        self.drive = drive
        t = triu_size(d)
        self.comp = get_compressor(cfg.compressor, t, cfg.k_for(d))
        self.codec = wire.make_codec(self.comp, t)
        self.alpha = self.comp.alpha if cfg.alpha is None else cfg.alpha
        self.x = jnp.zeros(d, dtype=jnp.float64) if x0 is None else jnp.asarray(x0)
        self.h_global = None
        # broadcast iterates, one per completed round — the master-side
        # record a resumed run replays to rebuild client state
        self.x_hist: list[np.ndarray] = []
        self._stopped = False

    def _broadcast(self, frame: Frame) -> None:
        for cid in self.order:
            send_frame(self.conns[cid], frame)
        if self.drive is not None:
            self.drive()

    def _collect(self, expect: MsgType) -> dict[int, Frame]:
        got = {}
        for cid in self.order:
            frame = recv_frame(self.conns[cid])
            if frame.type != expect or frame.client != cid:
                raise ValueError(
                    f"master expected {expect} from client {cid}, got "
                    f"{frame.type} from {frame.client}"
                )
            got[cid] = frame
        return got

    def _on_init_ack(self, cid: int, h_i: jax.Array) -> None:
        """Hook: one client's initial H_i^0 crossed the master (no-op here;
        the elastic master mirrors it for exact contribution retirement)."""

    def _on_decoded(self, cid: int, s_i: jax.Array) -> None:
        """Hook: one client's decoded correction S_i crossed the master."""

    def init_handshake(self) -> None:
        """INIT broadcast; clients report H_i^0 for the chosen hess0 policy."""
        self._broadcast(
            Frame(type=MsgType.INIT, payload=protocol.pack_vector(self.x))
        )
        acks = self._collect(MsgType.INIT_ACK)
        h_list = []
        for cid in self.order:
            h_i = protocol.unpack_vector(acks[cid].payload)
            self._on_init_ack(cid, h_i)
            h_list.append(h_i)
        self.h_global = jnp.mean(jnp.stack(h_list), axis=0)

    def _gather_uplinks(self, r: int) -> list[UplinkEntry]:
        """Collect one uplink frame per connection -> entries in client-id
        order (== the simulation's client axis).  A tree master overrides
        this to reassemble leaf entries out of AGG payloads instead."""
        ups = self._collect(MsgType.UPLINK)
        return [
            UplinkEntry(
                client=cid,
                sent_elems=ups[cid].sent_elems,
                payload_bits=ups[cid].payload_bits,
                frame_bytes=ups[cid].wire_bytes,
                payload=ups[cid].payload,
            )
            for cid in self.order
        ]

    def _decode_entries(self, entries: list[UplinkEntry]):
        """Unpack + decode the uplink entries (in the order given) into the
        per-client lists the aggregation consumes, accumulating the round's
        bit counters.  One copy of the decode loop — the tree/async/elastic
        masters reuse it so their per-entry op sequence cannot drift from
        the flat star's."""
        grads, s_list, l_list, f_list = [], [], [], []
        pbits = abits = fbytes = 0
        for e in entries:
            grad_i, l_i, f_i, hess_bytes = protocol.unpack_uplink(e.payload, self.d)
            s_i = self.codec.decode(hess_bytes, e.sent_elems)
            self._on_decoded(e.client, s_i)
            s_list.append(s_i)
            grads.append(grad_i)
            l_list.append(l_i)
            f_list.append(f_i)
            pbits += e.payload_bits
            abits += int(message_bits(self.comp, e.sent_elems))
            fbytes += e.frame_bytes
        return grads, s_list, l_list, f_list, abits, pbits, fbytes

    def _aggregate(self, entries: list[UplinkEntry]) -> dict:
        """Decode, average, Newton step — the master section of Algorithm 1
        over already-gathered uplink entries."""
        grads, s_list, l_list, f_list, abits, pbits, fbytes = (
            self._decode_entries(entries)
        )

        # identical jnp aggregation ops to make_fednl_round's master section
        grad = jnp.mean(jnp.stack(grads), axis=0)
        s = jnp.mean(jnp.stack(s_list), axis=0)
        l = jnp.mean(jnp.stack(l_list))
        f = jnp.mean(jnp.stack(f_list))

        x_new = master_step(self.x, self.h_global, grad, l, self.cfg)
        self.h_global = self.h_global + self.alpha * s
        self.x = x_new

        return {
            "grad_norm": float(jnp.linalg.norm(grad)),
            "f": float(f),
            "sent_bits": abits,
            "measured_payload_bits": pbits,
            "measured_frame_bytes": fbytes,
        }

    def step_round(self, r: int) -> dict:
        """One full protocol round: broadcast x, collect uplinks, aggregate,
        Newton step.  Returns the round's scalar metrics + bit counters.
        With a live ``repro.obs`` recorder the round is wrapped in a
        ``comm.round`` span carrying the round index and the measured wire
        counters (host scalars only — the trajectory is untouched)."""
        with _obs.CURRENT.span(
            "comm.round", master=type(self).__name__
        ) as sp:
            self._broadcast(
                Frame(
                    type=MsgType.ROUND,
                    round=r,
                    payload=protocol.pack_vector(self.x),
                )
            )
            self.x_hist.append(np.asarray(self.x))
            m = self._aggregate(self._gather_uplinks(r))
            sp.set(
                round=r,
                clients=len(self.order),
                wire_bytes=m["measured_frame_bytes"],
                payload_bits=m["measured_payload_bits"],
            )
            return m

    def replay_round(self, r: int, x_bcast: np.ndarray) -> None:
        """Resume support: re-broadcast a recorded iterate so clients replay
        their round body (advancing their PRNG spine and H_i exactly as the
        original run did); the uplinks are consumed UNdecoded — the master's
        own state comes from the checkpoint, not from re-aggregation."""
        self._broadcast(
            Frame(
                type=MsgType.ROUND,
                round=r,
                payload=protocol.pack_vector(jnp.asarray(x_bcast)),
            )
        )
        self.x_hist.append(np.asarray(x_bcast))
        self._collect(self.uplink_type)

    def stop(self) -> None:
        """Broadcast STOP (idempotent) so client loops exit cleanly."""
        if not self._stopped:
            self._stopped = True
            self._broadcast(Frame(type=MsgType.STOP))


def run_star_master(
    conns: dict[int, Connection],
    d: int,
    cfg: FedNLConfig,
    rounds: int = 100,
    tol: float = 0.0,
    x0: jax.Array | None = None,
    drive: Callable[[], None] | None = None,
) -> StarRunResult:
    """The classic closed hub event loop: INIT handshake, then FedNL rounds
    until tol/rounds, then STOP — a thin composition of :class:`StarMaster`
    (bit-identical to the historical inline loop)."""
    master = StarMaster(conns, d, cfg, x0=x0, drive=drive)
    master.init_handshake()

    grad_norms, f_vals = [], []
    bits_analytic, bits_measured, frame_bytes = [], [], []
    t_start = _obs.now()
    for r in range(rounds):
        m = master.step_round(r)
        grad_norms.append(m["grad_norm"])
        f_vals.append(m["f"])
        bits_analytic.append(m["sent_bits"])
        bits_measured.append(m["measured_payload_bits"])
        frame_bytes.append(m["measured_frame_bytes"])
        if tol > 0.0 and m["grad_norm"] < tol:
            break

    master.stop()
    wall = _obs.now() - t_start
    return StarRunResult(
        x=np.asarray(master.x),
        grad_norms=np.asarray(grad_norms),
        f_vals=np.asarray(f_vals),
        rounds=len(grad_norms),
        sent_bits=np.asarray(bits_analytic, dtype=np.int64),
        measured_payload_bits=np.asarray(bits_measured, dtype=np.int64),
        measured_frame_bytes=np.asarray(frame_bytes, dtype=np.int64),
        wall_time_s=wall,
    )


def make_loopback_clients(
    z: jax.Array, cfg: FedNLConfig, seed: int = 0
) -> tuple[dict[int, Connection], Callable[[], None]]:
    """In-process client fleet: master-side conns + the ``drive`` hook that
    lets them consume pending frames (shared by ``run_loopback`` and the
    star-loopback session backend — one wiring, one drive discipline)."""
    n_clients = z.shape[0]
    master_conns: dict[int, Connection] = {}
    clients: list[StarClient] = []
    for i in range(n_clients):
        a, b = loopback_pair()
        master_conns[i] = a
        clients.append(StarClient(i, n_clients, z[i], cfg, b, seed=seed))

    pending = [True] * n_clients

    def drive() -> None:
        for i, c in enumerate(clients):
            if pending[i]:
                pending[i] = c.serve_once()

    return master_conns, drive


def run_loopback(
    z: jax.Array,
    cfg: FedNLConfig,
    rounds: int = 100,
    tol: float = 0.0,
    seed: int = 0,
) -> StarRunResult:
    """Full protocol run over in-process loopback transport (one thread).

    Every message crosses the encode -> frame -> decode path; only the
    sockets are replaced by synchronous buffers.
    """
    d = z.shape[-1]
    master_conns, drive = make_loopback_clients(z, cfg, seed=seed)
    return run_star_master(
        master_conns, d, cfg, rounds=rounds, tol=tol, drive=drive
    )
