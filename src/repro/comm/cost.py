"""Bandwidth/latency cost model for the star topology (DESIGN.md §6).

The TCP star's communication time is not an ICI collective (the mesh
roofline's third term) but a hub-and-spoke exchange: the master's NIC is the
shared bottleneck for the n uplinks, and every round pays one broadcast plus
one uplink latency.  This model converts the wire-format byte counts (from
``repro.comm.wire`` / the measured star run) into seconds, giving benchmarks
and ``repro.roofline`` a comm term for the multi-node setting.

Defaults approximate the paper's LAN experiments: 1 Gbit/s links, ~0.2 ms
one-way latency.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CommCostModel:
    bandwidth_bps: float = 1e9  # per-link, bits/second
    latency_s: float = 2e-4  # one-way message latency
    master_shared_nic: bool = True  # n uplinks serialize through the hub NIC

    def transfer_s(self, bits: float) -> float:
        return self.latency_s + bits / self.bandwidth_bps

    def round_s(self, uplink_bits_total: float, bcast_bits: float, n_clients: int) -> float:
        """One FedNL round: broadcast x, then n client uplinks.

        With a shared master NIC the uplinks serialize on the wire (their
        latencies overlap, the bytes do not); otherwise they are parallel and
        the slowest (== mean, symmetric clients) uplink bounds the round.
        """
        bcast = self.latency_s + bcast_bits / self.bandwidth_bps
        if self.master_shared_nic:
            uplink = self.latency_s + uplink_bits_total / self.bandwidth_bps
        else:
            per_client = uplink_bits_total / max(n_clients, 1)
            uplink = self.latency_s + per_client / self.bandwidth_bps
        return bcast + uplink

    def run_s(self, uplink_bits_per_round, bcast_bits: float, n_clients: int) -> float:
        """Total comm seconds over a recorded per-round uplink-bits history."""
        return sum(
            self.round_s(float(b), bcast_bits, n_clients)
            for b in uplink_bits_per_round
        )


DEFAULT_COST = CommCostModel()
