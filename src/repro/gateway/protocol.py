"""Gateway RPC payload codecs + async frame I/O (DESIGN.md §14).

Frames reuse the 32-byte FNL1 header from :mod:`repro.comm.protocol`
(MsgType.SUBMIT .. GW_ERR); this module defines what goes *inside* them.
Every payload follows the FNLS1 idiom: a little-endian u32 length, a
canonical JSON header (sorted keys, hex-exact floats where bits matter),
then zero or more raw ``<f8`` array blobs whose shapes the header lists.
Nothing numeric ever round-trips through decimal truncation:

* spec hyper-parameters ride :mod:`repro.api.specwire` (Python float repr
  is shortest-round-trip, so JSON is exact for them);
* RoundRecord floats use ``float.hex()`` via the session codecs
  (:func:`repro.api.session._record_to_jsonable`);
* iterates (``RoundRecord.x``, ``RunReport.x``) ship as raw f64 blobs.

That is what makes the gateway's bit-identity contract possible: a record
decoded on the far side of a socket compares equal — hex digit for hex
digit — to the record a solo ``open_session(spec).run()`` produced.

Strictness mirrors specwire: unknown top-level payload keys and unknown
``options`` fields are rejected loudly, naming the dotted field — a remote
submitter is told *which* field is wrong in the synchronous error reply,
never left with a silently mangled experiment.
"""

from __future__ import annotations

import dataclasses
import json
import struct
from typing import Any

import numpy as np

from repro.api.report import RoundRecord, RunReport
from repro.api.session import (
    _record_from_jsonable,
    _record_to_jsonable,
    spec_to_dict,
)
from repro.api.specwire import SPEC_WIRE_VERSION, decode_spec_dict
from repro.comm.protocol import (
    HEADER_SIZE,
    Frame,
    MsgType,
    pack_frame,
    unpack_header,
)
from repro.serve_fednl.scheduler import SubmitOptions

# ---------------------------------------------------------------------------
# JSON-header + f8-blob container (the FNLS1 idiom, frame-sized)
# ---------------------------------------------------------------------------


def _pack(header: dict, blobs: list[np.ndarray] | None = None) -> bytes:
    blobs = blobs or []
    header = dict(header)
    header["blobs"] = [list(np.asarray(b).shape) for b in blobs]
    hj = json.dumps(header, sort_keys=True, separators=(",", ":")).encode()
    out = [struct.pack("<I", len(hj)), hj]
    out += [np.ascontiguousarray(b, dtype="<f8").tobytes() for b in blobs]
    return b"".join(out)


def _unpack(payload: bytes) -> tuple[dict, list[np.ndarray]]:
    (hlen,) = struct.unpack("<I", payload[:4])
    header = json.loads(payload[4 : 4 + hlen].decode())
    off = 4 + hlen
    blobs = []
    for shape in header.pop("blobs", []):
        n = int(np.prod(shape)) if shape else 1
        arr = np.frombuffer(payload[off : off + 8 * n], dtype="<f8").copy()
        blobs.append(arr.reshape(shape))
        off += 8 * n
    if off != len(payload):
        raise ValueError(
            f"gateway payload has {len(payload) - off} trailing bytes"
        )
    return header, blobs


# ---------------------------------------------------------------------------
# SUBMIT
# ---------------------------------------------------------------------------

_SUBMIT_KEYS = {"spec_wire_version", "spec", "options", "until", "tenant_id"}
_OPTION_FIELDS = {f.name for f in dataclasses.fields(SubmitOptions)}


def pack_submit(
    spec,
    until=None,
    tenant_id: str | None = None,
    options: SubmitOptions | None = None,
) -> bytes:
    """SUBMIT payload: versioned spec + scheduling choices.

    ``until`` crosses the wire only in its data forms — None, an int round
    budget, or a float tolerance (a StopPolicy with a predicate closure
    cannot be serialized; resolve it client-side to rounds/tol first).
    """
    if until is not None and not isinstance(until, (int, float)):
        raise TypeError(
            "until must be None, an int round budget, or a float tol to "
            f"cross the wire; got {type(until).__name__} (predicate stop "
            "policies are client-local closures)"
        )
    header: dict[str, Any] = {
        "spec_wire_version": SPEC_WIRE_VERSION,
        "spec": spec_to_dict(spec),
        "until": until,
        "tenant_id": tenant_id,
        "options": (
            None if options is None else dataclasses.asdict(options)
        ),
    }
    return _pack(header)


def unpack_submit(payload: bytes):
    """-> (spec, until, tenant_id, SubmitOptions | None); strict (module
    docstring) — raises ValueError naming the offending field."""
    header, _ = _unpack(payload)
    extra = sorted(set(header) - _SUBMIT_KEYS)
    if extra:
        raise ValueError(
            f"SUBMIT payload has unknown field(s): {', '.join(extra)} "
            f"(known fields: {', '.join(sorted(_SUBMIT_KEYS))})"
        )
    spec = decode_spec_dict(
        {
            k: header[k]
            for k in ("spec_wire_version", "spec")
            if k in header
        }
    )
    until = header.get("until")
    if until is not None and not isinstance(until, (int, float)):
        raise ValueError(
            f"until: must be null, an int round budget, or a float tol; "
            f"got {type(until).__name__}"
        )
    tenant_id = header.get("tenant_id")
    if tenant_id is not None and not isinstance(tenant_id, str):
        raise ValueError(
            f"tenant_id: must be null or a string, got "
            f"{type(tenant_id).__name__}"
        )
    opts_d = header.get("options")
    options = None
    if opts_d is not None:
        if not isinstance(opts_d, dict):
            raise ValueError(
                f"options: must be null or an object, got "
                f"{type(opts_d).__name__}"
            )
        unknown = sorted(set(opts_d) - _OPTION_FIELDS)
        if unknown:
            named = ", ".join(f"options.{u}" for u in unknown)
            raise ValueError(
                f"SUBMIT payload has unknown field(s): {named} (known "
                f"options fields: {', '.join(sorted(_OPTION_FIELDS))})"
            )
        options = SubmitOptions(**opts_d)
    return spec, until, tenant_id, options


# ---------------------------------------------------------------------------
# RECORD / STREAM_END
# ---------------------------------------------------------------------------


def pack_record(tenant_id: str, index: int, rec: RoundRecord) -> Frame:
    """One streamed RoundRecord as a RECORD frame (round in the header,
    hex-exact floats in the JSON, any PP iterate as a raw f64 blob)."""
    header = {
        "tenant_id": tenant_id,
        "index": index,
        "record": _record_to_jsonable(rec),
    }
    blobs = [np.asarray(rec.x)] if rec.x is not None else []
    return Frame(
        type=MsgType.RECORD, round=int(rec.round), payload=_pack(header, blobs)
    )


def unpack_record(payload: bytes) -> tuple[str, int, RoundRecord]:
    """-> (tenant_id, stream index, RoundRecord) — bit-exact floats."""
    header, blobs = _unpack(payload)
    d = header["record"]
    x = blobs[0] if d.get("has_x") else None
    return header["tenant_id"], int(header["index"]), _record_from_jsonable(d, x)


def pack_stream_end(
    tenant_id: str, drops: int, status: str, error: str | None = None
) -> Frame:
    """STREAM_END: terminal status + the counted-drops notice of the
    bounded observer queue (``drops`` records were skipped because this
    observer consumed too slowly; the engine never waited for it)."""
    return Frame(
        type=MsgType.STREAM_END,
        payload=_pack(
            {
                "tenant_id": tenant_id,
                "drops": int(drops),
                "status": status,
                "error": error,
            }
        ),
    )


def unpack_stream_end(payload: bytes) -> dict:
    header, _ = _unpack(payload)
    return header


# ---------------------------------------------------------------------------
# RESULT (full RunReport across the wire)
# ---------------------------------------------------------------------------


def pack_report(report: RunReport) -> bytes:
    """Serialize a RunReport: spec via specwire, records via the session
    hex-float codec, the final iterate + any per-record PP iterates as raw
    f64 blobs.  ``final_grad_norm_fn`` (a closure over problem arrays) does
    not cross the wire; full-participation reports recover the diagnostic
    from their last record, PP callers re-evaluate locally if needed."""
    rec_js = [_record_to_jsonable(r) for r in report.records]
    blobs = [np.asarray(report.x)]
    blobs += [np.asarray(r.x) for r in report.records if r.x is not None]
    header = {
        "spec_wire_version": SPEC_WIRE_VERSION,
        "spec": spec_to_dict(report.spec),
        "algorithm": report.algorithm,
        "backend": report.backend,
        "rounds": int(report.rounds),
        "wall_time_s": float(report.wall_time_s).hex(),
        "init_time_s": float(report.init_time_s).hex(),
        "extras": report.extras,
        "records": rec_js,
    }
    return _pack(header, blobs)


def unpack_report(payload: bytes) -> RunReport:
    header, blobs = _unpack(payload)
    spec = decode_spec_dict(
        {
            "spec_wire_version": header["spec_wire_version"],
            "spec": header["spec"],
        }
    )
    x, rest = blobs[0], blobs[1:]
    records = []
    it = iter(rest)
    for d in header["records"]:
        rx = next(it) if d.get("has_x") else None
        records.append(_record_from_jsonable(d, rx))
    return RunReport(
        spec=spec,
        algorithm=header["algorithm"],
        backend=header["backend"],
        x=x,
        records=records,
        rounds=int(header["rounds"]),
        wall_time_s=float.fromhex(header["wall_time_s"]),
        init_time_s=float.fromhex(header["init_time_s"]),
        extras=dict(header["extras"]),
    )


# ---------------------------------------------------------------------------
# small JSON frames (requests, acks, errors)
# ---------------------------------------------------------------------------


def pack_json(mtype: MsgType, obj: dict) -> Frame:
    return Frame(type=mtype, payload=_pack(obj))


def unpack_json(payload: bytes) -> dict:
    header, _ = _unpack(payload)
    return header


def error_frame(exc: BaseException) -> Frame:
    """GW_ERR naming the offending field where the message makes it
    derivable (specwire / SubmitOptions / SUBMIT validation errors all
    embed dotted field names)."""
    # KeyError's str() wraps the message in quotes; unwrap it
    msg = (
        str(exc.args[0])
        if isinstance(exc, KeyError) and exc.args
        else str(exc)
    )
    field = None
    if "unknown field(s): " in msg:
        field = msg.split("unknown field(s): ", 1)[1].split(",")[0].split(
            " "
        )[0].rstrip(",")
    elif ": " in msg:
        head = msg.split(": ", 1)[0]
        if head and " " not in head and head.replace(".", "").replace(
            "_", ""
        ).replace("[", "").replace("]", "").isalnum():
            field = head
    return pack_json(
        MsgType.GW_ERR,
        {"error": msg, "field": field, "kind": type(exc).__name__},
    )


class GatewayError(RuntimeError):
    """Client-side surface of a GW_ERR reply (``field`` names the offending
    submission field when the server could derive it)."""

    def __init__(self, message: str, field: str | None = None,
                 kind: str | None = None):
        super().__init__(message)
        self.field = field
        self.kind = kind


# ---------------------------------------------------------------------------
# async frame I/O (the gateway server side; sync peers use
# repro.comm.protocol.send_frame/recv_frame over a transport Connection)
# ---------------------------------------------------------------------------


async def read_frame_async(reader) -> Frame:
    """Read one frame from an :class:`asyncio.StreamReader`."""
    header = await reader.readexactly(HEADER_SIZE)
    frame, plen = unpack_header(header)
    payload = await reader.readexactly(plen) if plen else b""
    return dataclasses.replace(frame, payload=payload)


async def write_frame_async(writer, frame: Frame) -> None:
    """Write one frame to an :class:`asyncio.StreamWriter` and drain it —
    backpressure lands on the *caller's* coroutine only, never the engine
    tick loop (which writes to bounded in-memory queues instead)."""
    writer.write(pack_frame(frame))
    await writer.drain()
