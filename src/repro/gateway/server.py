"""GatewayServer — the networked front-end of the FedNL serving engine.

One asyncio event loop owns everything: the TCP listener, one coroutine per
client connection, and the engine tick cadence.  JAX work never runs on the
loop — each ``tick()`` executes in a worker thread via ``asyncio.to_thread``
— and socket writes never run inside the tick: the tick only appends to
bounded per-subscription queues, so a slow (or dead) remote observer can
never stall the optimization of anyone's experiment.

Division of labor (the §14 contract): the gateway is pure transport +
policy.  Scheduling policy lives in the engine's
:class:`~repro.serve_fednl.scheduler.FairShareQueue`; numerics live below
that.  Nothing in this module touches an array except to forward it, which
is why every gateway-served trajectory is bit-identical to a solo
``open_session(spec).run()`` — including tenants that were spilled,
evicted, or streamed to three observers along the way.

Backpressure model per STREAM subscription:

    tick thread ──append──▶ deque(maxlen=stream_queue) ──drain──▶ writer coro
                             (drop-oldest, drops counted)     (awaits socket)

The writer coroutine blocks only on its own socket's ``drain()``; when the
observer finally reads, it receives the *newest* records plus a counted-
drops notice in STREAM_END.  An observer that keeps up sees every record.
"""

from __future__ import annotations

import asyncio
import contextlib
import dataclasses
import pathlib
from collections import deque

from repro.comm.protocol import Frame, MsgType
from repro.gateway import protocol as gw
from repro.obs import core as _obs
from repro.serve_fednl.engine import FedNLServer, ServeConfig
from repro.serve_fednl.tenant import CANCELLED, EVICTED, FAILED, FINISHED


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Gateway sizing knobs (engine knobs ride in ``serve``).

    ``stream_queue`` bounds each STREAM subscription's record queue — the
    drop-oldest window a slow observer gets.  ``idle_sleep_s`` is the tick
    loop's poll interval while no tenant has work.
    """

    host: str = "127.0.0.1"
    port: int = 0  # 0 = ephemeral; read the bound port off .port
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)
    stream_queue: int = 256
    idle_sleep_s: float = 0.002


class _Subscription:
    """One observer of one tenant's record stream (server side)."""

    __slots__ = ("tenant_id", "queue", "drops", "sent", "event", "closed")

    def __init__(self, tenant_id: str, maxlen: int):
        self.tenant_id = tenant_id
        self.queue: deque = deque(maxlen=maxlen)
        self.drops = 0
        self.sent = 0  # records already enqueued (index into tenant.records)
        self.event = asyncio.Event()
        self.closed = False


class GatewayServer:
    """Serve the FedNL engine over TCP (module docstring).

    Lifecycle: construct, ``await start()`` (binds the listener and spawns
    the tick loop), ``await serve_forever()`` or poll, ``await stop()``.
    ``run()`` is the blocking one-call entry point used by
    ``scripts/gateway_serve.py``.
    """

    def __init__(self, config: GatewayConfig | None = None):
        self.config = config or GatewayConfig()
        self.engine = FedNLServer(self.config.serve)
        self.port: int | None = None
        self._server: asyncio.AbstractServer | None = None
        self._tick_task: asyncio.Task | None = None
        self._subs: list[_Subscription] = []
        self._done_waiters: dict[str, asyncio.Event] = {}
        self._work = asyncio.Event()
        self._stopping = False
        self._connections = 0
        self._tick_wall: list[float] = []  # per-tick seconds (stats/bench)

    # --- lifecycle --------------------------------------------------------

    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle_connection, self.config.host, self.config.port
        )
        self.port = self._server.sockets[0].getsockname()[1]
        self._tick_task = asyncio.create_task(self._tick_loop())

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def stop(self, spill: bool = False) -> None:
        self._stopping = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._tick_task is not None:
            self._work.set()
            self._tick_task.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await self._tick_task
        for sub in self._subs:
            sub.closed = True
            sub.event.set()
        await asyncio.to_thread(self.engine.shutdown, spill)

    def run(self, ready=None) -> None:
        """Blocking entry point: start, announce, serve until cancelled
        (``request_stop()`` from any thread, or SIGINT)."""

        async def main():
            self._loop = asyncio.get_running_loop()
            self._main_task = asyncio.current_task()
            await self.start()
            if ready is not None:
                ready(self.config.host, self.port)
            try:
                await self.serve_forever()
            except asyncio.CancelledError:
                pass
            finally:
                await self.stop()

        asyncio.run(main())

    def request_stop(self) -> None:
        """Thread-safe shutdown request for a ``run()``-driven gateway."""
        loop = getattr(self, "_loop", None)
        task = getattr(self, "_main_task", None)
        if loop is not None and task is not None and not loop.is_closed():
            loop.call_soon_threadsafe(task.cancel)

    # --- engine tick cadence ----------------------------------------------

    async def _tick_loop(self) -> None:
        """Own the engine cadence: tick in a worker thread while there is
        work, then pump subscriptions/waiters ON the loop thread (single-
        threaded access to the subscription structures — no locks)."""
        while not self._stopping:
            if self.engine._has_work():
                t0 = _obs.now()
                await asyncio.to_thread(self.engine.tick)
                dt = _obs.now() - t0
                self._tick_wall.append(dt)
                rec = _obs.CURRENT
                if rec.enabled:
                    rec.observe("gateway.tick.s", dt)
                self._pump()
            else:
                self._pump()  # flush terminal states for late subscribers
                self._work.clear()
                with contextlib.suppress(asyncio.TimeoutError):
                    await asyncio.wait_for(
                        self._work.wait(), self.config.idle_sleep_s
                    )

    def _pump(self) -> None:
        """Move newly produced records into subscription queues and fire
        completion events.  Appends to bounded deques only — never a socket
        write, so the engine tick cadence is independent of observers."""
        tenants = self.engine._tenants
        rec = _obs.CURRENT
        for sub in self._subs:
            t = tenants.get(sub.tenant_id)
            if t is None or sub.closed:
                continue
            recs = t.records
            if sub.sent < len(recs):
                for i in range(sub.sent, len(recs)):
                    if len(sub.queue) == sub.queue.maxlen:
                        sub.queue.popleft()  # drop-oldest, counted
                        sub.drops += 1
                        if rec.enabled:
                            rec.add("gateway.stream.dropped")
                    sub.queue.append((i, recs[i]))
                sub.sent = len(recs)
                sub.event.set()
            if t.status in (FINISHED, FAILED, EVICTED, CANCELLED):
                sub.closed = True
                sub.event.set()
        for tid, evt in self._done_waiters.items():
            t = tenants.get(tid)
            if t is not None and t.status in (
                FINISHED, FAILED, EVICTED, CANCELLED
            ):
                evt.set()

    # --- per-connection RPC loop ------------------------------------------

    async def _handle_connection(self, reader, writer) -> None:
        self._connections += 1
        try:
            while True:
                try:
                    frame = await gw.read_frame_async(reader)
                except (asyncio.IncompleteReadError, ConnectionError):
                    return
                try:
                    await self._dispatch(frame, writer)
                except (ValueError, TypeError, KeyError) as exc:
                    await gw.write_frame_async(writer, gw.error_frame(exc))
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            writer.close()
            with contextlib.suppress(Exception):
                await writer.wait_closed()

    async def _dispatch(self, frame: Frame, writer) -> None:
        # RPC latency is a plain labeled observation, not a span: spans nest
        # through a per-thread stack, and concurrent coroutines on the loop
        # thread would interleave their frames (DESIGN.md §15)
        rec = _obs.CURRENT
        t0 = _obs.now()
        try:
            await self._dispatch_inner(frame, writer)
        finally:
            if rec.enabled:
                rec.observe(
                    "gateway.rpc.s", _obs.now() - t0, verb=frame.type.name
                )

    async def _dispatch_inner(self, frame: Frame, writer) -> None:
        if frame.type == MsgType.SUBMIT:
            await self._rpc_submit(frame, writer)
        elif frame.type == MsgType.STATUS:
            await self._rpc_status(frame, writer)
        elif frame.type == MsgType.STREAM:
            await self._rpc_stream(frame, writer)
        elif frame.type == MsgType.RESULT:
            await self._rpc_result(frame, writer)
        elif frame.type == MsgType.EVICT:
            await self._rpc_evict(frame, writer)
        elif frame.type == MsgType.CANCEL:
            await self._rpc_cancel(frame, writer)
        elif frame.type == MsgType.METRICS:
            await self._rpc_metrics(frame, writer)
        else:
            raise ValueError(
                f"unexpected frame type {frame.type.name} on a gateway "
                "connection"
            )

    async def _rpc_submit(self, frame: Frame, writer) -> None:
        # decode strictly, then validate/enqueue in a worker thread (spec
        # checking may build compressors); errors surface synchronously as
        # GW_ERR naming the field — never a dead tenant ticks later
        spec, until, tenant_id, options = gw.unpack_submit(frame.payload)
        handle = await asyncio.to_thread(
            self.engine.submit, spec, until, tenant_id, options
        )
        self._work.set()
        await gw.write_frame_async(
            writer,
            gw.pack_json(
                MsgType.GW_OK,
                {
                    "tenant_id": handle.id,
                    "priority": handle.priority,
                    "lane": handle._tenant.lane,
                },
            ),
        )

    async def _rpc_status(self, frame: Frame, writer) -> None:
        req = gw.unpack_json(frame.payload)
        tid = req.get("tenant_id")
        if tid is None:
            stats = self.engine.stats()
            stats["connections"] = self._connections
            stats["subscriptions"] = sum(
                1 for s in self._subs if not s.closed
            )
            await gw.write_frame_async(
                writer, gw.pack_json(MsgType.GW_OK, {"stats": stats})
            )
            return
        t = self.engine._tenants.get(tid)
        if t is None:
            raise KeyError(f"no tenant {tid!r}")
        await gw.write_frame_async(
            writer,
            gw.pack_json(
                MsgType.GW_OK,
                {
                    "tenant_id": tid,
                    "status": t.status,
                    "round": t.round,
                    "records": len(t.records),
                    "priority": t.priority,
                    "lane": t.lane,
                },
            ),
        )

    async def _rpc_stream(self, frame: Frame, writer) -> None:
        """Subscribe this connection to one tenant's records.  The reply is
        GW_OK, then RECORD frames as they are produced, then STREAM_END with
        the drops count.  The connection returns to the RPC loop after."""
        req = gw.unpack_json(frame.payload)
        tid = req.get("tenant_id")
        t = self.engine._tenants.get(tid)
        if t is None:
            raise KeyError(f"no tenant {tid!r}")
        sub = _Subscription(tid, self.config.stream_queue)
        if req.get("from_start", True):
            pass  # sent=0: replay everything produced so far
        else:
            sub.sent = len(t.records)
        self._subs.append(sub)
        try:
            await gw.write_frame_async(
                writer, gw.pack_json(MsgType.GW_OK, {"tenant_id": tid})
            )
            self._pump_one(sub)  # catch up on already-produced records
            while True:
                await sub.event.wait()
                sub.event.clear()
                while sub.queue:
                    i, rec = sub.queue.popleft()
                    await gw.write_frame_async(
                        writer, gw.pack_record(tid, i, rec)
                    )
                if sub.closed and not sub.queue:
                    break
            t = self.engine._tenants[tid]
            await gw.write_frame_async(
                writer,
                gw.pack_stream_end(
                    tid,
                    sub.drops,
                    t.status,
                    str(t.error) if t.error is not None else None,
                ),
            )
        finally:
            sub.closed = True
            with contextlib.suppress(ValueError):
                self._subs.remove(sub)

    def _pump_one(self, sub: _Subscription) -> None:
        t = self.engine._tenants.get(sub.tenant_id)
        if t is None:
            sub.closed = True
            sub.event.set()
            return
        recs = t.records
        rec = _obs.CURRENT
        for i in range(sub.sent, len(recs)):
            if len(sub.queue) == sub.queue.maxlen:
                sub.queue.popleft()
                sub.drops += 1
                if rec.enabled:
                    rec.add("gateway.stream.dropped")
            sub.queue.append((i, recs[i]))
        sub.sent = len(recs)
        if t.status in (FINISHED, FAILED, EVICTED, CANCELLED):
            sub.closed = True
        sub.event.set()

    async def _rpc_result(self, frame: Frame, writer) -> None:
        req = gw.unpack_json(frame.payload)
        tid = req.get("tenant_id")
        t = self.engine._tenants.get(tid)
        if t is None:
            raise KeyError(f"no tenant {tid!r}")
        if t.status not in (FINISHED, FAILED, EVICTED, CANCELLED):
            evt = self._done_waiters.setdefault(tid, asyncio.Event())
            self._work.set()
            await evt.wait()
            self._done_waiters.pop(tid, None)
            t = self.engine._tenants[tid]
        if t.status == FINISHED:
            payload = await asyncio.to_thread(gw.pack_report, t.report)
            await gw.write_frame_async(
                writer, Frame(type=MsgType.RESULT, payload=payload)
            )
        else:
            detail = {
                FAILED: lambda: f"failed: {t.error}",
                EVICTED: lambda: (
                    f"evicted to {t.spill_path} — resume server-side or "
                    "fetch the checkpoint out of band"
                ),
                CANCELLED: lambda: "cancelled (state dropped)",
            }[t.status]()
            await gw.write_frame_async(
                writer,
                gw.pack_json(
                    MsgType.GW_ERR,
                    {
                        "error": f"tenant {tid!r} {detail}",
                        "field": None,
                        "kind": "RuntimeError",
                        "status": t.status,
                    },
                ),
            )

    async def _rpc_evict(self, frame: Frame, writer) -> None:
        req = gw.unpack_json(frame.payload)
        tid = req.get("tenant_id")
        path = await asyncio.to_thread(self.engine.evict, tid)
        self._pump()  # release streamers/waiters of the evicted tenant
        await gw.write_frame_async(
            writer,
            gw.pack_json(
                MsgType.GW_OK, {"tenant_id": tid, "checkpoint": str(path)}
            ),
        )

    async def _rpc_metrics(self, frame: Frame, writer) -> None:
        """METRICS verb (DESIGN.md §15): snapshot of the process recorder.

        Reply body: ``{"enabled": bool, "metrics": snapshot}`` — plus
        ``"prometheus"`` (text exposition) when the request asks
        ``{"format": "prometheus"}``.  Works against a disabled recorder
        (``enabled: false``, empty snapshot) so dashboards can poll
        unconditionally."""
        req = gw.unpack_json(frame.payload)
        rec = _obs.CURRENT
        if not rec.enabled:
            body = {"enabled": False, "metrics": {"enabled": False}}
        else:
            body = {"enabled": True, "metrics": rec.snapshot()}
            if req.get("format") == "prometheus":
                from repro.obs import export

                body["prometheus"] = export.prometheus_text(rec)
        await gw.write_frame_async(writer, gw.pack_json(MsgType.GW_OK, body))

    async def _rpc_cancel(self, frame: Frame, writer) -> None:
        req = gw.unpack_json(frame.payload)
        tid = req.get("tenant_id")
        await asyncio.to_thread(self.engine.cancel, tid)
        self._pump()
        await gw.write_frame_async(
            writer, gw.pack_json(MsgType.GW_OK, {"tenant_id": tid})
        )

    # --- introspection ----------------------------------------------------

    def tick_latencies(self) -> list[float]:
        """Wall seconds of every engine tick this gateway has driven (the
        slow-observer test asserts these are unaffected by a stalled
        stream consumer)."""
        return list(self._tick_wall)


def serve_gateway(config: GatewayConfig | None = None, ready=None) -> None:
    """Blocking convenience wrapper (``scripts/gateway_serve.py``)."""
    GatewayServer(config).run(ready=ready)
