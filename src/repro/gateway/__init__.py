"""repro.gateway — networked front-end for the FedNL serving engine.

The gateway puts :class:`~repro.serve_fednl.FedNLServer` behind a TCP
socket (DESIGN.md §14): remote clients SUBMIT serialized ExperimentSpecs,
STREAM per-round records as they are produced, and fetch bit-exact
RunReports with RESULT — while the gateway's asyncio loop owns the engine
tick cadence and its deficit-round-robin fair-share scheduler arbitrates
between priority classes.  The gateway is pure transport + policy: every
trajectory it serves is bit-identical to a solo
``open_session(spec).run()``.

Server:  ``scripts/gateway_serve.py`` or::

    from repro.gateway import GatewayConfig, GatewayServer
    GatewayServer(GatewayConfig(port=9970)).run()

Client::

    from repro.gateway import GatewayClient
    with GatewayClient("127.0.0.1", 9970) as gwc:
        h = gwc.submit(spec, until=40, priority="high")
        report = gwc.result(h.id)
"""

from repro.gateway.client import GatewayClient, RemoteTenant, stream_records
from repro.gateway.protocol import GatewayError
from repro.gateway.server import GatewayConfig, GatewayServer, serve_gateway

__all__ = [
    "GatewayClient",
    "GatewayConfig",
    "GatewayError",
    "GatewayServer",
    "RemoteTenant",
    "serve_gateway",
    "stream_records",
]
