"""GatewayClient — thin typed client for the gateway RPC (DESIGN.md §14).

Synchronous by design: a submitting script wants ``submit`` to return a
handle or raise *now* (the gateway validates at submission), and a record
stream is most naturally a generator.  One client = one TCP connection =
one RPC at a time — a ``stream()`` occupies the connection until the
generator is exhausted or closed, so open a second client for concurrent
streams (connections are cheap; the gateway multiplexes them).

    from repro.gateway import GatewayClient

    with GatewayClient("127.0.0.1", 9970) as gwc:
        h = gwc.submit(spec, until=40, priority="high")
        for rec in gwc.stream(h.id):
            print(rec.round, rec.grad_norm)
        report = gwc.result(h.id)    # bit-identical to solve(spec)
"""

from __future__ import annotations

import socket
import time

from repro.api.report import RunReport
from repro.comm.protocol import Frame, MsgType, recv_frame, send_frame
from repro.comm.transport import SocketConnection
from repro.gateway import protocol as gw
from repro.gateway.protocol import GatewayError
from repro.obs import core as _obs
from repro.serve_fednl.scheduler import SubmitOptions


class RemoteTenant:
    """Caller-side handle to one gateway-resident tenant (the network
    analogue of :class:`~repro.serve_fednl.tenant.TenantHandle`)."""

    def __init__(self, client: "GatewayClient", tenant_id: str,
                 priority: str, lane: str):
        self._client = client
        self.id = tenant_id
        self.priority = priority
        self.lane = lane

    def status(self) -> dict:
        return self._client.status(self.id)

    def stream(self, from_start: bool = True):
        return self._client.stream(self.id, from_start=from_start)

    def result(self) -> RunReport:
        return self._client.result(self.id)

    def cancel(self) -> None:
        self._client.cancel(self.id)

    def evict(self) -> str:
        return self._client.evict(self.id)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (
            f"RemoteTenant({self.id!r}, priority={self.priority!r}, "
            f"lane={self.lane!r})"
        )


class GatewayClient:
    """One connection to a :class:`~repro.gateway.server.GatewayServer`.

    Context-manager; all methods raise :class:`GatewayError` when the
    gateway replies GW_ERR (``.field`` names the offending submission
    field when the server could derive it).
    """

    def __init__(self, host: str, port: int, timeout: float = 120.0,
                 connect_retry_s: float = 10.0):
        deadline = _obs.monotonic() + connect_retry_s
        last: Exception | None = None
        while True:
            try:
                sock = socket.create_connection(
                    (host, port), timeout=timeout
                )
                break
            except OSError as exc:  # gateway may still be binding
                last = exc
                if _obs.monotonic() >= deadline:
                    raise ConnectionError(
                        f"gateway {host}:{port} not reachable after "
                        f"{connect_retry_s:.0f}s: {last}"
                    ) from exc
                time.sleep(0.05)
        self._conn = SocketConnection(sock)
        self.host, self.port = host, port
        self.stream_drops = 0  # drops notice of the most recent stream()
        # cumulative across every stream() on this client: records the
        # gateway's bounded queues dropped before we could read them — the
        # caller-visible face of the server's gateway.stream.dropped counter
        self.dropped_records = 0

    # --- plumbing ---------------------------------------------------------

    def _rpc(self, frame: Frame) -> Frame:
        send_frame(self._conn, frame)
        reply = recv_frame(self._conn)
        if reply.type == MsgType.GW_ERR:
            err = gw.unpack_json(reply.payload)
            raise GatewayError(
                err.get("error", "gateway error"),
                field=err.get("field"),
                kind=err.get("kind"),
            )
        return reply

    def close(self) -> None:
        self._conn.close()

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --- RPCs -------------------------------------------------------------

    def submit(
        self,
        spec,
        until=None,
        tenant_id: str | None = None,
        priority: str | None = None,
        options: SubmitOptions | None = None,
    ) -> RemoteTenant:
        """Submit one experiment; returns once the gateway has validated
        and enqueued it.  A bad spec/option raises :class:`GatewayError`
        here, synchronously, naming the field.  ``priority`` is shorthand
        for ``options=SubmitOptions(priority=...)``."""
        if priority is not None:
            if options is not None:
                raise ValueError(
                    "pass either priority= or options=, not both"
                )
            options = SubmitOptions(priority=priority)
        reply = self._rpc(
            Frame(
                type=MsgType.SUBMIT,
                payload=gw.pack_submit(
                    spec, until=until, tenant_id=tenant_id, options=options
                ),
            )
        )
        ok = gw.unpack_json(reply.payload)
        return RemoteTenant(
            self, ok["tenant_id"], ok["priority"], ok["lane"]
        )

    def status(self, tenant_id: str | None = None) -> dict:
        """One tenant's status dict, or (with no id) the engine stats."""
        reply = self._rpc(
            gw.pack_json(MsgType.STATUS, {"tenant_id": tenant_id})
        )
        out = gw.unpack_json(reply.payload)
        return out.get("stats", out)

    def stream(self, tenant_id: str, from_start: bool = True):
        """Yield the tenant's RoundRecords as the gateway produces them
        (``from_start=False`` skips records produced before subscribing).
        The generator ends when the tenant reaches a terminal state; the
        bounded-queue drop count is in ``self.stream_drops`` afterwards.
        The connection is occupied until the generator is exhausted."""
        self._rpc(  # GW_OK subscription ack (or GW_ERR -> raise)
            gw.pack_json(
                MsgType.STREAM,
                {"tenant_id": tenant_id, "from_start": from_start},
            )
        )

        def _gen():
            while True:
                frame = recv_frame(self._conn)
                if frame.type == MsgType.RECORD:
                    _tid, _idx, rec = gw.unpack_record(frame.payload)
                    yield rec
                elif frame.type == MsgType.STREAM_END:
                    end = gw.unpack_stream_end(frame.payload)
                    self.stream_drops = int(end["drops"])
                    self.dropped_records += self.stream_drops
                    self.stream_status = end["status"]
                    return
                else:  # pragma: no cover - protocol violation
                    raise GatewayError(
                        f"unexpected {frame.type.name} inside a stream"
                    )

        return _gen()

    def result(self, tenant_id: str) -> RunReport:
        """Block until the tenant finishes; returns its RunReport with
        bit-exact records and final iterate.  Raises :class:`GatewayError`
        if it failed / was evicted / was cancelled instead."""
        reply = self._rpc(
            gw.pack_json(MsgType.RESULT, {"tenant_id": tenant_id})
        )
        return gw.unpack_report(reply.payload)

    def cancel(self, tenant_id: str) -> None:
        self._rpc(gw.pack_json(MsgType.CANCEL, {"tenant_id": tenant_id}))

    def metrics(self, format: str | None = None) -> dict:
        """Snapshot of the gateway process's ``repro.obs`` recorder (the
        METRICS verb; DESIGN.md §15).  Returns ``{"enabled": bool,
        "metrics": snapshot}`` — with ``format="prometheus"`` the reply also
        carries the text exposition under ``"prometheus"``.  Safe against a
        gateway that never enabled observability (``enabled: false``)."""
        body: dict = {}
        if format is not None:
            body["format"] = format
        reply = self._rpc(gw.pack_json(MsgType.METRICS, body))
        return gw.unpack_json(reply.payload)

    def evict(self, tenant_id: str) -> str:
        """Checkpoint + deschedule the tenant; returns the gateway-side
        FNLS1 path (resume it there with ``FedNLServer.resume``)."""
        reply = self._rpc(
            gw.pack_json(MsgType.EVICT, {"tenant_id": tenant_id})
        )
        return gw.unpack_json(reply.payload)["checkpoint"]


def stream_records(host: str, port: int, tenant_id: str):
    """One-shot helper: open a dedicated connection and stream one
    tenant's records (use while the submitting client's connection is
    busy with its own RPCs)."""
    with GatewayClient(host, port) as c:
        yield from c.stream(tenant_id)
