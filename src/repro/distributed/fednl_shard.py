"""Multi-node FedNL: clients sharded across mesh devices via shard_map.

The paper's multi-node setting (Section 7, 9.3) is a star topology: n clients
uplink (grad_i, S_i, l_i) over TCP to one master.  On a TPU mesh the natural
mapping is:

  * clients -> the `data` mesh axis (each device simulates/hosts a block of
    clients and runs the vmapped client body locally);
  * the master reduction -> ICI collectives;
  * the Newton solve -> replicated on every device (d is small; cheaper than
    sharding a (d, d) Cholesky and avoids a broadcast of x afterwards).

Two aggregation strategies (the collective is THE communication cost here —
the roofline collective term):

  dense_psum       faithful-to-paper semantics: every client's correction is
                   densified locally and `psum`-ed as a length-T vector.
                   Collective bytes per round ~ T * 8 * (ring factor).

  sparse_allgather beyond-paper (DESIGN.md §7): sparsifying compressors uplink
                   only (idx: int32, val: f64) pairs of length k per client;
                   devices `all_gather` the pairs and scatter-add locally.
                   Collective bytes ~ n_clients * k * 12 — a T/(k * n_local)
                   -fold reduction whenever k << T.  Exactly the paper's §5.6
                   "use sparsity from FedNL compressors" trick, applied to the
                   collective instead of the CPU master loop.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.api.accounting import payload_bits_fn, wire_bits_fn
from repro.compressors import get_compressor
from repro.compressors.core import scatter_add_sparse
from repro.core.fednl import (
    FedNLConfig,
    FedNLState,
    client_round,
    fednl_init,
    master_step,
)
from repro.linalg import triu_size, frob_norm_from_packed
from repro.objectives.logreg import logreg_oracles_packed


def shard_problem(z, mesh: Mesh, axis: str = "data"):
    """Place (n_clients, n_i, d) data with clients sharded over `axis`."""
    return jax.device_put(z, NamedSharding(mesh, P(axis, None, None)))


def sharded_fednl_init(z, cfg: FedNLConfig, mesh: Mesh, axis: str = "data", seed: int = 0):
    state = fednl_init(z, cfg, seed=seed)
    h_local = jax.device_put(state.h_local, NamedSharding(mesh, P(axis, None)))
    rep = NamedSharding(mesh, P())
    return FedNLState(
        x=jax.device_put(state.x, rep),
        h_local=h_local,
        h_global=jax.device_put(state.h_global, rep),
        key=jax.device_put(state.key, rep),
        round=jax.device_put(state.round, rep),
    )


def make_sharded_fednl_step(
    n_clients: int, d: int, cfg: FedNLConfig, mesh: Mesh, axis: str = "data",
    aggregate: str = "dense_psum", payload_dtype=None,
):
    """Shape-only builder: returns `step(z, h_local, x, h_global, key)`.

    Used both by make_sharded_fednl_round (with concrete data) and by the
    production-mesh dry-run (with ShapeDtypeStruct stand-ins).

    payload_dtype: optional cast applied to the sparse collective VALUES
    before the all_gather (e.g. jnp.float32 halves the wire payload; the
    accuracy consequence is measured in EXPERIMENTS.md §Perf).
    """
    t = triu_size(d)
    comp = get_compressor(cfg.compressor, t, cfg.k_for(d))
    alpha = comp.alpha if cfg.alpha is None else cfg.alpha
    pay_fn = payload_bits_fn(comp, d)
    wire_fn = wire_bits_fn(comp, d)
    n_dev = mesh.shape[axis]
    if n_clients % n_dev:
        raise ValueError(f"n_clients={n_clients} not divisible by mesh axis {axis}={n_dev}")
    if aggregate == "sparse_allgather" and comp.compress_sparse is None:
        raise ValueError(f"{cfg.compressor} has no sparse form; use dense_psum")

    def body(z_loc, h_loc, x, h_global, key):
        # per-device PRNG stream: fold in the device's position on the axis
        dev = jax.lax.axis_index(axis)
        key_dev = jax.random.fold_in(key, dev)
        n_loc = z_loc.shape[0]
        client_keys = jax.random.split(key_dev, n_loc)

        if aggregate == "dense_psum":
            f_i, grad_i, s_i, l_i, h_loc_new, sent_i = jax.vmap(
                lambda zi, hi, ki: client_round(
                    zi, hi, x, ki, comp, alpha, cfg.lam, cfg.hessian_impl
                )
            )(z_loc, h_loc, client_keys)
            s = jax.lax.psum(jnp.sum(s_i, axis=0), axis) / n_clients
        else:  # sparse_allgather
            def client_sparse(zi, hi, ki):
                f_i, grad_i, hp = logreg_oracles_packed(
                    zi, x, cfg.lam, hessian=cfg.hessian_impl
                )
                delta = hp - hi
                idx, vals, sent = comp.compress_sparse(ki, delta)
                s_dense_local = scatter_add_sparse(idx, vals, t)
                l_i = frob_norm_from_packed(delta, d)
                return f_i, grad_i, idx, vals, l_i, hi + alpha * s_dense_local, sent

            f_i, grad_i, idx_i, vals_i, l_i, h_loc_new, sent_i = jax.vmap(
                client_sparse
            )(z_loc, h_loc, client_keys)
            # the compressed collective: gather only (idx, val) pairs
            if payload_dtype is not None:
                vals_i = vals_i.astype(payload_dtype)
            idx_all = jax.lax.all_gather(idx_i, axis, tiled=True)
            vals_all = jax.lax.all_gather(vals_i, axis, tiled=True)
            vals_all = vals_all.astype(x.dtype)
            s = scatter_add_sparse(idx_all, vals_all, t) / n_clients

        grad = jax.lax.psum(jnp.sum(grad_i, axis=0), axis) / n_clients
        l = jax.lax.psum(jnp.sum(l_i), axis) / n_clients
        f = jax.lax.psum(jnp.sum(f_i), axis) / n_clients
        sent = jax.lax.psum(jnp.sum(sent_i), axis)
        # uplink wire bits under the Section-7 encodings (repro.api.accounting);
        # cfg.accounting selects payload-only vs full-frame accounting
        bits_payload = jax.lax.psum(jnp.sum(jax.vmap(pay_fn)(sent_i)), axis)
        bits_wire = jax.lax.psum(jnp.sum(jax.vmap(wire_fn)(sent_i)), axis)

        x_new = master_step(x, h_global, grad, l, cfg)
        h_global_new = h_global + alpha * s
        gn = jnp.linalg.norm(grad)
        return (h_loc_new, x_new, h_global_new, gn, f, l, sent,
                bits_payload, bits_wire)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(), P(), P()),
        out_specs=(P(axis), P(), P(), P(), P(), P(), P(), P(), P()),
        check_rep=False,
    )


def make_sharded_fednl_round(
    z, cfg: FedNLConfig, mesh: Mesh, axis: str = "data",
    aggregate: str = "dense_psum", payload_dtype=None,
) -> Callable[[FedNLState], tuple[FedNLState, dict]]:
    """Build the shard_mapped round; `z` must already be sharded over `axis`."""
    n_clients, _, d = z.shape
    sharded = make_sharded_fednl_step(
        n_clients, d, cfg, mesh, axis, aggregate, payload_dtype
    )

    def round_fn(state: FedNLState):
        key, sub = jax.random.split(state.key)
        (h_loc_new, x_new, h_global_new, gn, f, l, sent,
         bits_payload, bits_wire) = sharded(
            z, state.h_local, state.x, state.h_global, sub
        )
        new_state = FedNLState(
            x=x_new, h_local=h_loc_new, h_global=h_global_new,
            key=key, round=state.round + 1,
        )
        bits = bits_payload if cfg.accounting == "payload" else bits_wire
        return new_state, {"grad_norm": gn, "f": f, "l": l,
                           "sent_elems": sent, "sent_bits": bits,
                           "sent_bits_payload": bits_payload,
                           "sent_bits_wire": bits_wire}

    return round_fn
