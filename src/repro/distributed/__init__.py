from repro.distributed.fednl_shard import (
    make_sharded_fednl_round,
    make_sharded_fednl_step,
    shard_problem,
    sharded_fednl_init,
)

__all__ = [
    "make_sharded_fednl_round",
    "make_sharded_fednl_step",
    "shard_problem",
    "sharded_fednl_init",
]
