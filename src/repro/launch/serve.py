"""Serving launcher: batched greedy decoding through the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_lm_params
from repro.models.encdec import init_encdec_params
from repro.serving import ServeEngine, Request
from repro.train.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    init = init_encdec_params if cfg.family == "encdec" else init_lm_params
    params = init(jax.random.PRNGKey(0), cfg)
    if args.checkpoint:
        params = load_checkpoint(args.checkpoint, params)

    engine = ServeEngine(params, cfg, batch_size=args.batch, max_len=128)
    for r in range(args.requests):
        engine.submit(Request(prompt=[(r * 7 + i) % cfg.vocab for i in range(5)],
                              max_new_tokens=args.new_tokens))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done)
    print(f"{cfg.name}: served {len(done)} requests, {total} tokens "
          f"in {dt:.1f}s ({total / dt:.0f} tok/s)")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
