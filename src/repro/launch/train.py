"""LM training launcher.

On real hardware this drives the production mesh; in this container it runs
reduced configs on the host device (or a fake-device mesh via
XLA_FLAGS=--xla_force_host_platform_device_count=N set BEFORE launch).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50 --batch 8 --seq 64
"""

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import data_axes
from repro.launch.specs import _ns
from repro.models import init_lm_params
from repro.models.encdec import init_encdec_params, encdec_param_specs
from repro.models.lm import lm_param_specs
from repro.models.layers import set_sharding_axes
from repro.train import make_train_step, synthetic_token_stream, adamw_init
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4x2' -> (data, model) mesh over visible devices")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    init = init_encdec_params if cfg.family == "encdec" else init_lm_params
    params = init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=args.lr))

    if args.mesh:
        dims = tuple(int(v) for v in args.mesh.split("x"))
        mesh = jax.make_mesh(dims, ("data", "model"))
        set_sharding_axes(data_axes(mesh), "model",
                          dict(zip(mesh.axis_names, mesh.devices.shape)))
        spec_fn = encdec_param_specs if cfg.family == "encdec" else lm_param_specs
        psh = _ns(mesh, spec_fn(cfg))
        osh = {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}
        params = jax.device_put(params, psh)
        opt = jax.device_put(opt, osh)
        step = jax.jit(step, in_shardings=(psh, osh, None),
                       out_shardings=(psh, osh, None))
    else:
        step = jax.jit(step)

    stream = synthetic_token_stream(cfg, args.batch, args.seq)
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, m = step(params, opt, batch)
        if i % max(1, args.steps // 10) == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f}")
    print(f"{args.steps} steps in {time.perf_counter() - t0:.1f}s")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params)
        print(f"saved {args.checkpoint}")


if __name__ == "__main__":
    main()
