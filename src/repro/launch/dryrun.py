import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers AND compiles under the production sharding config.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod
    ... --json out.json   (machine-readable roofline terms per combination)

The XLA_FLAGS line above MUST run before any jax import: it gives this
CPU-only container 512 placeholder host devices so `jax.make_mesh` can build
the 16x16 (single-pod, 256 chips) and 2x16x16 (two-pod, 512 chips) meshes.
Only this entry point does that — tests/benches see the single real device.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh, data_axes
from repro.launch.specs import SHAPES, build_dryrun, param_abstract_and_shardings
from repro.models.layers import set_sharding_axes
from repro import roofline as rl


def _register_mesh_axes(mesh) -> None:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    set_sharding_axes(data_axes(mesh), "model", sizes)


def _compile_spec(spec):
    jitted = jax.jit(
        spec.step_fn,
        in_shardings=spec.in_shardings,
        out_shardings=spec.out_shardings,
    )
    lowered = jitted.lower(*spec.args)
    return lowered, lowered.compile()


def _measure(cfg, shape_name, mesh, batch_override=None):
    """Per-device (flops, hbm bytes, collective-bytes dict) of one compile."""
    spec = build_dryrun(cfg, shape_name, mesh, batch_override=batch_override)
    _, compiled = _compile_spec(spec)
    costs = compiled.cost_analysis()
    if isinstance(costs, list):
        costs = costs[0]
    colls = rl.collective_bytes(compiled.as_text())
    return {
        "flops": float(costs.get("flops", 0.0)),
        "hbm_bytes": float(costs.get("bytes accessed", 0.0)),
        **{f"coll:{k}": float(v) for k, v in colls.items()},
    }


def probe_roofline(cfg, shape_name: str, mesh) -> dict:
    """FLOPs/bytes/collectives of the FULL config via small unrolled probes.

    XLA's cost analysis does not multiply while-loop bodies by trip count, so
    the rolled production program under-reports.  Layers are homogeneous and
    stacked, so every cost metric is exactly linear in (L, A*L, A) where L is
    layer count and A the accumulation steps:  cost = a + b*L + c*A + d*A*L.
    Four small unrolled compiles (two for inference shapes, where A = 1)
    identify the coefficients; we extrapolate to the full configuration.
    """
    shape = SHAPES[shape_name]
    pat = len(cfg.hybrid.pattern) if cfg.hybrid else 1
    l1, l2 = 2 * pat, 4 * pat

    def shrink(layers, accum):
        kw = dict(n_layers=layers, accum_steps=accum, unroll_layers=True)
        if cfg.encoder_layers:
            kw["encoder_layers"] = layers
        # full attention does identical total work for any q_chunk (every
        # chunk attends all keys), so probes use larger chunks to cut the
        # number of unrolled bodies.  Windowed attention's work DOES depend
        # on q_chunk -> keep the production value there.
        if cfg.window is None and cfg.family != "hybrid":
            kw["q_chunk"] = 4096
        return dataclasses.replace(cfg, **kw)

    dp_size = 1
    for ax, size in zip(mesh.axis_names, mesh.devices.shape):
        if ax in ("pod", "data"):
            dp_size *= size

    if shape.kind == "train":
        a_full = max(1, min(cfg.accum_steps, shape.batch // dp_size))
        micro = shape.batch // a_full
        p1 = _measure(shrink(l1, 1), shape_name, mesh, batch_override=micro)
        p2 = _measure(shrink(l2, 1), shape_name, mesh, batch_override=micro)
        p3 = _measure(shrink(l1, 2), shape_name, mesh, batch_override=2 * micro)
        p4 = _measure(shrink(l2, 2), shape_name, mesh, batch_override=2 * micro)
        out = {}
        for k in p1:
            d = ((p4[k] - p3[k]) - (p2[k] - p1[k])) / (l2 - l1)
            b = (p2[k] - p1[k]) / (l2 - l1) - d
            c = p3[k] - p1[k] - d * l1
            a = p1[k] - b * l1 - c - d * l1
            out[k] = max(0.0, a + b * cfg.n_layers + c * a_full + d * a_full * cfg.n_layers)
        return out
    p1 = _measure(shrink(l1, 1), shape_name, mesh)
    p2 = _measure(shrink(l2, 1), shape_name, mesh)
    out = {}
    for k in p1:
        slope = (p2[k] - p1[k]) / (l2 - l1)
        out[k] = max(0.0, p1[k] + slope * (cfg.n_layers - l1))
    return out


def _parse_overrides(pairs: list[str]) -> dict:
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
            roofline_probes: bool = True, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    rec: dict = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
    }
    _register_mesh_axes(mesh)
    spec = build_dryrun(cfg, shape_name, mesh)
    if spec.skip:
        rec["status"] = "skip"
        rec["reason"] = spec.skip
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {spec.skip}")
        return rec

    t0 = time.perf_counter()
    try:
        # 1) the PRODUCTION program (rolled scans) must lower AND compile —
        #    this is the multi-pod dry-run proof, and its memory_analysis is
        #    the real per-device footprint.
        with mesh:
            lowered, compiled = _compile_spec(spec)
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        shape = SHAPES[shape_name]
        params_abs, _ = param_abstract_and_shardings(cfg, mesh)
        tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
        mf = rl.model_flops_global(cfg, params_abs, tokens=tokens, kind=shape.kind)

        rec.update(
            status="ok",
            note=spec.note,
            compile_s=round(t_compile, 2),
            n_params=rl.count_params(params_abs),
            n_params_active=rl.active_params(cfg, params_abs),
            memory_analysis={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            },
        )
        if verbose:
            print(f"[ok] {arch} x {shape_name} ({rec['mesh']}): compile {t_compile:.1f}s")
            print(f"     memory_analysis: {mem}")

        # 2) roofline terms from unrolled probes (single-pod table)
        if roofline_probes:
            with mesh:
                est = probe_roofline(cfg, shape_name, mesh)
            coll = {k[5:]: v for k, v in est.items() if k.startswith("coll:")}
            coll_total = sum(coll.values())
            terms = {
                "compute": est["flops"] / rl.PEAK_FLOPS,
                "memory": est["hbm_bytes"] / rl.HBM_BW,
                "collective": coll_total / rl.ICI_BW,
            }
            dominant = max(terms, key=terms.get)
            rec["roofline"] = {
                "flops": est["flops"],
                "hbm_bytes": est["hbm_bytes"],
                "coll_bytes": coll_total,
                "compute_s": terms["compute"],
                "memory_s": terms["memory"],
                "collective_s": terms["collective"],
                "dominant": dominant,
                "model_flops": mf / chips,
                "useful_fraction": (mf / chips) / est["flops"] if est["flops"] else None,
            }
            rec["collectives"] = coll
            if verbose:
                print(f"     cost (probe-extrapolated, per chip): flops={est['flops']:.3e} "
                      f"hbm={est['hbm_bytes']:.3e} coll={coll_total:.3e}")
                print(f"     roofline: compute={terms['compute']:.4f}s "
                      f"memory={terms['memory']:.4f}s collective={terms['collective']:.4f}s "
                      f"dominant={dominant} useful={rec['roofline']['useful_fraction']:.3f}")
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] {arch} x {shape_name}: {rec['error']}")
            traceback.print_exc()
    return rec


def run_fednl_dryrun(multi_pod: bool = False) -> list[dict]:
    """The paper's own technique on the production mesh: lower + compile the
    shard_mapped FedNL round (clients on the data axis) and extract its
    roofline terms for each aggregation strategy.  W8A dimensions scaled to
    one pod: d=301, n_i=348, n = 16 clients/data-shard.
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.fednl import FedNLConfig
    from repro.distributed import make_sharded_fednl_step
    from repro.linalg import triu_size

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    d, n_i = 301, 348
    dp = mesh.shape["data"] * (mesh.shape["pod"] if multi_pod else 1)
    n_clients = 16 * mesh.shape["data"]  # 16 clients per data shard
    t = triu_size(d)
    cfg = FedNLConfig(compressor="topk", k_multiplier=8.0, lam=1e-3)

    records = []
    variants = [
        ("dense_psum", None),
        ("sparse_allgather", None),
        ("sparse_allgather_f32", jnp.float32),
    ]
    for name, payload in variants:
        agg = "dense_psum" if name == "dense_psum" else "sparse_allgather"
        step = make_sharded_fednl_step(
            n_clients, d, cfg, mesh, "data", agg, payload_dtype=payload
        )
        z = jax.ShapeDtypeStruct((n_clients, n_i, d), jnp.float64)
        h_loc = jax.ShapeDtypeStruct((n_clients, t), jnp.float64)
        x = jax.ShapeDtypeStruct((d,), jnp.float64)
        h_glob = jax.ShapeDtypeStruct((t,), jnp.float64)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        sh = lambda spec: NamedSharding(mesh, spec)
        rec = {"arch": f"fednl/{name}", "shape": "w8a_round",
               "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips}
        try:
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(sh(P("data")), sh(P("data")), sh(P()), sh(P()), sh(P())),
                ).lower(z, h_loc, x, h_glob, key)
                compiled = lowered.compile()
            costs = compiled.cost_analysis()
            if isinstance(costs, list):
                costs = costs[0]
            colls = rl.collective_bytes(compiled.as_text())
            coll_total = float(sum(colls.values()))
            flops = float(costs.get("flops", 0.0))
            hbm = float(costs.get("bytes accessed", 0.0))
            rec.update(
                status="ok",
                roofline={
                    "flops": flops,
                    "hbm_bytes": hbm,
                    "coll_bytes": coll_total,
                    "compute_s": flops / rl.PEAK_FLOPS,
                    "memory_s": hbm / rl.HBM_BW,
                    "collective_s": coll_total / rl.ICI_BW,
                    "dominant": max(
                        [("compute", flops / rl.PEAK_FLOPS),
                         ("memory", hbm / rl.HBM_BW),
                         ("collective", coll_total / rl.ICI_BW)],
                        key=lambda kv: kv[1],
                    )[0],
                },
                collectives=colls,
            )
            print(f"[ok] fednl/{name} ({rec['mesh']}): flops={flops:.3e} "
                  f"hbm={hbm:.3e} coll={coll_total:.3e} "
                  f"dom={rec['roofline']['dominant']}")
        except Exception as e:  # noqa: BLE001
            rec.update(status="fail", error=f"{type(e).__name__}: {e}")
            print(f"[FAIL] fednl/{name}: {rec['error']}")
            traceback.print_exc()
        records.append(rec)
    return records


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=[*SHAPES, "all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-roofline", action="store_true",
                    help="compile-proof only, skip the probe extrapolation")
    ap.add_argument("--fednl", action="store_true",
                    help="dry-run the FedNL sharded round itself (both meshes)")
    ap.add_argument("--set", action="append", default=[], metavar="FIELD=VALUE",
                    help="ArchConfig override (hillclimb variants), repeatable")
    ap.add_argument("--json", default=None, help="write records to this file")
    args = ap.parse_args()

    if args.fednl:
        records = run_fednl_dryrun(multi_pod=False)
        records += run_fednl_dryrun(multi_pod=True)
        if args.json:
            with open(args.json, "w") as fh:
                json.dump(records, fh, indent=2, default=float)
        n_fail = sum(r["status"] == "fail" for r in records)
        print(f"\nfednl dry-run: {len(records) - n_fail} ok, {n_fail} fail")
        raise SystemExit(1 if n_fail else 0)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                # roofline table is a single-pod deliverable; multi-pod pass
                # is the sharding proof only
                probes = (not args.no_roofline) and not mp
                records.append(run_one(arch, shape, mp, roofline_probes=probes,
                                       overrides=_parse_overrides(args.set)))
                sys.stdout.flush()
                if args.json:  # incremental checkpointing of the sweep
                    with open(args.json, "w") as fh:
                        json.dump(records, fh, indent=2, default=float)

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    n_fail = sum(r["status"] == "fail" for r in records)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(records, fh, indent=2, default=float)
        print(f"wrote {args.json}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
