"""Multi-process FedNL over TCP localhost — master + n client workers.

    PYTHONPATH=src python -m repro.launch.multiproc \
        --dataset tiny --compressor topk --rounds 40 --tol 1e-14 --check

The master process binds a localhost socket, spawns one OS process per client
(``multiprocessing`` spawn context: each child gets a fresh JAX runtime), and
runs the star event loop of ``repro.comm.star``.  Data distribution follows
the paper's experiment harness: every worker regenerates the deterministic
synthetic dataset from the shared seed and keeps only its own shard — no
training data crosses the wire, exactly the federated premise.

``--check`` reruns the same problem through the single-node ``run_fednl``
simulation and reports the max iterate/trajectory deviation (the star run is
designed to be bit-identical; see DESIGN.md §5).
"""

from __future__ import annotations

import argparse
import dataclasses
import multiprocessing as mp
import os

from repro.core.fednl import FedNLConfig


def _build_problem(dataset: str, shape, seed: int):
    import jax.numpy as jnp

    from repro.data import (
        DATASET_SHAPES,
        add_intercept,
        make_synthetic_logreg,
        partition_clients,
    )

    name_or_dims = shape if shape is not None else dataset
    if isinstance(name_or_dims, str):
        d, n, n_i = DATASET_SHAPES[name_or_dims]
    else:
        d, n, n_i = name_or_dims
    x, y = make_synthetic_logreg(name_or_dims, seed=seed)
    return jnp.asarray(partition_clients(add_intercept(x), y, n, n_i, seed=seed))


def _client_entry(
    client_id: int,
    n_clients: int,
    dataset: str,
    shape,
    cfg_dict: dict,
    seed: int,
    host: str,
    port: int,
) -> None:
    """Client process: build shard, dial the master, serve rounds."""
    import jax

    jax.config.update("jax_enable_x64", True)  # FedNL is FP64 end-to-end
    from repro.comm.star import StarClient
    from repro.comm.transport import connect_to_master

    z = _build_problem(dataset, shape, seed)
    conn = connect_to_master(host, port, client_id)
    client = StarClient(
        client_id, n_clients, z[client_id], FedNLConfig(**cfg_dict), conn, seed=seed
    )
    client.run()


def run_multiproc(
    cfg: FedNLConfig,
    dataset: str = "tiny",
    shape: tuple[int, int, int] | None = None,
    rounds: int = 100,
    tol: float = 0.0,
    seed: int = 0,
    host: str = "127.0.0.1",
):
    """Library entry: spawn client processes, run the master loop, join.

    Returns the :class:`repro.comm.star.StarRunResult` of the master.
    """
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.comm.star import run_star_master
    from repro.comm.transport import TCPMaster

    z = _build_problem(dataset, shape, seed)
    n_clients, _, d = z.shape

    master = TCPMaster(n_clients, host=host)
    # spawn (not fork): children must re-initialize the JAX runtime cleanly
    ctx = mp.get_context("spawn")
    # make `repro` importable in the children regardless of the parent's cwd
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    old_pp = os.environ.get("PYTHONPATH")
    os.environ["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    procs = []
    try:
        for i in range(n_clients):
            p = ctx.Process(
                target=_client_entry,
                args=(
                    i,
                    n_clients,
                    dataset,
                    shape,
                    dataclasses.asdict(cfg),
                    seed,
                    host,
                    master.port,
                ),
                daemon=True,
            )
            p.start()
            procs.append(p)
        conns = master.accept_clients()
        result = run_star_master(conns, d, cfg, rounds=rounds, tol=tol)
        for conn in conns.values():
            conn.close()
        for p in procs:
            p.join(timeout=60)
        return result
    finally:
        if old_pp is None:
            os.environ.pop("PYTHONPATH", None)
        else:
            os.environ["PYTHONPATH"] = old_pp
        for p in procs:
            if p.is_alive():
                p.terminate()
        master.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tiny")
    ap.add_argument("--compressor", default="topk")
    ap.add_argument("--k-multiplier", type=float, default=8.0)
    ap.add_argument("--option", default="B", choices=["A", "B"])
    ap.add_argument("--lam", type=float, default=1e-3)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--tol", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="compare against the single-node run_fednl trajectory")
    args = ap.parse_args()

    cfg = FedNLConfig(
        compressor=args.compressor,
        k_multiplier=args.k_multiplier,
        option=args.option,
        lam=args.lam,
        mu=args.lam,
    )
    res = run_multiproc(
        cfg, dataset=args.dataset, rounds=args.rounds, tol=args.tol, seed=args.seed
    )
    if res.rounds == 0:
        print("rounds=0 (nothing to run; INIT/STOP handshake only)")
        return
    mb = res.measured_frame_bytes.sum() / 1e6
    print(f"rounds={res.rounds} ||grad||={res.grad_norms[-1]:.3e} "
          f"f={res.f_vals[-1]:.8f} wall={res.wall_time_s:.2f}s")
    print(f"uplink: measured {mb:.2f} MB framed, "
          f"payload bits measured=={'analytic' if (res.measured_payload_bits == res.sent_bits).all() else 'MISMATCH'}")

    if args.check:
        import numpy as np

        from repro.core import run_fednl

        z = _build_problem(args.dataset, None, args.seed)
        ref = run_fednl(z, cfg, rounds=args.rounds, tol=args.tol, seed=args.seed)
        r = min(res.rounds, ref.rounds)
        dx = float(np.max(np.abs(res.x - ref.x)))
        dg = float(np.max(np.abs(res.grad_norms[:r] - ref.grad_norms[:r])))
        print(f"vs single-node: max|x_tcp - x_sim|={dx:.3e} "
              f"max|gn_tcp - gn_sim|={dg:.3e} (paper target <= 1e-8)")


if __name__ == "__main__":
    main()
