"""Multi-process FedNL / FedNL-PP over TCP localhost — master + n workers.

    PYTHONPATH=src python -m repro.launch.multiproc \
        --dataset tiny --compressor topk --rounds 40 --tol 1e-14 --check

    # partial participation (Algorithm 3), 3-of-8 clients per round,
    # 20% fault-injected dropout handled by survivor partial sums:
    PYTHONPATH=src python -m repro.launch.multiproc \
        --algo fednl-pp --tau 3 --drop-prob 0.2 --rounds 60 --check

The master process binds a localhost socket, spawns one OS process per client
(``multiprocessing`` spawn context: each child gets a fresh JAX runtime), and
runs the star event loop of ``repro.comm.star`` (full participation) or
``repro.comm.star_pp`` (FedNL-PP: only the sampled tau clients receive or do
any work each round).  Data distribution follows the paper's experiment
harness: every worker regenerates the deterministic synthetic dataset from
the shared seed and keeps only its own shard — no training data crosses the
wire, exactly the federated premise.

``--check`` reruns the same problem through the single-node simulation
(``run_fednl`` / ``run_fednl_pp``) and reports the max iterate/trajectory
deviation (fault-free runs are designed to be bit-identical; DESIGN.md §5/§5a).
"""

from __future__ import annotations

import argparse
import dataclasses
import multiprocessing as mp
import os
import threading

from repro.core.fednl import FedNLConfig


def _build_problem(dataset: str, shape, seed: int):
    # one data pipeline for every backend: the shards a TCP worker builds
    # must be bit-identical to what solve() materializes everywhere else
    from repro.api.spec import DataSpec

    return DataSpec(dataset=dataset or "tiny", shape=shape, seed=seed).build()


def _client_entry(
    client_id: int,
    n_clients: int,
    dataset: str,
    shape,
    cfg_dict: dict,
    seed: int,
    host: str,
    port: int,
    pp: bool = False,
    fault_dict: dict | None = None,
    data_seed: int | None = None,
) -> None:
    """Client process: build shard, dial the master, serve rounds."""
    import jax

    jax.config.update("jax_enable_x64", True)  # FedNL is FP64 end-to-end
    from repro.comm.transport import connect_to_master

    z = _build_problem(dataset, shape, seed if data_seed is None else data_seed)
    conn = connect_to_master(host, port, client_id)
    if pp:
        from repro.comm.star_pp import StarPPClient
        from repro.comm.transport import FaultSpec

        fault = FaultSpec(**fault_dict) if fault_dict else None
        client = StarPPClient(
            client_id,
            n_clients,
            z[client_id],
            FedNLConfig(**cfg_dict),
            conn,
            seed=seed,
            fault=fault,
        )
    else:
        from repro.comm.star import StarClient

        client = StarClient(
            client_id, n_clients, z[client_id], FedNLConfig(**cfg_dict), conn, seed=seed
        )
    client.run()


def _aggregator_entry(
    agg_id: int,
    subtree,
    n_clients: int,
    d: int,
    dataset: str,
    shape,
    cfg_dict: dict,
    seed: int,
    parent_host: str,
    parent_port: int,
    combine: str,
    data_seed: int | None = None,
) -> None:
    """Aggregator process: bind a listener for the subtree, spawn its
    children (leaf client processes and nested aggregators), dial the
    parent, serve AGG rounds.

    Teardown ordering is the contract (the PR 6 refcount fix, one level
    deeper): the subtree's children are released — connections closed,
    processes joined — BEFORE this node closes its own listener and parent
    connection, so a tree tears down leaves-first and the root's
    ``ClientCluster.close()`` never abandons a grandchild.
    """
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.comm.topology import build_aggregator
    from repro.comm.transport import TCPMaster, connect_to_master

    subtree = tuple(subtree)
    listener = TCPMaster(len(subtree), host=parent_host)
    procs: list = []
    children: dict = {}
    parent_conn = None
    try:
        agg_children = set()
        to_spawn = []
        for pos, node in enumerate(subtree):
            if isinstance(node, (tuple, list)):
                agg_children.add(pos)
                # non-daemon: a daemonic process may not spawn its own
                # children, and nested aggregators spawn a subtree
                to_spawn.append(
                    (
                        _aggregator_entry,
                        (
                            pos, tuple(node), n_clients, d, dataset, shape,
                            cfg_dict, seed, parent_host, listener.port,
                            combine, data_seed,
                        ),
                        False,
                    )
                )
            else:
                to_spawn.append(
                    (
                        _client_entry,
                        (
                            int(node), n_clients, dataset, shape, cfg_dict,
                            seed, parent_host, listener.port, False, None,
                            data_seed,
                        ),
                        True,
                    )
                )
        procs = _spawn_procs(to_spawn)
        children = listener.accept_clients()
        parent_conn = connect_to_master(parent_host, parent_port, agg_id)
        cfg = FedNLConfig(**cfg_dict)
        node = build_aggregator(
            agg_id, parent_conn, children, d, cfg,
            combine=combine, agg_children=agg_children,
        )
        node.run()
    finally:
        # children first: conns closed + procs joined before our own
        # listener/parent sockets go away
        for conn in children.values():
            conn.close()
        for p in procs:
            p.join(timeout=60)
        for p in procs:
            if p.is_alive():
                p.terminate()
        listener.close()
        if parent_conn is not None:
            parent_conn.close()


# serializes the PYTHONPATH mutate-spawn-restore window across threads
# (solve_many dispatches star-tcp specs from a worker pool)
_SPAWN_ENV_LOCK = threading.Lock()


def _spawn_procs(targets) -> list:
    """Start one spawn-context process per ``(target, args, daemon)`` triple
    with ``src/`` on the children's PYTHONPATH (mutate-spawn-restore under
    the shared lock).  Children capture os.environ at start(), so nested
    spawns — aggregator processes spawning their own subtrees — inherit the
    path without re-mutating anything.  ``daemon`` must be False for any
    child that spawns processes of its own (aggregators)."""
    ctx = mp.get_context("spawn")
    src_dir = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    procs: list = []
    with _SPAWN_ENV_LOCK:
        old_pp = os.environ.get("PYTHONPATH")
        os.environ["PYTHONPATH"] = src_dir + (
            os.pathsep + old_pp if old_pp else ""
        )
        try:
            for target, args, daemon in targets:
                p = ctx.Process(target=target, args=args, daemon=daemon)
                p.start()
                procs.append(p)
        finally:
            if old_pp is None:
                os.environ.pop("PYTHONPATH", None)
            else:
                os.environ["PYTHONPATH"] = old_pp
    return procs

# every live (not yet closed) cluster, so a serving engine — or a test —
# can prove no process fleet leaked after shutdown/eviction; guarded by its
# own lock because clusters are created/closed from pool threads
_LIVE_CLUSTERS: "set[ClientCluster]" = set()
_LIVE_LOCK = threading.Lock()


class ClientCluster:
    """A live fleet of TCP client processes around one bound master socket.

    Extracted from the closed run-everything scaffold so the star-tcp
    Session backend can keep the cluster open across ``step()`` calls (and
    across a save/resume boundary: a resumed session simply spawns a fresh
    cluster — client state is rebuilt by protocol replay, never persisted).
    ``run_multiproc[_pp]`` still compose it into the classic bind -> spawn ->
    run -> join shape.

    Lifecycle under shared use (the multi-tenant serving engine holds many
    clusters at once): each cluster is reference-counted — ``acquire()``
    adds a holder, ``release()`` drops one and tears the fleet down when the
    last holder lets go — and ``close()`` is an idempotent force-teardown
    that any holder may call (an engine evicting a star-tcp tenant mid-run,
    or its exception path, must never leak subprocesses no matter how many
    holders remain).  ``live_count()`` / ``close_all()`` expose the global
    registry of not-yet-closed clusters for shutdown sweeps and leak
    assertions.
    """

    def __init__(
        self,
        dataset: str,
        shape,
        seed: int,
        host: str = "127.0.0.1",
        pp: bool = False,
        fault_dict: dict | None = None,
        data_seed: int | None = None,
        cfg: FedNLConfig | None = None,
    ):
        import jax

        jax.config.update("jax_enable_x64", True)
        from repro.comm.transport import TCPMaster

        # dims only — the master never holds the training data; workers
        # rebuild their shard from the data seed
        from repro.api.spec import DataSpec

        d, n_clients, _ = DataSpec(
            dataset=dataset or "tiny",
            shape=shape,
            seed=seed if data_seed is None else data_seed,
        ).dims()
        self.d = d
        self.n_clients = n_clients
        self._master = TCPMaster(n_clients, host=host)
        self._init_lifecycle()
        cfg_dict = dataclasses.asdict(cfg) if cfg is not None else {}
        self.procs: list = []
        self.conns: dict = {}
        # spawn + accept under one guard: a mid-loop start() failure (fd/pid
        # exhaustion under solve_many's concurrent star-tcp pool) must not
        # leak the bound master socket or already-started children
        try:
            self.procs = _spawn_procs(
                [
                    (
                        _client_entry,
                        (
                            i, n_clients, dataset, shape, cfg_dict, seed,
                            host, self._master.port, pp, fault_dict,
                            data_seed,
                        ),
                        True,
                    )
                    for i in range(n_clients)
                ]
            )
            self.conns = self._master.accept_clients()
        except Exception:
            self.close(join_timeout=5)
            raise

    def _init_lifecycle(self) -> None:
        """Refcount + leak-registry bookkeeping shared with subclasses
        (registration happens only after the master socket bound — a failed
        bind must not leave a phantom entry in the _LIVE registry)."""
        self._refs = 1  # the creator holds the first reference
        self._closed = False
        self._lifecycle_lock = threading.Lock()
        with _LIVE_LOCK:
            _LIVE_CLUSTERS.add(self)

    def acquire(self) -> "ClientCluster":
        """Register another holder of this (open) cluster."""
        with self._lifecycle_lock:
            if self._closed:
                raise RuntimeError("cannot acquire a closed ClientCluster")
            self._refs += 1
        return self

    def release(self, join_timeout: float = 60) -> None:
        """Drop one holder; the last release tears the fleet down."""
        with self._lifecycle_lock:
            self._refs = max(0, self._refs - 1)
            last = self._refs == 0
        if last:
            self.close(join_timeout=join_timeout)

    def close(self, join_timeout: float = 60) -> None:
        """Close connections, join (then terminate) workers, unbind.

        Idempotent force-teardown: safe to call from any holder (or twice —
        e.g. an engine's exception path after a normal release), regardless
        of the reference count.
        """
        with self._lifecycle_lock:
            if self._closed:
                return
            self._closed = True
            self._refs = 0
        with _LIVE_LOCK:
            _LIVE_CLUSTERS.discard(self)
        for conn in self.conns.values():
            conn.close()
        for p in self.procs:
            p.join(timeout=join_timeout)
        for p in self.procs:
            if p.is_alive():
                p.terminate()
        self._master.close()

    @property
    def closed(self) -> bool:
        return self._closed

    @classmethod
    def live_count(cls) -> int:
        """Number of clusters created but not yet closed (leak probe)."""
        with _LIVE_LOCK:
            return len(_LIVE_CLUSTERS)

    @classmethod
    def close_all(cls, join_timeout: float = 10) -> int:
        """Force-close every live cluster (engine shutdown sweep); returns
        how many were closed."""
        with _LIVE_LOCK:
            stragglers = list(_LIVE_CLUSTERS)
        for c in stragglers:
            c.close(join_timeout=join_timeout)
        return len(stragglers)


class TreeClientCluster(ClientCluster):
    """A live process *tree* for a tree-of-stars run (repro.comm.topology).

    The root binds one listener; each immediate child is an aggregator
    process (``_aggregator_entry``) owning a subtree — which in turn spawns
    its leaf client processes and any deeper aggregators.  ``conns`` are
    keyed by root-subtree index (the aggregator node ids a TreeMaster
    expects), not client ids.  Shares :class:`ClientCluster`'s refcounted
    lifecycle and ``_LIVE`` registry, so ``live_count()``/``close_all()``
    leak probes cover process trees too; teardown is leaves-first — each
    aggregator releases its children before closing its own sockets, and
    only then does the root's :meth:`close` join the aggregator processes.
    """

    def __init__(
        self,
        dataset: str,
        shape,
        seed: int,
        topology,
        host: str = "127.0.0.1",
        data_seed: int | None = None,
        cfg: FedNLConfig | None = None,
    ):
        import jax

        jax.config.update("jax_enable_x64", True)
        from repro.api.spec import DataSpec
        from repro.comm.transport import TCPMaster

        d, n_clients, _ = DataSpec(
            dataset=dataset or "tiny",
            shape=shape,
            seed=seed if data_seed is None else data_seed,
        ).dims()
        self.d = d
        self.n_clients = n_clients
        tree = topology.resolve(n_clients)
        self._master = TCPMaster(len(tree), host=host)
        self._init_lifecycle()
        cfg_dict = dataclasses.asdict(cfg) if cfg is not None else {}
        self.procs = []
        self.conns = {}
        try:
            self.procs = _spawn_procs(
                [
                    (
                        _aggregator_entry,
                        (
                            i, subtree, n_clients, d, dataset, shape,
                            cfg_dict, seed, host, self._master.port,
                            topology.combine, data_seed,
                        ),
                        # aggregators spawn their own children, so they
                        # cannot be daemonic
                        False,
                    )
                    for i, subtree in enumerate(tree)
                ]
            )
            self.conns = self._master.accept_clients()
        except Exception:
            self.close(join_timeout=5)
            raise


def _run_with_clients(
    cfg: FedNLConfig,
    dataset: str,
    shape,
    seed: int,
    host: str,
    master_fn,
    pp: bool = False,
    fault_dict: dict | None = None,
    data_seed: int | None = None,
):
    """Shared scaffold: bind, spawn one process per client, run, join.

    ``master_fn(conns, d) -> result`` is the hub loop (full or PP).
    ``data_seed`` decouples the synthetic-data seed from the algorithm PRNG
    seed (default: same, the historical behaviour).
    """
    cluster = ClientCluster(
        dataset,
        shape,
        seed,
        host=host,
        pp=pp,
        fault_dict=fault_dict,
        data_seed=data_seed,
        cfg=cfg,
    )
    try:
        return master_fn(cluster.conns, cluster.d)
    finally:
        cluster.close()


def run_multiproc(
    cfg: FedNLConfig,
    dataset: str = "tiny",
    shape: tuple[int, int, int] | None = None,
    rounds: int = 100,
    tol: float = 0.0,
    seed: int = 0,
    host: str = "127.0.0.1",
    data_seed: int | None = None,
):
    """Library entry: spawn client processes, run the master loop, join.

    Returns the :class:`repro.comm.star.StarRunResult` of the master.
    (Prefer ``repro.api.solve`` with ``backend='star-tcp'`` — this is the
    driver that backend wraps.)
    """
    from repro.comm.star import run_star_master

    def master_fn(conns, d):
        return run_star_master(conns, d, cfg, rounds=rounds, tol=tol)

    return _run_with_clients(
        cfg, dataset, shape, seed, host, master_fn, data_seed=data_seed
    )


def run_multiproc_pp(
    cfg: FedNLConfig,
    tau: int,
    dataset: str = "tiny",
    shape: tuple[int, int, int] | None = None,
    rounds: int = 100,
    seed: int = 0,
    host: str = "127.0.0.1",
    on_dropout: str = "partial",
    fault=None,
    data_seed: int | None = None,
):
    """FedNL-PP over TCP localhost: tau-of-n sampling per round, optional
    fault injection (``fault``: a :class:`repro.comm.transport.FaultSpec`).

    Returns the :class:`repro.comm.star_pp.StarPPRunResult` of the master.
    (Prefer ``repro.api.solve`` with ``backend='star-tcp'`` — this is the
    driver that backend wraps.)
    """
    from repro.comm.star_pp import StarPPMaster

    def master_fn(conns, d):
        master = StarPPMaster(
            conns, d, cfg, tau, seed=seed, on_dropout=on_dropout
        )
        return master.run(rounds)

    return _run_with_clients(
        cfg,
        dataset,
        shape,
        seed,
        host,
        master_fn,
        pp=True,
        fault_dict=dataclasses.asdict(fault) if fault is not None else None,
        data_seed=data_seed,
    )


def main() -> None:
    """CLI: build one declarative ExperimentSpec, solve it on star-tcp, and
    (with --check) re-solve the *same spec* on the local backend — the
    cross-backend reproducibility claim as a one-field change."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="fednl", choices=["fednl", "fednl-pp"])
    ap.add_argument("--dataset", default="tiny")
    ap.add_argument("--compressor", default="topk")
    ap.add_argument("--k-multiplier", type=float, default=8.0)
    ap.add_argument("--option", default="B", choices=["A", "B"])
    ap.add_argument("--lam", type=float, default=1e-3)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--tol", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="compare against the single-node simulation trajectory")
    # FedNL-PP options
    ap.add_argument("--tau", type=int, default=0,
                    help="PP: sampled clients per round (default n//2)")
    ap.add_argument("--on-dropout", default="partial",
                    choices=["partial", "resample"])
    ap.add_argument("--drop-prob", type=float, default=0.0)
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--straggler-delay", type=float, default=0.05)
    args = ap.parse_args()

    import numpy as np

    from repro.api import (
        CompressorSpec,
        DataSpec,
        ExperimentSpec,
        FaultSpec,
        solve,
    )

    pp = args.algo == "fednl-pp"
    fault = None
    if pp and (args.drop_prob > 0 or args.straggler_prob > 0):
        fault = FaultSpec(
            drop_prob=args.drop_prob,
            straggler_prob=args.straggler_prob,
            straggler_delay_s=args.straggler_delay,
            seed=args.seed,
        )
    spec = ExperimentSpec(
        lam=args.lam,
        data=DataSpec(dataset=args.dataset, seed=args.seed),
        algorithm=args.algo,
        compressor=CompressorSpec(args.compressor, args.k_multiplier),
        option=args.option,
        mu=args.lam,
        tau=args.tau if (pp and args.tau > 0) else None,
        on_dropout=args.on_dropout,
        fault=fault,
        backend="star-tcp",
        rounds=args.rounds,
        tol=args.tol,
        seed=args.seed,
    )
    rep = solve(spec)
    if rep.rounds == 0:
        print("rounds=0 (nothing to run; INIT/STOP handshake only)")
        return
    print(rep.summary())
    frame_kb = rep.extras["measured_frame_bytes"].sum() / 1e3
    bits_match = (rep.extras["measured_payload_bits"] == rep.sent_bits_payload).all()
    print(f"uplink: measured {frame_kb:.1f} kB framed, payload bits "
          f"measured=={'analytic' if bits_match else 'MISMATCH'}")
    if pp:
        parts = sum(len(p) for p in rep.participants)
        drops = sum(len(d) for d in rep.dropped)
        print(f"tau={rep.extras['tau']} contributions={parts} drops={drops}")

    if args.check:
        if pp:
            # the PP diagnostic rebuilds the problem on the master; only pay
            # for it when the user asked for the parity check
            print(f"||grad(x_final)||={rep.final_grad_norm:.3e}")
        if pp and fault is not None:
            # no fault-free reference to compare a faulted trajectory against
            print("--check skipped: faulted PP runs diverge from the "
                  "fault-free simulation by design")
            return
        ref = solve(spec.replace(backend="local", fault=None))
        if pp:
            dx = float(np.max(np.abs(rep.x_hist - ref.x_hist)))
            print(f"vs single-node PP: max|x_tcp - x_sim|={dx:.3e} "
                  "(fault-free runs are bit-identical; target 0)")
        else:
            r = min(rep.rounds, ref.rounds)
            dx = float(np.max(np.abs(rep.x - ref.x)))
            dg = float(np.max(np.abs(rep.grad_norms[:r] - ref.grad_norms[:r])))
            print(f"vs single-node: max|x_tcp - x_sim|={dx:.3e} "
                  f"max|gn_tcp - gn_sim|={dg:.3e} (paper target <= 1e-8)")


if __name__ == "__main__":
    main()
