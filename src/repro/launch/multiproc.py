"""Multi-process FedNL / FedNL-PP over TCP localhost — master + n workers.

    PYTHONPATH=src python -m repro.launch.multiproc \
        --dataset tiny --compressor topk --rounds 40 --tol 1e-14 --check

    # partial participation (Algorithm 3), 3-of-8 clients per round,
    # 20% fault-injected dropout handled by survivor partial sums:
    PYTHONPATH=src python -m repro.launch.multiproc \
        --algo fednl-pp --tau 3 --drop-prob 0.2 --rounds 60 --check

The master process binds a localhost socket, spawns one OS process per client
(``multiprocessing`` spawn context: each child gets a fresh JAX runtime), and
runs the star event loop of ``repro.comm.star`` (full participation) or
``repro.comm.star_pp`` (FedNL-PP: only the sampled tau clients receive or do
any work each round).  Data distribution follows the paper's experiment
harness: every worker regenerates the deterministic synthetic dataset from
the shared seed and keeps only its own shard — no training data crosses the
wire, exactly the federated premise.

``--check`` reruns the same problem through the single-node simulation
(``run_fednl`` / ``run_fednl_pp``) and reports the max iterate/trajectory
deviation (fault-free runs are designed to be bit-identical; DESIGN.md §5/§5a).
"""

from __future__ import annotations

import argparse
import dataclasses
import multiprocessing as mp
import os

from repro.core.fednl import FedNLConfig


def _build_problem(dataset: str, shape, seed: int):
    import jax.numpy as jnp

    from repro.data import (
        DATASET_SHAPES,
        add_intercept,
        make_synthetic_logreg,
        partition_clients,
    )

    name_or_dims = shape if shape is not None else dataset
    if isinstance(name_or_dims, str):
        d, n, n_i = DATASET_SHAPES[name_or_dims]
    else:
        d, n, n_i = name_or_dims
    x, y = make_synthetic_logreg(name_or_dims, seed=seed)
    return jnp.asarray(partition_clients(add_intercept(x), y, n, n_i, seed=seed))


def _client_entry(
    client_id: int,
    n_clients: int,
    dataset: str,
    shape,
    cfg_dict: dict,
    seed: int,
    host: str,
    port: int,
    pp: bool = False,
    fault_dict: dict | None = None,
) -> None:
    """Client process: build shard, dial the master, serve rounds."""
    import jax

    jax.config.update("jax_enable_x64", True)  # FedNL is FP64 end-to-end
    from repro.comm.transport import connect_to_master

    z = _build_problem(dataset, shape, seed)
    conn = connect_to_master(host, port, client_id)
    if pp:
        from repro.comm.star_pp import StarPPClient
        from repro.comm.transport import FaultSpec

        fault = FaultSpec(**fault_dict) if fault_dict else None
        client = StarPPClient(
            client_id,
            n_clients,
            z[client_id],
            FedNLConfig(**cfg_dict),
            conn,
            seed=seed,
            fault=fault,
        )
    else:
        from repro.comm.star import StarClient

        client = StarClient(
            client_id, n_clients, z[client_id], FedNLConfig(**cfg_dict), conn, seed=seed
        )
    client.run()


def _run_with_clients(
    cfg: FedNLConfig,
    dataset: str,
    shape,
    seed: int,
    host: str,
    master_fn,
    pp: bool = False,
    fault_dict: dict | None = None,
):
    """Shared scaffold: bind, spawn one process per client, run, join.

    ``master_fn(conns, d) -> result`` is the hub loop (full or PP).
    """
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.comm.transport import TCPMaster

    z = _build_problem(dataset, shape, seed)
    n_clients, _, d = z.shape

    master = TCPMaster(n_clients, host=host)
    # spawn (not fork): children must re-initialize the JAX runtime cleanly
    ctx = mp.get_context("spawn")
    # make `repro` importable in the children regardless of the parent's cwd
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    old_pp = os.environ.get("PYTHONPATH")
    os.environ["PYTHONPATH"] = src_dir + (os.pathsep + old_pp if old_pp else "")
    procs = []
    try:
        for i in range(n_clients):
            p = ctx.Process(
                target=_client_entry,
                args=(
                    i,
                    n_clients,
                    dataset,
                    shape,
                    dataclasses.asdict(cfg),
                    seed,
                    host,
                    master.port,
                    pp,
                    fault_dict,
                ),
                daemon=True,
            )
            p.start()
            procs.append(p)
        conns = master.accept_clients()
        result = master_fn(conns, d)
        for conn in conns.values():
            conn.close()
        for p in procs:
            p.join(timeout=60)
        return result
    finally:
        if old_pp is None:
            os.environ.pop("PYTHONPATH", None)
        else:
            os.environ["PYTHONPATH"] = old_pp
        for p in procs:
            if p.is_alive():
                p.terminate()
        master.close()


def run_multiproc(
    cfg: FedNLConfig,
    dataset: str = "tiny",
    shape: tuple[int, int, int] | None = None,
    rounds: int = 100,
    tol: float = 0.0,
    seed: int = 0,
    host: str = "127.0.0.1",
):
    """Library entry: spawn client processes, run the master loop, join.

    Returns the :class:`repro.comm.star.StarRunResult` of the master.
    """
    from repro.comm.star import run_star_master

    def master_fn(conns, d):
        return run_star_master(conns, d, cfg, rounds=rounds, tol=tol)

    return _run_with_clients(cfg, dataset, shape, seed, host, master_fn)


def run_multiproc_pp(
    cfg: FedNLConfig,
    tau: int,
    dataset: str = "tiny",
    shape: tuple[int, int, int] | None = None,
    rounds: int = 100,
    seed: int = 0,
    host: str = "127.0.0.1",
    on_dropout: str = "partial",
    fault=None,
):
    """FedNL-PP over TCP localhost: tau-of-n sampling per round, optional
    fault injection (``fault``: a :class:`repro.comm.transport.FaultSpec`).

    Returns the :class:`repro.comm.star_pp.StarPPRunResult` of the master.
    """
    from repro.comm.star_pp import StarPPMaster

    def master_fn(conns, d):
        master = StarPPMaster(
            conns, d, cfg, tau, seed=seed, on_dropout=on_dropout
        )
        return master.run(rounds)

    return _run_with_clients(
        cfg,
        dataset,
        shape,
        seed,
        host,
        master_fn,
        pp=True,
        fault_dict=dataclasses.asdict(fault) if fault is not None else None,
    )


def _main_pp(args, cfg: FedNLConfig) -> None:
    from repro.comm.transport import FaultSpec

    fault = None
    if args.drop_prob > 0 or args.straggler_prob > 0:
        fault = FaultSpec(
            drop_prob=args.drop_prob,
            straggler_prob=args.straggler_prob,
            straggler_delay_s=args.straggler_delay,
            seed=args.seed,
        )
    res = run_multiproc_pp(
        cfg,
        tau=args.tau,
        dataset=args.dataset,
        rounds=args.rounds,
        seed=args.seed,
        on_dropout=args.on_dropout,
        fault=fault,
    )
    drops = sum(len(d) for d in res.dropped)
    parts = sum(len(p) for p in res.participants)
    kb = res.measured_frame_bytes.sum() / 1e3
    print(f"rounds={res.rounds} tau={args.tau} contributions={parts} "
          f"drops={drops} wall={res.wall_time_s:.2f}s")
    print(f"uplink: {kb:.1f} kB framed, payload bits measured=="
          f"{'analytic' if (res.measured_payload_bits == res.sent_bits).all() else 'MISMATCH'}")

    if args.check:
        import jax.numpy as jnp
        import numpy as np

        from repro.core import eval_full, run_fednl_pp

        z = _build_problem(args.dataset, None, args.seed)
        _, g = eval_full(z, jnp.asarray(res.x), cfg.lam)
        print(f"||grad(x_final)||={float(jnp.linalg.norm(g)):.3e}")
        if fault is None:
            ref = run_fednl_pp(z, cfg, tau=args.tau, rounds=args.rounds,
                               seed=args.seed)
            dx = float(np.max(np.abs(res.x_hist - ref.x_hist)))
            print(f"vs single-node PP: max|x_tcp - x_sim|={dx:.3e} "
                  "(fault-free runs are bit-identical; target 0)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="fednl", choices=["fednl", "fednl-pp"])
    ap.add_argument("--dataset", default="tiny")
    ap.add_argument("--compressor", default="topk")
    ap.add_argument("--k-multiplier", type=float, default=8.0)
    ap.add_argument("--option", default="B", choices=["A", "B"])
    ap.add_argument("--lam", type=float, default=1e-3)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--tol", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="compare against the single-node simulation trajectory")
    # FedNL-PP options
    ap.add_argument("--tau", type=int, default=0,
                    help="PP: sampled clients per round (default n//2)")
    ap.add_argument("--on-dropout", default="partial",
                    choices=["partial", "resample"])
    ap.add_argument("--drop-prob", type=float, default=0.0)
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--straggler-delay", type=float, default=0.05)
    args = ap.parse_args()

    cfg = FedNLConfig(
        compressor=args.compressor,
        k_multiplier=args.k_multiplier,
        option=args.option,
        lam=args.lam,
        mu=args.lam,
    )
    if args.algo == "fednl-pp":
        if args.tau <= 0:
            from repro.data import DATASET_SHAPES

            args.tau = max(1, DATASET_SHAPES[args.dataset][1] // 2)
        _main_pp(args, cfg)
        return

    res = run_multiproc(
        cfg, dataset=args.dataset, rounds=args.rounds, tol=args.tol, seed=args.seed
    )
    if res.rounds == 0:
        print("rounds=0 (nothing to run; INIT/STOP handshake only)")
        return
    mb = res.measured_frame_bytes.sum() / 1e6
    print(f"rounds={res.rounds} ||grad||={res.grad_norms[-1]:.3e} "
          f"f={res.f_vals[-1]:.8f} wall={res.wall_time_s:.2f}s")
    print(f"uplink: measured {mb:.2f} MB framed, "
          f"payload bits measured=={'analytic' if (res.measured_payload_bits == res.sent_bits).all() else 'MISMATCH'}")

    if args.check:
        import numpy as np

        from repro.core import run_fednl

        z = _build_problem(args.dataset, None, args.seed)
        ref = run_fednl(z, cfg, rounds=args.rounds, tol=args.tol, seed=args.seed)
        r = min(res.rounds, ref.rounds)
        dx = float(np.max(np.abs(res.x - ref.x)))
        dg = float(np.max(np.abs(res.grad_norms[:r] - ref.grad_norms[:r])))
        print(f"vs single-node: max|x_tcp - x_sim|={dx:.3e} "
              f"max|gn_tcp - gn_sim|={dg:.3e} (paper target <= 1e-8)")


if __name__ == "__main__":
    main()
