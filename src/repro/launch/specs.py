"""Input specs + sharding assembly for the multi-pod dry-run.

`build_dryrun(cfg, shape_name, mesh)` returns everything `.lower().compile()`
needs for one (architecture x input-shape x mesh) combination:
ShapeDtypeStruct stand-ins for every argument (weak-type-correct, shardable,
zero device allocation — params/opt/cache come from `jax.eval_shape` over the
real init functions) plus in/out shardings.

Shapes (assigned):
    train_4k     seq 4,096    global_batch 256   -> train_step
    prefill_32k  seq 32,768   global_batch 32    -> prefill_step
    decode_32k   seq 32,768   global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524,288  global_batch 1     -> serve_step; requires a
                 sub-quadratic arch (SSM / hybrid / SWA) — others are skipped
                 with a reason (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import data_axes
from repro.models.encdec import (
    encdec_cache_specs,
    encdec_param_specs,
    init_encdec_cache,
    init_encdec_params,
)
from repro.models.lm import (
    cache_specs,
    init_decode_cache,
    init_lm_params,
    lm_param_specs,
    padded_vocab,
)
from repro.train.optimizer import adamw_init
from repro.train.step import make_prefill_step, make_serve_step, make_train_step


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec(4096, 256, "train"),
    "prefill_32k": ShapeSpec(32768, 32, "prefill"),
    "decode_32k": ShapeSpec(32768, 128, "decode"),
    "long_500k": ShapeSpec(524288, 1, "decode"),
}

ENCDEC_DECODE_SRC = 4096  # cross-attention K/V length for decode shapes


@dataclasses.dataclass
class DryRunSpec:
    step_fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    skip: str | None = None  # reason, when the combination is skipped
    note: str = ""


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _abstract(f, *a, **kw):
    return jax.eval_shape(lambda: f(*a, **kw))


def _init_fn(cfg: ArchConfig):
    if cfg.family == "encdec":
        return init_encdec_params, encdec_param_specs
    return (lambda key, c: init_lm_params(key, c)), lm_param_specs


def sanitize_specs(abstract_tree, spec_tree, sizes: dict[str, int]):
    """Drop spec axes whose mesh size does not divide the dimension (the
    per-dimension fallback `models.layers.constrain` applies to activations,
    here applied to parameter/cache specs — e.g. chatglm's d_ff=13696 cannot
    shard 256-ways under tp2d and falls back to its largest valid axis)."""

    def fix(arr, spec):
        out = []
        for dim, entry in zip(arr.shape, tuple(spec) + (None,) * (len(arr.shape) - len(spec))):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            # greedily keep the prefix of axes that still divides
            kept = []
            n = 1
            for a in axes:
                if dim % (n * sizes[a]) == 0:
                    kept.append(a)
                    n *= sizes[a]
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)

    return jax.tree.map(
        fix, abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"),
    )


def param_abstract_and_shardings(cfg: ArchConfig, mesh: Mesh, serve: bool = False):
    init, spec_fn = _init_fn(cfg)
    params = _abstract(init, jax.random.PRNGKey(0), cfg)
    tp2d = serve and cfg.serve_sharding == "tp2d"
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    specs = sanitize_specs(params, spec_fn(cfg, serve_tp2d=tp2d), sizes)
    shardings = _ns(mesh, specs)
    return params, shardings


def opt_abstract_and_shardings(params, param_sh, mesh: Mesh):
    opt = _abstract(adamw_init, params)
    sh = {
        "m": param_sh,
        "v": param_sh,
        "step": NamedSharding(mesh, P()),
    }
    return opt, sh


def _batch_abstract(cfg: ArchConfig, batch: int, seq: int, *, dp):
    """Abstract training/prefill batch + shardings."""
    specs: dict[str, Any] = {}
    sh: dict[str, Any] = {}
    if cfg.family == "encdec":
        specs["src_embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.float32)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        sh["src_embeds"] = P(dp, None, None)
        sh["tokens"] = P(dp, None)
        sh["labels"] = P(dp, None)
        return specs, sh
    text = seq - (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    specs["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
    sh["tokens"] = P(dp, None)
    sh["labels"] = P(dp, None)
    if cfg.family == "vlm":
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32
        )
        sh["img_embeds"] = P(dp, None, None)
    return specs, sh


def build_dryrun(
    cfg: ArchConfig, shape_name: str, mesh: Mesh, *, batch_override: int | None = None
) -> DryRunSpec:
    shape = SHAPES[shape_name]
    if batch_override is not None:
        shape = dataclasses.replace(shape, batch=batch_override)
    dp = data_axes(mesh)
    dp_size = 1
    for ax in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[ax]

    if shape.kind == "decode" and shape_name == "long_500k" and not cfg.sublquadratic:
        return DryRunSpec(
            step_fn=None, args=(), in_shardings=None, out_shardings=None,
            skip=f"{cfg.name} is full-quadratic attention; long_500k needs "
                 "a sub-quadratic arch (SSM/hybrid/SWA) — skipped per DESIGN.md §4",
        )

    params, param_sh = param_abstract_and_shardings(
        cfg, mesh, serve=shape.kind == "decode"
    )
    if shape.kind == "decode" and cfg.serve_params_dtype == "bfloat16":
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
            ),
            params,
        )

    if shape.kind == "train":
        accum = max(1, min(cfg.accum_steps, shape.batch // dp_size))
        cfg_run = dataclasses.replace(cfg, accum_steps=accum)
        batch_abs, batch_sh = _batch_abstract(cfg_run, shape.batch, shape.seq, dp=dp)
        opt, opt_sh = opt_abstract_and_shardings(params, param_sh, mesh)
        step = make_train_step(cfg_run)
        metrics_sh = {"loss": P(), "grad_norm": P()}
        return DryRunSpec(
            step_fn=step,
            args=(params, opt, batch_abs),
            in_shardings=(param_sh, opt_sh, _ns(mesh, batch_sh)),
            out_shardings=(param_sh, opt_sh, _ns(mesh, metrics_sh)),
            note=f"accum_steps={accum}",
        )

    if shape.kind == "prefill":
        batch_abs, batch_sh = _batch_abstract(cfg, shape.batch, shape.seq, dp=dp)
        step = make_prefill_step(cfg)
        out_sh = NamedSharding(mesh, P(dp, None))  # (B, Vp) last-pos logits
        return DryRunSpec(
            step_fn=step,
            args=(params, batch_abs),
            in_shardings=(param_sh, _ns(mesh, batch_sh)),
            out_shardings=out_sh,
        )

    # decode
    batch_axis = dp if shape.batch >= dp_size else None
    seq_axis = "data" if batch_axis is None else None
    if cfg.family == "encdec":
        cache = _abstract(
            init_encdec_cache, cfg, shape.batch, shape.seq, ENCDEC_DECODE_SRC
        )
        cache_sh = _ns(mesh, encdec_cache_specs(cfg, batch_axis=batch_axis, seq_axis=seq_axis))
    else:
        cache = _abstract(init_decode_cache, cfg, shape.batch, shape.seq)
        cache_sh = _ns(mesh, cache_specs(cfg, batch_axis=batch_axis, seq_axis=seq_axis))
    tokens = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    tokens_sh = NamedSharding(mesh, P(batch_axis, None))
    logits_sh = NamedSharding(mesh, P(batch_axis, None, None))
    step = make_serve_step(cfg)
    return DryRunSpec(
        step_fn=step,
        args=(params, cache, tokens),
        in_shardings=(param_sh, cache_sh, tokens_sh),
        out_shardings=(logits_sh, cache_sh),
        note=f"cache_batch_axis={batch_axis} cache_seq_axis={seq_axis}",
    )
