"""Production mesh definitions.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests and benches see the single real CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...] | str:
    """The batch-sharding axis (pod folds into data on the multi-pod mesh)."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def sweep_mesh_devices(batch_size: int) -> int:
    """How many local devices the sweep engine can shard a batch of
    ``batch_size`` specs across: the largest device count that divides the
    batch (1 = keep the batch on one device, no mesh needed)."""
    n_dev = len(jax.devices())
    while n_dev > 1 and batch_size % n_dev:
        n_dev -= 1
    return n_dev


def make_sweep_mesh(n_dev: int):
    """1-D mesh over the spec axis of a batched sweep (``solve_many``):
    each device runs the identical scan program on its shard of the stacked
    per-spec state — no collectives, embarrassingly parallel."""
    return jax.make_mesh((n_dev,), ("sweep",))
