"""FedNL launcher CLI (the framework's `bin_fednl_local` equivalent).

    PYTHONPATH=src python -m repro.launch.fednl_run \
        --dataset w8a --compressor topk --rounds 1000 --tol 1e-15

Accepts either a named synthetic dataset shape (w8a/a9a/phishing/tiny) or a
real LIBSVM file via --libsvm PATH --clients N --per-client M.
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import FedNLConfig, run_fednl
from repro.data import (
    DATASET_SHAPES,
    make_synthetic_logreg,
    parse_libsvm,
    add_intercept,
    partition_clients,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="w8a", choices=list(DATASET_SHAPES))
    ap.add_argument("--libsvm", default=None, help="path to a LIBSVM file")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--per-client", type=int, default=None)
    ap.add_argument("--compressor", default="topk")
    ap.add_argument("--k-multiplier", type=float, default=8.0)
    ap.add_argument("--option", default="B", choices=["A", "B"])
    ap.add_argument("--lam", type=float, default=1e-3)
    ap.add_argument("--rounds", type=int, default=1000)
    ap.add_argument("--tol", type=float, default=0.0)
    ap.add_argument("--line-search", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.libsvm:
        x, y = parse_libsvm(args.libsvm)
        n, n_i = args.clients, args.per_client
        if n is None or n_i is None:
            raise SystemExit("--libsvm requires --clients and --per-client")
    else:
        d, n, n_i = DATASET_SHAPES[args.dataset]
        x, y = make_synthetic_logreg(args.dataset, seed=args.seed)
    z = jnp.asarray(partition_clients(add_intercept(x), y, n, n_i, seed=args.seed))
    print(f"problem: n={n} clients, n_i={n_i}, d={z.shape[-1]}")

    cfg = FedNLConfig(
        compressor=args.compressor,
        k_multiplier=args.k_multiplier,
        option=args.option,
        lam=args.lam,
        mu=args.lam,
    )
    res = run_fednl(z, cfg, rounds=args.rounds, tol=args.tol,
                    line_search=args.line_search, seed=args.seed)
    print(f"rounds={res.rounds} ||grad||={res.grad_norms[-1]:.3e} "
          f"f={res.f_vals[-1]:.8f}")
    print(f"init={res.init_time_s:.2f}s solve={res.wall_time_s:.2f}s "
          f"uplink={np.sum(res.sent_bits) / 8e6:.1f} MB")


if __name__ == "__main__":
    main()
