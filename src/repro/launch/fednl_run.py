"""FedNL launcher CLI (the framework's `bin_fednl_local` equivalent).

    PYTHONPATH=src python -m repro.launch.fednl_run \
        --dataset w8a --compressor topk --rounds 1000 --tol 1e-15

Accepts either a named synthetic dataset shape (w8a/a9a/phishing/tiny) or a
real LIBSVM file via --libsvm PATH --clients N --per-client M.  A thin shell
around ``repro.api.solve``: the flags populate one declarative
ExperimentSpec; ``--backend`` re-runs the identical experiment elsewhere.
"""

import argparse

from repro.api import (
    CompressorSpec,
    DataSpec,
    ExperimentSpec,
    list_backends,
    solve,
)
from repro.data import DATASET_SHAPES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="w8a", choices=list(DATASET_SHAPES))
    ap.add_argument("--libsvm", default=None, help="path to a LIBSVM file")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--per-client", type=int, default=None)
    ap.add_argument("--compressor", default="topk")
    ap.add_argument("--k-multiplier", type=float, default=8.0)
    ap.add_argument("--option", default="B", choices=["A", "B"])
    ap.add_argument("--lam", type=float, default=1e-3)
    ap.add_argument("--rounds", type=int, default=1000)
    ap.add_argument("--tol", type=float, default=0.0)
    ap.add_argument("--line-search", action="store_true")
    ap.add_argument("--backend", default="local", choices=list_backends())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.libsvm and (args.clients is None or args.per_client is None):
        raise SystemExit("--libsvm requires --clients and --per-client")
    spec = ExperimentSpec(
        lam=args.lam,
        data=DataSpec(
            dataset=args.dataset,
            libsvm=args.libsvm,
            clients=args.clients,
            per_client=args.per_client,
            seed=args.seed,
        ),
        algorithm="fednl-ls" if args.line_search else "fednl",
        compressor=CompressorSpec(args.compressor, args.k_multiplier),
        option=args.option,
        mu=args.lam,
        backend=args.backend,
        rounds=args.rounds,
        tol=args.tol,
        seed=args.seed,
    )
    if args.libsvm and args.backend != "star-tcp":
        # parse the LIBSVM file once and hand the problem straight to solve
        # (star-tcp rebuilds in its workers and rejects libsvm anyway)
        z = spec.data.build()
        n, n_i, d = z.shape
        print(f"problem: n={n} clients, n_i={n_i}, d={d}")
        rep = solve(spec, z=z)
    else:
        d, n, n_i = spec.data.dims()
        print(f"problem: n={n} clients, n_i={n_i}, d={d}")
        rep = solve(spec)
    print(rep.summary())


if __name__ == "__main__":
    main()
