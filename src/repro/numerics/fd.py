"""Finite-difference oracle verification (paper Appendix L.4 item 8:
"means for sanity checks for gradient and Hessian oracles with finite
differences approach").

Central differences in float64; used by tests to certify the analytic
logistic-regression oracles of Eq. (3)-(5).
"""

from __future__ import annotations

import numpy as np


def fd_grad(f, x: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    g = np.zeros_like(x)
    for i in range(x.size):
        e = np.zeros_like(x)
        e[i] = eps
        g[i] = (float(f(x + e)) - float(f(x - e))) / (2 * eps)
    return g


def fd_hess(f, x: np.ndarray, eps: float = 1e-5) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    d = x.size
    h = np.zeros((d, d))
    fx = float(f(x))
    for i in range(d):
        ei = np.zeros_like(x)
        ei[i] = eps
        for j in range(i, d):
            ej = np.zeros_like(x)
            ej[j] = eps
            h[i, j] = (
                float(f(x + ei + ej)) - float(f(x + ei)) - float(f(x + ej)) + fx
            ) / (eps * eps)
            h[j, i] = h[i, j]
    return h


def check_oracles(f, grad, hess, x: np.ndarray, *, gtol=1e-5, htol=1e-3):
    """Return (grad_err, hess_err) max-abs deviations vs finite differences."""
    g_err = float(np.max(np.abs(np.asarray(grad(x)) - fd_grad(f, x))))
    h_err = float(np.max(np.abs(np.asarray(hess(x)) - fd_hess(f, x))))
    return g_err, h_err
