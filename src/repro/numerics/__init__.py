from repro.numerics.fd import fd_grad, fd_hess, check_oracles

__all__ = ["fd_grad", "fd_hess", "check_oracles"]
