"""repro.obs core — counters, gauges, histograms, spans (DESIGN.md §15).

One process-global recorder slot, ``CURRENT``, holds either the no-op
:data:`NULL` recorder (the default — observability off) or a live
:class:`Recorder`.  Instrumented call sites across the engine, gateway,
comm and session layers read the slot fresh each time::

    from repro.obs import core as obs

    rec = obs.CURRENT
    if rec.enabled:
        rec.add("engine.spills")              # counter
    with rec.span("engine.tick") as sp:       # timed span -> ring buffer
        ...
        sp.set(slots=n)                       # fields attached at exit

Disabled cost: ``obs.CURRENT`` is one module-attribute lookup and
``rec.enabled`` is a class attribute (False on :class:`NullRecorder`), so
an instrumented hot path that never fires costs a lookup and a branch.
The no-op recorder's methods allocate nothing — ``NULL.span()`` returns a
process-wide singleton — which tests/test_obs.py pins with a gc object
census.

Metric model (stdlib only, no deps):

* **Counter** — monotone float/int ``add``.
* **Gauge** — last-write-wins ``set``.
* **Histogram** — fixed log2 buckets (``HIST_BUCKETS`` of them, bucket
  ``i`` spanning ``[2**(HIST_LO_EXP+i-1), 2**(HIST_LO_EXP+i))``) plus
  exact ``count``/``sum``/``min``/``max``.  The hot path is one
  ``math.frexp``, one clamp and five scalar updates — no per-sample
  storage, so an instrumented loop never grows memory.
* **Span** — a context manager recording ``(name, start, duration,
  depth, parent, labels)`` into a bounded ring (``deque(maxlen=...)``,
  drop-oldest with a counted ``spans_dropped``).  Span exit also feeds
  the duration into the *label-free* histogram of the same name: spans
  may carry unbounded labels (tenant ids, round indices), metrics must
  not (the §15 cardinality rule), so the labels stay on the ring record.

Label cardinality rule: metric labels (``add``/``gauge``/``observe``
kwargs) must come from bounded sets — priority class, RPC verb, frame
type, backend, lane.  Tenant ids and round indices belong on spans.

The never-touch-numerics invariant: nothing in this module imports jax
or numpy, and no instrumented call site feeds a recorded value back into
computation — scripts/smoke_obs.py CI-gates that obs-on trajectories are
bit-identical to obs-off.
"""

from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from typing import Any

# the sanctioned clocks: migration rule 8 (scripts/check_api_migration.py)
# confines raw time.perf_counter()/time.monotonic() instrumentation in
# src/repro/{serve_fednl,gateway,comm} to these aliases
now = time.perf_counter
monotonic = time.monotonic

# --- histogram geometry (pinned by tests/test_obs.py) ----------------------

HIST_BUCKETS = 64
HIST_LO_EXP = -30  # bucket 0 upper bound = 2**HIST_LO_EXP (~9.3e-10)


def bucket_index(value: float) -> int:
    """Log2 bucket of ``value``: the index ``i`` with
    ``2**(HIST_LO_EXP+i-1) <= value < 2**(HIST_LO_EXP+i)``, clamped to
    ``[0, HIST_BUCKETS)``; values <= 0 land in bucket 0."""
    if value <= 0.0:
        return 0
    i = math.frexp(value)[1] - HIST_LO_EXP  # frexp: 2**(e-1) <= v < 2**e
    if i < 0:
        return 0
    if i >= HIST_BUCKETS:
        return HIST_BUCKETS - 1
    return i


def bucket_le(i: int) -> float:
    """Upper bound of bucket ``i`` (inf for the overflow bucket)."""
    if i >= HIST_BUCKETS - 1:
        return math.inf
    return 2.0 ** (HIST_LO_EXP + i)


# --- instruments -----------------------------------------------------------


class Counter:
    """Monotone counter (one (name, labels) series)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0

    def add(self, n=1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins gauge (one (name, labels) series)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Fixed log2-bucket histogram (module docstring); O(1) per sample."""

    __slots__ = ("name", "labels", "buckets", "count", "sum", "min", "max")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.buckets = [0] * HIST_BUCKETS
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        self.buckets[bucket_index(value)] += 1
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def quantile_le(self, q: float) -> float:
        """Upper bound of the bucket holding the ``q``-quantile sample —
        a factor-2-resolution percentile (log buckets; the exact mean is
        ``sum / count``)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for i, n in enumerate(self.buckets):
            seen += n
            if seen >= target:
                return bucket_le(i)
        return bucket_le(HIST_BUCKETS - 1)  # pragma: no cover - q > 1


class SpanRecord:
    """One completed span in the ring buffer (JSONL-serializable)."""

    __slots__ = ("name", "start_s", "dur_s", "depth", "parent", "labels")

    def __init__(self, name, start_s, dur_s, depth, parent, labels):
        self.name = name
        self.start_s = start_s
        self.dur_s = dur_s
        self.depth = depth
        self.parent = parent
        self.labels = labels

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "start_s": self.start_s,
            "dur_s": self.dur_s,
            "depth": self.depth,
            "parent": self.parent,
            "labels": self.labels,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SpanRecord":
        return cls(
            d["name"], d["start_s"], d["dur_s"], d["depth"], d["parent"],
            dict(d["labels"]),
        )

    def __eq__(self, other) -> bool:
        return isinstance(other, SpanRecord) and self.to_dict() == other.to_dict()

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"SpanRecord({self.to_dict()!r})"


class _Span:
    """Live span context manager (created by :meth:`Recorder.span`)."""

    __slots__ = ("_rec", "name", "labels", "_t0", "_depth", "_parent")

    def __init__(self, rec: "Recorder", name: str, labels: dict):
        self._rec = rec
        self.name = name
        self.labels = labels

    def set(self, **fields) -> "_Span":
        """Attach fields to the span record (merged into its labels)."""
        self.labels.update(fields)
        return self

    def __enter__(self) -> "_Span":
        stack = self._rec._span_stack()
        self._parent = stack[-1].name if stack else None
        self._depth = len(stack)
        stack.append(self)
        self._t0 = now()
        return self

    def __exit__(self, *exc) -> bool:
        dur = now() - self._t0
        stack = self._rec._span_stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._rec._finish_span(
            SpanRecord(self.name, self._t0, dur, self._depth, self._parent,
                       self.labels)
        )
        return False


class _NullSpan:
    """Reusable no-op span: one process-wide instance, zero allocation."""

    __slots__ = ()

    def set(self, **fields) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _NullInstrument:
    """Reusable no-op counter/gauge/histogram handle."""

    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0

    def add(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class NullRecorder:
    """The disabled default: every method is a no-op returning a shared
    singleton, so instrumentation left in place costs an attribute lookup
    and a call that allocates nothing."""

    __slots__ = ()
    enabled = False

    def add(self, name, value=1, **labels) -> None:
        pass

    def gauge(self, name, value, **labels) -> None:
        pass

    def observe(self, name, value, **labels) -> None:
        pass

    def span(self, name, **labels) -> _NullSpan:
        return _NULL_SPAN

    def counter(self, name, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT


NULL = NullRecorder()


class Recorder:
    """A live metric/span recorder (module docstring for the model).

    Series creation (first sight of a (name, labels) pair) takes a lock;
    subsequent updates are plain attribute writes on the instrument —
    GIL-safe for the engine's single tick thread plus the gateway loop.
    ``span_capacity`` bounds the span ring; overflow drops the *oldest*
    record and counts it in ``spans_dropped``.
    """

    enabled = True

    def __init__(self, span_capacity: int = 8192):
        if span_capacity < 1:
            raise ValueError("span_capacity must be >= 1")
        self.span_capacity = span_capacity
        self.spans_dropped = 0
        self.started_at = now()
        self._lock = threading.Lock()
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._hists: dict[tuple, Histogram] = {}
        self._spans: deque[SpanRecord] = deque(maxlen=span_capacity)
        self._tls = threading.local()

    # --- series lookup ----------------------------------------------------

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())) if labels else ())

    def _series(self, table: dict, cls, name: str, labels: dict):
        key = self._key(name, labels)
        inst = table.get(key)
        if inst is None:
            with self._lock:
                inst = table.setdefault(key, cls(name, key[1]))
        return inst

    def counter(self, name: str, **labels) -> Counter:
        """Bound counter handle (pre-resolve once, ``add`` in the loop)."""
        return self._series(self._counters, Counter, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        """Bound histogram handle for hot loops."""
        return self._series(self._hists, Histogram, name, labels)

    # --- direct updates ---------------------------------------------------

    def add(self, name: str, value=1, **labels) -> None:
        self._series(self._counters, Counter, name, labels).add(value)

    def gauge(self, name: str, value, **labels) -> None:
        self._series(self._gauges, Gauge, name, labels).set(value)

    def observe(self, name: str, value: float, **labels) -> None:
        self._series(self._hists, Histogram, name, labels).observe(value)

    # --- spans ------------------------------------------------------------

    def span(self, name: str, **labels) -> _Span:
        return _Span(self, name, labels)

    def _span_stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _finish_span(self, rec: SpanRecord) -> None:
        if len(self._spans) == self._spans.maxlen:
            self.spans_dropped += 1
        self._spans.append(rec)
        # label-free duration histogram (the §15 cardinality rule)
        self.observe(rec.name, rec.dur_s)

    def spans(self, name: str | None = None) -> list[SpanRecord]:
        """Ring-buffer contents, oldest first (optionally one span name)."""
        out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    # --- introspection / reset --------------------------------------------

    def value(self, name: str, **labels):
        """Current value of one counter/gauge series (None if unseen)."""
        key = self._key(name, labels)
        inst = self._counters.get(key) or self._gauges.get(key)
        return None if inst is None else inst.value

    def hist(self, name: str, **labels) -> Histogram | None:
        return self._hists.get(self._key(name, labels))

    def hists(self, name: str) -> list[Histogram]:
        """Every histogram series with this name (one per label set)."""
        with self._lock:
            return [h for (n, _), h in self._hists.items() if n == name]

    def snapshot(self) -> dict:
        """JSON-able view of every series (the METRICS RPC payload).
        Series keys render as ``name{k=v,...}``."""

        def fmt(key: tuple) -> str:
            name, labels = key
            if not labels:
                return name
            inner = ",".join(f"{k}={v}" for k, v in labels)
            return f"{name}{{{inner}}}"

        with self._lock:
            counters = {fmt(k): c.value for k, c in self._counters.items()}
            gauges = {fmt(k): g.value for k, g in self._gauges.items()}
            hists = {
                fmt(k): {
                    "count": h.count,
                    "sum": h.sum,
                    "min": h.min if h.count else None,
                    "max": h.max if h.count else None,
                    "p50_le": h.quantile_le(0.5),
                    "p99_le": h.quantile_le(0.99),
                    "buckets": [
                        [i, n] for i, n in enumerate(h.buckets) if n
                    ],
                }
                for k, h in self._hists.items()
            }
        return {
            "enabled": True,
            "uptime_s": now() - self.started_at,
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
            "spans": len(self._spans),
            "span_capacity": self.span_capacity,
            "spans_dropped": self.spans_dropped,
        }

    def dump_spans_jsonl(self, path) -> int:
        """Write the span ring as JSON Lines; returns the record count."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict(), sort_keys=True))
                f.write("\n")
        return len(spans)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._spans.clear()
            self.spans_dropped = 0
            self.started_at = now()


def load_spans_jsonl(path) -> list[SpanRecord]:
    """Read a :meth:`Recorder.dump_spans_jsonl` file back."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(SpanRecord.from_dict(json.loads(line)))
    return out


# --- the process-global slot ------------------------------------------------

CURRENT: NullRecorder | Recorder = NULL


def get() -> NullRecorder | Recorder:
    return CURRENT


def set_current(rec: NullRecorder | Recorder):
    """Swap the process-global recorder (also refreshes the ``repro.obs``
    package attribute so both spellings stay in sync)."""
    global CURRENT
    CURRENT = rec
    import sys

    pkg = sys.modules.get("repro.obs")
    if pkg is not None:
        pkg.CURRENT = rec
    return rec


def enable(span_capacity: int = 8192) -> Recorder:
    """Install (and return) a fresh live :class:`Recorder`."""
    return set_current(Recorder(span_capacity=span_capacity))


def disable() -> NullRecorder:
    """Restore the no-op default."""
    set_current(NULL)
    return NULL
