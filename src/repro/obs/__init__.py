"""repro.obs — zero-overhead observability: counters, gauges, histograms,
spans, and export surfaces (Prometheus text, JSONL spans, gateway METRICS).

Quickstart::

    from repro import obs

    rec = obs.enable()                 # install a live Recorder
    ... run the engine / gateway ...
    print(obs.export.prometheus_text(rec))
    rec.dump_spans_jsonl("spans.jsonl")
    obs.disable()                      # restore the no-op default

The disabled default (``obs.core.NULL``) makes every instrumented call
site a no-op costing one attribute lookup; see ``repro/obs/core.py`` and
DESIGN.md §15 for the contract.  Instrumented modules must read the slot
via ``from repro.obs import core as obs`` + ``obs.CURRENT`` (always
fresh); ``repro.obs.CURRENT`` is kept in sync for interactive use.
"""

from repro.obs import core, export
from repro.obs.core import (
    CURRENT,
    HIST_BUCKETS,
    HIST_LO_EXP,
    NULL,
    Histogram,
    NullRecorder,
    Recorder,
    SpanRecord,
    bucket_index,
    bucket_le,
    disable,
    enable,
    get,
    load_spans_jsonl,
    set_current,
)

__all__ = [
    "CURRENT",
    "HIST_BUCKETS",
    "HIST_LO_EXP",
    "NULL",
    "Histogram",
    "NullRecorder",
    "Recorder",
    "SpanRecord",
    "bucket_index",
    "bucket_le",
    "core",
    "disable",
    "enable",
    "export",
    "get",
    "load_spans_jsonl",
    "set_current",
]
