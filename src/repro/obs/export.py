"""repro.obs.export — render a Recorder snapshot for external consumers.

Two formats, both stdlib-only:

* :func:`prometheus_text` — Prometheus text exposition (v0.0.4): counters
  as ``<name>_total``, gauges plain, histograms as cumulative ``_bucket``
  series with ``le`` labels plus ``_sum``/``_count``.  Metric names have
  dots rewritten to underscores (``engine.tick`` -> ``engine_tick``);
  label values are escaped per the spec.
* :func:`spans_jsonl` / :func:`render_snapshot` — JSONL span dump and a
  compact human-readable table used by ``scripts/obs_top.py``.

These functions read a recorder (or a ``snapshot()`` dict fetched over
the gateway METRICS verb) and never mutate it.
"""

from __future__ import annotations

import json
import math

from repro.obs.core import Recorder, bucket_le


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if (ch.isalnum() or ch == "_") else "_")
    s = "".join(out)
    if s and s[0].isdigit():
        s = "_" + s
    return s


def _prom_value(v) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if isinstance(v, float):
        return repr(v)
    return str(v)


def _prom_labels(labels: tuple, extra: tuple = ()) -> str:
    items = list(labels) + list(extra)
    if not items:
        return ""
    body = ",".join(
        '{}="{}"'.format(
            k,
            str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n"),
        )
        for k, v in items
    )
    return "{" + body + "}"


def prometheus_text(rec: Recorder) -> str:
    """Render every series of ``rec`` in Prometheus text format."""
    lines: list[str] = []
    with rec._lock:
        counters = sorted(rec._counters.items())
        gauges = sorted(rec._gauges.items())
        hists = sorted(rec._hists.items())

    seen_types: set = set()

    for (name, labels), c in counters:
        pn = _prom_name(name) + "_total"
        if pn not in seen_types:
            seen_types.add(pn)
            lines.append(f"# TYPE {pn} counter")
        lines.append(f"{pn}{_prom_labels(labels)} {_prom_value(c.value)}")

    for (name, labels), g in gauges:
        pn = _prom_name(name)
        if pn not in seen_types:
            seen_types.add(pn)
            lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn}{_prom_labels(labels)} {_prom_value(g.value)}")

    for (name, labels), h in hists:
        pn = _prom_name(name)
        if pn not in seen_types:
            seen_types.add(pn)
            lines.append(f"# TYPE {pn} histogram")
        cum = 0
        for i, n in enumerate(h.buckets[:-1]):  # last bucket == the +Inf line
            if n == 0:
                continue
            cum += n
            le = _prom_value(bucket_le(i))
            lines.append(
                f"{pn}_bucket{_prom_labels(labels, (('le', le),))} {cum}"
            )
        lines.append(
            f"{pn}_bucket{_prom_labels(labels, (('le', '+Inf'),))} {h.count}"
        )
        lines.append(f"{pn}_sum{_prom_labels(labels)} {_prom_value(h.sum)}")
        lines.append(f"{pn}_count{_prom_labels(labels)} {h.count}")

    lines.append(f"obs_spans_dropped_total {rec.spans_dropped}")
    return "\n".join(lines) + "\n"


def spans_jsonl(rec: Recorder, name: str | None = None) -> str:
    """Span ring as a JSON Lines string (oldest first)."""
    return "".join(
        json.dumps(s.to_dict(), sort_keys=True) + "\n" for s in rec.spans(name)
    )


def render_snapshot(snap: dict, width: int = 78) -> str:
    """Compact console table from a ``Recorder.snapshot()`` dict — the
    ``scripts/obs_top.py`` body.  Works on the JSON fetched over the
    gateway METRICS verb (no live Recorder needed)."""
    lines: list[str] = []

    def sec(title: str) -> None:
        lines.append(title)
        lines.append("-" * min(width, len(title)))

    if not snap.get("enabled", False):
        return "observability disabled (obs.enable() not called)\n"

    sec(f"counters  (uptime {snap.get('uptime_s', 0.0):.1f}s)")
    for key in sorted(snap.get("counters", {})):
        lines.append(f"  {key:<48} {snap['counters'][key]}")
    if snap.get("gauges"):
        sec("gauges")
        for key in sorted(snap["gauges"]):
            lines.append(f"  {key:<48} {snap['gauges'][key]}")
    if snap.get("histograms"):
        sec("histograms  (count / mean / p50<= / p99<= / max)")
        for key in sorted(snap["histograms"]):
            h = snap["histograms"][key]
            mean = h["sum"] / h["count"] if h["count"] else 0.0
            lines.append(
                f"  {key:<40} {h['count']:>7} {mean:>10.3g}"
                f" {h['p50_le']:>10.3g} {h['p99_le']:>10.3g}"
                f" {(h['max'] if h['max'] is not None else 0.0):>10.3g}"
            )
    lines.append(
        f"spans: {snap.get('spans', 0)}/{snap.get('span_capacity', 0)}"
        f"  dropped: {snap.get('spans_dropped', 0)}"
    )
    return "\n".join(lines) + "\n"
