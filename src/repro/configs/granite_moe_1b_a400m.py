"""granite-moe-1b-a400m [moe, hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32 experts top-8.
head_dim = 64.  Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    moe=MoECfg(n_experts=32, top_k=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    accum_steps=2,
)
