"""granite-3-2b [dense, hf:ibm-granite/granite-3.0-2b-base].

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
head_dim = 2048/32 = 64.  Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv=8,
    d_ff=8192,
    vocab=49155,
    head_dim=64,
    activation="silu_glu",
    source="hf:ibm-granite/granite-3.0-2b-base",
    accum_steps=4,
)
