"""seamless-m4t-large-v2 [audio enc-dec, arXiv:2308.11596].

24L d_model=1024 16H (GQA kv=16 == MHA) d_ff=8192 vocab=256206.
Transformer backbone only: the speech frontend (mel + conv) is the stubbed
modality frontend — input_specs supplies frame embeddings (B, S_src, 1024).
24 encoder + 24 decoder layers; head_dim = 1024/16 = 64.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
    activation="gelu",
    frontend="audio",
    source="arXiv:2308.11596",
    accum_steps=4,
    q_chunk=512,
)
