"""Unified architecture config for the assigned model zoo.

Every assigned architecture gets one `src/repro/configs/<id>.py` exporting
`CONFIG` (the exact published configuration, source cited) built on this
dataclass.  `reduced()` produces the CPU-smoke-test variant (2 layers,
d_model <= 512, <= 4 experts) of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    """Mamba2 / SSD block dimensions."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2  # d_inner = expand * d_model
    chunk: int = 128  # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    """RecurrentGemma-style pattern: `pattern[i % len(pattern)]` per layer."""

    pattern: Sequence[str] = ("rglru", "rglru", "attn")  # 1:2 attn:recurrent
    lru_width: int | None = None  # default d_model
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    activation: str = "silu_glu"  # silu_glu | sq_relu | gelu
    rope_fraction: float = 1.0  # chatglm "2d rope": rotary on half the dims
    window: int | None = None  # sliding-window attention (mixtral/mistral)
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    hybrid: HybridCfg | None = None
    encoder_layers: int = 0  # > 0 => encoder-decoder
    frontend: str | None = None  # "audio" | "vision" (stubbed per carve-out)
    n_frontend_tokens: int = 576  # VLM: image patch tokens prepended
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    source: str = ""  # citation
    # runtime knobs (per-arch dry-run tuning, not architecture)
    accum_steps: int = 1  # gradient-accumulation microbatches in train_step
    q_chunk: int = 512  # attention query-chunk size (online softmax)
    unroll_layers: bool = False  # unroll the layer scan (dry-run cost accuracy:
    # XLA cost_analysis does not multiply FLOPs/collectives by while-loop trip
    # counts, so the roofline pass compiles with unrolled layers)
    remat_policy: str = "full"  # full | dots | none — per-layer checkpoint
    # policy ("dots" saves matmul outputs: less recompute, more memory)
    moe_dense_decode: bool = False  # decode-time MoE: compute all experts
    # densely and mask (no dispatch scatter/all-to-all); E/top_k x more FLOPs
    # on a tiny token count in exchange for removing the dispatch collectives
    serve_params_dtype: str = "float32"  # decode-time param storage; bfloat16
    # halves the per-layer FSDP weight all-gather bytes (compute is bf16 anyway)
    serve_sharding: str = "fsdp"  # fsdp | tp2d — decode-time param sharding.
    # fsdp reuses the training layout (weights sharded over data+model ->
    # per-layer weight all-gathers at decode); tp2d shards feature dims over
    # BOTH axes so decode psums small activations instead (EXPERIMENTS §Perf)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def sublquadratic(self) -> bool:
        """True if long_500k decode is supported (SSM/hybrid/SWA)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        changes: dict = dict(
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv=min(self.n_kv, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=32,
            window=min(self.window, 64) if self.window else None,
            encoder_layers=2 if self.encoder_layers else 0,
            n_frontend_tokens=16 if self.frontend else 0,
            accum_steps=1,
            q_chunk=32,
        )
        if self.moe:
            changes["moe"] = MoECfg(
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                capacity_factor=self.moe.capacity_factor,
            )
        if self.ssm:
            changes["ssm"] = SSMCfg(d_state=16, head_dim=16, expand=2, chunk=16)
        if self.hybrid:
            changes["hybrid"] = HybridCfg(
                pattern=self.hybrid.pattern, lru_width=None, local_window=32
            )
        return dataclasses.replace(self, **changes)
