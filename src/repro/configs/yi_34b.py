"""yi-34b [dense llama-arch, arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
head_dim = 7168/56 = 128.  Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    activation="silu_glu",
    tie_embeddings=False,
    source="arXiv:2403.04652",
    accum_steps=16,
    q_chunk=512,
)
