"""llava-next-mistral-7b [vlm, hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B language backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, sliding window 4096; head_dim 128.  The vision encoder +
projector is the stubbed modality frontend: input_specs supplies anyres patch
embeddings (B, n_img=576, d_model-compatible) that the learned img_proj maps
into the token stream.  SWA -> long_500k decode runs.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    window=4096,
    activation="silu_glu",
    frontend="vision",
    n_frontend_tokens=576,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    accum_steps=8,
    q_chunk=512,
)
