"""Architecture registry: the 10 assigned configs + the paper's own FedNL
problem configs.  `--arch <id>` in the launchers resolves through here."""

from importlib import import_module

from repro.configs.base import ArchConfig, MoECfg, SSMCfg, HybridCfg

ARCH_IDS = [
    "seamless_m4t_large_v2",
    "nemotron_4_15b",
    "mamba2_2_7b",
    "mixtral_8x22b",
    "granite_3_2b",
    "yi_34b",
    "granite_moe_1b_a400m",
    "llava_next_mistral_7b",
    "chatglm3_6b",
    "recurrentgemma_2b",
]

_ALIASES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "nemotron-4-15b": "nemotron_4_15b",
    "mamba2-2.7b": "mamba2_2_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-3-2b": "granite_3_2b",
    "yi-34b": "yi_34b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "chatglm3-6b": "chatglm3_6b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    return import_module(f"repro.configs.{mod_name}").CONFIG


def list_archs() -> list[str]:
    return list(ARCH_IDS)


__all__ = [
    "ArchConfig",
    "MoECfg",
    "SSMCfg",
    "HybridCfg",
    "ARCH_IDS",
    "get_config",
    "list_archs",
]
