"""chatglm3-6b [dense, arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024, 2d RoPE (rotary on
half the head dims -> rope_fraction = 0.5).  head_dim = 128.
Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv=2,
    d_ff=13696,
    vocab=65024,
    head_dim=128,
    rope_fraction=0.5,
    activation="silu_glu",
    source="arXiv:2406.12793",
    accum_steps=8,
)
