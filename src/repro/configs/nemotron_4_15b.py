"""nemotron-4-15b [dense, arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU MLP.
head_dim = 6144/48 = 128.  Full attention -> long_500k skipped (DESIGN.md §4).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=24576,
    vocab=256000,
    head_dim=128,
    activation="sq_relu",
    tie_embeddings=False,
    source="arXiv:2402.16819",
    accum_steps=8,
    q_chunk=512,
)
