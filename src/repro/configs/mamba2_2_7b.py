"""mamba2-2.7b [ssm, arXiv:2405.21060].

64L d_model=2560 attention-free (SSD), vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads.  Sub-quadratic:
long_500k decode runs (O(1) recurrent state).
"""

from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, chunk=128),
    source="arXiv:2405.21060",
    accum_steps=8,
)
