"""mixtral-8x22b [moe, arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, 8 experts top-2,
sliding-window attention (4096).  SWA -> long_500k decode runs with a ring
KV cache.  head_dim = 128.
"""

from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    window=4096,
    moe=MoECfg(n_experts=8, top_k=2),
    tie_embeddings=False,
    source="arXiv:2401.04088",
    accum_steps=16,
    q_chunk=512,
)
