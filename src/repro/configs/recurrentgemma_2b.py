"""recurrentgemma-2b [hybrid, arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
RG-LRU + local attention in the Griffin 1:2 pattern
(rglru, rglru, attn repeating); local window 2048; head_dim 256.
Sub-quadratic -> long_500k decode runs (LRU state + ring window cache).
"""

from repro.configs.base import ArchConfig, HybridCfg

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    activation="gelu",
    hybrid=HybridCfg(pattern=("rglru", "rglru", "attn"), local_window=2048),
    source="arXiv:2402.19427",
    accum_steps=4,
)
