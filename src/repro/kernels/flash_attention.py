"""Pallas TPU kernel: flash attention (online softmax) with causal and
sliding-window masking.

This serves the architecture-zoo side of the framework: 32k-token prefill
cannot materialize (S, S) score matrices (25 GB/layer for nemotron shapes), so
attention must be computed blockwise with an online softmax.  The models use a
pure-jnp chunked scan (models/attention.py) that XLA lowers on any backend —
this kernel is the TPU-native version of the same computation and is validated
against ref.flash_attention_ref in interpret mode.

Layout: q, k, v are (heads, seq, head_dim); the grid is
(heads, q_blocks, kv_blocks) with the kv axis innermost ("arbitrary"
semantics) accumulating into VMEM scratch (running max m, denominator l,
weighted accumulator acc).  Blocks that the causal/sliding-window mask fully
zeroes are skipped with `pl.when` — for window W << S the kernel does
O(S * W) work, which is what makes long_500k decodable architectures
(mixtral/llava SWA) trainable at long context.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams

_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, causal, window, block_q, block_k, kv_blocks, kv_len,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_k

    # block-level skip: fully-masked (q_block, kv_block) pairs do no work
    skip = False
    if causal:
        skip = k_start > q_start + block_q - 1
    if window is not None:
        skip = jnp.logical_or(
            skip, k_start + block_k - 1 < q_start - (window - 1)
        )

    @pl.when(jnp.logical_not(skip))
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0].astype(jnp.float32)  # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len  # padded keys are never attended
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(s <= _NEG / 2, 0.0, jnp.exp(s - m_new))
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kj == kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    kv_len: int | None = None,
) -> jax.Array:
    """q, k, v: (heads, seq, head_dim), seq divisible by the block sizes.

    kv_len: true (unpadded) number of keys; positions >= kv_len are masked.
    """
    hn, sq, dh = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    s = dh**-0.5 if scale is None else scale
    grid = (hn, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=s,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        kv_blocks=grid[2],
        kv_len=sk if kv_len is None else kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hn, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
