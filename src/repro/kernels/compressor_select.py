"""Pallas kernel: fused compressor selection over the packed upper triangle.

One ``pallas_call`` per compressed message covers the whole selection
pipeline that the jnp path spells as 4-6 separate XLA ops (rank keys ->
top_k -> gather -> scatter -> count): the length-T packed-triu correction
vector is resident in VMEM once, and ranking + keep-mask + dense scatter +
the sent-element count all happen in that single pass.  Three variants:

  TopK      magnitude ranking via :func:`repro.compressors.select.
            threshold_keep_mask` — a 31-step binary search on the int32 bit
            patterns of the f32 rank keys (compares + full-array reductions
            only; no sort, no gather), then a masked select.
  RandSeqK  the Appendix-C contiguous window as a membership mask
            ``(pos - s) mod T < k`` — gather-free, one vector compare.
  TopLEK    TopK ranking plus the Algorithm-4 adaptive energy prefix.  The
            prefix stage needs the kept values in rank order, so this
            variant runs the canonical ``lax.top_k``-based primitive
            (:func:`~repro.compressors.select.toplek_from_uniform`) inside
            the kernel body — bit-identical to the jnp path by construction.

The PRNG draws (RandSeqK's start index, TopLEK's Bernoulli uniform) are made
OUTSIDE the kernel and passed as scalar operands, so fused and unfused paths
consume identical key streams (`repro.compressors.select` module docstring).

Selection parity contract (DESIGN.md §12): identical index set — f32 rank
keys, lowest-index tie-break — and bit-identical dense output vs the
`repro.compressors.core` reference; pinned by tests/test_kernels.py on
adversarial near-tie inputs.

Validation status: these kernels are exercised in interpret mode (the CPU
container); TopK/RandSeqK restrict themselves to Mosaic-friendly primitives
(iota, bitcast, compare, sum/cumsum, select), while TopLEK's in-kernel
``lax.top_k`` additionally needs sort support from the Mosaic lowering —
re-validate on real TPU hardware before flipping them into the default
serving path there (ops.select_* route to jnp off-TPU regardless).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compressors.select import (
    rank_keys,
    threshold_keep_mask,
    toplek_from_uniform,
)


def _out_shapes(u: jax.Array):
    return (
        jax.ShapeDtypeStruct(u.shape, u.dtype),  # dense u_hat
        jax.ShapeDtypeStruct((1,), jnp.int32),  # sent payload elements
    )


def _topk_kernel(u_ref, o_ref, sent_ref, *, k: int):
    u = u_ref[...]
    keep = threshold_keep_mask(rank_keys(u), k)
    o_ref[...] = jnp.where(keep, u, jnp.zeros_like(u))
    sent_ref[0] = jnp.int32(k)


def select_topk_pallas(
    u: jax.Array, k: int, *, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Fused dense TopK: ``(u_hat, sent)`` in one VMEM-resident pass."""
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        out_shape=_out_shapes(u),
        interpret=interpret,
    )(u)


def _randseqk_kernel(u_ref, s_ref, o_ref, sent_ref, *, k: int):
    u = u_ref[...]
    t = u.shape[0]
    pos = jnp.arange(t)
    keep = (pos - s_ref[0]) % t < k
    o_ref[...] = jnp.where(keep, u, jnp.zeros_like(u))
    sent_ref[0] = jnp.int32(k)


def select_randseqk_pallas(
    u: jax.Array, k: int, s: jax.Array, *, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Fused dense RandSeqK given the start draw ``s``: the circular window
    becomes a membership mask — no roll, no gather, pure copies (so output
    bits match the jnp roll formulation exactly)."""
    return pl.pallas_call(
        functools.partial(_randseqk_kernel, k=k),
        out_shape=_out_shapes(u),
        interpret=interpret,
    )(u, jnp.reshape(s, (1,)))


def _toplek_kernel(u_ref, unif_ref, o_ref, sent_ref, *, k: int):
    u_hat, kept = toplek_from_uniform(u_ref[...], k, unif_ref[0])
    o_ref[...] = u_hat
    sent_ref[0] = kept.astype(jnp.int32)


def select_toplek_pallas(
    u: jax.Array, k: int, unif: jax.Array, *, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Fused dense TopLEK given the Bernoulli uniform ``unif`` (in u's
    dtype): ranking, energy prefix, adaptive keep and the data-dependent
    sent count in one pass."""
    return pl.pallas_call(
        functools.partial(_toplek_kernel, k=k),
        out_shape=_out_shapes(u),
        interpret=interpret,
    )(u, jnp.reshape(unif, (1,)))
