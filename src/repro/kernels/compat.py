"""Version-tolerance shims for the Pallas TPU API.

jax < 0.4.34 exposed ``pltpu.CompilerParams``; it was renamed
``TPUCompilerParams`` and newer releases are renaming it back — resolve
whichever the installed jax ships, once, for all kernels.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(
    pltpu, "TPUCompilerParams", getattr(pltpu, "CompilerParams", None)
)
