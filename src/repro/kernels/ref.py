"""Pure-jnp oracles for the Pallas kernels (the `ref.py` ground truth).

Every kernel in this package is validated against these references across a
shape/dtype sweep (tests/test_kernels.py) in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def hessian_syrk_ref(z: jax.Array, h: jax.Array) -> jax.Array:
    """H = Z^T diag(h) Z for Z: (n, d), h: (n,) -> (d, d) symmetric."""
    return z.T @ (h[:, None] * z)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Dense attention reference.  q,k,v: (seq, heads, head_dim) single batch.

    window: sliding-window size W — query t attends to keys in
    [t - W + 1, t] (combined with causality).  None = full causal/bidir.
    """
    sq, hn, dh = q.shape
    sk = k.shape[0]
    s = 1.0 / jnp.sqrt(dh) if scale is None else scale
    logits = jnp.einsum("qhd,khd->hqk", q, k) * s
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("hqk,khd->qhd", p, v)
