"""Pallas TPU kernel: symmetric rank-n_i update H = Z^T diag(h) Z.

This is the paper's dominant compute hot-spot (§5.10 "Hessian and Gradients
Oracles: x3.072").  The paper's CPU strategy: evaluate the Hessian as a sum of
symmetric rank-1 matrices, compute ONLY the upper-diagonal part, symmetrize
once at the end, and tile for the L1/L2 caches.

TPU adaptation (DESIGN.md §2): the same idea re-derived for the MXU + VMEM
hierarchy —

  * the (d, d) output is computed in (bd, bd) MXU-aligned tiles (bd multiple
    of 128 for f32);
  * a 3D grid (i, j, k) marches over output tiles x sample chunks; the k axis
    accumulates partial SYRK products in the VMEM-resident output tile
    ("arbitrary" dimension semantics: megacore partitions i/j only);
  * tiles strictly BELOW the diagonal are skipped with `pl.when` — half the
    MXU work and half the HBM writes, exactly the paper's upper-triangle
    trick at tile granularity;
  * diag(h) is fused into the right operand load (one multiply in VMEM, no
    materialized (n, d) scaled copy in HBM).

The jit'd wrapper (ops.hessian_syrk) pads (n, d) to tile multiples, mirrors
the strict upper tiles after the call, and slices the padding away.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import TPUCompilerParams


def _syrk_kernel(z_i_ref, z_j_ref, h_ref, o_ref, *, grid_k: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # skip tiles strictly below the block diagonal: their values are the
    # mirror of (j, i) and never read by the wrapper.
    @pl.when(j >= i)
    def _compute():
        zi = z_i_ref[...]  # (bk, bd) chunk of Z for row-tile i
        zj = z_j_ref[...]  # (bk, bd) chunk of Z for col-tile j
        hh = h_ref[...]  # (bk,) sample weights
        zj_scaled = zj * hh[:, None]
        acc = jax.lax.dot_general(
            zi,
            zj_scaled,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=o_ref.dtype,
        )
        o_ref[...] += acc


def hessian_syrk_pallas(
    z: jax.Array,
    h: jax.Array,
    *,
    block_d: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Upper-block-triangular H = Z^T diag(h) Z; inputs must be pre-padded to
    multiples of the block sizes.  Returns the raw tile output (strictly-lower
    tiles are zero); see ops.hessian_syrk for the symmetrized public API.
    """
    n, d = z.shape
    assert n % block_n == 0 and d % block_d == 0, (n, d, block_n, block_d)
    grid = (d // block_d, d // block_d, n // block_n)
    kernel = functools.partial(_syrk_kernel, grid_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_n,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), z.dtype),
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(z, z, h)
