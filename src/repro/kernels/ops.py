"""Jit'd public wrappers around the Pallas kernels.

On the CPU container the kernels execute in interpret mode (the kernel body
runs as Python/jnp — bit-accurate vs the TPU semantics for these ops); on a
TPU backend `interpret=False` compiles through Mosaic.  `_should_interpret`
picks automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.hessian_syrk import hessian_syrk_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_d", "block_n", "interpret"))
def hessian_syrk(
    z: jax.Array,
    h: jax.Array,
    *,
    block_d: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """H = Z^T diag(h) Z via the upper-triangular Pallas SYRK kernel.

    z: (n, d) design matrix, h: (n,) nonneg sample weights -> (d, d) symmetric.
    Zero-pads to tile multiples (zero-weight rows are exact no-ops; padded
    feature columns are sliced away), mirrors the strict-upper tiles.
    """
    n, d = z.shape
    interp = _should_interpret() if interpret is None else interpret
    zp = _pad_to(_pad_to(z, 0, block_n), 1, block_d)
    hp = _pad_to(h, 0, block_n)
    u = hessian_syrk_pallas(
        zp, hp, block_d=block_d, block_n=block_n, interpret=interp
    )
    dp = zp.shape[1]
    # mirror strict-upper block tiles; diagonal tiles are already full blocks
    blk = jnp.arange(dp) // block_d
    strict_upper = blk[None, :] > blk[:, None]
    diag_block = blk[None, :] == blk[:, None]
    us = jnp.where(strict_upper, u, 0.0)
    full = us + us.T + jnp.where(diag_block, u, 0.0)
    return full[:d, :d]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention, (seq, heads, head_dim) layout (matches ref.py).

    Pads seq to block multiples (padded queries are discarded; padded keys are
    masked out by causality/window because they sit at positions >= seq).
    """
    sq, hn, dh = q.shape
    sk = k.shape[0]
    interp = _should_interpret() if interpret is None else interpret
    qt = _pad_to(jnp.swapaxes(q, 0, 1), 1, block_q)
    kt = _pad_to(jnp.swapaxes(k, 0, 1), 1, block_k)
    vt = _pad_to(jnp.swapaxes(v, 0, 1), 1, block_k)
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interp, kv_len=sk,
    )
    return jnp.swapaxes(out[:, :sq], 0, 1)
