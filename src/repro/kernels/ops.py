"""Jit'd public wrappers around the Pallas kernels.

On the CPU container the Pallas programs execute in interpret mode (the
kernel body runs as traced jnp — bit-accurate vs the TPU semantics for these
ops); on a TPU backend ``interpret=False`` compiles through Mosaic.

Two wrapper-layer rules keep the jit caches honest:

* **Interpret resolution happens eagerly, before jit.**  The public wrappers
  resolve ``interpret=None`` -> ``jax.default_backend() != "tpu"`` at call
  time and pass the resolved bool through the *static* ``interpret``
  argument.  Resolving it inside the jitted body would bake the choice into
  the cache entry under the ``interpret=None`` key: the first call pins the
  backend decision for every later call (wrong if the default backend
  changes, or differs across processes sharing a compilation cache).
* **No per-call mask construction.**  The strict-upper/diagonal block masks
  used by the SYRK mirror epilogue are built ONCE per (dp, block_d) with
  numpy at trace time (`_mirror_masks`, lru_cached) and embedded in the
  compiled program as constants — the hot path carries no O(d^2) mask
  rebuild.

The selection wrappers (``select_topk`` / ``select_toplek`` /
``select_randseqk``) route the compressor hot path: on TPU they invoke the
fused Pallas selection kernel (`repro.kernels.compressor_select`); elsewhere
they run the canonical jnp selection primitives
(`repro.compressors.select`), which the kernel is pinned against bit-for-bit
(same f32 magnitude keys, same lowest-index tie-break — DESIGN.md §12).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hessian_syrk import hessian_syrk_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _should_interpret() -> bool:
    """True when the Pallas kernels must run in interpret mode (non-TPU).

    Call this EAGERLY (outside jit) and pass the result through a static
    argument — see the module docstring.
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Eagerly resolve an ``interpret=None`` default to the backend choice."""
    return _should_interpret() if interpret is None else bool(interpret)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.lru_cache(maxsize=32)
def _mirror_masks(dp: int, block_d: int) -> tuple[np.ndarray, np.ndarray]:
    """(strict_upper, diag_block) boolean tile masks as numpy constants.

    Built once per (dp, block_d) on the host; inside a traced function they
    embed as compile-time constants, so the mirror epilogue costs two
    selects and a transpose — no per-call iota/compare mask construction.
    """
    blk = np.arange(dp) // block_d
    strict_upper = blk[None, :] > blk[:, None]
    diag_block = blk[None, :] == blk[:, None]
    return strict_upper, diag_block


@functools.partial(jax.jit, static_argnames=("block_d", "block_n", "interpret"))
def _hessian_syrk_jit(
    z: jax.Array,
    h: jax.Array,
    block_d: int,
    block_n: int,
    interpret: bool,
) -> jax.Array:
    n, d = z.shape
    zp = _pad_to(_pad_to(z, 0, block_n), 1, block_d)
    hp = _pad_to(h, 0, block_n)
    u = hessian_syrk_pallas(
        zp, hp, block_d=block_d, block_n=block_n, interpret=interpret
    )
    dp = zp.shape[1]
    # mirror strict-upper block tiles; diagonal tiles are already full blocks
    strict_upper, diag_block = _mirror_masks(dp, block_d)
    us = jnp.where(strict_upper, u, 0.0)
    full = us + us.T + jnp.where(diag_block, u, 0.0)
    return full[:d, :d]


def hessian_syrk(
    z: jax.Array,
    h: jax.Array,
    *,
    block_d: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """H = Z^T diag(h) Z via the upper-triangular Pallas SYRK kernel.

    z: (n, d) design matrix, h: (n,) nonneg sample weights -> (d, d) symmetric.
    Zero-pads to tile multiples (zero-weight rows are exact no-ops; padded
    feature columns are sliced away), mirrors the strict-upper tiles.

    ``interpret=None`` resolves to the current default backend *at call
    time* (not at trace time — the resolved flag is a static jit argument,
    so interpret and Mosaic variants occupy distinct cache entries).
    """
    return _hessian_syrk_jit(z, h, block_d, block_n, resolve_interpret(interpret))


def _syrk_blockform(z: jax.Array, h: jax.Array, block_d: int) -> jax.Array:
    """Upper block-row strips of H = Z^T diag(h) Z, concatenated to (d, d).

    Row strip i multiplies only against columns j >= lo_i — the paper's
    §5.10 half-work trick at tile granularity, the same schedule as the
    Pallas kernel's ``pl.when(j >= i)``.  Strips use EXACT slice widths: NO
    column padding.  Padding d up to a tile multiple inflates the strip
    flops past the plain full product for d just above a boundary (w8a's
    d=301 padded to 384 does 2n*98304 flops vs the full product's
    2n*90601 — measured *slower*), while exact slices do
    2n*sum_i w_i*(d - lo_i) ~ 0.69 * 2n*d^2 here.

    The result agrees with H at every (i, j) the strips cover — in
    particular the ENTIRE upper triangle and the full diagonal blocks — so
    both the mirrored dense form and the packed-triu gather read true
    entries straight off it.
    """
    _, d = z.shape
    zsc = h[:, None] * z
    strips = []
    for lo in range(0, d, block_d):
        w = min(block_d, d - lo)
        strip = z[:, lo : lo + w].T @ zsc[:, lo:]
        strips.append(jnp.pad(strip, ((0, 0), (lo, 0))) if lo else strip)
    return jnp.concatenate(strips, axis=0)


def _hessian_syrk_xla(z: jax.Array, h: jax.Array, block_d: int) -> jax.Array:
    n, d = z.shape
    if d <= block_d:
        # single tile: the whole-matrix expression IS the tile program —
        # bit-identical to the pure-jnp oracle (DESIGN.md §12)
        return z.T @ (h[:, None] * z)
    u = _syrk_blockform(z, h, block_d)
    strict_upper, diag_block = _mirror_masks(d, block_d)
    us = jnp.where(strict_upper, u, 0.0)
    return us + us.T + jnp.where(diag_block, u, 0.0)


def hessian_syrk_xla(z: jax.Array, h: jax.Array, *, block_d: int = 128) -> jax.Array:
    """H = Z^T diag(h) Z as an upper-block-triangular XLA program.

    The same tile schedule as the Pallas kernel (compute row strips j >= i,
    mirror once) expressed as plain dot_generals, so it runs at full speed on
    backends where Pallas only has interpret mode.  For d <= block_d the
    program is literally ``z.T @ (h[:, None] * z)`` — bit-identical to the
    pure-jnp oracle; for larger d the blocked accumulation order differs
    from the single dot_general by O(1) ulp (documented, DESIGN.md §12).

    Deliberately NOT jitted here: the round programs trace it inline (a
    nested pjit call could shift fusion boundaries and cost the d <= block_d
    bit-identity guarantee); standalone callers wrap it in jax.jit.
    """
    return _hessian_syrk_xla(z, h, block_d)


def hessian_fused(
    z: jax.Array,
    h: jax.Array,
    *,
    block_d: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """The default fused-Hessian entry point of the round hot path.

    Routes eagerly (host-side, never inside a trace) on the resolved
    backend: Mosaic-compiled Pallas SYRK on TPU, the tile-equivalent XLA
    program (:func:`hessian_syrk_xla`) everywhere else — interpret-mode
    Pallas is a validation path, ~9x slower than XLA on CPU, so it is never
    the default hot path (`repro.objectives.logreg` routes here with
    ``hessian="fused"``; ``hessian="pallas"`` forces the wrapper above).
    """
    if resolve_interpret(interpret):
        return hessian_syrk_xla(z, h, block_d=block_d)
    return hessian_syrk(z, h, block_d=block_d, block_n=block_n, interpret=False)


def hessian_syrk_packed(
    z: jax.Array,
    h: jax.Array,
    *,
    block_d: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """``pack_triu(Z^T diag(h) Z)`` without materializing the mirrored matrix.

    The round hot path only ever consumes the Hessian in packed
    upper-triangle form (compression, Frobenius norms, the H_i updates all
    operate on the (T,) vector — DESIGN.md §12), so the fused client oracle
    gathers the packed entries straight off the block-row strips: every
    (i, j >= i) entry already lives there, and the mirror epilogue would
    only add +0.0 to each before ``pack_triu`` re-extracts it.  Bit-identical
    to ``pack_triu(hessian_fused(z, h))`` (+0.0 can only flip a -0.0 to +0.0,
    and callers add the regularization term packed, replaying the historical
    ``hess + lam*eye`` per-element op order exactly).
    """
    from repro.linalg import pack_triu

    if resolve_interpret(interpret):
        _, d = z.shape
        if d <= block_d:
            return pack_triu(z.T @ (h[:, None] * z))
        return pack_triu(_syrk_blockform(z, h, block_d))
    return pack_triu(hessian_syrk(z, h, block_d=block_d, interpret=False))


# ---------------------------------------------------------------------------
# fused compressor selection (TopK / TopLEK ranking, RandSeqK window)
# ---------------------------------------------------------------------------

def select_topk(u: jax.Array, k: int, *, interpret: bool | None = None,
                fused: bool = False):
    """Fused TopK selection: ``(u_hat, sent)`` in one pass over u.

    Selection contract (DESIGN.md §12): rank by f32(|u|), ties broken toward
    the lowest packed index — pinned in `repro.compressors.select`.  On TPU
    this runs the Pallas selection kernel; elsewhere the canonical jnp
    primitives (bit-identical output by the pinned contract).

    ``fused=True`` picks the sort-free threshold-mask formulation on CPU —
    literally the algorithm the Pallas kernel runs.  It is faster inside the
    fused round's per-client ``lax.map`` (no batched-sort layout, measured
    ~1.6x on w8a) and slower under ``vmap``, so the reference round keeps
    the sorted form; the outputs are bit-identical either way.
    """
    if resolve_interpret(interpret):
        from repro.compressors import select as csel

        if fused:
            return csel.topk_dense_masked(u, k), jnp.asarray(k)
        return csel.topk_dense(u, k), jnp.asarray(k)
    from repro.kernels.compressor_select import select_topk_pallas

    u_hat, sent = select_topk_pallas(u, k, interpret=False)
    return u_hat, sent[0].astype(jnp.asarray(k).dtype)


def select_toplek(key: jax.Array, u: jax.Array, k: int, *,
                  interpret: bool | None = None, fused: bool = False):
    """Fused TopLEK: TopK ranking + the Algorithm-4 adaptive prefix.

    The Bernoulli draw stays outside the kernel as ``uniform(key)`` in u's
    dtype — exactly what ``jax.random.bernoulli(key, p)`` lowers to — so
    fused and unfused paths consume the PRNG stream identically.

    ``fused`` is accepted for call-site symmetry with the other selectors
    and ignored: the adaptive prefix needs the ranked ORDER (cumulative
    energy in descending-key order), which the sort-free threshold mask
    cannot provide, so both rounds share the one sorted body.
    """
    del fused
    from repro.compressors import select as csel

    unif = csel.toplek_uniform(key, u.dtype)
    if resolve_interpret(interpret):
        return csel.toplek_from_uniform(u, k, unif)
    from repro.kernels.compressor_select import select_toplek_pallas

    u_hat, sent = select_toplek_pallas(u, k, unif, interpret=False)
    return u_hat, sent[0].astype(jnp.asarray(k).dtype)


def select_randseqk(key: jax.Array, u: jax.Array, k: int, *,
                    interpret: bool | None = None, fused: bool = False):
    """Fused RandSeqK (Appendix C): one PRG draw, contiguous window keep.

    ``fused=True`` uses the gather-free circular-window mask (the Pallas
    kernel's formulation) instead of roll + prefix slice; values are pure
    copies either way, so the outputs are bit-identical.
    """
    t = u.shape[0]
    s = jax.random.randint(key, (), 0, t)
    if resolve_interpret(interpret):
        from repro.compressors import select as csel

        if fused:
            return csel.randseqk_dense_masked(u, k, s), jnp.asarray(k)
        return csel.randseqk_dense(u, k, s), jnp.asarray(k)
    from repro.kernels.compressor_select import select_randseqk_pallas

    u_hat, sent = select_randseqk_pallas(u, k, s, interpret=False)
    return u_hat, sent[0].astype(jnp.asarray(k).dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def _flash_attention_jit(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool,
    window: int | None,
    scale: float | None,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    sq, hn, dh = q.shape
    sk = k.shape[0]
    qt = _pad_to(jnp.swapaxes(q, 0, 1), 1, block_q)
    kt = _pad_to(jnp.swapaxes(k, 0, 1), 1, block_k)
    vt = _pad_to(jnp.swapaxes(v, 0, 1), 1, block_k)
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret, kv_len=sk,
    )
    return jnp.swapaxes(out[:, :sq], 0, 1)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention, (seq, heads, head_dim) layout (matches ref.py).

    Pads seq to block multiples (padded queries are discarded; padded keys are
    masked out by causality/window because they sit at positions >= seq).
    ``interpret=None`` resolves eagerly at call time (see module docstring).
    """
    return _flash_attention_jit(
        q, k, v, causal, window, scale, block_q, block_k,
        resolve_interpret(interpret),
    )
