from repro.train.optimizer import adamw_init, adamw_update, AdamWConfig
from repro.train.step import make_train_step, make_serve_step, loss_for
from repro.train.data import synthetic_batch, synthetic_token_stream
from repro.train.checkpoint import save_checkpoint, load_checkpoint

__all__ = [
    "adamw_init",
    "adamw_update",
    "AdamWConfig",
    "make_train_step",
    "make_serve_step",
    "loss_for",
    "synthetic_batch",
    "synthetic_token_stream",
    "save_checkpoint",
    "load_checkpoint",
]
