"""Synthetic token pipeline for the LM zoo.

Deterministic, seedable next-token-predictable streams (a noisy order-2
Markov chain over the vocab) so that short training runs show a real loss
decrease in the end-to-end example — not just random labels.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig


def synthetic_batch(cfg: ArchConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """One training batch matching the family's input contract."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab

    def stream(n, s):
        # x_{t} = (a * x_{t-1} + b) mod v with occasional noise: learnable
        a, b = 6364136223846793005 % v or 7, 1442695040888963407 % v or 11
        x = rng.integers(0, v, size=(n, 1))
        cols = [x]
        for _ in range(s - 1):
            nxt = (cols[-1] * a + b) % v
            noise = rng.random((n, 1)) < 0.1
            nxt = np.where(noise, rng.integers(0, v, size=(n, 1)), nxt)
            cols.append(nxt)
        return np.concatenate(cols, axis=1).astype(np.int32)

    if cfg.family == "encdec":
        tokens = stream(batch, seq)
        return {
            "src_embeds": rng.standard_normal((batch, seq, cfg.d_model)).astype(
                np.float32
            ),
            "tokens": tokens,
            "labels": np.concatenate(
                [tokens[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1
            ),
        }
    tokens = stream(batch, seq)
    out = {
        "tokens": tokens,
        "labels": np.concatenate(
            [tokens[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1
        ),
    }
    if cfg.family == "vlm":
        out["img_embeds"] = rng.standard_normal(
            (batch, cfg.n_frontend_tokens, cfg.d_model)
        ).astype(np.float32)
    return out


def synthetic_token_stream(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    """Infinite iterator of batches (fresh seed per step)."""
    step = 0
    while True:
        yield synthetic_batch(cfg, batch, seq, seed=seed + step)
        step += 1
