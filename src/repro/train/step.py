"""train_step / serve_step builders for every architecture family.

`make_train_step(cfg)` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with gradient accumulation over cfg.accum_steps microbatches (a lax.scan):
global batch (B, S) is reshaped to (A, B/A, S); grads are accumulated in f32
and applied once — this is what bounds per-device activation memory for the
33B/140B dry-run configs (DESIGN.md §5).

`make_serve_step(cfg)` returns (params, cache, tokens) -> (logits, cache),
one token with a KV/state cache — the function lowered by the decode shapes.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.encdec import encdec_decode_step, encdec_loss, encdec_prefill
from repro.models.lm import lm_decode_step, lm_loss, lm_prefill
from repro.train.optimizer import AdamWConfig, adamw_update


def loss_for(cfg: ArchConfig) -> Callable:
    if cfg.family == "encdec":
        return lambda params, batch: encdec_loss(params, cfg, batch)
    return lambda params, batch: lm_loss(params, cfg, batch)


def _split_microbatches(batch: dict, accum: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])

    return {k: r(v) for k, v in batch.items()}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = loss_for(cfg)
    accum = max(1, cfg.accum_steps)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = _split_microbatches(batch, accum)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grads_acc, g
                )
                return (loss_acc + l, grads_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zeros), micro, unroll=cfg.unroll_layers)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    if cfg.family == "encdec":
        def prefill_step(params, batch):
            return encdec_prefill(params, cfg, batch["src_embeds"], batch["tokens"])
    elif cfg.family == "vlm":
        def prefill_step(params, batch):
            return lm_prefill(params, cfg, batch["tokens"], batch.get("img_embeds"))
    else:
        def prefill_step(params, batch):
            return lm_prefill(params, cfg, batch["tokens"])

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    if cfg.family == "encdec":
        def serve_step(params, cache, tokens):
            return encdec_decode_step(params, cfg, cache, tokens)
    else:
        def serve_step(params, cache, tokens):
            return lm_decode_step(params, cfg, cache, tokens)

    return serve_step
