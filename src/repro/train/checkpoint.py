"""Flat-npz pytree checkpointing (no orbax dependency).

Pytrees are flattened with '/'-joined key paths; restore rebuilds against a
reference pytree structure (shape/dtype checked).
"""

from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save_checkpoint(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (a pytree of arrays)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        key = prefix[:-1]
        arr = data[key]
        ref = np.asarray(tree)
        if arr.shape != ref.shape:
            raise ValueError(f"checkpoint mismatch at {key}: {arr.shape} vs {ref.shape}")
        return jax.numpy.asarray(arr, dtype=ref.dtype)

    return rebuild(like)
