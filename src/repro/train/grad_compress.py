"""EF21-style compressed gradient aggregation for the LM training loop.

Reuses the FedNL compressor substrate (TopK on flattened leaves) as a
first-order gradient compressor with error feedback (Richtárik et al., EF21 —
reference [47] of the paper): each worker maintains an estimator g_i and
uplinks only C(grad_i - g_i); the estimator update g <- g + C(grad - g) is
exactly FedNL's Hessian-learning rule applied to gradients.

In the pjit data-parallel setting the compression is modeled on the
globally-averaged gradient (the estimator sequence is identical when all
workers see the same average); the collective saving applies per-worker on a
real multi-node deployment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ef21_init(params):
    return jax.tree.map(jnp.zeros_like, params)


def _topk_leaf(delta: jax.Array, frac: float) -> jax.Array:
    flat = delta.ravel()
    k = max(1, int(frac * flat.size))
    _, idx = jax.lax.top_k(jnp.abs(flat).astype(jnp.float32), k)
    comp = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return comp.reshape(delta.shape)


def ef21_step(grads, est, frac: float):
    """Returns (new_estimator, grads_to_apply).  grads_to_apply == estimator."""
    def upd(g, e):
        return e + _topk_leaf(g - e, frac)

    new_est = jax.tree.map(upd, grads, est)
    return new_est, new_est
