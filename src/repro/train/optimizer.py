"""AdamW, implemented directly on pytrees (no optax dependency).

Moment tensors share the parameter sharding (the specs pytree is reused
verbatim for m and v), so optimizer state is FSDP-sharded exactly like params.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), dtype=jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, opt_state["v"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        return p - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, gnorm
