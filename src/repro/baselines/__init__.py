from repro.baselines.numpy_reference import run_fednl_numpy_reference

__all__ = ["run_fednl_numpy_reference"]
