"""Reference-style FedNL baseline: per-client Python loop over NumPy.

This mirrors the structure of the original FedNL prototype the paper starts
from (https://github.com/Rustem-Islamov/FedNL-Public): a Python `for` loop
over clients per round, dense d x d Hessian handling, NumPy everywhere, no
fusion/symmetry/sparsity exploitation.  The benchmark table compares this
against the JAX implementation to reproduce the shape of the paper's x1000
claim on THIS machine (the paper's factor is C++/AVX-512 vs Python/NumPy on a
24-core Xeon; ours is jit/vmap-fused XLA vs the same reference style).
"""

from __future__ import annotations

import time

import numpy as np


def _topk_dense(m: np.ndarray, k: int) -> np.ndarray:
    """TopK on the full dense matrix, the reference way (no triu packing)."""
    flat = np.abs(m).ravel()
    idx = np.argpartition(flat, -k)[-k:]
    out = np.zeros_like(m).ravel()
    out[idx] = m.ravel()[idx]
    return out.reshape(m.shape)


def _randk_dense(rng, m: np.ndarray, k: int) -> np.ndarray:
    idx = rng.choice(m.size, size=k, replace=False)
    out = np.zeros_like(m).ravel()
    out[idx] = m.ravel()[idx]
    return out.reshape(m.shape)


def run_fednl_numpy_reference(
    z: np.ndarray, lam: float, rounds: int, compressor: str = "topk",
    k_multiplier: float = 8.0, seed: int = 0,
):
    """z: (n_clients, n_i, d).  Returns (grad_norm_last, wall_seconds)."""
    n, n_i, d = z.shape
    k = int(k_multiplier * d) * 2  # dense-matrix budget ~= 2x triu budget
    rng = np.random.default_rng(seed)
    x = np.zeros(d)
    h_local = np.zeros((n, d, d))
    # reference initializes shifts at the exact Hessians
    for i in range(n):
        mrg = z[i] @ x
        s = 1.0 / (1.0 + np.exp(-mrg))
        w = s * (1 - s) / n_i
        h_local[i] = z[i].T @ (w[:, None] * z[i]) + lam * np.eye(d)
    h_global = h_local.mean(axis=0)

    t0 = time.perf_counter()
    gnorm = np.inf
    for _ in range(rounds):
        grads = np.zeros((n, d))
        s_sum = np.zeros((d, d))
        l_sum = 0.0
        for i in range(n):  # the reference's per-client Python loop
            mrg = z[i] @ x
            sig = 1.0 / (1.0 + np.exp(-mrg))
            grads[i] = -(z[i].T @ (1.0 - sig)) / n_i + lam * x
            w = sig * (1 - sig) / n_i
            hess = z[i].T @ (w[:, None] * z[i]) + lam * np.eye(d)
            diff = hess - h_local[i]
            if compressor == "topk":
                s_i = _topk_dense(diff, k)
            elif compressor == "randk":
                s_i = _randk_dense(rng, diff, k)
            else:
                s_i = diff
            l_sum += np.linalg.norm(diff, "fro")
            h_local[i] = h_local[i] + s_i
            s_sum += s_i
        grad = grads.mean(axis=0)
        l = l_sum / n
        x = x - np.linalg.solve(h_global + l * np.eye(d), grad)
        h_global = h_global + s_sum / n
        gnorm = float(np.linalg.norm(grad))
    return gnorm, time.perf_counter() - t0
