from repro.compressors.core import (
    Compressor,
    CompressorSpec,
    get_compressor,
    COMPRESSORS,
    topk,
    randk,
    randseqk,
    toplek,
    natural,
    identity,
)

__all__ = [
    "Compressor",
    "CompressorSpec",
    "get_compressor",
    "COMPRESSORS",
    "topk",
    "randk",
    "randseqk",
    "toplek",
    "natural",
    "identity",
]
