"""FedNL matrix compressors on packed upper-triangle vectors.

The six compressors of the paper (Section 8, Appendices C & D):

  - TopK      : keep the k largest-magnitude entries (contractive, delta = k/T)
  - RandK     : keep k entries u.a.r. without replacement (unbiased, omega = T/k - 1)
  - RandSeqK  : *cache-aware* RandK (Appendix C, NEW in paper): one random start
                index, k *contiguous* entries (mod T).  Same expectation and
                variance as RandK, but a single PRG invocation and a contiguous
                memory access pattern.  On TPU this is `jnp.roll` + a prefix
                slice — a sublane-aligned contiguous VMEM read instead of RandK's
                random gather.
  - TopLEK    : adaptive Top-<=K (Appendix D, NEW in paper): sends k' <= k entries,
                randomizing between the two adjacent prefix sizes so that the
                contractive inequality E||C(x)-x||^2 <= (1-delta)||x||^2 holds with
                *tight equality* at delta = k/T.
  - Natural   : probabilistic rounding to powers of two (Horvath et al.);
                unbiased with omega = 1/8.  Implemented with frexp/ldexp-style
                mantissa ops (the paper uses free CPU byte addressing; TPU/JAX
                has no such luxury — assumption change noted in DESIGN.md).
  - Identity  : C(x) = x.

Conventions
-----------
All compressors consume/produce the packed upper-triangle vector u of length
T = d(d+1)/2 (see repro.linalg.triu).  Off-diagonal entries represent two matrix
elements; selection probabilities are uniform over the T packed slots, exactly as
in the paper's Appendix C (which samples from the upper-triangle sequence E).

FedNL theory runs with *contractive* compressors.  Unbiased compressors C with
variance parameter omega are used through their scaled form C/(1+omega), which is
contractive with delta = 1/(1+omega) (standard FedNL reduction).  `get_compressor`
returns the scaled form by default and reports:
    alpha  - recommended Hessian learning rate (1.0 for the scaled/contractive form)
    delta  - contraction parameter of the returned operator

Each `compress(key, u)` returns `(u_hat, sent_elems)` where `u_hat` is the dense
(decompressed) result used by the simulation and `sent_elems` is the number of
scalar payload entries a real network transfer would carry (TopLEK makes this
data-dependent).  `message_bits(comp, sent_elems)` converts to wire bits using
the paper's Section 7 encodings (32-bit indices; PRG-seed reconstruction for
RandK/RandSeqK; sign+exponent-only payload for Natural); the byte-level
encoder/decoder pairs realizing exactly these bit counts on a real transport
live in `repro.comm.wire` (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Callable

import jax
import jax.numpy as jnp

from repro.compressors import select

FP_BITS = 64  # paper uses FP64 end-to-end
IDX_BITS = 32  # paper: "fixed-width 32-bit integer format surpassed varying sizes"
NATURAL_BITS = 12  # sign + 11-bit FP64 exponent per entry


# ---------------------------------------------------------------------------
# raw compressors (unscaled)
# ---------------------------------------------------------------------------

def _rank_keys(u: jax.Array) -> jax.Array:
    """f32 magnitude keys for selection — the PINNED contract shared with the
    fused kernel path, re-exported from :mod:`repro.compressors.select`.

    lax.top_k over f64 keys is ~9x slower than f32 on the CPU backend (and
    f32 sort keys are the TPU-native path); ranking in f32 while keeping the
    f64 PAYLOAD preserves the contractive property up to f32 rounding of
    near-ties.  Both the jnp and Pallas paths rank in f32 with a stable
    lowest-index tie-break — ranking widths MUST NOT diverge between paths,
    or near-tie entries silently select different index sets (DESIGN.md §12;
    regression-tested on adversarial near-ties in tests/test_kernels.py).
    """
    return select.rank_keys(u)


def topk(u: jax.Array, k: int, *, fused: bool = False) -> tuple[jax.Array, jax.Array]:
    """Deterministic TopK by magnitude.  Contractive with delta = k/T.

    Routed through the fused selection entry point (`repro.kernels.ops`):
    the Pallas kernel on TPU, the canonical jnp primitives (bit-identical by
    the selection contract) everywhere else.  ``fused=True`` picks the
    sort-free threshold-mask formulation the fused round maps per client
    (see `repro.compressors.select.topk_dense_masked`); outputs are
    bit-identical either way.
    """
    from repro.kernels import ops as kops

    return kops.select_topk(u, k, fused=fused)


def randk(key: jax.Array, u: jax.Array, k: int, *, scaled: bool = True):
    """RandK: k slots u.a.r. without replacement.

    scaled=True  -> C/(1+omega): plain masking (delta = k/T)
    scaled=False -> unbiased form, entries scaled by T/k (omega = T/k - 1)
    """
    t = u.shape[0]
    # uniform k-subset without replacement via top-k of iid uniform keys
    # (jax.random.choice's permutation path is an order of magnitude slower)
    keys = jax.random.uniform(key, (t,), dtype=jnp.float32)
    _, idx = jax.lax.top_k(keys, k)
    u_hat = jnp.zeros_like(u).at[idx].set(u[idx])
    if not scaled:
        u_hat = u_hat * (t / k)
    return u_hat, jnp.asarray(k)


def randseqk(key: jax.Array, u: jax.Array, k: int, *, scaled: bool = True,
             fused: bool = False):
    """Cache-aware RandK (paper Appendix C).

    One PRG draw s ~ U[T]; keep slots {s, s+1, ..., s+k-1 mod T}.  Marginal
    inclusion probability is k/T for every slot, hence the same expectation and
    variance bound as RandK (paper Observations 1 & 2).  The contiguous window is
    realized as roll + prefix slice (or, ``fused=True``, the bit-identical
    gather-free window mask): a sequential memory access on TPU.
    """
    t = u.shape[0]
    if scaled:
        from repro.kernels import ops as kops

        return kops.select_randseqk(key, u, k, fused=fused)
    s = jax.random.randint(key, (), 0, t)
    return select.randseqk_dense(u, k, s) * (t / k), jnp.asarray(k)


def toplek(key: jax.Array, u: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Adaptive Top-Less-Equal-K (paper Algorithm 4).

    Target contraction delta = k/T (TopK's worst case).  Let alpha_m be the
    energy fraction captured by the top-m entries.  Find the prefix size m* with
    alpha_{m*-1} < delta <= alpha_{m*}; keep m*-1 entries with probability
    p = (alpha_hi - delta) / (alpha_hi - alpha_lo) and m* entries otherwise, so
    that E||C(u)-u||^2 = (1-delta)||u||^2 exactly.

    The body lives in :func:`repro.compressors.select.toplek_from_uniform`
    with the Bernoulli draw hoisted to ``uniform(key)`` (bit-identical to
    ``jax.random.bernoulli`` — verified in tests), shared verbatim by the
    Pallas kernel; routing goes through `repro.kernels.ops.select_toplek`.
    """
    from repro.kernels import ops as kops

    return kops.select_toplek(key, u, k)


def natural(key: jax.Array, u: jax.Array, *, scaled: bool = True):
    """Natural compression: probabilistic rounding to the nearest powers of two.

    |u| = 2^(e-1) * t with t in [1, 2); round down to 2^(e-1) w.p. (2 - t),
    up to 2^e w.p. (t - 1).  Unbiased with omega = 1/8.
    """
    mant, exp = jnp.frexp(jnp.abs(u))  # |u| = mant * 2^exp, mant in [0.5, 1)
    t2 = 2.0 * mant  # in [1, 2)
    p_up = t2 - 1.0
    up = jax.random.bernoulli(key, jnp.clip(p_up, 0.0, 1.0), shape=u.shape)
    pow2 = jnp.ldexp(jnp.ones_like(u), exp - 1 + up.astype(exp.dtype))
    out = jnp.where(u == 0, 0.0, jnp.sign(u) * pow2)
    if scaled:
        out = out * (8.0 / 9.0)
    return out, jnp.asarray(u.shape[0])


def identity(u: jax.Array) -> tuple[jax.Array, jax.Array]:
    return u, jnp.asarray(u.shape[0])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# sparse (index, value) forms — used by the compressed-collective aggregation
# (repro.distributed): instead of psum-ing dense length-T vectors, devices
# all_gather only the k (idx, val) pairs per client and scatter-add on the
# master.  Padding entries carry val=0 (scatter-add of zero is a no-op).
# ---------------------------------------------------------------------------

def topk_sparse(u: jax.Array, k: int):
    idx = select.topk_indices(u, k)
    return idx.astype(jnp.int32), u[idx], jnp.asarray(k)


def randk_sparse(key: jax.Array, u: jax.Array, k: int):
    t = u.shape[0]
    keys = jax.random.uniform(key, (t,), dtype=jnp.float32)
    _, idx = jax.lax.top_k(keys, k)
    return idx.astype(jnp.int32), u[idx], jnp.asarray(k)


def randseqk_sparse(key: jax.Array, u: jax.Array, k: int):
    t = u.shape[0]
    s = jax.random.randint(key, (), 0, t)
    idx = ((s + jnp.arange(k)) % t).astype(jnp.int32)
    rolled = jnp.roll(u, -s)  # contiguous window read
    return idx, rolled[:k], jnp.asarray(k)


def toplek_sparse(key: jax.Array, u: jax.Array, k: int):
    """TopLEK with a fixed-size k buffer; entries past `kept` are zero-padded."""
    u_hat, kept = toplek(key, u, k)
    _, idx = jax.lax.top_k(_rank_keys(u_hat), k)
    pos_mask = jnp.arange(k) < kept
    return (
        jnp.where(pos_mask, idx, 0).astype(jnp.int32),
        jnp.where(pos_mask, u_hat[idx], 0.0),
        kept,
    )


def scatter_add_sparse(idx: jax.Array, vals: jax.Array, t: int) -> jax.Array:
    """Decompress-and-accumulate a batch of sparse messages into one (T,) vector."""
    return jnp.zeros((t,), dtype=vals.dtype).at[idx.ravel()].add(vals.ravel())


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A configured compressor: `compress(key, u) -> (u_hat, sent_elems)`.

    `compress_sparse(key, u) -> (idx, vals, sent_elems)` exists for
    sparsification compressors (TopK/RandK/RandSeqK/TopLEK) and is None for
    dense ones (Natural/Identity).
    """

    name: str
    compress: Callable[[jax.Array, jax.Array], tuple[jax.Array, jax.Array]]
    alpha: float  # recommended Hessian learning rate for FedNL
    delta: float  # contraction parameter of the returned (scaled) operator
    bits_per_elem: float  # payload bits per sent element
    header_bits: float  # per-message constant (seed / count)
    compress_sparse: Callable | None = None
    k: int = 0


@dataclasses.dataclass(frozen=True)
class CompressorSpec:
    name: str
    make: Callable[..., Compressor]  # (T, k, fused=False) -> Compressor


def _make_topk(t: int, k: int, fused: bool = False) -> Compressor:
    return Compressor("topk", lambda key, u: topk(u, k, fused=fused), alpha=1.0,
                      delta=k / t, bits_per_elem=FP_BITS + IDX_BITS, header_bits=0,
                      compress_sparse=lambda key, u: topk_sparse(u, k), k=k)


def _make_randk(t: int, k: int, fused: bool = False) -> Compressor:
    del fused  # RandK's uniform-subset gather has no masked formulation
    return Compressor("randk", lambda key, u: randk(key, u, k), alpha=1.0,
                      delta=k / t, bits_per_elem=FP_BITS, header_bits=FP_BITS,
                      compress_sparse=lambda key, u: randk_sparse(key, u, k), k=k)


def _make_randseqk(t: int, k: int, fused: bool = False) -> Compressor:
    return Compressor("randseqk", lambda key, u: randseqk(key, u, k, fused=fused),
                      alpha=1.0,
                      delta=k / t, bits_per_elem=FP_BITS, header_bits=IDX_BITS,
                      compress_sparse=lambda key, u: randseqk_sparse(key, u, k), k=k)


def _make_toplek(t: int, k: int, fused: bool = False) -> Compressor:
    del fused  # the adaptive prefix is order-dependent: one sorted body
    return Compressor("toplek", lambda key, u: toplek(key, u, k), alpha=1.0,
                      delta=k / t, bits_per_elem=FP_BITS + IDX_BITS,
                      header_bits=IDX_BITS,
                      compress_sparse=lambda key, u: toplek_sparse(key, u, k), k=k)


def _make_natural(t: int, k: int, fused: bool = False) -> Compressor:
    del k, fused
    return Compressor("natural", lambda key, u: natural(key, u), alpha=1.0,
                      delta=8.0 / 9.0, bits_per_elem=NATURAL_BITS, header_bits=0)


def _make_identity(t: int, k: int, fused: bool = False) -> Compressor:
    del k, fused
    return Compressor("identity", lambda key, u: identity(u), alpha=1.0,
                      delta=1.0, bits_per_elem=FP_BITS, header_bits=0)


COMPRESSORS: dict[str, CompressorSpec] = {
    "topk": CompressorSpec("topk", _make_topk),
    "randk": CompressorSpec("randk", _make_randk),
    "randseqk": CompressorSpec("randseqk", _make_randseqk),
    "toplek": CompressorSpec("toplek", _make_toplek),
    "natural": CompressorSpec("natural", _make_natural),
    "identity": CompressorSpec("identity", _make_identity),
}


def get_compressor(name: str, t: int, k: int = 0, *, fused: bool = False) -> Compressor:
    """Build a compressor for packed-triu length `t` with sparsity budget `k`.

    ``fused=True`` binds the kernel-layer selection formulations (threshold
    mask for TopK, window mask for RandSeqK) that the fused round maps per
    client — bit-identical outputs to the default sorted/rolled forms
    (DESIGN.md §12), different performance profile (faster under lax.map,
    slower under vmap on CPU).  Registered factories keep the legacy
    ``(t, k)`` contract: ``fused`` is only forwarded to factories whose
    signature accepts a third argument, so user compressors (which have no
    masked formulation to select) are called exactly as before.
    """
    if name not in COMPRESSORS:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(COMPRESSORS)}")
    if name in ("topk", "randk", "randseqk", "toplek") and not (0 < k <= t):
        raise ValueError(f"{name} needs 0 < k <= T, got k={k}, T={t}")
    make = COMPRESSORS[name].make
    try:
        takes_fused = len(inspect.signature(make).parameters) >= 3
    except (TypeError, ValueError):  # builtins / C callables: legacy form
        takes_fused = False
    return make(t, k, fused) if takes_fused else make(t, k)


def message_bits(c: Compressor, sent_elems: jax.Array) -> jax.Array:
    """Wire bits for one compressed Hessian message (Section 7 encodings)."""
    return sent_elems * c.bits_per_elem + c.header_bits
