"""Pinned selection primitives shared by the jnp compressors and the Pallas
selection kernel (DESIGN.md §12).

The selection contract
----------------------
Every sparsifying compressor path — pure-jnp (`repro.compressors.core`),
fused (`repro.kernels.compressor_select`), sparse wire form — MUST select the
same index set, defined as:

  * rank keys are ``f32(|u|)`` (:func:`rank_keys`) — NOT the f64 magnitudes.
    Ranking in f64 is ~9x slower through ``lax.top_k`` on CPU and is not the
    TPU-native sort width; more importantly, *mixing* widths across paths is
    a parity bug: f64 entries that are distinct but collide when rounded to
    f32 would be ordered differently by an f64-ranking kernel, silently
    selecting a different index set than the f32-ranking jnp path.  Both
    paths therefore rank in f32, always.
  * ties (equal f32 keys — including the near-tie collisions above) break
    toward the LOWEST packed-triu index.  ``jax.lax.top_k`` guarantees this
    stable order; :func:`threshold_keep_mask` reproduces the identical set
    without a sort (the Pallas-kernel formulation).  The regression tests in
    tests/test_kernels.py pin set equality on adversarial near-tie inputs.

The TopLEK randomization consumes its PRNG key as a single uniform draw in
the payload dtype: ``jax.random.bernoulli(key, p)`` lowers to exactly
``uniform(key, (), p.dtype) < p``, so :func:`toplek_from_uniform` takes the
uniform as an operand and fused/unfused paths replay the same PRNG stream
bit-for-bit (verified in tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

RANK_DTYPE = jnp.float32


def rank_keys(u: jax.Array) -> jax.Array:
    """The pinned selection keys: f32 magnitudes (see module docstring)."""
    return jnp.abs(u).astype(RANK_DTYPE)


def topk_indices(u: jax.Array, k: int) -> jax.Array:
    """Indices of the k largest-magnitude entries, lowest-index tie-break,
    in descending key order (the canonical ranking both paths pin to)."""
    _, idx = jax.lax.top_k(rank_keys(u), k)
    return idx


def topk_dense(u: jax.Array, k: int) -> jax.Array:
    """Dense TopK sparsification C(u): zeros everywhere but the selected set."""
    idx = topk_indices(u, k)
    return jnp.zeros_like(u).at[idx].set(u[idx])


def topk_dense_masked(u: jax.Array, k: int) -> jax.Array:
    """Dense TopK via :func:`threshold_keep_mask` — the sort-free formulation
    the Pallas selection kernel runs, bit-identical to :func:`topk_dense`
    (same selected set by the pinned contract; values are pure copies).

    On CPU the two formulations trade places with the mapping strategy: the
    mask (31 compare/sum passes, no data movement) beats the batched sort
    inside a per-client ``lax.map`` (~1.6x on w8a's T=45451) but loses under
    ``vmap`` — the fused round picks it together with ``lax.map``
    (repro.core.fednl.FUSED_VMAP_MAX_D)."""
    keep = threshold_keep_mask(rank_keys(u), k)
    return jnp.where(keep, u, jnp.zeros_like(u))


def threshold_keep_mask(keys: jax.Array, k: int) -> jax.Array:
    """Boolean keep-mask selecting the same set as ``top_k(keys, k)`` without
    a sort — the formulation the Pallas selection kernel runs.

    ``keys`` must be the non-negative f32 :func:`rank_keys`.  Their int32 bit
    patterns order identically to their values (IEEE-754 monotonicity on
    non-negatives), so the k-th largest key is found by a 31-step binary
    search on the bit pattern — compares and full-array sums only, no data
    movement.  Entries strictly above the threshold are kept; of the entries
    EQUAL to it, the first ``k - n_gt`` in index order are kept (prefix of
    the running tie count), which is exactly ``lax.top_k``'s stable
    lowest-index tie-break.  Set equality (ties included) is pinned by
    tests/test_kernels.py.
    """
    bits = jax.lax.bitcast_convert_type(keys, jnp.int32)

    def body(i, t):
        cand = t | (1 << (30 - i))
        return jnp.where(jnp.sum(bits >= cand) >= k, cand, t)

    thr = jax.lax.fori_loop(0, 31, body, jnp.int32(0))
    gt = bits > thr
    eq = bits == thr
    n_gt = jnp.sum(gt)
    return gt | (eq & (jnp.cumsum(eq) <= k - n_gt))


def randseqk_window_mask(t: int, k: int, s: jax.Array) -> jax.Array:
    """Membership mask of the circular window {s, ..., s+k-1 mod T} — the
    gather-free form of RandSeqK's contiguous slice."""
    pos = jnp.arange(t)
    return (pos - s) % t < k


def randseqk_dense(u: jax.Array, k: int, s: jax.Array) -> jax.Array:
    """Dense RandSeqK given the start draw ``s``: roll + prefix slice + roll
    back (the paper's contiguous single-PRG-draw window, Appendix C).  Values
    are pure copies, so this is bit-identical to masking with
    :func:`randseqk_window_mask`."""
    rolled = jnp.roll(u, -s)
    window = jnp.zeros_like(u).at[:k].set(rolled[:k])
    return jnp.roll(window, s)


def randseqk_dense_masked(u: jax.Array, k: int, s: jax.Array) -> jax.Array:
    """Dense RandSeqK via :func:`randseqk_window_mask` — the gather-free
    formulation the Pallas kernel runs; bit-identical to
    :func:`randseqk_dense` (values are pure copies)."""
    return jnp.where(
        randseqk_window_mask(u.shape[0], k, s), u, jnp.zeros_like(u)
    )


def toplek_from_uniform(
    u: jax.Array, k: int, unif: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """TopLEK (paper Algorithm 4) with the Bernoulli draw supplied as a
    uniform ``unif`` in u's dtype: ``unif < p`` replays
    ``jax.random.bernoulli(key, p)`` bit-for-bit (module docstring), letting
    the fused kernel consume the same PRNG stream as the jnp path.

    Target contraction delta = k/T.  Let alpha_m be the energy fraction of
    the top-m entries; find m* with alpha_{m*-1} < delta <= alpha_{m*}, keep
    m*-1 entries w.p. p = (alpha_hi - delta)/(alpha_hi - alpha_lo) else m*,
    so E||C(u)-u||^2 = (1-delta)||u||^2 holds with equality.
    """
    t = u.shape[0]
    delta = k / t
    # only the top-k prefix can ever be kept (alpha_k >= k/T always), so a
    # partial top-k selection suffices — no full T-sort (paper §5.11 spirit).
    idx = topk_indices(u, k)
    vals = u[idx]  # descending by rank key, lowest-index ties first
    s2 = vals.astype(jnp.float64) ** 2 if u.dtype == jnp.float64 else vals**2
    csum = jnp.cumsum(s2)
    total = jnp.sum(u * u)
    safe_total = jnp.where(total > 0, total, 1.0)
    alphas = (csum / safe_total).astype(u.dtype)  # alphas[m-1] = alpha_m
    # smallest m (1-indexed) with alpha_m >= delta
    m_star = jnp.searchsorted(alphas, delta, side="left") + 1
    m_star = jnp.minimum(m_star, k)
    alpha_hi = alphas[m_star - 1]
    alpha_lo = jnp.where(m_star > 1, alphas[jnp.maximum(m_star - 2, 0)], 0.0)
    gap = alpha_hi - alpha_lo
    p = jnp.where(gap > 0, (alpha_hi - delta) / jnp.where(gap > 0, gap, 1.0), 0.0)
    p = jnp.clip(p, 0.0, 1.0)
    take_lo = unif < p
    kept = jnp.where(take_lo, m_star - 1, m_star)
    kept = jnp.where(total > 0, kept, 0)
    keep_mask = jnp.arange(k) < kept
    u_hat = jnp.zeros_like(u).at[idx].set(jnp.where(keep_mask, vals, 0.0))
    return u_hat, kept


def toplek_uniform(key: jax.Array, dtype) -> jax.Array:
    """The single TopLEK PRNG draw, in the dtype ``bernoulli`` would use (the
    probability's dtype == the payload dtype here)."""
    return jax.random.uniform(key, (), dtype=dtype)
