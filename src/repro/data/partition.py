"""Client partitioning: the paper's preprocessing pipeline.

"We augmented each sample with an artificial feature equal to 1 to have an
intercept term ... The dataset is reshuffled u.a.r and was split across n
clients with n_i [samples]; the remaining samples were excluded." (§5, App. B)

`absorb_labels` implements §5.13: labels b_ij are folded into the design matrix
(z_j = b_ij * a_ij), which removes them from all three oracles.
"""

from __future__ import annotations

import numpy as np


def add_intercept(x: np.ndarray) -> np.ndarray:
    return np.concatenate([x, np.ones((x.shape[0], 1), dtype=x.dtype)], axis=1)


def absorb_labels(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    return x * y[:, None]


def partition_clients(
    x: np.ndarray,
    y: np.ndarray,
    n_clients: int,
    n_i: int,
    seed: int = 0,
    shuffle: bool = True,
) -> np.ndarray:
    """Return z: (n_clients, n_i, d) label-absorbed per-client design matrices.

    Samples beyond n_clients * n_i are dropped (paper: "the remaining 49
    samples were excluded").
    """
    n_total = n_clients * n_i
    if x.shape[0] < n_total:
        raise ValueError(
            f"need {n_total} samples for {n_clients} clients x {n_i}, have {x.shape[0]}"
        )
    if shuffle:
        perm = np.random.default_rng(seed).permutation(x.shape[0])
        x, y = x[perm], y[perm]
    z = absorb_labels(x[:n_total], y[:n_total])
    return z.reshape(n_clients, n_i, x.shape[1])
