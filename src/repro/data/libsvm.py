"""LIBSVM text-format reader/writer (paper §3: "The selected dataset in LIBSVM
format is read from disk storage").

Format per line:  <label> <index>:<value> <index>:<value> ...
Indices are 1-based.  The parser is a single pass over the mapped bytes —
the JAX-framework analogue of the paper's memory-mapped custom parser (§5.2):
we mmap the file and split on newlines without building temporary strings
per token beyond Python's baseline.
"""

from __future__ import annotations

import mmap
import os

import numpy as np


def parse_libsvm(path: str | os.PathLike, n_features: int | None = None):
    """Parse a LIBSVM file into a dense (n, d) float64 matrix + (n,) labels.

    Labels are normalized to {-1, +1} (0/1 inputs are mapped to -1/+1).
    """
    labels: list[float] = []
    rows: list[list[tuple[int, float]]] = []
    max_idx = 0
    with open(path, "rb") as fh:
        size = os.fstat(fh.fileno()).st_size
        if size == 0:
            return np.zeros((0, n_features or 0)), np.zeros((0,))
        with mmap.mmap(fh.fileno(), 0, access=mmap.ACCESS_READ) as mm:
            for line in iter(mm.readline, b""):
                line = line.strip()
                if not line or line.startswith(b"#"):
                    continue
                parts = line.split()
                labels.append(float(parts[0]))
                feats = []
                for tok in parts[1:]:
                    idx_b, val_b = tok.split(b":", 1)
                    idx = int(idx_b)
                    feats.append((idx, float(val_b)))
                    if idx > max_idx:
                        max_idx = idx
                rows.append(feats)
    d = n_features if n_features is not None else max_idx
    x = np.zeros((len(rows), d), dtype=np.float64)
    for r, feats in enumerate(rows):
        for idx, val in feats:
            if idx <= d:
                x[r, idx - 1] = val
    y = np.asarray(labels, dtype=np.float64)
    # normalize labels to {-1, +1}
    uniq = np.unique(y)
    if set(uniq.tolist()) <= {0.0, 1.0}:
        y = 2.0 * y - 1.0
    y = np.where(y > 0, 1.0, -1.0)
    return x, y


def write_libsvm(path: str | os.PathLike, x: np.ndarray, y: np.ndarray) -> None:
    """Write a dense matrix as LIBSVM text (used by tests and the generator)."""
    with open(path, "w") as fh:
        for row, lab in zip(np.asarray(x), np.asarray(y)):
            feats = " ".join(
                f"{i + 1}:{v:.17g}" for i, v in enumerate(row) if v != 0.0
            )
            fh.write(f"{int(lab):+d} {feats}\n")
