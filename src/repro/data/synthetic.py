"""Synthetic logistic-regression problem generator.

The paper ships `bin_opt_problem_generator` ("Optional synthetics optimization
problem generator", Appendix L.5).  The real LIBSVM W8A/A9A/PHISHING files are
not available offline, so experiments use synthetic instances with the *same
dimensions and splits* as the paper's tables:

    w8a       d=301 (300 features + intercept), n=142 clients, n_i=348/350
    a9a       d=124, n_i=229
    phishing  d=69,  n_i=77

Features are sparse-ish gaussians; labels come from a planted x* with logistic
noise, giving a well-conditioned strongly-convex instance once lambda > 0 —
matching the paper's regime (lambda=1e-3, kappa <= 5.8).
"""

from __future__ import annotations

import numpy as np

# (d_including_intercept, n_clients, n_i) per paper Tables 1-3
DATASET_SHAPES = {
    "w8a": (301, 142, 348),
    "a9a": (124, 142, 229),
    "phishing": (69, 142, 77),
    "tiny": (24, 8, 40),  # test-sized instance
}


def make_synthetic_logreg(
    name_or_dims,
    seed: int = 0,
    density: float = 0.25,
):
    """Generate (features, labels) with shapes matching a paper dataset.

    Returns x: (n_samples, d-1) raw features (intercept NOT yet added) and
    y: (n_samples,) in {-1, +1}; pass through add_intercept + partition_clients
    to obtain the federated problem, mirroring the paper's pipeline
    (augment with intercept -> reshuffle u.a.r. -> split into n_i chunks).
    """
    if isinstance(name_or_dims, str):
        d, n_clients, n_i = DATASET_SHAPES[name_or_dims]
    else:
        d, n_clients, n_i = name_or_dims
    n_samples = n_clients * n_i
    rng = np.random.default_rng(seed)
    d_raw = d - 1  # the intercept column is appended later
    x = rng.standard_normal((n_samples, d_raw))
    mask = rng.random((n_samples, d_raw)) < density
    x = np.where(mask, x, 0.0)
    # keep feature scale comparable to LIBSVM's 0/1-ish features
    x /= max(1.0, np.sqrt(density * d_raw) / 2.0)
    x_star = rng.standard_normal(d_raw) / np.sqrt(d_raw)
    logits = x @ x_star + 0.25 * rng.standard_normal(n_samples)
    p = 1.0 / (1.0 + np.exp(-logits))
    y = np.where(rng.random(n_samples) < p, 1.0, -1.0)
    return x, y
