from repro.data.libsvm import parse_libsvm, write_libsvm
from repro.data.synthetic import make_synthetic_logreg, DATASET_SHAPES
from repro.data.partition import partition_clients, absorb_labels, add_intercept

__all__ = [
    "parse_libsvm",
    "write_libsvm",
    "make_synthetic_logreg",
    "DATASET_SHAPES",
    "partition_clients",
    "absorb_labels",
    "add_intercept",
]
