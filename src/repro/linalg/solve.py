"""Newton-system solves for the FedNL master (paper §5.9).

The paper moved from Gaussian elimination to Cholesky-Banachiewicz with
optimized forward/backward substitution (×1.31).  On TPU/XLA the analogue is
`cho_factor`/`cho_solve` (LAPACK-style blocked Cholesky lowered by XLA).

Two master step rules (Algorithm 1, Line 11):
  Option A:  x+ = x - [H]_mu^{-1} grad       ([.]_mu = eigenvalue projection to >= mu)
  Option B:  x+ = x - (H + l I)^{-1} grad    (l = averaged Frobenius error, keeps PD)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve


def psd_project(h: jax.Array, mu: float | jax.Array) -> jax.Array:
    """[H]_mu: clip eigenvalues of a symmetric matrix from below at mu."""
    w, v = jnp.linalg.eigh(h)
    w = jnp.maximum(w, mu)
    return (v * w[..., None, :]) @ jnp.swapaxes(v, -1, -2)


def cholesky_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve A x = b for symmetric positive-definite A via Cholesky."""
    c, low = cho_factor(a)
    return cho_solve((c, low), b)


def newton_solve_optionA(h: jax.Array, grad: jax.Array, mu: float) -> jax.Array:
    """Direction [H]_mu^{-1} grad (Option A / 'projection')."""
    return cholesky_solve(psd_project(h, mu), grad)


def newton_solve_optionB(h: jax.Array, grad: jax.Array, l: jax.Array) -> jax.Array:
    """Direction (H + l I)^{-1} grad (Option B / 'Frobenius shift')."""
    d = h.shape[-1]
    # paper §5.8: "careful implementation of adding the same scalar to the diagonal"
    h_reg = h + l * jnp.eye(d, dtype=h.dtype)
    return cholesky_solve(h_reg, grad)
