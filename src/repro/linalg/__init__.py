from repro.linalg.triu import (
    triu_size,
    triu_indices,
    pack_triu,
    unpack_triu,
    frob_norm_from_packed,
)
from repro.linalg.solve import (
    newton_solve_optionA,
    newton_solve_optionB,
    psd_project,
    cholesky_solve,
)

__all__ = [
    "triu_size",
    "triu_indices",
    "pack_triu",
    "unpack_triu",
    "frob_norm_from_packed",
    "newton_solve_optionA",
    "newton_solve_optionB",
    "psd_project",
    "cholesky_solve",
]
