"""Packed upper-triangular representation of symmetric matrices.

The paper (§5.10, §5.13, Appendix C) exploits symmetry of the Hessian: only the
upper triangle is computed, stored, compressed, and communicated.  We mirror that
with a packed vector layout of size T = d(d+1)/2.  All FedNL compressors operate
on this packed form; the dense matrix is only materialized where linear algebra
needs it (Newton solve on the master).

Layout: row-major upper triangle, i.e. element (i, j) with j >= i sits at
    offset(i, j) = i*d - i*(i-1)//2 + (j - i)

Frobenius norm of the symmetric matrix from packed form needs off-diagonal
entries counted twice; `frob_norm_from_packed` handles that with a precomputed
weight vector (cheap, reused every round — the paper's §5.8 "use symmetry during
evaluating ||.||_F" trick).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def triu_size(d: int) -> int:
    """Number of elements in the upper triangle (incl. diagonal) of a d x d matrix."""
    return d * (d + 1) // 2


@functools.lru_cache(maxsize=64)
def triu_indices(d: int) -> tuple[np.ndarray, np.ndarray]:
    """Static (rows, cols) index arrays for the packed layout.

    Computed once per dimension and cached (paper §5.11: "computed and stored
    indices for the upper triangular part once without recomputing").
    """
    rows, cols = np.triu_indices(d)
    return rows.astype(np.int32), cols.astype(np.int32)


@functools.lru_cache(maxsize=64)
def _offdiag_weights(d: int) -> np.ndarray:
    """Weight 1.0 on diagonal entries, 2.0 off-diagonal (for norms/inner products)."""
    rows, cols = triu_indices(d)
    return np.where(rows == cols, 1.0, 2.0)


def pack_triu(m: jax.Array) -> jax.Array:
    """Pack the upper triangle of a symmetric (d, d) matrix into a (T,) vector."""
    d = m.shape[-1]
    rows, cols = triu_indices(d)
    return m[..., rows, cols]


def unpack_triu(u: jax.Array, d: int) -> jax.Array:
    """Unpack a (..., T) packed vector into the full symmetric (..., d, d) matrix."""
    rows, cols = triu_indices(d)
    out = jnp.zeros(u.shape[:-1] + (d, d), dtype=u.dtype)
    out = out.at[..., rows, cols].set(u)
    # mirror: add transpose, subtract the diagonal we double-counted
    diag = jnp.diagonal(out, axis1=-2, axis2=-1)  # (..., d)
    eye = jnp.eye(d, dtype=u.dtype)
    return out + jnp.swapaxes(out, -1, -2) - diag[..., :, None] * eye


def frob_norm_from_packed(u: jax.Array, d: int) -> jax.Array:
    """||M||_F of the symmetric matrix represented by packed vector u."""
    w = jnp.asarray(_offdiag_weights(d), dtype=u.dtype)
    return jnp.sqrt(jnp.sum(w * u * u, axis=-1))


def frob_inner_from_packed(u: jax.Array, v: jax.Array, d: int) -> jax.Array:
    """<U, V>_F for two symmetric matrices in packed form."""
    w = jnp.asarray(_offdiag_weights(d), dtype=u.dtype)
    return jnp.sum(w * u * v, axis=-1)
