"""Memory-pressure management: spilling tenants to FNLS1 checkpoints.

The spill contract (DESIGN.md §11): a spilled tenant is written as an
ordinary byte-stable FNLS1 session checkpoint — the SAME format
:meth:`repro.api.session.Session.save` produces — so a spilled file is not
an engine-private artifact: ``open_session(spec, restore=path)`` resumes it
outside the engine, and ``FedNLServer.resume(path)`` re-admits it.  Batched
and solo tenants converge on the format from opposite directions:

* a **solo** tenant spills through ``session.save(path)`` + ``close()``
  (closing also tears down wire transports — a star-tcp tenant's client
  fleet is released the moment it spills, never leaked);
* a **batch** tenant's algorithm state is wrapped in a
  :class:`~repro.api.session.SessionState` with the *local-backend layout*
  (``meta={"kind": ...}``, arrays under ``state.*``, ``backend="local"``) —
  exactly what ``_LocalSessionHandle.snapshot()`` would have produced, so
  restore goes through the same ``algo.init`` + ``restored_state`` path the
  local handle uses and stays bit-identical.

Victim selection implements two policies over the resident set:
``"lru"`` spills the least-recently-advanced tenant first (admission-order
tiebreak → round-robin time-slicing when everyone advances every tick);
``"cost"`` spills the largest resident state first (packed Hessian ~d^2),
freeing the most memory per spill.
"""

from __future__ import annotations

import pathlib
import tempfile

from repro.api.backends import state_arrays
from repro.api.session import SessionState, save_state
from repro.serve_fednl.tenant import RUNNING, SPILLED, Tenant


class SpillManager:
    """Owns the spill directory and the spill/victim mechanics."""

    def __init__(self, spill_dir=None, policy: str = "lru"):
        if policy not in ("lru", "cost"):
            raise ValueError(
                f"eviction policy must be 'lru' or 'cost', got {policy!r}"
            )
        self.policy = policy
        self._tmp = None
        if spill_dir is None:
            self._tmp = tempfile.TemporaryDirectory(prefix="fednl-serve-")
            spill_dir = self._tmp.name
        self.dir = pathlib.Path(spill_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.spill_count = 0
        self.resume_count = 0

    def path_for(self, tenant: Tenant) -> pathlib.Path:
        return self.dir / f"{tenant.tenant_id}.r{tenant.round}.fnlsess"

    def pick_victims(
        self, resident: list[Tenant], n: int, current_tick: int
    ) -> list[Tenant]:
        """Choose up to ``n`` spill victims from ``resident``.  Tenants
        admitted or resumed on the current tick are exempt (no thrashing a
        tenant back out before it has advanced a single round)."""
        candidates = [
            t
            for t in resident
            if t.status == RUNNING and t.admitted_tick < current_tick
        ]
        if self.policy == "cost":
            candidates.sort(key=lambda t: (-t.cost, t.last_active_tick))
        else:  # lru
            candidates.sort(
                key=lambda t: (t.last_active_tick, t.admitted_tick)
            )
        return candidates[:n]

    def spill(self, tenant: Tenant) -> pathlib.Path:
        """Write ``tenant`` to disk and drop its resident state."""
        path = self.path_for(tenant)
        if tenant.lane == "solo":
            tenant.session.save(path)
            tenant.session.close()  # releases wire transports too
            tenant.session = None
        else:
            save_state(
                SessionState(
                    spec=tenant.spec,
                    algorithm=tenant.algo.name,
                    backend="local",
                    round=tenant.round,
                    meta={"kind": tenant.algo.kind},
                    arrays=state_arrays(tenant.state),
                    records=tuple(tenant.records),
                ),
                path,
            )
            tenant.state = None
        tenant.spill_path = path
        tenant.status = SPILLED
        tenant.spill_count += 1
        self.spill_count += 1
        return path

    def cleanup(self) -> None:
        if self._tmp is not None:
            self._tmp.cleanup()
            self._tmp = None
