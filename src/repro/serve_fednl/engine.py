"""`FedNLServer` — the multi-tenant FedNL serving event loop.

One engine multiplexes many concurrent experiments (tenants) through the
continuous-batching scheduler (``repro.serve_fednl.scheduler``): every
``tick()`` admits queued tenants up to capacity, spills resident tenants to
FNLS1 checkpoints under memory pressure (``repro.serve_fednl.spill``),
re-forms the batching groups, advances every in-flight tenant exactly ONE
round — batched tenants through one jitted switched round kernel per group,
solo tenants through their open :class:`repro.api.session.Session` — and
applies each tenant's :class:`~repro.api.session.StopPolicy` per slot.

    server = FedNLServer(ServeConfig(max_resident=16))
    handles = [server.submit(spec) for spec in specs]
    server.serve_until_idle()          # or server.start() for a thread
    reports = [h.result() for h in handles]

Numerics bar (pinned by tests/test_serve_fednl.py and scripts/
smoke_serve.py): every record of every served tenant is bit-identical to a
solo ``open_session(spec).run()`` — regardless of which tenants it was
batched with, in what order they arrived, or how often it was spilled and
resumed along the way.
"""

from __future__ import annotations

import dataclasses
import pathlib
import threading
from typing import Any

import jax
import numpy as np

from repro.obs import core as obs

from repro.api.backends import full_round_record, restored_state
from repro.api.report import RunReport, RunReportBuilder
from repro.api.session import (
    SessionState,
    load_state,
    open_session,
    resolve_policy,
)
from repro.serve_fednl.scheduler import (
    DEFAULT_PRIORITIES,
    DEFAULT_PRIORITY,
    FairShareQueue,
    GroupRuntime,
    SubmitOptions,
    serve_group_key,
    serve_lane,
)
from repro.serve_fednl.spill import SpillManager
from repro.serve_fednl.tenant import (
    CANCELLED,
    EVICTED,
    FINISHED,
    QUEUED,
    RUNNING,
    SPILLED,
    Tenant,
    TenantHandle,
)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine sizing and policy knobs.

    ``max_resident`` caps how many tenants hold live (device) state at once
    — beyond it, victims spill to disk and re-queue (round-robin
    time-slicing).  ``admit_per_tick`` bounds admission work per tick.
    ``max_group`` caps slots per batched tick launch.  ``eviction`` picks
    the spill victim policy (``"lru"`` | ``"cost"``).  ``spill_dir`` is
    where checkpoints go (default: a private temporary directory, removed
    at shutdown).  ``pad_pow2`` pads batch slot counts to powers of two so
    re-formed groups reuse compiled tick programs.  ``priorities`` names
    the admission classes and their fair-share weights (deficit round-robin
    over class queues — DESIGN.md §14; a single class degenerates to FIFO);
    ``quantum`` scales the per-cycle DRR credit.
    """

    max_resident: int = 16
    admit_per_tick: int = 8
    max_group: int = 16
    eviction: str = "lru"
    spill_dir: str | pathlib.Path | None = None
    pad_pow2: bool = True
    priorities: dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_PRIORITIES)
    )
    quantum: float = 1.0


class FedNLServer:
    """Serve many FedNL experiments through one engine (module docstring).

    Thread model: ``submit``/``resume`` only enqueue (cheap, lock-guarded);
    all JAX work happens inside ``tick()`` — called either synchronously
    (``tick``/``serve_until_idle``) or by the single background thread
    ``start()`` spawns.  One lock serializes ticks against queue mutation.
    """

    def __init__(self, config: ServeConfig | None = None):
        self.config = config or ServeConfig()
        if self.config.max_resident < 1:
            raise ValueError("max_resident must be >= 1")
        jax.config.update("jax_enable_x64", True)
        self._lock = threading.RLock()
        self._queue = FairShareQueue(
            self.config.priorities, quantum=self.config.quantum
        )
        # the class submit() falls back to when no SubmitOptions is given
        self._default_priority = (
            DEFAULT_PRIORITY
            if DEFAULT_PRIORITY in self.config.priorities
            else self._queue._order[0]
        )
        self._tenants: dict[str, Tenant] = {}
        self._groups: dict[tuple, GroupRuntime] = {}
        self._spill = SpillManager(
            self.config.spill_dir, policy=self.config.eviction
        )
        self._z_cache: dict[Any, Any] = {}
        self._counter = 0
        self._ticks = 0
        self._finished = 0
        self._failed = 0
        self._evicted = 0
        self._cancelled = 0
        self._launches = 0
        self._slots_live = 0
        self._slots_padded = 0
        self._admissions_by_class = {p: 0 for p in self.config.priorities}
        self._rounds_by_class = {p: 0 for p in self.config.priorities}
        self._thread: threading.Thread | None = None
        self._stop_evt = threading.Event()
        self._shut = False

    # --- intake -----------------------------------------------------------

    def submit(
        self,
        spec,
        until=None,
        tenant_id: str | None = None,
        options: SubmitOptions | None = None,
    ) -> TenantHandle:
        """Enqueue one experiment; returns immediately with a handle.

        ``until`` follows :meth:`repro.api.session.Session.run` (None | int
        | float | StopPolicy); ``options`` picks the admission priority
        class (:class:`~repro.serve_fednl.scheduler.SubmitOptions`).
        Validation is upfront and SYNCHRONOUS: anything ``solve()`` would
        reject — plus a bad compressor/k, an unresolvable alpha, a bad tau,
        an unknown priority class — is rejected here, before it ever
        reaches a tick (a remote SUBMIT gets an error frame naming the
        field, not a dead tenant discovered ticks later).
        """
        from repro.api.facade import check_spec
        from repro.api.registry import get_algorithm, get_backend

        algo = get_algorithm(spec.algorithm)
        backend = get_backend(spec.backend)
        check_spec(spec, algo, backend)
        # resolve the compressor upfront: a bad name/k must fail the submit,
        # not detonate inside a later tick that serves other tenants too
        from repro.api.batch import resolved_alpha
        from repro.compressors import get_compressor
        from repro.linalg import triu_size

        d, n_clients, _ = spec.data.dims()
        cfg = spec.fednl_config()
        get_compressor(cfg.compressor, triu_size(d), cfg.k_for(d))
        # resolve everything else _admit would have resolved lazily: the
        # Hessian learning rate (compressor-dependent default) and, for PP,
        # the participation size — both must fail the SUBMIT, not the tick
        resolved_alpha(spec, d)
        if algo.kind == "pp":
            spec.tau_for(n_clients)
        if not backend.supports_sessions:
            raise ValueError(
                f"backend {spec.backend!r} does not support sessions and "
                "cannot be served; run it with solve(spec) instead"
            )
        policy = resolve_policy(until, spec)
        if policy.tol is not None and algo.kind == "pp":
            raise ValueError(
                "tol-based stopping is undefined for partial participation "
                "(the server never sees the global gradient); use max_rounds "
                "or a predicate on the records instead"
            )
        return self._enqueue(
            spec, policy, serve_lane(spec, algo, backend), tenant_id,
            self._resolve_priority(options),
        )

    def resume(
        self,
        checkpoint,
        until=None,
        tenant_id: str | None = None,
        options: SubmitOptions | None = None,
    ) -> TenantHandle:
        """Re-admit a spilled/evicted/external FNLS1 checkpoint (a path from
        :meth:`evict`, :meth:`Session.save`, or a
        :class:`~repro.api.session.SessionState`).  The run continues
        bit-identically from its checkpointed round."""
        from repro.api.registry import get_algorithm, get_backend

        state = (
            checkpoint
            if isinstance(checkpoint, SessionState)
            else load_state(checkpoint)
        )
        spec = state.spec
        algo = get_algorithm(spec.algorithm)
        backend = get_backend(spec.backend)
        policy = resolve_policy(until, spec)
        lane = serve_lane(spec, algo, backend)
        if lane == "batch" and state.backend != "local":
            lane = "solo"  # foreign state layout: replay through its backend
        handle = self._enqueue(
            spec, policy, lane, tenant_id, self._resolve_priority(options)
        )
        t = handle._tenant
        t.restore = state
        t.round = int(state.round)
        t.records = list(state.records)
        return handle

    def _resolve_priority(self, options: SubmitOptions | None) -> str:
        if options is None:
            return self._default_priority
        if not isinstance(options, SubmitOptions):
            raise TypeError(
                f"options must be a SubmitOptions, got "
                f"{type(options).__name__}"
            )
        options.validate(self.config.priorities)
        return options.priority

    def _enqueue(self, spec, policy, lane, tenant_id, priority) -> TenantHandle:
        with self._lock:
            if self._shut:
                raise RuntimeError("engine is shut down")
            if tenant_id is None:
                tenant_id = f"t{self._counter:04d}"
                self._counter += 1
            if tenant_id in self._tenants:
                raise ValueError(f"tenant id {tenant_id!r} already in use")
            t = Tenant(
                tenant_id=tenant_id, spec=spec, policy=policy, lane=lane,
                priority=priority,
            )
            self._tenants[tenant_id] = t
            t.enqueued_at = obs.now()
            self._queue.push(t)
            return TenantHandle(t)

    # --- the tick ---------------------------------------------------------

    def tick(self) -> dict:
        """One scheduling round: pressure -> admit -> batch -> solo.

        Returns a small stats dict for this tick (admitted, spilled, groups,
        live/padded slot counts, finished).  With a live ``repro.obs``
        recorder installed the tick is wrapped in an ``engine.tick`` span
        (fields: admitted/spilled/groups/slots/finished plus the jit-compile
        delta, so consumers can split cold ticks out) and feeds the
        engine.* counters/gauges/histograms listed in DESIGN.md §15 — all
        host-side scalars, never touching tenant numerics."""
        rec = obs.CURRENT
        with self._lock, rec.span("engine.tick") as sp:
            if self._shut:
                raise RuntimeError("engine is shut down")
            self._ticks += 1
            now = self._ticks
            compiles0 = (
                sum(g.compiles for g in self._groups.values())
                if rec.enabled
                else 0
            )
            out = {"tick": now, "admitted": 0, "spilled": 0, "groups": 0,
                   "slots": 0, "slots_padded": 0, "finished": 0}

            # 1. memory pressure: make room for queued tenants by spilling
            # resident ones (victims re-queue at the back of their class
            # queue -> round-robin time-slicing within each class)
            resident = [
                t for t in self._tenants.values() if t.status == RUNNING
            ]
            admittable = min(len(self._queue), self.config.admit_per_tick)
            free = self.config.max_resident - len(resident)
            if admittable > free:
                victims = self._spill.pick_victims(
                    resident, admittable - free, now
                )
                for v in victims:
                    self._spill.spill(v)
                    v.enqueued_at = obs.now()
                    self._queue.push(v)
                    out["spilled"] += 1

            # 2. admission: deficit round-robin over the priority classes
            # (FIFO within a class; resumes restore their checkpointed state)
            n_res = sum(
                1 for t in self._tenants.values() if t.status == RUNNING
            )
            admitted = 0
            while (
                self._queue
                and admitted < self.config.admit_per_tick
                and n_res < self.config.max_resident
            ):
                t = self._queue.pop()
                if t is None or t.status in (EVICTED, CANCELLED):
                    continue  # evicted/cancelled while queued
                if rec.enabled and t.enqueued_at:
                    rec.observe(
                        "engine.queue.wait_s",
                        obs.now() - t.enqueued_at,
                        cls=t.priority,
                    )
                    rec.add("engine.admissions", cls=t.priority)
                self._admit(t, now)
                admitted += 1
                self._admissions_by_class[t.priority] += 1
                if t.status == RUNNING:
                    n_res += 1
                elif t.status == FINISHED:
                    out["finished"] += 1
            out["admitted"] = admitted

            # 3. batched lane: re-form groups, one switched kernel per chunk
            running = [
                t for t in self._tenants.values() if t.status == RUNNING
            ]
            groups: dict[tuple, list[Tenant]] = {}
            for t in running:
                if t.lane == "batch":
                    groups.setdefault(t.group_key, []).append(t)
            for key, members in groups.items():
                rt = self._groups[key]
                for lo in range(0, len(members), self.config.max_group):
                    chunk = members[lo : lo + self.config.max_group]
                    t1 = obs.now()
                    metrics, n_pad = rt.tick_group(
                        chunk, pad_pow2=self.config.pad_pow2
                    )
                    launch_s = obs.now() - t1
                    per = launch_s / len(chunk)
                    if rec.enabled:
                        rec.observe("engine.batch.launch_s", launch_s)
                        rec.observe("engine.group.slots", len(chunk))
                        rec.add("engine.rounds", len(chunk), lane="batch")
                    self._launches += 1
                    self._slots_live += len(chunk)
                    self._slots_padded += n_pad
                    out["groups"] += 1
                    out["slots"] += len(chunk)
                    out["slots_padded"] += n_pad
                    for t, m in zip(chunk, metrics):
                        t.wall_time_s += per
                        rr = full_round_record(t.round, m)
                        t.records.append(rr)
                        t.round += 1
                        self._rounds_by_class[t.priority] += 1
                        t.last_active_tick = now
                        if t.policy.hit(rr) or t.round >= t.policy.max_rounds:
                            self._finish_batch(t)
                            out["finished"] += 1

            # 4. solo lane: one Session round per tenant per tick
            for t in running:
                if t.lane != "solo" or t.status != RUNNING:
                    continue
                try:
                    recs = t.session.step(1)
                except Exception as exc:  # tenant-local failure, not engine
                    try:
                        t.session.close()
                    except Exception:
                        pass
                    self._failed += 1
                    t.fail(exc)
                    continue
                t.last_active_tick = now
                if recs:
                    t.records.append(recs[0])
                    t.round = t.session.round
                    self._rounds_by_class[t.priority] += 1
                    if rec.enabled:
                        rec.add("engine.rounds", lane="solo")
                if (
                    not recs
                    or t.policy.hit(recs[0])
                    or t.round >= t.policy.max_rounds
                ):
                    self._finish_solo(t)
                    out["finished"] += 1

            if rec.enabled:
                if out["spilled"]:
                    rec.add("engine.spills", out["spilled"])
                for cls_name, depth in self._queue.backlog().items():
                    rec.gauge("engine.queue.depth", depth, cls=cls_name)
                rec.gauge(
                    "engine.resident",
                    sum(
                        1
                        for t in self._tenants.values()
                        if t.status == RUNNING
                    ),
                )
                sp.set(
                    tick=now,
                    admitted=out["admitted"],
                    spilled=out["spilled"],
                    groups=out["groups"],
                    slots=out["slots"],
                    finished=out["finished"],
                    compiles=sum(g.compiles for g in self._groups.values())
                    - compiles0,
                )
            return out

    def _z_for(self, spec):
        if spec.data not in self._z_cache:
            self._z_cache[spec.data] = spec.data.build()
        return self._z_cache[spec.data]

    def _admit(self, t: Tenant, now: int) -> None:
        import jax.numpy as jnp

        from repro.api.batch import resolved_alpha
        from repro.api.registry import get_algorithm, get_backend

        resumed = t.status == SPILLED or t.restore is not None
        if t.lane == "solo":
            backend = get_backend(t.spec.backend)
            z = self._z_for(t.spec) if backend.needs_problem else None
            restore = t.spill_path if t.status == SPILLED else t.restore
            t0 = obs.now()
            t.session = open_session(t.spec, z=z, restore=restore)
            t.init_time_s += obs.now() - t0
            t.restore = None
            t.round = t.session.round
            t.records = list(t.session.records)
        else:
            algo = get_algorithm(t.spec.algorithm)
            t.algo = algo
            z = self._z_for(t.spec)
            d = int(z.shape[-1])
            cfg = t.spec.fednl_config()
            t0 = obs.now()
            state = algo.init(z, cfg, x0=None, seed=t.spec.seed)
            restore = None
            if t.status == SPILLED:
                restore = load_state(t.spill_path)
            elif t.restore is not None:
                restore = t.restore
            if restore is not None:
                state = restored_state(
                    state, restore, place=lambda arr, ref: jnp.asarray(arr)
                )
                t.round = int(restore.round)
                t.restore = None
            t.state = state
            t.init_time_s += obs.now() - t0
            t.comp_branch = (cfg.compressor, cfg.k_for(d))
            t.group_key = serve_group_key(t.spec, d)
            if t.group_key not in self._groups:
                self._groups[t.group_key] = GroupRuntime(
                    z, cfg, resolved_alpha(t.spec, d), algo.make_batch_round
                )
        if resumed:
            self._spill.resume_count += 1
        t.status = RUNNING
        t.admitted_tick = now
        t.last_active_tick = now
        # a tenant admitted at (or past) its round budget finishes at once
        # (solve()'s rounds=0 semantics: INIT only, no rounds)
        if t.round >= t.policy.max_rounds:
            if t.lane == "solo":
                self._finish_solo(t)
            else:
                self._finish_batch(t)

    # --- completion -------------------------------------------------------

    def _finish_batch(self, t: Tenant) -> None:
        builder = RunReportBuilder(t.spec, t.algo.name, "local")
        builder.extend(t.records)
        report = builder.build(
            x=np.asarray(t.state.x),
            wall_time_s=t.wall_time_s,
            init_time_s=t.init_time_s,
            extras={"served": True, "spills": t.spill_count},
        )
        t.finish(report)
        self._finished += 1

    def _finish_solo(self, t: Tenant) -> None:
        report = t.session.report()
        report.extras["served"] = True
        report.extras["spills"] = t.spill_count
        sess = t.session
        t.finish(report)
        sess.close()
        self._finished += 1

    # --- eviction / persistence -------------------------------------------

    def evict(self, tenant_id: str) -> pathlib.Path:
        """Gracefully evict one tenant: checkpoint it to disk (closing any
        wire transports it held) and remove it from scheduling.  Returns the
        FNLS1 path — an ordinary session checkpoint, resumable with
        :meth:`resume` or ``open_session(spec, restore=path)``."""
        with self._lock:
            t = self._tenants.get(tenant_id)
            if t is None:
                raise KeyError(f"no tenant {tenant_id!r}")
            if t.status == RUNNING:
                self._spill.spill(t)
            elif t.status == QUEUED and t.restore is not None:
                # never materialized: persist the pending restore state
                from repro.api.session import save_state

                t.spill_path = self._spill.path_for(t)
                save_state(t.restore, t.spill_path)
            elif t.status != SPILLED:
                raise ValueError(
                    f"tenant {tenant_id!r} is {t.status!r}; only queued/"
                    "running/spilled tenants can be evicted"
                )
            t.status = EVICTED
            t.restore = None
            self._evicted += 1
            t.done_event.set()
            return t.spill_path

    def cancel(self, tenant_id: str) -> None:
        """Drop one tenant without a checkpoint: its device/session state is
        released, any spill file is deleted, and the id leaves scheduling.
        Unlike :meth:`evict` nothing survives — ``result()`` raises and the
        spec must be resubmitted to run again.  Finished/failed tenants keep
        their outcome (cancelling them is an error)."""
        with self._lock:
            t = self._tenants.get(tenant_id)
            if t is None:
                raise KeyError(f"no tenant {tenant_id!r}")
            if t.status not in (QUEUED, RUNNING, SPILLED):
                raise ValueError(
                    f"tenant {tenant_id!r} is {t.status!r}; only queued/"
                    "running/spilled tenants can be cancelled"
                )
            if t.status == RUNNING and t.session is not None:
                try:
                    t.session.close()
                except Exception:
                    pass
            if t.spill_path is not None:
                try:
                    t.spill_path.unlink(missing_ok=True)
                except OSError:
                    pass
            t.session = None
            t.state = None
            t.restore = None
            t.status = CANCELLED
            self._cancelled += 1
            t.done_event.set()

    # --- driving ----------------------------------------------------------

    def _has_work(self) -> bool:
        with self._lock:
            return bool(self._queue) or any(
                t.status in (RUNNING, SPILLED)
                for t in self._tenants.values()
            )

    def serve_until_idle(self, max_ticks: int | None = None) -> int:
        """Tick until every tenant is finished/failed/evicted; returns the
        number of ticks run.  ``max_ticks`` is a runaway guard."""
        n = 0
        while self._has_work():
            self.tick()
            n += 1
            if max_ticks is not None and n >= max_ticks:
                raise RuntimeError(
                    f"serve_until_idle exceeded max_ticks={max_ticks}"
                )
        return n

    def start(self) -> None:
        """Spawn the background serving thread (idempotent).  All JAX work
        stays on that thread; callers just submit() and wait()."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._serve_loop, name="fednl-serve", daemon=True
        )
        self._thread.start()

    def _serve_loop(self) -> None:
        while not self._stop_evt.is_set():
            if self._has_work():
                self.tick()
            else:
                self._stop_evt.wait(0.002)

    def stop(self, timeout: float = 30.0) -> None:
        """Stop the background thread (tenants keep their state; ticking can
        resume via tick()/start())."""
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def shutdown(self, spill: bool = False) -> None:
        """Tear the engine down.  With ``spill=True`` every live tenant is
        checkpointed first (set an explicit ``spill_dir`` to keep the files
        past shutdown); queued-only tenants are simply evicted.  Always
        closes every solo session — no wire transport (star-tcp client
        fleet) survives the engine."""
        self.stop()
        with self._lock:
            if self._shut:
                return
            for t in self._tenants.values():
                if t.status == RUNNING:
                    if spill:
                        self._spill.spill(t)
                    elif t.session is not None:
                        try:
                            t.session.close()
                        except Exception:
                            pass
                    t.session = None
                    t.state = None
                if t.status in (QUEUED, RUNNING, SPILLED):
                    t.status = EVICTED
                    self._evicted += 1
                    t.done_event.set()
            self._queue.clear()
            if self.config.spill_dir is None:
                self._spill.cleanup()  # private tmp dir: nothing to keep
            self._shut = True

    def __enter__(self) -> "FedNLServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(spill=False)

    # --- introspection ----------------------------------------------------

    def stats(self) -> dict:
        """Cumulative engine counters (occupancy = live slots / padded
        slots across every batched launch)."""
        with self._lock:
            statuses: dict[str, int] = {}
            for t in self._tenants.values():
                statuses[t.status] = statuses.get(t.status, 0) + 1
            return {
                "ticks": self._ticks,
                "tenants": len(self._tenants),
                "finished": self._finished,
                "failed": self._failed,
                "evicted": self._evicted,
                "cancelled": self._cancelled,
                "queued": len(self._queue),
                "backlog": self._queue.backlog(),
                "admissions_by_class": dict(self._admissions_by_class),
                "rounds_by_class": dict(self._rounds_by_class),
                "statuses": statuses,
                "spills": self._spill.spill_count,
                "resumes": self._spill.resume_count,
                "batch_launches": self._launches,
                "batch_occupancy": (
                    self._slots_live / self._slots_padded
                    if self._slots_padded
                    else None
                ),
                "compiles": sum(g.compiles for g in self._groups.values()),
                "groups": len(self._groups),
            }


def serve_all(specs, config: ServeConfig | None = None) -> list[RunReport]:
    """Convenience: serve ``specs`` to completion through one engine and
    return their reports in order (the serving analogue of ``solve_many``
    for heterogeneous, stop-policy-bearing runs)."""
    with FedNLServer(config) as server:
        handles = [server.submit(spec) for spec in specs]
        server.serve_until_idle()
        return [h.result() for h in handles]
