"""repro.serve_fednl — multi-tenant FedNL serving engine.

Not to be confused with :mod:`repro.serving`, the Part-II LM *token*
serving engine (continuous batching of text generation requests).  This
package is the FedNL analogue one level up: continuous batching of whole
**optimization sessions** — many concurrent experiments multiplexed through
one :class:`FedNLServer`, each advanced one round per tick through shared
jitted switched round kernels, spilled to byte-stable FNLS1 checkpoints
under memory pressure, and guaranteed bit-identical to a solo
``open_session(spec).run()`` (DESIGN.md §11).

    from repro.serve_fednl import FedNLServer, ServeConfig

    with FedNLServer(ServeConfig(max_resident=16)) as server:
        handles = [server.submit(spec) for spec in specs]
        server.serve_until_idle()
        reports = [h.result() for h in handles]
"""

from repro.serve_fednl.engine import FedNLServer, ServeConfig, serve_all
from repro.serve_fednl.scheduler import (
    DEFAULT_PRIORITIES,
    DEFAULT_PRIORITY,
    FairShareQueue,
    SubmitOptions,
    serve_group_key,
    serve_lane,
)
from repro.serve_fednl.tenant import TenantHandle

__all__ = [
    "DEFAULT_PRIORITIES",
    "DEFAULT_PRIORITY",
    "FairShareQueue",
    "FedNLServer",
    "ServeConfig",
    "SubmitOptions",
    "TenantHandle",
    "serve_all",
    "serve_group_key",
    "serve_lane",
]
