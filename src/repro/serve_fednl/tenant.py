"""Tenant bookkeeping for the FedNL serving engine.

A *tenant* is one experiment admitted to the engine: its spec, resolved stop
policy, per-round records accumulated so far, and whichever runtime form it
currently has — a live algorithm state on the batched lane, an open
:class:`repro.api.session.Session` on the solo lane, or a spilled FNLS1
checkpoint on disk.  The public face is :class:`TenantHandle`, a thin view
the submitting caller keeps while the engine owns the tenant.
"""

from __future__ import annotations

import dataclasses
import pathlib
import threading
from typing import Any

from repro.api.report import RoundRecord, RunReport
from repro.api.session import StopPolicy

# tenant lifecycle: queued -> running <-> spilled -> finished
#                                    \-> evicted (explicit, checkpointed, leaves the engine)
#                                    \-> cancelled (explicit, state dropped, no checkpoint)
#                                    \-> failed  (solo-lane step exception)
QUEUED = "queued"
RUNNING = "running"
SPILLED = "spilled"
FINISHED = "finished"
EVICTED = "evicted"
CANCELLED = "cancelled"
FAILED = "failed"


@dataclasses.dataclass
class Tenant:
    """Engine-internal record of one admitted experiment (mutable)."""

    tenant_id: str
    spec: Any  # ExperimentSpec
    policy: StopPolicy
    lane: str  # "batch" | "solo"
    priority: str = "normal"  # admission class (scheduler.FairShareQueue)
    status: str = QUEUED
    round: int = 0
    records: list[RoundRecord] = dataclasses.field(default_factory=list)
    # batch lane runtime (None while queued/spilled/finished)
    algo: Any = None
    state: Any = None  # algorithm-state NamedTuple (device arrays)
    comp_branch: tuple[str, int] | None = None  # (compressor name, k)
    group_key: tuple | None = None
    # solo lane runtime
    session: Any = None  # repro.api.session.Session
    # spill / restore
    spill_path: pathlib.Path | None = None
    restore: Any = None  # pending SessionState (resume() admits through it)
    restore_path: pathlib.Path | None = None
    # accounting
    admitted_tick: int = -1
    last_active_tick: int = -1
    spill_count: int = 0
    wall_time_s: float = 0.0
    init_time_s: float = 0.0
    # obs.now() timestamp of the last queue entry (submit or spill re-queue);
    # feeds the engine.queue.wait_s histogram at admission
    enqueued_at: float = 0.0
    # result / failure
    report: RunReport | None = None
    error: BaseException | None = None
    done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event
    )

    @property
    def cost(self) -> int:
        """Relative resident-memory cost of this tenant (packed Hessian
        state dominates: ~d^2 floats) — the 'cost' eviction policy spills
        the most expensive tenants first."""
        d = self.spec.data.dims()[0]
        return d * d

    def finish(self, report: RunReport) -> None:
        self.report = report
        self.status = FINISHED
        self.state = None
        self.session = None
        self.done_event.set()

    def fail(self, exc: BaseException) -> None:
        self.error = exc
        self.status = FAILED
        self.state = None
        self.session = None
        self.done_event.set()


class TenantHandle:
    """Caller-side view of a submitted experiment.

    The engine advances the tenant on its own thread (or inside an explicit
    ``tick()`` / ``serve_until_idle()`` call); the handle only observes:
    ``status`` / ``round`` / ``records`` read the live tenant, ``wait()``
    blocks until the run finishes (or fails), and ``result()`` returns the
    final :class:`~repro.api.report.RunReport` — bit-identical, record for
    record, to a solo ``open_session(spec).run()``.
    """

    def __init__(self, tenant: Tenant):
        self._tenant = tenant

    @property
    def id(self) -> str:
        return self._tenant.tenant_id

    @property
    def spec(self):
        return self._tenant.spec

    @property
    def status(self) -> str:
        return self._tenant.status

    @property
    def round(self) -> int:
        return self._tenant.round

    @property
    def records(self) -> tuple[RoundRecord, ...]:
        return tuple(self._tenant.records)

    @property
    def priority(self) -> str:
        return self._tenant.priority

    @property
    def done(self) -> bool:
        return self._tenant.status in (FINISHED, FAILED, EVICTED, CANCELLED)

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the tenant finishes or fails (True) or the timeout
        expires (False).  Only useful with a started engine thread — a
        synchronous caller drives ``tick()`` itself instead."""
        return self._tenant.done_event.wait(timeout)

    def result(self) -> RunReport:
        """The final report.  Raises if the run failed, was evicted, or has
        not finished yet (drive the engine first)."""
        t = self._tenant
        if t.status == FAILED:
            raise RuntimeError(
                f"tenant {t.tenant_id!r} failed"
            ) from t.error
        if t.status == EVICTED:
            raise RuntimeError(
                f"tenant {t.tenant_id!r} was evicted to "
                f"{t.spill_path} — resume it with "
                "FedNLServer.resume(path) or open_session(spec, restore=path)"
            )
        if t.status == CANCELLED:
            raise RuntimeError(
                f"tenant {t.tenant_id!r} was cancelled (state dropped, no "
                "checkpoint); resubmit the spec to run it again"
            )
        if t.report is None:
            raise RuntimeError(
                f"tenant {t.tenant_id!r} has not finished "
                f"(status {t.status!r}); call tick()/serve_until_idle() or "
                "wait() on a started engine"
            )
        return t.report

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        t = self._tenant
        return (
            f"TenantHandle({t.tenant_id!r}, status={t.status!r}, "
            f"round={t.round}, lane={t.lane!r})"
        )
