"""Continuous-batching scheduler: group keys, lanes, and the batched tick.

The scheduling model mirrors in-flight request batching in an LLM serving
engine.  Every engine *tick*, the in-flight tenants are re-partitioned into
batching groups; each group advances ONE round through a single jitted
switched round kernel (:class:`repro.core.fednl_batch.BatchRoundTable`);
then stop policies are checked per slot and the groups dissolve.  Tenants
are admitted, finish, or spill **between** ticks, so group membership is
recomputed every time — the compiled tick programs are what persists.

What may share a group (the §9 bit-exactness invariants, restated for the
serving layout):

* same **serve group key** — every trace-shaping hyper-parameter except the
  compressor, the seed, the round budget, and the stop tolerance:
  ``(algorithm, data, objective, lam, option, mu, hess0, accounting,
  ls_*, alpha)``.  The problem data is part of the key because the bit-exact
  layout closes ``z`` over the jit (a sliced z operand shifts the matmul
  kernels by an ulp — DESIGN.md §9).
* **arbitrary, differing round indices.**  The round kernel reads the round
  counter from each slot's state; nothing in the trace depends on a shared
  round index, so a tenant at round 37 and one at round 0 co-batch.  This is
  the continuous part of continuous batching — the sweep engine's
  ``lax.scan`` over a common ``rounds`` is replaced by the host tick loop.
* **different compressors / k / seeds.**  Compressor variation enters
  through the exact ``lax.switch`` branch table (selection + integer bit
  accounting only); seeds live in each slot's PRNG state.
* ``tol`` differs freely: the engine host-syncs every tick anyway (unlike
  the sweep scan), so per-slot tol stopping costs nothing extra — this is
  why tol early-stop blocks the *sweep* batch lane but not the *serve* one.

Padding: tick programs are compiled per (branch-table size, slot count);
slot counts are padded up to powers of two by duplicating slot 0.  Safe
because ``lax.map`` applies one per-element program to every slot — a pad
slot's values can never shape a live slot's bits (§9 again) — and it bounds
compile count at O(log max_group) per group key.

Admission (DESIGN.md §14): tenants wait in per-priority-class queues served
by deficit round-robin (:class:`FairShareQueue`).  Each class ``c`` has a
configured weight ``w_c``; per DRR cycle a class earns ``quantum * w_c``
admission credit and spends 1 credit per admitted tenant, so under
saturation class admission rates converge to the weight ratios exactly.
An empty class's deficit resets to zero (no credit hoarding), FIFO order
holds within a class (one class degenerates to the PR-6 FIFO queue), and
the head of a backlogged class ``c`` waits at most

    ceil(1 / (quantum * w_c)) * sum_{j != c} (quantum * w_j + 1)

foreign admissions — the starvation bound pinned by
tests/test_serve_fednl.py's hypothesis property.  Spill victims re-enter
the *back* of their class queue, so round-robin time-slicing now happens
per class and the fair share composes with memory pressure.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp

from repro.api.batch import resolved_alpha
from repro.core.fednl_batch import BatchRoundTable

# default priority classes (ServeConfig.priorities overrides); weights are
# admission shares under saturation, not absolute rates
DEFAULT_PRIORITIES = {"high": 4.0, "normal": 2.0, "low": 1.0}

DEFAULT_PRIORITY = "normal"


@dataclasses.dataclass(frozen=True)
class SubmitOptions:
    """Per-submission scheduling choices (``FedNLServer.submit(options=...)``,
    and the SUBMIT payload over the gateway).

    ``priority`` names one of the engine's configured priority classes
    (``ServeConfig.priorities``; defaults high/normal/low at weights 4/2/1).
    Validation happens at submission — an unknown class is a synchronous
    error naming the field, never a dead tenant discovered ticks later.
    """

    priority: str = DEFAULT_PRIORITY

    def validate(self, classes: dict[str, float]) -> None:
        if not isinstance(self.priority, str) or self.priority not in classes:
            raise ValueError(
                f"options.priority: unknown priority class "
                f"{self.priority!r}; this engine's configured classes are "
                f"{' | '.join(sorted(classes))}"
            )


class FairShareQueue:
    """Deficit-round-robin admission queue over weighted priority classes.

    ``push`` appends to the tenant's class queue (FIFO within class);
    ``pop`` returns the next tenant under DRR (module docstring).  Class
    iteration order is fixed (descending weight, then name) so the service
    pattern — and therefore the starvation bound — is deterministic.
    All state mutation happens under the engine lock (the engine is the
    only caller); this class itself is not thread-safe.
    """

    def __init__(self, classes: dict[str, float], quantum: float = 1.0):
        if not classes:
            raise ValueError("need at least one priority class")
        for name, w in classes.items():
            if not (isinstance(w, (int, float)) and w > 0):
                raise ValueError(
                    f"priority class {name!r} needs a positive weight, "
                    f"got {w!r}"
                )
        if quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self.weights = {name: float(w) for name, w in classes.items()}
        self.quantum = float(quantum)
        self._order = sorted(self.weights, key=lambda n: (-self.weights[n], n))
        self._queues: dict[str, deque] = {n: deque() for n in self._order}
        self._deficit: dict[str, float] = {n: 0.0 for n in self._order}
        self._ptr = 0
        self._in_service = False
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def push(self, tenant, priority: str | None = None) -> None:
        """Enqueue ``tenant`` at the back of its class queue.  ``priority``
        overrides ``tenant.priority`` (used by tests driving bare objects)."""
        name = priority if priority is not None else tenant.priority
        if name not in self._queues:
            raise ValueError(
                f"unknown priority class {name!r}; configured classes are "
                f"{' | '.join(sorted(self.weights))}"
            )
        self._queues[name].append(tenant)
        self._n += 1

    def _advance(self) -> None:
        self._ptr = (self._ptr + 1) % len(self._order)
        self._in_service = False

    def pop(self):
        """Dequeue the next tenant under DRR, or None when empty."""
        if self._n == 0:
            return None
        while True:
            name = self._order[self._ptr]
            q = self._queues[name]
            if not q:
                # empty class: reset credit (no hoarding) and move on
                self._deficit[name] = 0.0
                self._advance()
                continue
            if not self._in_service:
                # entering this class's service turn: earn one quantum
                self._deficit[name] += self.quantum * self.weights[name]
                self._in_service = True
            if self._deficit[name] >= 1.0:
                self._deficit[name] -= 1.0
                self._n -= 1
                return q.popleft()
            # credit exhausted for this turn; next class
            self._advance()

    def clear(self) -> None:
        for q in self._queues.values():
            q.clear()
        for name in self._deficit:
            self._deficit[name] = 0.0
        self._n = 0
        self._ptr = 0
        self._in_service = False

    def backlog(self) -> dict[str, int]:
        """Queued tenants per class (introspection / stats)."""
        return {n: len(q) for n, q in self._queues.items()}

    def starvation_bound(self, priority: str) -> int:
        """Max foreign admissions before the head of ``priority``'s queue is
        admitted, per the DRR analysis in the module docstring."""
        import math

        w = self.weights[priority]
        cycles = math.ceil(1.0 / (self.quantum * w))
        per_cycle = sum(
            self.quantum * wj + 1
            for n, wj in self.weights.items()
            if n != priority
        )
        return int(math.ceil(cycles * per_cycle))


def serve_lane(spec, algo, backend) -> str:
    """Which lane serves this spec: "batch" (the vectorized tick) or "solo"
    (a per-tenant Session stepped one round per tick).

    Mirrors :func:`repro.api.batch._batch_blockers` minus the two blockers
    that do not apply to serving: ``tol > 0`` (the tick loop host-syncs every
    round regardless) and ``rounds == 0`` (a zero-round tenant just finishes
    at admission).
    """
    from repro.api.backends import LOCAL_BACKEND

    if (
        backend is LOCAL_BACKEND
        and algo.make_batch_round is not None
        and algo.kind == "full"
        and spec.hessian_impl != "pallas"
    ):
        return "batch"
    return "solo"


def serve_group_key(spec, d: int) -> tuple:
    """Trace-shaping co-scheduling key (see module docstring).  The sweep
    engine's :func:`repro.api.batch._group_key` minus ``rounds`` — round
    budgets are per-slot stop conditions here, not trace shape."""
    return (
        spec.algorithm,
        spec.data,
        spec.objective,
        spec.lam,
        spec.option,
        spec.mu,
        spec.hess0,
        spec.hessian_impl,
        spec.accounting,
        spec.ls_c,
        spec.ls_gamma,
        spec.ls_max_steps,
        spec.ls_tol,
        resolved_alpha(spec, d),
    )


class GroupRuntime:
    """One serve group key's persistent compiled machinery: the problem
    ``z`` (closed over), the growable compressor branch table, and the
    per-(table, slot-count) jitted tick programs — all owned by a
    :class:`~repro.core.fednl_batch.BatchRoundTable`."""

    def __init__(self, z, cfg, alpha: float, make_batch_round):
        self.table = BatchRoundTable(
            z, cfg, alpha, make_batch_round=make_batch_round
        )

    @property
    def compiles(self) -> int:
        return self.table.compiles

    def branch_index(self, name: str, k: int) -> int:
        return self.table.branch_index(name, k)

    def tick_group(self, tenants: list, pad_pow2: bool = True):
        """Advance every tenant in ``tenants`` one round.

        Stacks the per-tenant states along a slot axis (padding to the
        group's slot bucket by duplicating slot 0), runs the group's tick
        program, unstacks, and returns ``(metrics, n_pad)``: the per-slot
        metrics views in tenant order plus the padded slot count actually
        launched.  The caller materializes records and applies stop
        policies.
        """
        states = [t.state for t in tenants]
        comp_idx = [
            self.branch_index(*t.comp_branch) for t in tenants
        ]
        n = len(tenants)
        # branch indices resolved first: bucket choice depends on the
        # (possibly grown) table length
        n_pad = self.table.bucket_for(n, pad_pow2)
        if n_pad > n:
            states = states + [states[0]] * (n_pad - n)
            comp_idx = comp_idx + [comp_idx[0]] * (n_pad - n)
        state_b = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        state_b, metrics_b = self.table.tick(
            jnp.asarray(comp_idx, jnp.int32), state_b
        )
        # unstack live slots only; pad slots are discarded
        for i, t in enumerate(tenants):
            t.state = jax.tree.map(lambda a, i=i: a[i], state_b)
        return [
            jax.tree.map(lambda a, i=i: a[i], metrics_b) for i in range(n)
        ], n_pad
