"""Continuous-batching scheduler: group keys, lanes, and the batched tick.

The scheduling model mirrors in-flight request batching in an LLM serving
engine.  Every engine *tick*, the in-flight tenants are re-partitioned into
batching groups; each group advances ONE round through a single jitted
switched round kernel (:class:`repro.core.fednl_batch.BatchRoundTable`);
then stop policies are checked per slot and the groups dissolve.  Tenants
are admitted, finish, or spill **between** ticks, so group membership is
recomputed every time — the compiled tick programs are what persists.

What may share a group (the §9 bit-exactness invariants, restated for the
serving layout):

* same **serve group key** — every trace-shaping hyper-parameter except the
  compressor, the seed, the round budget, and the stop tolerance:
  ``(algorithm, data, objective, lam, option, mu, hess0, accounting,
  ls_*, alpha)``.  The problem data is part of the key because the bit-exact
  layout closes ``z`` over the jit (a sliced z operand shifts the matmul
  kernels by an ulp — DESIGN.md §9).
* **arbitrary, differing round indices.**  The round kernel reads the round
  counter from each slot's state; nothing in the trace depends on a shared
  round index, so a tenant at round 37 and one at round 0 co-batch.  This is
  the continuous part of continuous batching — the sweep engine's
  ``lax.scan`` over a common ``rounds`` is replaced by the host tick loop.
* **different compressors / k / seeds.**  Compressor variation enters
  through the exact ``lax.switch`` branch table (selection + integer bit
  accounting only); seeds live in each slot's PRNG state.
* ``tol`` differs freely: the engine host-syncs every tick anyway (unlike
  the sweep scan), so per-slot tol stopping costs nothing extra — this is
  why tol early-stop blocks the *sweep* batch lane but not the *serve* one.

Padding: tick programs are compiled per (branch-table size, slot count);
slot counts are padded up to powers of two by duplicating slot 0.  Safe
because ``lax.map`` applies one per-element program to every slot — a pad
slot's values can never shape a live slot's bits (§9 again) — and it bounds
compile count at O(log max_group) per group key.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.batch import resolved_alpha
from repro.core.fednl_batch import BatchRoundTable


def serve_lane(spec, algo, backend) -> str:
    """Which lane serves this spec: "batch" (the vectorized tick) or "solo"
    (a per-tenant Session stepped one round per tick).

    Mirrors :func:`repro.api.batch._batch_blockers` minus the two blockers
    that do not apply to serving: ``tol > 0`` (the tick loop host-syncs every
    round regardless) and ``rounds == 0`` (a zero-round tenant just finishes
    at admission).
    """
    from repro.api.backends import LOCAL_BACKEND

    if (
        backend is LOCAL_BACKEND
        and algo.make_batch_round is not None
        and algo.kind == "full"
        and spec.hessian_impl != "pallas"
    ):
        return "batch"
    return "solo"


def serve_group_key(spec, d: int) -> tuple:
    """Trace-shaping co-scheduling key (see module docstring).  The sweep
    engine's :func:`repro.api.batch._group_key` minus ``rounds`` — round
    budgets are per-slot stop conditions here, not trace shape."""
    return (
        spec.algorithm,
        spec.data,
        spec.objective,
        spec.lam,
        spec.option,
        spec.mu,
        spec.hess0,
        spec.hessian_impl,
        spec.accounting,
        spec.ls_c,
        spec.ls_gamma,
        spec.ls_max_steps,
        spec.ls_tol,
        resolved_alpha(spec, d),
    )


class GroupRuntime:
    """One serve group key's persistent compiled machinery: the problem
    ``z`` (closed over), the growable compressor branch table, and the
    per-(table, slot-count) jitted tick programs — all owned by a
    :class:`~repro.core.fednl_batch.BatchRoundTable`."""

    def __init__(self, z, cfg, alpha: float, make_batch_round):
        self.table = BatchRoundTable(
            z, cfg, alpha, make_batch_round=make_batch_round
        )

    @property
    def compiles(self) -> int:
        return self.table.compiles

    def branch_index(self, name: str, k: int) -> int:
        return self.table.branch_index(name, k)

    def tick_group(self, tenants: list, pad_pow2: bool = True):
        """Advance every tenant in ``tenants`` one round.

        Stacks the per-tenant states along a slot axis (padding to the
        group's slot bucket by duplicating slot 0), runs the group's tick
        program, unstacks, and returns ``(metrics, n_pad)``: the per-slot
        metrics views in tenant order plus the padded slot count actually
        launched.  The caller materializes records and applies stop
        policies.
        """
        states = [t.state for t in tenants]
        comp_idx = [
            self.branch_index(*t.comp_branch) for t in tenants
        ]
        n = len(tenants)
        # branch indices resolved first: bucket choice depends on the
        # (possibly grown) table length
        n_pad = self.table.bucket_for(n, pad_pow2)
        if n_pad > n:
            states = states + [states[0]] * (n_pad - n)
            comp_idx = comp_idx + [comp_idx[0]] * (n_pad - n)
        state_b = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        state_b, metrics_b = self.table.tick(
            jnp.asarray(comp_idx, jnp.int32), state_b
        )
        # unstack live slots only; pad slots are discarded
        for i, t in enumerate(tenants):
            t.state = jax.tree.map(lambda a, i=i: a[i], state_b)
        return [
            jax.tree.map(lambda a, i=i: a[i], metrics_b) for i in range(n)
        ], n_pad
