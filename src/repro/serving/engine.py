"""Batched LM *token* serving engine over the per-family serve_step.

(FedNL sessions are served elsewhere: ``repro.serve_fednl`` — DESIGN.md §11.)

A deliberately small production shape: fixed-batch slots, greedy sampling,
per-slot stop conditions, prompt consumption through the same decode step
(sequential prefill — correct for every family including SSM/hybrid state,
since the decode recurrences ARE the prefill recurrences one token at a
time).  The dry-run's `prefill_step` covers the batched-prefill compute path;
fusing batched prefill into this engine's cache is listed as future work in
DESIGN.md.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import init_decode_cache
from repro.models.encdec import init_encdec_cache
from repro.train.step import make_serve_step


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ArchConfig, batch_size: int = 4,
                 max_len: int = 256, src_len: int = 16, eos_id: int | None = None):
        self.params = params
        self.cfg = cfg
        self.batch = batch_size
        self.max_len = max_len
        self.eos = eos_id
        self.step = jax.jit(make_serve_step(cfg))
        if cfg.family == "encdec":
            self.cache = init_encdec_cache(cfg, batch_size, max_len, src_len)
        else:
            self.cache = init_decode_cache(cfg, batch_size, max_len)
        self.slots: list[Request | None] = [None] * batch_size
        self._pending: list[Request] = []
        self._cursor = np.zeros(batch_size, dtype=np.int64)  # prompt position

    def submit(self, req: Request) -> None:
        self._pending.append(req)

    def _fill_slots(self) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and self._pending:
                self.slots[i] = self._pending.pop(0)
                self._cursor[i] = 0

    def _next_inputs(self) -> np.ndarray:
        toks = np.zeros((self.batch, 1), dtype=np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            c = self._cursor[i]
            if c < len(req.prompt):
                toks[i, 0] = req.prompt[c]
            elif req.generated:
                toks[i, 0] = req.generated[-1]
            else:
                toks[i, 0] = req.prompt[-1]
        return toks

    def run(self, max_steps: int = 512) -> list[Request]:
        """Drive all submitted requests to completion; returns them in order."""
        finished: list[Request] = []
        self._fill_slots()
        steps = 0
        while any(s is not None for s in self.slots) or self._pending:
            toks = jnp.asarray(self._next_inputs())
            logits, self.cache = self.step(self.params, self.cache, toks)
            nxt = np.asarray(
                jnp.argmax(logits[:, 0, : self.cfg.vocab], axis=-1)
            )
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                self._cursor[i] += 1
                if self._cursor[i] >= len(req.prompt):
                    req.generated.append(int(nxt[i]))
                    hit_eos = self.eos is not None and nxt[i] == self.eos
                    if len(req.generated) >= req.max_new_tokens or hit_eos:
                        req.done = True
                        finished.append(req)
                        self.slots[i] = None
            self._fill_slots()
            steps += 1
            if steps >= max_steps:
                break
        # NOTE: a production engine would reset per-slot cache state between
        # requests; with the shared monotone `pos` this engine serves one
        # wave of requests per instance (documented simplification).
        return finished
