"""LM *token* serving engine (Part-II zoo appendix — DESIGN.md §II).

Serves language-model generation requests: fixed-batch decode slots, greedy
sampling, per-slot stop conditions.  Not to be confused with
``repro.serve_fednl`` (DESIGN.md §11), the multi-tenant engine that serves
concurrent FedNL *optimization sessions* with continuous batching — that is
the one the paper-reproduction side of the repo uses.
"""

from repro.serving.engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request"]
