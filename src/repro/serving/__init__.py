from repro.serving.engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request"]
