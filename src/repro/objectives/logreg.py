"""L2-regularized logistic regression oracles (paper Eq. 2-5).

Data layout follows the paper's §5.13 optimization: labels b_ij are absorbed
into the design matrix, i.e. each client holds Z in R^{n_i x d} with rows
z_j = b_ij * a_ij.  Then with margins m = Z x:

    f_i(x)    = (1/n_i) sum_j log(1 + exp(-m_j)) + (lambda/2) ||x||^2
    grad f_i  = -(1/n_i) Z^T (1 - sigma(m)) + lambda x
    hess f_i  = (1/n_i) Z^T diag(sigma(m) (1 - sigma(m))) Z + lambda I

§5.7 ("Reuse Computation from Oracles", x1.50): the margins and sigmoid values
are computed ONCE and shared by all three oracles — `logreg_oracles` is the
fused oracle; the individual functions exist for testing / autodiff parity.

Numerical care: log(1+exp(-m)) is evaluated as softplus(-m) via
`jax.nn.softplus` (stable for large |m|), and sigma*(1-sigma) is formed from
sigma directly (paper §5.7: g(-z)*g(z) reuse).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """A federated logistic-regression instance.

    z: (n_clients, n_i, d)  label-absorbed design matrices (rows b_ij * a_ij)
    lam: L2 regularization coefficient
    """

    z: jax.Array
    lam: float

    @property
    def n_clients(self) -> int:
        return self.z.shape[0]

    @property
    def n_i(self) -> int:
        return self.z.shape[1]

    @property
    def dim(self) -> int:
        return self.z.shape[2]


def logreg_margin_stats(z: jax.Array, x: jax.Array):
    """margins m = Z x and sigmoid values (the §5.7 shared quantities)."""
    m = z @ x
    sigma = jax.nn.sigmoid(m)
    return m, sigma


def logreg_f(z: jax.Array, x: jax.Array, lam: float) -> jax.Array:
    m = z @ x
    return jnp.mean(jax.nn.softplus(-m)) + 0.5 * lam * jnp.sum(x * x)


def logreg_grad(z: jax.Array, x: jax.Array, lam: float) -> jax.Array:
    _, sigma = logreg_margin_stats(z, x)
    n_i = z.shape[0]
    return -(z.T @ (1.0 - sigma)) / n_i + lam * x


def logreg_hess(z: jax.Array, x: jax.Array, lam: float) -> jax.Array:
    _, sigma = logreg_margin_stats(z, x)
    n_i, d = z.shape
    h = sigma * (1.0 - sigma) / n_i  # (n_i,)
    return z.T @ (h[:, None] * z) + lam * jnp.eye(d, dtype=z.dtype)


HESSIAN_IMPLS = ("fused", "jnp", "pallas")


def logreg_oracles(
    z: jax.Array,
    x: jax.Array,
    lam: float,
    *,
    use_kernel: bool = False,
    hessian: str | None = None,
):
    """Fused (f, grad, hess) sharing one margin/sigmoid computation (§5.7).

    hessian: which SYRK realizes Z^T diag(h) Z (DESIGN.md §12):
      "fused"   (default) repro.kernels.ops.hessian_fused — the Pallas SYRK
                kernel on TPU, its tile-equivalent XLA program elsewhere.
                For d <= 128 (one tile) the XLA program is literally the
                "jnp" expression, so the default is bit-identical to the
                historical path there; for larger d the blocked accumulation
                drifts by O(1) ulp (documented).
      "jnp"     the single-dot_general expression — the parity reference
                every fused variant is pinned against.
      "pallas"  force the Pallas wrapper (interpret mode off-TPU) — the
                kernel-validation path, not a CPU hot path.
    use_kernel=True is the deprecated spelling of hessian="pallas".
    """
    if hessian is None:
        hessian = "pallas" if use_kernel else "fused"
    if hessian not in HESSIAN_IMPLS:
        raise ValueError(
            f"unknown hessian {hessian!r}; use {' | '.join(HESSIAN_IMPLS)}"
        )
    n_i, d = z.shape
    m, sigma = logreg_margin_stats(z, x)
    f = jnp.mean(jax.nn.softplus(-m)) + 0.5 * lam * jnp.sum(x * x)
    grad = -(z.T @ (1.0 - sigma)) / n_i + lam * x
    h = sigma * (1.0 - sigma) / n_i
    reg = lam * jnp.eye(d, dtype=z.dtype)
    if hessian == "fused":
        from repro.kernels import ops as kops

        hess = kops.hessian_fused(z, h) + reg
    elif hessian == "pallas":
        from repro.kernels import ops as kops

        hess = kops.hessian_syrk(z, h) + reg
    else:
        hess = z.T @ (h[:, None] * z) + reg
    return f, grad, hess


@functools.lru_cache(maxsize=64)
def _packed_eye(d: int) -> np.ndarray:
    """pack_triu(eye(d)) as a host numpy constant (embedded at trace time)."""
    from repro.linalg import triu_indices

    rows, cols = triu_indices(d)
    return np.where(rows == cols, 1.0, 0.0)


def logreg_oracles_packed(
    z: jax.Array,
    x: jax.Array,
    lam: float,
    *,
    hessian: str = "fused",
):
    """Fused client oracle: (f, grad, pack_triu(hess)) in one pass.

    The FedNL round consumes the Hessian exclusively in packed
    upper-triangle form (T = d(d+1)/2); for ``hessian="fused"`` the packed
    vector is gathered straight off the SYRK block strips
    (:func:`repro.kernels.ops.hessian_syrk_packed`) — the mirrored (d, d)
    matrix is never materialized, and the regularization is added packed
    (``lam * pack_triu(eye)``), replaying the historical
    ``pack_triu(hess + lam*eye)`` per-element op order bit-for-bit.  The
    "jnp" / "pallas" reference paths build the full matrix and pack it,
    exactly as :func:`logreg_oracles` callers always have.
    """
    if hessian not in HESSIAN_IMPLS:
        raise ValueError(
            f"unknown hessian {hessian!r}; use {' | '.join(HESSIAN_IMPLS)}"
        )
    n_i, d = z.shape
    m, sigma = logreg_margin_stats(z, x)
    f = jnp.mean(jax.nn.softplus(-m)) + 0.5 * lam * jnp.sum(x * x)
    grad = -(z.T @ (1.0 - sigma)) / n_i + lam * x
    h = sigma * (1.0 - sigma) / n_i
    if hessian == "fused":
        from repro.kernels import ops as kops

        hp = kops.hessian_syrk_packed(z, h)
        return f, grad, hp + lam * jnp.asarray(_packed_eye(d), dtype=z.dtype)
    from repro.linalg import pack_triu

    reg = lam * jnp.eye(d, dtype=z.dtype)
    if hessian == "pallas":
        from repro.kernels import ops as kops

        hess = kops.hessian_syrk(z, h) + reg
    else:
        hess = z.T @ (h[:, None] * z) + reg
    return f, grad, pack_triu(hess)
