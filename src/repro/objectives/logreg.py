"""L2-regularized logistic regression oracles (paper Eq. 2-5).

Data layout follows the paper's §5.13 optimization: labels b_ij are absorbed
into the design matrix, i.e. each client holds Z in R^{n_i x d} with rows
z_j = b_ij * a_ij.  Then with margins m = Z x:

    f_i(x)    = (1/n_i) sum_j log(1 + exp(-m_j)) + (lambda/2) ||x||^2
    grad f_i  = -(1/n_i) Z^T (1 - sigma(m)) + lambda x
    hess f_i  = (1/n_i) Z^T diag(sigma(m) (1 - sigma(m))) Z + lambda I

§5.7 ("Reuse Computation from Oracles", x1.50): the margins and sigmoid values
are computed ONCE and shared by all three oracles — `logreg_oracles` is the
fused oracle; the individual functions exist for testing / autodiff parity.

Numerical care: log(1+exp(-m)) is evaluated as softplus(-m) via
`jax.nn.softplus` (stable for large |m|), and sigma*(1-sigma) is formed from
sigma directly (paper §5.7: g(-z)*g(z) reuse).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """A federated logistic-regression instance.

    z: (n_clients, n_i, d)  label-absorbed design matrices (rows b_ij * a_ij)
    lam: L2 regularization coefficient
    """

    z: jax.Array
    lam: float

    @property
    def n_clients(self) -> int:
        return self.z.shape[0]

    @property
    def n_i(self) -> int:
        return self.z.shape[1]

    @property
    def dim(self) -> int:
        return self.z.shape[2]


def logreg_margin_stats(z: jax.Array, x: jax.Array):
    """margins m = Z x and sigmoid values (the §5.7 shared quantities)."""
    m = z @ x
    sigma = jax.nn.sigmoid(m)
    return m, sigma


def logreg_f(z: jax.Array, x: jax.Array, lam: float) -> jax.Array:
    m = z @ x
    return jnp.mean(jax.nn.softplus(-m)) + 0.5 * lam * jnp.sum(x * x)


def logreg_grad(z: jax.Array, x: jax.Array, lam: float) -> jax.Array:
    _, sigma = logreg_margin_stats(z, x)
    n_i = z.shape[0]
    return -(z.T @ (1.0 - sigma)) / n_i + lam * x


def logreg_hess(z: jax.Array, x: jax.Array, lam: float) -> jax.Array:
    _, sigma = logreg_margin_stats(z, x)
    n_i, d = z.shape
    h = sigma * (1.0 - sigma) / n_i  # (n_i,)
    return z.T @ (h[:, None] * z) + lam * jnp.eye(d, dtype=z.dtype)


def logreg_oracles(z: jax.Array, x: jax.Array, lam: float, *, use_kernel: bool = False):
    """Fused (f, grad, hess) sharing one margin/sigmoid computation (§5.7).

    use_kernel: route the Hessian SYRK through the Pallas kernel wrapper
    (repro.kernels.ops.hessian_syrk); default is the pure-jnp path, which XLA
    fuses well on CPU and is the oracle the kernel is tested against.
    """
    n_i, d = z.shape
    m, sigma = logreg_margin_stats(z, x)
    f = jnp.mean(jax.nn.softplus(-m)) + 0.5 * lam * jnp.sum(x * x)
    grad = -(z.T @ (1.0 - sigma)) / n_i + lam * x
    h = sigma * (1.0 - sigma) / n_i
    if use_kernel:
        from repro.kernels import ops as kops

        hess = kops.hessian_syrk(z, h) + lam * jnp.eye(d, dtype=z.dtype)
    else:
        hess = z.T @ (h[:, None] * z) + lam * jnp.eye(d, dtype=z.dtype)
    return f, grad, hess
