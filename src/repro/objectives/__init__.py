from repro.objectives.logreg import (
    LogRegProblem,
    logreg_f,
    logreg_grad,
    logreg_hess,
    logreg_oracles,
    logreg_margin_stats,
)
from repro.objectives.quadratic import QuadraticProblem, quadratic_oracles

__all__ = [
    "LogRegProblem",
    "logreg_f",
    "logreg_grad",
    "logreg_hess",
    "logreg_oracles",
    "logreg_margin_stats",
    "QuadraticProblem",
    "quadratic_oracles",
]
