"""Symmetric quadratic objectives (second problem family the paper ships:
"out-of-the-box implementations for logistic regression and Symmetric
Quadratic Objectives", Appendix L.5).

    f_i(x) = 0.5 x^T B_i x - c_i^T x,   grad = B_i x - c_i,   hess = B_i.

Useful for exact tests: FedNL with the Identity compressor must converge in one
step from any x0 once H = mean(B_i).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuadraticProblem:
    b: jax.Array  # (n_clients, d, d) symmetric PD
    c: jax.Array  # (n_clients, d)

    @property
    def n_clients(self) -> int:
        return self.b.shape[0]

    @property
    def dim(self) -> int:
        return self.b.shape[-1]


def quadratic_oracles(b: jax.Array, c: jax.Array, x: jax.Array):
    f = 0.5 * x @ (b @ x) - c @ x
    grad = b @ x - c
    return f, grad, b
