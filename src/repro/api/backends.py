"""Built-in execution backends + algorithm registrations.

Each backend is a strategy object wrapping an *existing* driver — the
simulation round builders (``repro.core``), the sharded round
(``repro.distributed``), the star event loops (``repro.comm.star[_pp]``) and
the multi-process TCP launcher (``repro.launch.multiproc``) — and normalizing
its output into :class:`repro.api.RunReport`.  No round loop is reimplemented
here except the thin local streaming loop, which replays ``run_fednl`` /
``run_fednl_pp`` op-for-op (the parity suite pins it to the golden traces
bit-for-bit; ``repro.core.runner`` stays the independent reference).

Capability matrix (what ``Backend.supports`` encodes):

  backend        fednl  fednl-ls  fednl-pp
  local            x       x         x
  sharded          x       -         -     (no sharded LS/PP round yet)
  star-loopback    x       -         x     (no LS wire protocol)
  star-tcp         x       -         x
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import (
    Algorithm,
    Backend,
    register_algorithm,
    register_backend,
)
from repro.api.report import RoundRecord, RunReport
from repro.core.fednl import fednl_init, make_fednl_round
from repro.core.fednl_batch import (
    make_fednl_batch_round,
    make_fednl_ls_batch_round,
)
from repro.core.fednl_ls import make_fednl_ls_round
from repro.core.fednl_pp import fednl_pp_init, make_fednl_pp_round
from repro.core.runner import eval_full

# ---------------------------------------------------------------------------
# built-in algorithms (Algorithms 1-3 of the paper)
# ---------------------------------------------------------------------------

FEDNL = register_algorithm(
    Algorithm(
        name="fednl",
        kind="full",
        init=fednl_init,
        make_round=lambda z, cfg, tau=None: make_fednl_round(z, cfg),
        make_batch_round=make_fednl_batch_round,
    )
)

FEDNL_LS = register_algorithm(
    Algorithm(
        name="fednl-ls",
        kind="full",
        line_search=True,
        init=fednl_init,
        make_round=lambda z, cfg, tau=None: make_fednl_ls_round(z, cfg),
        make_batch_round=make_fednl_ls_batch_round,
    )
)

FEDNL_PP = register_algorithm(
    Algorithm(
        name="fednl-pp",
        kind="pp",
        init=fednl_pp_init,
        make_round=make_fednl_pp_round,
    )
)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _opt_int(value) -> int | None:
    return None if value is None else int(value)


def _full_records_from_arrays(
    grad_norms, f_vals, sent_bits, payload_bits, wire_bits
) -> list[RoundRecord]:
    """Uniform records from the per-round arrays a star/legacy result carries."""
    return [
        RoundRecord(
            round=r,
            grad_norm=float(grad_norms[r]),
            f=float(f_vals[r]) if f_vals is not None else None,
            sent_bits=int(sent_bits[r]),
            sent_bits_payload=_opt_int(payload_bits[r] if payload_bits is not None else None),
            sent_bits_wire=_opt_int(wire_bits[r] if wire_bits is not None else None),
        )
        for r in range(len(grad_norms))
    ]


def _pp_final_grad_norm(z, x, lam: float) -> float:
    _, g = eval_full(z, jnp.asarray(x), lam)
    return float(jnp.linalg.norm(g))


# ---------------------------------------------------------------------------
# local: the single-process simulation (vmapped clients, jitted round)
# ---------------------------------------------------------------------------

class LocalBackend(Backend):
    """Streaming equivalent of ``run_fednl`` / ``run_fednl_pp``: identical
    init -> jit -> warm-up -> iterate sequence (bit-parity pinned by
    tests/test_api.py), but recording the unified per-round records with
    both accounting models."""

    name = "local"
    supports_x0 = True

    def run(self, spec, algo: Algorithm, z, x0) -> RunReport:
        cfg = spec.fednl_config()
        tau = spec.tau_for(z.shape[0]) if algo.kind == "pp" else None
        t0 = time.perf_counter()
        state = algo.init(z, cfg, x0=x0, seed=spec.seed)
        round_fn = jax.jit(algo.make_round(z, cfg, tau))
        # warm-up compile outside the timed loop (paper separates init/solve)
        state_c, _ = round_fn(state)
        jax.block_until_ready(state_c)
        init_time = time.perf_counter() - t0

        # metrics stay on-device inside the timed loop: the tol check is the
        # only per-round host sync, so a tol=0 run dispatches asynchronously
        # and syncs once at the end (wall_time_s measures program throughput,
        # not device->host latency per round)
        raw = []
        t1 = time.perf_counter()
        if algo.kind == "full":
            for r in range(spec.rounds):
                state, m = round_fn(state)
                raw.append(m)
                if spec.tol > 0.0 and float(m.grad_norm) < spec.tol:
                    break
            jax.block_until_ready(state.x)
            wall = time.perf_counter() - t1
            records = [
                RoundRecord(
                    round=r,
                    grad_norm=float(m.grad_norm),
                    f=float(m.f),
                    l=float(m.l),
                    sent_elems=int(m.sent_elems),
                    sent_bits=int(m.sent_bits),
                    sent_bits_payload=int(m.sent_bits_payload),
                    sent_bits_wire=int(m.sent_bits_wire),
                    ls_steps=_opt_int(getattr(m, "ls_steps", None)),
                )
                for r, m in enumerate(raw)
            ]
            return RunReport(
                spec=spec,
                algorithm=algo.name,
                backend=self.name,
                x=np.asarray(state.x),
                records=records,
                rounds=len(records),
                wall_time_s=wall,
                init_time_s=init_time,
            )

        # --- pp: record the iterate trajectory; grad is a post-run diagnostic
        for r in range(spec.rounds):
            state, m = round_fn(state)
            raw.append(m)
        jax.block_until_ready(state.h_global)
        wall = time.perf_counter() - t1
        records = [
            RoundRecord(
                round=r,
                l=float(m.l),
                sent_elems=int(m.sent_elems),
                sent_bits=int(m.sent_bits),
                sent_bits_payload=int(m.sent_bits_payload),
                sent_bits_wire=int(m.sent_bits_wire),
                x=np.asarray(m.x),
                participants=tuple(int(i) for i in np.asarray(m.idx)),
                dropped=(),
            )
            for r, m in enumerate(raw)
        ]
        # the deployable model: Algorithm-3 line 4 on the post-run invariants
        # (same eager ops as run_fednl_pp / the star master — bit-comparable)
        from repro.linalg import cholesky_solve, unpack_triu

        d = z.shape[-1]
        x_final = cholesky_solve(
            unpack_triu(state.h_global, d)
            + state.l_global * jnp.eye(d, dtype=jnp.float64),
            state.g_global,
        )
        return RunReport(
            spec=spec,
            algorithm=algo.name,
            backend=self.name,
            x=np.asarray(x_final),
            records=records,
            rounds=len(records),
            wall_time_s=wall,
            init_time_s=init_time,
            final_grad_norm_fn=lambda: _pp_final_grad_norm(z, x_final, cfg.lam),
            extras={"tau": tau},
        )


# ---------------------------------------------------------------------------
# sharded: clients shard_mapped across mesh devices (repro.distributed)
# ---------------------------------------------------------------------------

class ShardedBackend(Backend):
    name = "sharded"

    def supports(self, algo: Algorithm) -> bool:
        # identity, not name: this backend drives make_sharded_fednl_round
        # directly, so a re-registered custom "fednl" would silently run the
        # builtin algorithm instead of algo.make_round
        return algo is FEDNL  # no sharded LS/PP round builder yet

    def run(self, spec, algo: Algorithm, z, x0) -> RunReport:
        from repro.distributed import (
            make_sharded_fednl_round,
            shard_problem,
            sharded_fednl_init,
        )

        cfg = spec.fednl_config()
        n_dev = spec.devices if spec.devices is not None else jax.device_count()
        t0 = time.perf_counter()
        mesh = jax.make_mesh((n_dev,), ("data",))
        zs = shard_problem(z, mesh)
        state = sharded_fednl_init(zs, cfg, mesh, seed=spec.seed)
        round_fn = jax.jit(
            make_sharded_fednl_round(zs, cfg, mesh, aggregate=spec.aggregate)
        )
        state_c, _ = round_fn(state)
        jax.block_until_ready(state_c.x)
        init_time = time.perf_counter() - t0

        # same deferred-sync discipline as LocalBackend: tol is the only
        # per-round host sync, records materialize after the timed loop
        raw = []
        t1 = time.perf_counter()
        for r in range(spec.rounds):
            state, m = round_fn(state)
            raw.append(m)
            if spec.tol > 0.0 and float(m["grad_norm"]) < spec.tol:
                break
        jax.block_until_ready(state.x)
        wall = time.perf_counter() - t1
        records = [
            RoundRecord(
                round=r,
                grad_norm=float(m["grad_norm"]),
                f=float(m["f"]),
                l=float(m["l"]),
                sent_elems=int(m["sent_elems"]),
                sent_bits=int(m["sent_bits"]),
                sent_bits_payload=int(m["sent_bits_payload"]),
                sent_bits_wire=int(m["sent_bits_wire"]),
            )
            for r, m in enumerate(raw)
        ]
        return RunReport(
            spec=spec,
            algorithm=algo.name,
            backend=self.name,
            x=np.asarray(state.x),
            records=records,
            rounds=len(records),
            wall_time_s=wall,
            init_time_s=init_time,
            extras={"devices": n_dev, "aggregate": spec.aggregate},
        )


# ---------------------------------------------------------------------------
# star backends: the real wire protocol (loopback transport / TCP processes)
# ---------------------------------------------------------------------------

def _star_full_report(spec, algo, res, backend_name: str) -> RunReport:
    """StarRunResult -> RunReport (sent_bits honors spec.accounting)."""
    wire_bits = 8 * res.measured_frame_bytes
    selected = res.sent_bits if spec.accounting == "payload" else wire_bits
    records = _full_records_from_arrays(
        res.grad_norms, res.f_vals, selected, res.sent_bits, wire_bits
    )
    return RunReport(
        spec=spec,
        algorithm=algo.name,
        backend=backend_name,
        x=np.asarray(res.x),
        records=records,
        rounds=res.rounds,
        wall_time_s=res.wall_time_s,
        init_time_s=0.0,  # INIT handshake is inside the event loop
        extras={
            "measured_payload_bits": res.measured_payload_bits,
            "measured_frame_bytes": res.measured_frame_bytes,
        },
    )


def _star_pp_report(spec, algo, res, backend_name: str, z_fn, tau: int) -> RunReport:
    """StarPPRunResult -> RunReport with participation per round.

    ``z_fn`` lazily supplies the problem for the post-run grad diagnostic —
    star-tcp masters never hold the data, so the rebuild only happens if the
    caller actually reads ``final_grad_norm``."""
    wire_bits = 8 * res.measured_frame_bytes
    records = [
        RoundRecord(
            round=r,
            l=float(res.l_hist[r]),
            sent_bits=int(
                res.sent_bits[r] if spec.accounting == "payload" else wire_bits[r]
            ),
            sent_bits_payload=int(res.sent_bits[r]),
            sent_bits_wire=int(wire_bits[r]),
            x=np.asarray(res.x_hist[r]),
            participants=tuple(res.participants[r]),
            dropped=tuple(res.dropped[r]),
        )
        for r in range(res.rounds)
    ]
    return RunReport(
        spec=spec,
        algorithm=algo.name,
        backend=backend_name,
        x=np.asarray(res.x),
        records=records,
        rounds=res.rounds,
        wall_time_s=res.wall_time_s,
        init_time_s=0.0,
        final_grad_norm_fn=(
            (lambda: _pp_final_grad_norm(z_fn(), res.x, spec.lam))
            if z_fn is not None
            else None
        ),
        extras={
            "tau": tau,
            "measured_payload_bits": res.measured_payload_bits,
            "measured_frame_bytes": res.measured_frame_bytes,
        },
    )


class StarLoopbackBackend(Backend):
    """Full wire protocol (encode -> frame -> decode) over in-process
    loopback connections — deterministic, socket-free."""

    name = "star-loopback"
    supports_faults = True

    def supports(self, algo: Algorithm) -> bool:
        # identity, not name: the wire event loops implement the builtin
        # protocols only — a re-registered custom "fednl" must be refused,
        # not silently replaced by the builtin trajectory
        return algo is FEDNL or algo is FEDNL_PP  # no LS wire protocol

    def run(self, spec, algo: Algorithm, z, x0) -> RunReport:
        if algo.kind == "pp":
            from repro.comm.star_pp import run_pp_loopback

            tau = spec.tau_for(z.shape[0])
            res = run_pp_loopback(
                z,
                spec.fednl_config(),
                tau=tau,
                rounds=spec.rounds,
                seed=spec.seed,
                on_dropout=spec.on_dropout,
                fault=spec.fault,
            )
            return _star_pp_report(spec, algo, res, self.name, lambda: z, tau)
        from repro.comm.star import run_loopback

        res = run_loopback(
            z, spec.fednl_config(), rounds=spec.rounds, tol=spec.tol, seed=spec.seed
        )
        return _star_full_report(spec, algo, res, self.name)


class StarTCPBackend(Backend):
    """Master + one OS process per client over TCP localhost
    (``repro.launch.multiproc``).  Workers regenerate their shard from
    ``spec.data`` — no training data crosses the wire, so only seeded
    synthetic data specs are supported."""

    name = "star-tcp"
    needs_problem = False  # workers rebuild their shards from the data seed
    supports_faults = True

    def supports(self, algo: Algorithm) -> bool:
        # identity, not name — same reasoning as StarLoopbackBackend
        return algo is FEDNL or algo is FEDNL_PP

    def run(self, spec, algo: Algorithm, z, x0) -> RunReport:
        if spec.data.libsvm is not None:
            raise ValueError(
                "star-tcp workers rebuild synthetic data from spec.data.seed; "
                "libsvm problems can only run on local/sharded/star-loopback"
            )
        from repro.launch.multiproc import run_multiproc, run_multiproc_pp

        cfg = spec.fednl_config()
        if algo.kind == "pp":
            tau = spec.tau_for(spec.data.dims()[1])
            res = run_multiproc_pp(
                cfg,
                tau=tau,
                dataset=spec.data.dataset,
                shape=spec.data.shape,
                rounds=spec.rounds,
                seed=spec.seed,
                host=spec.host,
                on_dropout=spec.on_dropout,
                fault=spec.fault,
                data_seed=spec.data.seed,
            )
            # the master never holds the data; rebuild it lazily only if the
            # caller reads the final_grad_norm diagnostic
            return _star_pp_report(spec, algo, res, self.name, spec.data.build, tau)
        res = run_multiproc(
            cfg,
            dataset=spec.data.dataset,
            shape=spec.data.shape,
            rounds=spec.rounds,
            tol=spec.tol,
            seed=spec.seed,
            host=spec.host,
            data_seed=spec.data.seed,
        )
        return _star_full_report(spec, algo, res, self.name)


# bound instances: the sweep engine identity-checks against LOCAL_BACKEND
# (an overwritten "local" registration must not be silently batched around)
LOCAL_BACKEND = register_backend(LocalBackend())
SHARDED_BACKEND = register_backend(ShardedBackend())
STAR_LOOPBACK_BACKEND = register_backend(StarLoopbackBackend())
STAR_TCP_BACKEND = register_backend(StarTCPBackend())
