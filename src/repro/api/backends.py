"""Built-in execution backends + algorithm registrations.

Each backend is a strategy object wrapping an *existing* driver — the
simulation round builders (``repro.core``), the sharded round
(``repro.distributed``), the star masters (``repro.comm.star[_pp]``) and the
multi-process TCP client cluster (``repro.launch.multiproc``) — exposed at
round granularity through ``Backend.open() -> SessionHandle`` (DESIGN.md
§10).  ``solve()`` is the open -> run -> close composition of the same
handles, so the streaming path IS the batch path: the parity suite pins it
to the golden traces bit-for-bit and ``repro.core.runner`` stays the
independent reference.  The simulation handles execute chunked segments
between yields (metrics stay on-device until a chunk ends); the star handles
drive the wire masters one protocol round per step and rebuild client state
on restore by replaying broadcasts (no client state on disk).

Capability matrix (what ``Backend.supports`` encodes):

  backend        fednl  fednl-ls  fednl-pp
  local            x       x         x
  sharded          x       -         -     (no sharded LS/PP round yet)
  star-loopback    x       -         x     (no LS wire protocol)
  star-tcp         x       -         x
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import (
    Algorithm,
    Backend,
    SessionHandle,
    register_algorithm,
    register_backend,
)
from repro.api.report import RoundRecord
from repro.core.fednl import fednl_init, make_fednl_round
from repro.core.fednl_batch import (
    make_fednl_batch_round,
    make_fednl_ls_batch_round,
)
from repro.core.fednl_ls import make_fednl_ls_round
from repro.core.fednl_pp import fednl_pp_init, make_fednl_pp_round
from repro.core.runner import eval_full

# ---------------------------------------------------------------------------
# built-in algorithms (Algorithms 1-3 of the paper)
# ---------------------------------------------------------------------------

FEDNL = register_algorithm(
    Algorithm(
        name="fednl",
        kind="full",
        init=fednl_init,
        make_round=lambda z, cfg, tau=None: make_fednl_round(z, cfg),
        make_batch_round=make_fednl_batch_round,
    )
)

FEDNL_LS = register_algorithm(
    Algorithm(
        name="fednl-ls",
        kind="full",
        line_search=True,
        init=fednl_init,
        make_round=lambda z, cfg, tau=None: make_fednl_ls_round(z, cfg),
        make_batch_round=make_fednl_ls_batch_round,
    )
)

FEDNL_PP = register_algorithm(
    Algorithm(
        name="fednl-pp",
        kind="pp",
        init=fednl_pp_init,
        make_round=make_fednl_pp_round,
    )
)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _opt_int(value) -> int | None:
    return None if value is None else int(value)


def _pp_final_grad_norm(z, x, lam: float) -> float:
    _, g = eval_full(z, jnp.asarray(x), lam)
    return float(jnp.linalg.norm(g))


# ---------------------------------------------------------------------------
# state/record helpers (public: the serving engine steps the same algorithm
# states externally and must serialize/record them byte- and bit-identically)
# ---------------------------------------------------------------------------

def state_arrays(state, prefix: str = "state.") -> dict[str, np.ndarray]:
    """NamedTuple algorithm state -> checkpoint arrays."""
    return {prefix + f: np.asarray(v) for f, v in zip(state._fields, state)}


def restored_state(state0, restore, place=jnp.asarray, prefix: str = "state."):
    """Rebuild an algorithm-state NamedTuple from checkpoint arrays, using a
    freshly initialized ``state0`` as the structural template (``place``
    controls device placement — the sharded backend re-shards per field)."""
    missing = [f for f in state0._fields if prefix + f not in restore.arrays]
    if missing:
        raise ValueError(
            f"checkpoint is missing state arrays {missing} for backend "
            f"{restore.backend!r} (truncated or foreign checkpoint?)"
        )
    return type(state0)(
        **{
            f: place(restore.arrays[prefix + f], ref)
            for f, ref in zip(state0._fields, state0)
        }
    )


def full_round_record(r: int, m) -> RoundRecord:
    """One full-participation simulation-metrics row -> RoundRecord.

    Shared by the local session handle and the serving engine's batched
    lane: the host-side float()/int() materialization is part of the
    bit-parity surface, so there is exactly one copy of it."""
    return RoundRecord(
        round=r,
        grad_norm=float(m.grad_norm),
        f=float(m.f),
        l=float(m.l),
        sent_elems=int(m.sent_elems),
        sent_bits=int(m.sent_bits),
        sent_bits_payload=int(m.sent_bits_payload),
        sent_bits_wire=int(m.sent_bits_wire),
        ls_steps=_opt_int(getattr(m, "ls_steps", None)),
    )


def pp_round_record(r: int, m) -> RoundRecord:
    """One FedNL-PP simulation-metrics row -> RoundRecord."""
    return RoundRecord(
        round=r,
        l=float(m.l),
        sent_elems=int(m.sent_elems),
        sent_bits=int(m.sent_bits),
        sent_bits_payload=int(m.sent_bits_payload),
        sent_bits_wire=int(m.sent_bits_wire),
        x=np.asarray(m.x),
        participants=tuple(int(i) for i in np.asarray(m.idx)),
        dropped=(),
    )


# ---------------------------------------------------------------------------
# local: the single-process simulation (vmapped clients, jitted round)
# ---------------------------------------------------------------------------

class _LocalSessionHandle(SessionHandle):
    """Round-granular form of the ``run_fednl`` / ``run_fednl_pp`` loop:
    identical init -> jit -> warm-up -> iterate sequence (bit-parity pinned
    by tests/test_api.py).  ``step_rounds(n)`` executes one chunked segment:
    metrics stay on-device until the chunk ends, so the chunk is the only
    host sync and an observer-free ``run()`` keeps the monolithic solve's
    deferred-sync profile."""

    def __init__(self, spec, algo: Algorithm, z, x0, restore=None):
        self._spec = spec
        self._algo = algo
        self._z = z
        self._cfg = spec.fednl_config()
        self._tau = spec.tau_for(z.shape[0]) if algo.kind == "pp" else None
        self.round = int(restore.round) if restore is not None else 0
        self.wall_time_s = 0.0
        t0 = time.perf_counter()
        state = algo.init(z, self._cfg, x0=x0, seed=spec.seed)
        if restore is not None:
            state = restored_state(
                state, restore, place=lambda arr, ref: jnp.asarray(arr)
            )
        self._state = state
        self._round_fn = jax.jit(algo.make_round(z, self._cfg, self._tau))
        # warm-up compile outside the solve clock (paper separates init/solve)
        state_c, _ = self._round_fn(state)
        jax.block_until_ready(state_c)
        self.init_time_s = time.perf_counter() - t0

    def step_rounds(self, n: int) -> list[RoundRecord]:
        raw = []
        t1 = time.perf_counter()
        for _ in range(n):
            self._state, m = self._round_fn(self._state)
            raw.append(m)
        jax.block_until_ready(
            self._state.x if self._algo.kind == "full" else self._state.h_global
        )
        self.wall_time_s += time.perf_counter() - t1
        r0 = self.round
        self.round += n
        if self._algo.kind == "full":
            return [full_round_record(r0 + i, m) for i, m in enumerate(raw)]
        return [pp_round_record(r0 + i, m) for i, m in enumerate(raw)]

    def snapshot(self) -> tuple[dict, dict[str, np.ndarray]]:
        return {"kind": self._algo.kind}, state_arrays(self._state)

    def finalize(self) -> dict:
        if self._algo.kind == "full":
            return {"x": np.asarray(self._state.x)}
        # the deployable model: Algorithm-3 line 4 on the current invariants
        # (same eager ops as run_fednl_pp / the star master — bit-comparable)
        from repro.linalg import cholesky_solve, unpack_triu

        z, state, lam = self._z, self._state, self._cfg.lam
        d = z.shape[-1]
        x_final = cholesky_solve(
            unpack_triu(state.h_global, d)
            + state.l_global * jnp.eye(d, dtype=jnp.float64),
            state.g_global,
        )
        return {
            "x": np.asarray(x_final),
            "final_grad_norm_fn": lambda: _pp_final_grad_norm(z, x_final, lam),
            "extras": {"tau": self._tau},
        }


class LocalBackend(Backend):
    name = "local"
    supports_x0 = True
    supports_sessions = True

    def open(self, spec, algo: Algorithm, z, x0, restore=None) -> SessionHandle:
        return _LocalSessionHandle(spec, algo, z, x0, restore=restore)


# ---------------------------------------------------------------------------
# sharded: clients shard_mapped across mesh devices (repro.distributed)
# ---------------------------------------------------------------------------

class _ShardedSessionHandle(SessionHandle):
    """Same chunked-segment discipline as the local handle, over the
    shard_mapped round; restore re-places each checkpoint array with the
    sharding of a freshly initialized state."""

    def __init__(self, spec, algo: Algorithm, z, x0, restore=None):
        from repro.distributed import (
            make_sharded_fednl_round,
            shard_problem,
            sharded_fednl_init,
        )

        self._spec = spec
        cfg = spec.fednl_config()
        self._n_dev = (
            spec.devices if spec.devices is not None else jax.device_count()
        )
        self.round = int(restore.round) if restore is not None else 0
        self.wall_time_s = 0.0
        t0 = time.perf_counter()
        mesh = jax.make_mesh((self._n_dev,), ("data",))
        zs = shard_problem(z, mesh)
        state = sharded_fednl_init(zs, cfg, mesh, seed=spec.seed)
        if restore is not None:
            state = restored_state(
                state,
                restore,
                place=lambda arr, ref: jax.device_put(arr, ref.sharding),
            )
        self._state = state
        self._round_fn = jax.jit(
            make_sharded_fednl_round(zs, cfg, mesh, aggregate=spec.aggregate)
        )
        state_c, _ = self._round_fn(state)
        jax.block_until_ready(state_c.x)
        self.init_time_s = time.perf_counter() - t0

    def step_rounds(self, n: int) -> list[RoundRecord]:
        raw = []
        t1 = time.perf_counter()
        for _ in range(n):
            self._state, m = self._round_fn(self._state)
            raw.append(m)
        jax.block_until_ready(self._state.x)
        self.wall_time_s += time.perf_counter() - t1
        r0 = self.round
        self.round += n
        return [
            RoundRecord(
                round=r0 + i,
                grad_norm=float(m["grad_norm"]),
                f=float(m["f"]),
                l=float(m["l"]),
                sent_elems=int(m["sent_elems"]),
                sent_bits=int(m["sent_bits"]),
                sent_bits_payload=int(m["sent_bits_payload"]),
                sent_bits_wire=int(m["sent_bits_wire"]),
            )
            for i, m in enumerate(raw)
        ]

    def snapshot(self) -> tuple[dict, dict[str, np.ndarray]]:
        return {"kind": "full"}, state_arrays(self._state)

    def finalize(self) -> dict:
        return {
            "x": np.asarray(self._state.x),
            "extras": {
                "devices": self._n_dev,
                "aggregate": self._spec.aggregate,
            },
        }


class ShardedBackend(Backend):
    name = "sharded"
    supports_sessions = True

    def supports(self, algo: Algorithm) -> bool:
        # identity, not name: this backend drives make_sharded_fednl_round
        # directly, so a re-registered custom "fednl" would silently run the
        # builtin algorithm instead of algo.make_round
        return algo is FEDNL  # no sharded LS/PP round builder yet

    def open(self, spec, algo: Algorithm, z, x0, restore=None) -> SessionHandle:
        return _ShardedSessionHandle(spec, algo, z, x0, restore=restore)


# ---------------------------------------------------------------------------
# star backends: the real wire protocol (loopback transport / TCP processes)
# ---------------------------------------------------------------------------

class _StarFullSessionHandle(SessionHandle):
    """Full-participation star master held open at round granularity.

    ``restore`` resumes from a checkpoint: the master's own state (x, H) is
    deserialized, while the freshly built/spawned clients rebuild theirs by
    replaying the checkpointed broadcast history through the normal wire
    protocol (spec + PRNG spine; the replayed uplinks are consumed
    undecoded).  ``closer`` releases the transport (TCP client cluster)."""

    def __init__(self, spec, master, restore=None, closer=None):
        self._spec = spec
        self._master = master
        self._closer = closer
        self._measured_pbits: list[int] = []
        self._frame_bytes: list[int] = []
        self.round = 0
        self.wall_time_s = 0.0
        t0 = time.perf_counter()
        master.init_handshake()
        if restore is not None:
            for r, x_b in enumerate(restore.arrays["x_hist"]):
                master.replay_round(r, x_b)
            master.x = jnp.asarray(restore.arrays["x"])
            master.h_global = jnp.asarray(restore.arrays["h_global"])
            self._measured_pbits = [
                int(b) for b in restore.arrays["measured_payload_bits"]
            ]
            self._frame_bytes = [
                int(b) for b in restore.arrays["measured_frame_bytes"]
            ]
            self.round = int(restore.round)
        self.init_time_s = time.perf_counter() - t0

    def step_rounds(self, n: int) -> list[RoundRecord]:
        recs = []
        t1 = time.perf_counter()
        for i in range(n):
            r = self.round + i
            m = self._master.step_round(r)
            self._measured_pbits.append(m["measured_payload_bits"])
            self._frame_bytes.append(m["measured_frame_bytes"])
            wire_bits = 8 * m["measured_frame_bytes"]
            parts = m.get("participants")
            recs.append(
                RoundRecord(
                    round=r,
                    grad_norm=m["grad_norm"],
                    f=m["f"],
                    sent_bits=(
                        m["sent_bits"]
                        if self._spec.accounting == "payload"
                        else wire_bits
                    ),
                    sent_bits_payload=m["sent_bits"],
                    sent_bits_wire=wire_bits,
                    # async/elastic masters report who contributed/was active;
                    # the plain star reports nothing (everyone, every round)
                    participants=(
                        tuple(int(i) for i in parts)
                        if parts is not None
                        else None
                    ),
                )
            )
        self.wall_time_s += time.perf_counter() - t1
        self.round += n
        return recs

    def snapshot(self) -> tuple[dict, dict[str, np.ndarray]]:
        m = self._master
        d = m.d
        return {"kind": "full"}, {
            "x": np.asarray(m.x),
            "h_global": np.asarray(m.h_global),
            "x_hist": (
                np.stack(m.x_hist)
                if m.x_hist
                else np.zeros((0, d), dtype=np.float64)
            ),
            "measured_payload_bits": np.asarray(self._measured_pbits, np.int64),
            "measured_frame_bytes": np.asarray(self._frame_bytes, np.int64),
        }

    def finalize(self) -> dict:
        return {
            "x": np.asarray(self._master.x),
            "extras": {
                "measured_payload_bits": np.asarray(self._measured_pbits, np.int64),
                "measured_frame_bytes": np.asarray(self._frame_bytes, np.int64),
            },
        }

    def close(self) -> None:
        self._master.stop()
        if self._closer is not None:
            self._closer()
            self._closer = None


class _StarPPSessionHandle(SessionHandle):
    """FedNL-PP star master held open at round granularity.

    Restore replays the checkpointed per-round iterates as SELECT traffic
    (same PRNG spine, same fault draws — resampled replacements included),
    rebuilding the sampled clients' (H_i, l_i, g_i) without any client
    state on disk, then deserializes the master invariants."""

    def __init__(self, spec, master, tau: int, z_fn, restore=None, closer=None):
        self._spec = spec
        self._master = master
        self._tau = tau
        self._z_fn = z_fn
        self._closer = closer
        self._measured_pbits: list[int] = []
        self._frame_bytes: list[int] = []
        self.round = 0
        self.wall_time_s = 0.0
        t0 = time.perf_counter()
        master._init_handshake()
        if restore is not None:
            # the broadcast history rides in the records (every PP record
            # carries its x) — no separate x_hist array in the checkpoint
            for r, rec in enumerate(restore.records):
                master.replay_round(r, rec.x)
            master.h_global = jnp.asarray(restore.arrays["h_global"])
            master.l_global = jnp.asarray(restore.arrays["l_global"])
            master.g_global = jnp.asarray(restore.arrays["g_global"])
            master.key = jnp.asarray(restore.arrays["key"])
            self._measured_pbits = [
                int(b) for b in restore.arrays["measured_payload_bits"]
            ]
            self._frame_bytes = [
                int(b) for b in restore.arrays["measured_frame_bytes"]
            ]
            self.round = int(restore.round)
        self.init_time_s = time.perf_counter() - t0

    def step_rounds(self, n: int) -> list[RoundRecord]:
        recs = []
        t1 = time.perf_counter()
        for i in range(n):
            r = self.round + i
            m = self._master.step_round(r)
            self._measured_pbits.append(m["measured_payload_bits"])
            self._frame_bytes.append(m["measured_frame_bytes"])
            wire_bits = 8 * m["measured_frame_bytes"]
            recs.append(
                RoundRecord(
                    round=r,
                    l=float(m["l"]),
                    sent_bits=(
                        m["sent_bits"]
                        if self._spec.accounting == "payload"
                        else wire_bits
                    ),
                    sent_bits_payload=m["sent_bits"],
                    sent_bits_wire=wire_bits,
                    x=m["x"],
                    participants=tuple(m["participants"]),
                    dropped=tuple(m["dropped"]),
                )
            )
        self.wall_time_s += time.perf_counter() - t1
        self.round += n
        return recs

    def snapshot(self) -> tuple[dict, dict[str, np.ndarray]]:
        m = self._master
        return {"kind": "pp"}, {
            "h_global": np.asarray(m.h_global),
            "l_global": np.asarray(m.l_global),
            "g_global": np.asarray(m.g_global),
            "key": np.asarray(m.key),
            "measured_payload_bits": np.asarray(self._measured_pbits, np.int64),
            "measured_frame_bytes": np.asarray(self._frame_bytes, np.int64),
        }

    def finalize(self) -> dict:
        x_final = np.asarray(self._master._solve_x())
        z_fn, lam = self._z_fn, self._spec.lam
        return {
            "x": x_final,
            # the master never holds the data (star-tcp); rebuild it lazily
            # only if the caller reads the final_grad_norm diagnostic
            "final_grad_norm_fn": (
                (lambda: _pp_final_grad_norm(z_fn(), x_final, lam))
                if z_fn is not None
                else None
            ),
            "extras": {
                "tau": self._tau,
                "measured_payload_bits": np.asarray(self._measured_pbits, np.int64),
                "measured_frame_bytes": np.asarray(self._frame_bytes, np.int64),
            },
        }

    def close(self) -> None:
        self._master.stop()
        if self._closer is not None:
            self._closer()
            self._closer = None


class StarLoopbackBackend(Backend):
    """Full wire protocol (encode -> frame -> decode) over in-process
    loopback connections — deterministic, socket-free."""

    name = "star-loopback"
    supports_faults = True
    supports_sessions = True
    supports_topology = True

    def supports(self, algo: Algorithm) -> bool:
        # identity, not name: the wire event loops implement the builtin
        # protocols only — a re-registered custom "fednl" must be refused,
        # not silently replaced by the builtin trajectory
        return algo is FEDNL or algo is FEDNL_PP  # no LS wire protocol

    def open(self, spec, algo: Algorithm, z, x0, restore=None) -> SessionHandle:
        n_clients, _, d = z.shape
        cfg = spec.fednl_config()
        if algo.kind == "pp":
            from repro.comm.star_pp import StarPPMaster, make_pp_loopback_clients

            tau = spec.tau_for(n_clients)
            conns, drive = make_pp_loopback_clients(
                z, cfg, seed=spec.seed, fault=spec.fault
            )
            master = StarPPMaster(
                conns, d, cfg, tau,
                seed=spec.seed, on_dropout=spec.on_dropout, drive=drive,
            )
            return _StarPPSessionHandle(
                spec, master, tau, lambda: z, restore=restore
            )

        # all full-participation wiring — plain star, tree-of-stars, async,
        # elastic — goes through the one topology construction seam
        # (migration rule 6: masters are built inside repro.comm)
        from repro.comm.topology import open_loopback_master

        master = open_loopback_master(
            z, cfg,
            topology=spec.topology, membership=spec.membership,
            seed=spec.seed,
        )
        return _StarFullSessionHandle(spec, master, restore=restore)


class StarTCPBackend(Backend):
    """Master + one OS process per client over TCP localhost
    (``repro.launch.multiproc``).  Workers regenerate their shard from
    ``spec.data`` — no training data crosses the wire, so only seeded
    synthetic data specs are supported."""

    name = "star-tcp"
    needs_problem = False  # workers rebuild their shards from the data seed
    supports_faults = True
    supports_sessions = True
    supports_topology = True

    def supports(self, algo: Algorithm) -> bool:
        # identity, not name — same reasoning as StarLoopbackBackend
        return algo is FEDNL or algo is FEDNL_PP

    def open(self, spec, algo: Algorithm, z, x0, restore=None) -> SessionHandle:
        if spec.data.libsvm is not None:
            raise ValueError(
                "star-tcp workers rebuild synthetic data from spec.data.seed; "
                "libsvm problems can only run on local/sharded/star-loopback"
            )
        import dataclasses as _dc

        from repro.launch.multiproc import ClientCluster, TreeClientCluster

        cfg = spec.fednl_config()
        pp = algo.kind == "pp"
        topo = spec.topology
        if topo is not None and topo.kind == "tree":
            # process tree: one aggregator process per root subtree, which
            # spawns (and later tears down, leaves-first) its own children
            cluster = TreeClientCluster(
                spec.data.dataset,
                spec.data.shape,
                spec.seed,
                topo,
                host=spec.host,
                data_seed=spec.data.seed,
                cfg=cfg,
            )
        else:
            cluster = ClientCluster(
                spec.data.dataset,
                spec.data.shape,
                spec.seed,
                host=spec.host,
                pp=pp,
                fault_dict=(
                    _dc.asdict(spec.fault) if spec.fault is not None else None
                ),
                data_seed=spec.data.seed,
                cfg=cfg,
            )
        try:
            if pp:
                from repro.comm.star_pp import StarPPMaster

                tau = spec.tau_for(spec.data.dims()[1])
                master = StarPPMaster(
                    cluster.conns, cluster.d, cfg, tau,
                    seed=spec.seed, on_dropout=spec.on_dropout,
                )
                return _StarPPSessionHandle(
                    spec, master, tau, spec.data.build,
                    restore=restore, closer=cluster.close,
                )
            from repro.comm.topology import make_master

            master = make_master(
                cluster.conns, cluster.d, cfg,
                topology=topo, membership=spec.membership,
                n_clients=cluster.n_clients,
            )
            return _StarFullSessionHandle(
                spec, master, restore=restore, closer=cluster.close
            )
        except Exception:
            cluster.close()
            raise


# bound instances: the sweep engine identity-checks against LOCAL_BACKEND
# (an overwritten "local" registration must not be silently batched around)
LOCAL_BACKEND = register_backend(LocalBackend())
SHARDED_BACKEND = register_backend(ShardedBackend())
STAR_LOOPBACK_BACKEND = register_backend(StarLoopbackBackend())
STAR_TCP_BACKEND = register_backend(StarTCPBackend())
