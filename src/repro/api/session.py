"""Incremental execution sessions: open_session(spec) -> step/observe/save/resume.

``solve(spec)`` is a run-to-completion black box; this module is the
round-granular form underneath it (DESIGN.md §10).  ``open_session`` builds
the same validated problem + backend machinery ``solve`` would, but hands
back a :class:`Session` that advances **one round at a time**:

    s = open_session(spec)
    s.on_round(lambda rec: print(rec.round, rec.grad_norm))
    s.step(5)                       # 5 rounds, records streamed to observers
    s.save("run.fnlsess")           # serialize mid-run
    report = s.run()                # finish under the spec's rounds/tol
    s.close()

    s2 = open_session(spec, restore="run.fnlsess")   # later / elsewhere
    report2 = s2.run()              # bit-identical to the uninterrupted run

Numerics contract (the acceptance bar, pinned by tests/test_session.py and
scripts/smoke_api.py): ``step(k)`` then ``step(m)`` is bit-identical to
``step(k + m)`` and to sequential ``solve()`` on every session-capable
backend, and save -> restore mid-run is bit-identical to an uninterrupted
run.  Backends honor it by executing chunked segments between yields without
letting the chunking shape the trajectory (``registry.SessionHandle``).

Checkpoint wire format ``FNLS1`` (documented in DESIGN.md §10): a flat
deterministic binary — magic ``FNLSESS1``, u64 header length, a sorted-key
JSON header (spec, round index, backend meta, per-round records with float
fields as ``float.hex`` strings, array manifest), then the raw little-endian
array blobs in manifest order.  Deliberately not npz: zip containers embed
timestamps, and the byte-stability property (save -> load -> save is the
identity on bytes) is part of the contract.  Only master-side state is
serialized; wire-backend clients rebuild their state from the spec plus a
replayed PRNG spine (DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Callable

import numpy as np

from repro.api.report import RoundRecord, RunReport, RunReportBuilder
from repro.obs import core as _obs
from repro.api.spec import CompressorSpec, DataSpec, ExperimentSpec

_MAGIC = b"FNLSESS1"
_VERSION = 1

# record fields that hold floats / ints / tuples, for the hex-exact encoding
_REC_FLOAT = ("grad_norm", "f", "l")
_REC_INT = ("round", "sent_elems", "sent_bits", "sent_bits_payload",
            "sent_bits_wire", "ls_steps")
_REC_TUPLE = ("participants", "dropped")


# ---------------------------------------------------------------------------
# stop policies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StopPolicy:
    """When :meth:`Session.run` stops, beyond exhausting the round budget.

    ``max_rounds`` caps the TOTAL round count (None -> ``spec.rounds``);
    ``tol`` stops once a round's grad norm drops below it (full-participation
    algorithms only — the PP server never sees the gradient); ``predicate``
    is an arbitrary ``RoundRecord -> bool`` custom criterion, stopping on the
    first True.  The stopping round is always included in the records,
    matching ``solve()``'s early-stop semantics.
    """

    max_rounds: int | None = None
    tol: float | None = None
    predicate: Callable[[RoundRecord], bool] | None = None

    @property
    def streaming(self) -> bool:
        """True when stopping needs a per-round look at the records."""
        return self.tol is not None or self.predicate is not None

    def hit(self, rec: RoundRecord) -> bool:
        """True when ``rec`` satisfies a streaming stop criterion (tol or
        predicate; the round-budget cap is checked against the round count,
        not a record).  The single stop test shared by :meth:`Session.run`
        and the serving engine (``repro.serve_fednl``), so a session served
        behind the engine stops on exactly the record a solo ``run()``
        would."""
        if (
            self.tol is not None
            and rec.grad_norm is not None
            and rec.grad_norm < self.tol
        ):
            return True
        return self.predicate is not None and bool(self.predicate(rec))


def resolve_policy(until, spec: ExperimentSpec) -> StopPolicy:
    """Normalize a ``run(until=...)`` argument into a :class:`StopPolicy`
    under ``spec``'s defaults (public so external drivers — the serving
    engine — resolve stop conditions exactly like :meth:`Session.run`)."""
    if until is None:
        return StopPolicy(
            max_rounds=spec.rounds,
            tol=spec.tol if spec.tol > 0.0 else None,
        )
    if isinstance(until, StopPolicy):
        if until.max_rounds is None:
            return dataclasses.replace(until, max_rounds=spec.rounds)
        return until
    if isinstance(until, bool):
        raise TypeError("until must be None | int | float | StopPolicy")
    if isinstance(until, int):
        return StopPolicy(max_rounds=until)
    if isinstance(until, float):
        return StopPolicy(max_rounds=spec.rounds, tol=until)
    raise TypeError(
        f"until must be None | int (max total rounds) | float (grad tol) | "
        f"StopPolicy, got {type(until).__name__}"
    )


# ---------------------------------------------------------------------------
# SessionState + the FNLS1 checkpoint format
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SessionState:
    """Everything needed to resume a run bit-identically: the spec, the
    round index, the backend's master-side state (model x, Hessian
    estimate/shift, PRNG spine — as ``meta`` scalars + ``arrays``), and the
    accumulated per-round records/bit counters."""

    spec: ExperimentSpec
    algorithm: str
    backend: str
    round: int
    meta: dict[str, Any]
    arrays: dict[str, np.ndarray]
    records: tuple[RoundRecord, ...]
    version: int = _VERSION


def spec_to_dict(spec: ExperimentSpec) -> dict:
    """JSON-able projection of a spec (tuples become lists)."""
    return dataclasses.asdict(spec)


def spec_from_dict(d: dict) -> ExperimentSpec:
    """Rebuild an ExperimentSpec from :func:`spec_to_dict` output."""
    from repro.comm.transport import FaultSpec

    d = dict(d)
    data = dict(d.pop("data"))
    if data.get("shape") is not None:
        data["shape"] = tuple(data["shape"])
    comp = dict(d.pop("compressor"))
    fault = d.pop("fault")
    topo = d.pop("topology", None)
    mem = d.pop("membership", None)
    topology = membership = None
    if topo is not None or mem is not None:
        from repro.comm.topology import (
            MembershipEvent,
            MembershipSpec,
            TopologySpec,
        )

        if topo is not None:
            topo = dict(topo)
            if topo.get("edges") is not None:
                topo["edges"] = tuple(tuple(g) for g in topo["edges"])
            topology = TopologySpec(**topo)
        if mem is not None:
            membership = MembershipSpec(
                events=tuple(
                    MembershipEvent(**dict(e)) for e in dict(mem)["events"]
                )
            )
    return ExperimentSpec(
        data=DataSpec(**data),
        compressor=CompressorSpec(**comp),
        fault=FaultSpec(**fault) if fault is not None else None,
        topology=topology,
        membership=membership,
        **d,
    )


def _hexf(v) -> str | None:
    return None if v is None else float(v).hex()

def _unhexf(v) -> float | None:
    return None if v is None else float.fromhex(v)


def _record_to_jsonable(rec: RoundRecord) -> dict:
    out: dict[str, Any] = {}
    for f in _REC_FLOAT:
        out[f] = _hexf(getattr(rec, f))
    for f in _REC_INT:
        v = getattr(rec, f)
        out[f] = None if v is None else int(v)
    for f in _REC_TUPLE:
        v = getattr(rec, f)
        out[f] = None if v is None else [int(i) for i in v]
    out["has_x"] = rec.x is not None
    return out


def _record_from_jsonable(d: dict, x: np.ndarray | None) -> RoundRecord:
    kw: dict[str, Any] = {"x": x}
    for f in _REC_FLOAT:
        kw[f] = _unhexf(d[f])
    for f in _REC_INT:
        kw[f] = d[f] if d[f] is None else int(d[f])
    for f in _REC_TUPLE:
        kw[f] = None if d[f] is None else tuple(d[f])
    return RoundRecord(**kw)


def save_state(state: SessionState, path) -> pathlib.Path:
    """Write the FNLS1 checkpoint.  Deterministic: identical SessionStates
    produce identical bytes (sorted JSON keys, hex-exact floats, raw
    little-endian array blobs — no container timestamps)."""
    arrays = dict(state.arrays)
    # per-round PP iterates ride as one stacked array, not JSON floats
    xs = [r.x for r in state.records if r.x is not None]
    if xs:
        if len(xs) != len(state.records):
            raise ValueError("records mix x-carrying and x-less rounds")
        arrays["__records_x__"] = np.stack([np.asarray(x) for x in xs])
    manifest = {}
    blobs = []
    for name in sorted(arrays):
        # NB reshape after ascontiguousarray: it promotes 0-d arrays to 1-d
        arr = np.asarray(arrays[name])
        arr = np.ascontiguousarray(arr).reshape(arr.shape)
        if arr.dtype.byteorder == ">":  # pragma: no cover - no BE hosts in CI
            arr = arr.astype(arr.dtype.newbyteorder("<"))
        manifest[name] = {"dtype": arr.dtype.str, "shape": list(arr.shape)}
        blobs.append(arr.tobytes())
    header = {
        "version": state.version,
        "format": "FNLS1",
        "algorithm": state.algorithm,
        "backend": state.backend,
        "round": int(state.round),
        "spec": spec_to_dict(state.spec),
        "meta": state.meta,
        "records": [_record_to_jsonable(r) for r in state.records],
        "arrays": manifest,
    }
    hdr = json.dumps(header, sort_keys=True, separators=(",", ":")).encode()
    path = pathlib.Path(path)
    with open(path, "wb") as f:
        f.write(_MAGIC)
        f.write(len(hdr).to_bytes(8, "little"))
        f.write(hdr)
        for blob in blobs:
            f.write(blob)
    return path


def load_state(path) -> SessionState:
    """Read an FNLS1 checkpoint back into a :class:`SessionState`."""
    raw = pathlib.Path(path).read_bytes()
    if raw[: len(_MAGIC)] != _MAGIC:
        raise ValueError(
            f"{path}: not a FedNL session checkpoint (bad magic "
            f"{raw[:len(_MAGIC)]!r}; expected {_MAGIC!r})"
        )
    n = int.from_bytes(raw[8:16], "little")
    header = json.loads(raw[16 : 16 + n].decode())
    if header.get("version") != _VERSION:
        raise ValueError(
            f"{path}: checkpoint version {header.get('version')} not "
            f"supported (this build reads version {_VERSION})"
        )
    off = 16 + n
    arrays: dict[str, np.ndarray] = {}
    for name in sorted(header["arrays"]):
        info = header["arrays"][name]
        dt = np.dtype(info["dtype"])
        count = int(np.prod(info["shape"], dtype=np.int64)) if info["shape"] else 1
        nbytes = dt.itemsize * count
        arrays[name] = np.frombuffer(
            raw[off : off + nbytes], dtype=dt
        ).reshape(info["shape"]).copy()
        off += nbytes
    rec_x = arrays.pop("__records_x__", None)
    records = tuple(
        _record_from_jsonable(d, rec_x[i] if d["has_x"] else None)
        for i, d in enumerate(header["records"])
    )
    return SessionState(
        spec=spec_from_dict(header["spec"]),
        algorithm=header["algorithm"],
        backend=header["backend"],
        round=int(header["round"]),
        meta=header["meta"],
        arrays=arrays,
        records=records,
        version=int(header["version"]),
    )


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------

class Session:
    """One live run at round granularity.  Created by :func:`open_session`;
    drives a backend :class:`repro.api.registry.SessionHandle`."""

    def __init__(self, spec, algo, backend, handle, records=()):
        self.spec = spec
        self._algo = algo
        self._backend = backend
        self._handle = handle
        self._builder = RunReportBuilder(spec, algo.name, backend.name)
        self._builder.extend(list(records))
        self._observers: list[Callable[[RoundRecord], None]] = []
        self._closed = False

    # --- introspection ----------------------------------------------------

    @property
    def round(self) -> int:
        """Rounds executed so far (checkpoint rounds included after restore)."""
        return self._handle.round

    @property
    def records(self) -> tuple[RoundRecord, ...]:
        return tuple(self._builder.records)

    @property
    def state(self) -> SessionState:
        """Frozen serializable snapshot of the run (see :func:`save_state`)."""
        meta, arrays = self._handle.snapshot()
        return SessionState(
            spec=self.spec,
            algorithm=self._algo.name,
            backend=self._backend.name,
            round=self.round,
            meta=meta,
            arrays=arrays,
            records=self.records,
        )

    # --- observers --------------------------------------------------------

    def on_round(self, fn: Callable[[RoundRecord], None]):
        """Register an observer streamed every produced RoundRecord (in round
        order).  Returns ``fn`` so it can double as a decorator."""
        self._observers.append(fn)
        return fn

    # --- execution --------------------------------------------------------

    def step(self, n: int = 1) -> list[RoundRecord]:
        """Advance exactly ``n`` rounds (not capped by ``spec.rounds`` — the
        cap is :meth:`run`'s job) and return their records.  Composable:
        ``step(k); step(m)`` is bit-identical to ``step(k + m)``."""
        if self._closed:
            raise RuntimeError("session is closed")
        if n < 0:
            raise ValueError(f"step count must be >= 0, got {n}")
        if n == 0:
            return []
        rec = _obs.CURRENT
        t0 = _obs.now()
        recs = self._handle.step_rounds(n)
        if rec.enabled:
            # one step_rounds call == one device->host sync of its records
            rec.observe(
                "session.step.s", _obs.now() - t0, backend=self.spec.backend
            )
            rec.add("session.rounds", len(recs), backend=self.spec.backend)
            rec.add("session.host_syncs", backend=self.spec.backend)
        self._builder.extend(recs)
        for rec in recs:
            for fn in self._observers:
                fn(rec)
        return recs

    def run(self, until=None) -> RunReport:
        """Advance under a stop policy and report.

        ``until``: None (the spec's rounds/tol — what ``solve()`` does), an
        int (max TOTAL rounds), a float (grad-norm tol), or a
        :class:`StopPolicy`.  Callable repeatedly: each call continues from
        the current round and returns the cumulative report.
        """
        policy = resolve_policy(until, self.spec)
        if policy.tol is not None and self._algo.kind == "pp":
            raise ValueError(
                "tol-based stopping is undefined for partial participation "
                "(the server never sees the global gradient); use max_rounds "
                "or a predicate on the records instead"
            )
        target = policy.max_rounds
        if not policy.streaming and not self._observers:
            # no per-round consumer: one chunked segment, deferred host sync
            self.step(max(0, target - self.round))
            return self.report()
        while self.round < target:
            recs = self.step(1)
            if not recs:
                break
            if policy.hit(recs[0]):
                break
        return self.report()

    def report(self, spec=None) -> RunReport:
        """The cumulative :class:`RunReport` for the rounds executed so far
        (non-destructive: the session can keep stepping afterwards)."""
        tail = self._handle.finalize()
        return self._builder.build(
            x=tail["x"],
            wall_time_s=self._handle.wall_time_s,
            init_time_s=self._handle.init_time_s,
            final_grad_norm_fn=tail.get("final_grad_norm_fn"),
            extras=tail.get("extras"),
            spec=spec,
        )

    # --- persistence / lifecycle ------------------------------------------

    def save(self, path) -> pathlib.Path:
        """Serialize the current state to ``path`` (FNLS1 checkpoint);
        ``open_session(spec, restore=path)`` resumes it bit-identically."""
        return save_state(self.state, path)

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._handle.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# open_session
# ---------------------------------------------------------------------------

def open_session(
    spec: ExperimentSpec,
    z=None,
    x0=None,
    restore: str | pathlib.Path | SessionState | None = None,
) -> Session:
    """Open an incremental run of ``spec`` — the Session form of ``solve``.

    ``z`` / ``x0`` mirror :func:`repro.api.solve`.  ``restore`` resumes from
    a checkpoint (a path written by :meth:`Session.save`, or a
    :class:`SessionState`); the spec must describe the same experiment as the
    checkpoint (only run control — rounds / tol / host — may differ;
    :meth:`ExperimentSpec.check_restore_from` rejects anything else loudly).
    """
    import jax

    from repro.api.facade import check_spec
    from repro.api.registry import get_algorithm, get_backend

    jax.config.update("jax_enable_x64", True)
    state = None
    if restore is not None:
        state = restore if isinstance(restore, SessionState) else load_state(restore)
        spec.check_restore_from(state.spec)
        if x0 is not None:
            raise ValueError(
                "x0 cannot be combined with restore: the checkpoint already "
                "fixes the trajectory (x0 only applies to fresh runs)"
            )
    algo = get_algorithm(spec.algorithm)
    backend = get_backend(spec.backend)
    check_spec(spec, algo, backend, z=z, x0=x0)
    if not backend.supports_sessions:
        raise ValueError(
            f"backend {spec.backend!r} does not support sessions (no "
            "Backend.open); run it to completion with solve(spec) instead"
        )
    if z is None and backend.needs_problem:
        z = spec.data.build()
    handle = backend.open(spec, algo, z, x0, restore=state)
    return Session(
        spec, algo, backend, handle,
        records=state.records if state is not None else (),
    )
