# The declarative experiment API (DESIGN.md §8): one frozen ExperimentSpec
# describing algorithm x data x compressor x accounting x backend, one
# solve(spec) facade returning the unified RunReport, and registries that
# make algorithms/backends/compressors pluggable strategy objects.
#
# Import note: this package is imported *by* repro.core (the accounting
# shims), so nothing here may import repro.core at module level — built-in
# algorithm/backend registration happens lazily on first registry lookup.
from repro.api.accounting import (
    ACCOUNTINGS,
    make_bits_fn,
    payload_bits_fn,
    wire_bits_fn,
)
from repro.api.facade import solve, solve_many
from repro.api.registry import (
    Algorithm,
    Backend,
    SessionHandle,
    get_algorithm,
    get_backend,
    list_algorithms,
    list_backends,
    register_algorithm,
    register_backend,
    register_compressor,
)
from repro.api.report import RoundRecord, RunReport, RunReportBuilder, SweepReport
from repro.api.session import (
    Session,
    SessionState,
    StopPolicy,
    load_state,
    open_session,
    save_state,
)
from repro.api.spec import CompressorSpec, DataSpec, ExperimentSpec
from repro.api.specwire import SPEC_WIRE_VERSION, decode_spec, encode_spec
from repro.api.sweep import SweepSpec
from repro.comm.transport import FaultSpec

# TopologySpec / MembershipSpec / MembershipEvent are lazy module attributes:
# repro.comm.topology pulls the jax-heavy star stack, and `import repro.api`
# must stay cheap for spec-only consumers
_TOPOLOGY_EXPORTS = ("TopologySpec", "MembershipSpec", "MembershipEvent")


def __getattr__(name: str):
    if name in _TOPOLOGY_EXPORTS:
        from repro.comm import topology

        return getattr(topology, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "MembershipEvent",
    "MembershipSpec",
    "TopologySpec",
    "ACCOUNTINGS",
    "Algorithm",
    "Backend",
    "CompressorSpec",
    "DataSpec",
    "ExperimentSpec",
    "FaultSpec",
    "RoundRecord",
    "RunReport",
    "RunReportBuilder",
    "Session",
    "SessionHandle",
    "SessionState",
    "StopPolicy",
    "SPEC_WIRE_VERSION",
    "SweepReport",
    "SweepSpec",
    "decode_spec",
    "encode_spec",
    "load_state",
    "open_session",
    "save_state",
    "get_algorithm",
    "get_backend",
    "list_algorithms",
    "list_backends",
    "make_bits_fn",
    "payload_bits_fn",
    "wire_bits_fn",
    "register_algorithm",
    "register_backend",
    "register_compressor",
    "solve",
    "solve_many",
]
