"""Unified uplink bit accounting for the FedNL family (DESIGN.md §8).

Single source of truth for the two accounting models every runner reports:

  payload  Section-7 Hessian payload bits (``message_bits``), equal to the
           measured wire payload bytes of ``repro.comm.wire``; the FedNL-PP
           uplink additionally carries the (d + 1) FP64 ``dl || dg`` section
           (``pp_message_bits``).
  wire     full framed uplink bytes including the protocol header
           (``frame_bits`` / ``pp_frame_bits``).

Both are *exact* closed-form models of the byte streams the star transports
actually emit (asserted against measured bytes in tests/test_comm.py and
tests/test_comm_pp.py) and jit-compatible in ``sent_elems``.

This module collapses the previously duplicated ``core.fednl.make_bits_fn``
and ``core.fednl_pp.make_pp_bits_fn``; those names remain as thin deprecated
re-exports for back-compat.
"""

from __future__ import annotations

from typing import Callable

from repro.compressors.core import FP_BITS, IDX_BITS, Compressor, message_bits

ACCOUNTINGS = ("payload", "wire")

SHARDED_AGGREGATES = ("dense_psum", "sparse_allgather")


def sharded_uplink_bits(aggregate: str, t: int, k: int, n_clients: int) -> int:
    """Per-round uplink bits of the sharded-collective round (DESIGN.md §7).

    ``dense_psum`` all-reduces the full packed upper triangle (T FP64 words
    per client); ``sparse_allgather`` gathers only the k compressed
    ``(int32 idx, FP64 val)`` pairs per client.  One closed-form model shared
    by the benchmark tables and the sharded round's own reporting — no
    magic byte constants at call sites.
    """
    if aggregate == "dense_psum":
        per_client = t * FP_BITS
    elif aggregate == "sparse_allgather":
        per_client = k * (FP_BITS + IDX_BITS)
    else:
        raise ValueError(
            f"unknown aggregate {aggregate!r}; use "
            f"{' | '.join(SHARDED_AGGREGATES)}"
        )
    return per_client * n_clients


def payload_bits_fn(comp: Compressor, d: int, pp: bool = False) -> Callable:
    """Section-7 payload bits per uplink message (PP adds the dl/dg section)."""
    if pp:
        return lambda s_e: message_bits(comp, s_e) + (d + 1) * FP_BITS
    return lambda s_e: message_bits(comp, s_e)


def wire_bits_fn(comp: Compressor, d: int, pp: bool = False) -> Callable:
    """Full framed uplink bits per message (protocol header + padding)."""
    from repro.comm.wire import frame_bits, pp_frame_bits

    if pp:
        return lambda s_e: pp_frame_bits(comp, s_e, d)
    return lambda s_e: frame_bits(comp, s_e, d)


def make_bits_fn(
    comp: Compressor, d: int, accounting: str, pp: bool = False
) -> Callable:
    """Per-message wire-bit model selected by ``ExperimentSpec.accounting``
    (equivalently ``FedNLConfig.accounting``); ``pp`` selects the FedNL-PP
    triple pricing."""
    if accounting == "payload":
        return payload_bits_fn(comp, d, pp)
    if accounting == "wire":
        return wire_bits_fn(comp, d, pp)
    raise ValueError(
        f"unknown accounting {accounting!r}; use {' | '.join(ACCOUNTINGS)}"
    )
